"""Layer-2 JAX models: the full-design gradient graphs per GLM family.

Each builder returns a jax-jittable function whose only inputs are the
concrete arrays the Rust coordinator supplies at run time:

* ``gaussian / binomial / poisson``: ``(X (n,p), β (p,), y (n,)) → g (p,)``
* ``multinomial``:  ``(X (n,p), B (p,m), Y (n,m) one-hot) → G (p,m)``
* ``screen``:       ``(c_sorted (p,), λ (p,)) → cumsum(c − λ) (p,)``

The gradients call the Layer-1 Pallas kernels (`kernels.slope_grad`), so
the AOT lowering in `aot.py` bakes the tiling schedule into the same HLO
artifact the Rust PJRT runtime executes. Everything is float64: the KKT
thresholds the screening safeguard uses at the small-σ end of the path are
far below float32 resolution (DESIGN.md §8).
"""

import jax
import jax.numpy as jnp

from .kernels import slope_grad as k

FAMILIES = ("gaussian", "binomial", "poisson", "multinomial")


def gradient_fn(family: str):
    """Return the gradient function for `family` (see module docstring)."""
    if family == "gaussian":
        return lambda x, beta, y: (k.gradient_gaussian(x, beta, y),)
    if family == "binomial":
        return lambda x, beta, y: (k.gradient_binomial(x, beta, y),)
    if family == "poisson":
        return lambda x, beta, y: (k.gradient_poisson(x, beta, y),)
    if family == "multinomial":
        return lambda x, beta, y: (k.gradient_multinomial(x, beta, y),)
    raise ValueError(f"unknown family {family!r}")


def screen_fn():
    """The screening-criterion scan (Algorithm 1's running sum)."""
    return lambda c, lam: (k.screen_cumsum(c, lam),)


def abstract_args(family: str, n: int, p: int, m: int = 1):
    """ShapeDtypeStructs for lowering the gradient of `family`."""
    f64 = jnp.float64
    x = jax.ShapeDtypeStruct((n, p), f64)
    if family == "multinomial":
        return (
            x,
            jax.ShapeDtypeStruct((p, m), f64),
            jax.ShapeDtypeStruct((n, m), f64),
        )
    return (x, jax.ShapeDtypeStruct((p,), f64), jax.ShapeDtypeStruct((n,), f64))


def abstract_screen_args(p: int):
    """ShapeDtypeStructs for lowering the screening scan."""
    f64 = jnp.float64
    return (jax.ShapeDtypeStruct((p,), f64), jax.ShapeDtypeStruct((p,), f64))

"""Pure-jnp oracles for every Pallas kernel and model gradient.

These are the correctness ground truth: no Pallas, no tiling — just the
textbook expressions. ``python/tests`` asserts the kernels match these to
tight tolerances over hypothesis-generated shapes and data.
"""

import jax
import jax.numpy as jnp


def matvec(x, beta):
    """η = X β."""
    return x @ beta


def tmatvec(x, h):
    """g = Xᵀ h."""
    return x.T @ h


def matmat(x, b):
    """E = X B."""
    return x @ b


def tmatmat(x, h):
    """G = Xᵀ H."""
    return x.T @ h


def screen_cumsum(c_sorted, lam):
    """cumsum(c − λ) — Algorithm 1's running criterion."""
    return jnp.cumsum(c_sorted - lam)


def gradient_gaussian(x, beta, y):
    """∇½‖Xβ − y‖² = Xᵀ(Xβ − y)."""
    return x.T @ (x @ beta - y)


def gradient_binomial(x, beta, y):
    """∇ Σ[log(1+e^η) − yη] = Xᵀ(σ(η) − y)."""
    return x.T @ (jax.nn.sigmoid(x @ beta) - y)


def gradient_poisson(x, beta, y):
    """∇ Σ[e^η − yη] = Xᵀ(e^η − y)."""
    return x.T @ (jnp.exp(x @ beta) - y)


def gradient_multinomial(x, beta, y_onehot):
    """∇ Σ[lse(η_i) − η_{i,y_i}] = Xᵀ(softmax(η) − Y)."""
    return x.T @ (jax.nn.softmax(x @ beta, axis=1) - y_onehot)


def prox_sorted_l1(v, lam):
    """Reference prox of the sorted-ℓ1 norm (stack PAVA, numpy-style);
    mirrors the Rust implementation for cross-language agreement tests."""
    import numpy as np

    v = np.asarray(v, dtype=np.float64)
    lam = np.asarray(lam, dtype=np.float64)
    p = v.shape[0]
    order = np.argsort(-np.abs(v), kind="stable")
    z = np.abs(v)[order] - lam[:p]
    # stack of (start, end, sum)
    blocks = []
    for i in range(p):
        blk = [i, i, z[i]]
        while blocks and blocks[-1][2] / (blocks[-1][1] - blocks[-1][0] + 1) <= blk[2] / (
            blk[1] - blk[0] + 1
        ):
            prev = blocks.pop()
            blk = [prev[0], blk[1], prev[2] + blk[2]]
        blocks.append(blk)
    out = np.zeros(p)
    for start, end, total in blocks:
        mean = max(total / (end - start + 1), 0.0)
        for k in range(start, end + 1):
            idx = order[k]
            out[idx] = mean * np.sign(v[idx])
    return out

"""Layer-1 Pallas kernels: the O(np) full-design gradient hot spot.

The strong screening rule pays one full-width gradient ``∇f(β) = Xᵀ h(Xβ, y)``
per path step (paper §2.2.1). On TPU the design matrix never fits in VMEM,
so both matrix products are expressed as Pallas kernels tiled over the
predictor dimension:

* :func:`matvec`   — ``η = X β``  : grid over p-blocks, accumulating into
  the full ``η`` output block (sequential grid ⇒ safe accumulation).
* :func:`tmatvec`  — ``g = Xᵀ h`` : grid over p-blocks, each block an
  independent ``(n × bp)ᵀ ⋅ n`` product (embarrassingly parallel over the
  grid).
* :func:`matmat` / :func:`tmatmat` — the multinomial (n×m) variants.
* :func:`screen_cumsum_blocks` — per-block cumulative sums + block totals
  for the screening criterion ``cumsum(|c|↓ − λ)`` (two-phase scan: the
  tiny cross-block offset fix-up happens in plain jnp).

The BlockSpec plays the role the paper's column-partitioned BLAS calls play
in the R implementation: it expresses the HBM↔VMEM streaming schedule.
``interpret=True`` everywhere — the CPU PJRT plugin cannot execute Mosaic
custom-calls (see DESIGN.md §7); block shapes are still chosen MXU-shaped
(multiples of 128 where possible) so the same kernels lower for real TPUs.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# All kernels run in interpret mode on CPU (see module docstring).
INTERPRET = True

# Default VMEM tile over the predictor dimension. 512 columns × 8 B × n≤8k
# rows keeps X-blocks ≤ 32 MiB in f64 worst-case; the aot driver shrinks it
# for very tall designs.
DEFAULT_BLOCK_P = 512


def _pick_block(p: int, n: int, block_p: int | None) -> int:
    """Choose a p-tile that divides p and respects a ~16 MiB VMEM budget."""
    if block_p is None:
        budget = 16 * 1024 * 1024 // (8 * max(n, 1))  # f64 bytes per column
        block_p = max(64, min(DEFAULT_BLOCK_P, budget))
    block_p = min(block_p, p)
    while p % block_p != 0:  # shapes are pre-padded to multiples of 64
        block_p -= 1
    return max(block_p, 1)


def matvec(x, beta, *, block_p: int | None = None):
    """``η = X β`` tiled over predictor blocks with accumulation."""
    n, p = x.shape
    bp = _pick_block(p, n, block_p)

    def kernel(x_ref, b_ref, o_ref):
        @pl.when(pl.program_id(0) == 0)
        def _init():
            o_ref[...] = jnp.zeros_like(o_ref)

        o_ref[...] += x_ref[...] @ b_ref[...]

    return pl.pallas_call(
        kernel,
        grid=(p // bp,),
        in_specs=[
            pl.BlockSpec((n, bp), lambda j: (0, j)),
            pl.BlockSpec((bp,), lambda j: (j,)),
        ],
        out_specs=pl.BlockSpec((n,), lambda j: (0,)),
        out_shape=jax.ShapeDtypeStruct((n,), x.dtype),
        interpret=INTERPRET,
    )(x, beta)


def tmatvec(x, h, *, block_p: int | None = None):
    """``g = Xᵀ h`` tiled over predictor blocks."""
    n, p = x.shape
    bp = _pick_block(p, n, block_p)

    def kernel(x_ref, h_ref, o_ref):
        o_ref[...] = x_ref[...].T @ h_ref[...]

    return pl.pallas_call(
        kernel,
        grid=(p // bp,),
        in_specs=[
            pl.BlockSpec((n, bp), lambda j: (0, j)),
            pl.BlockSpec((n,), lambda j: (0,)),
        ],
        out_specs=pl.BlockSpec((bp,), lambda j: (j,)),
        out_shape=jax.ShapeDtypeStruct((p,), x.dtype),
        interpret=INTERPRET,
    )(x, h)


def matmat(x, b, *, block_p: int | None = None):
    """``E = X B`` for multinomial coefficients ``B (p × m)``."""
    n, p = x.shape
    m = b.shape[1]
    bp = _pick_block(p, n, block_p)

    def kernel(x_ref, b_ref, o_ref):
        @pl.when(pl.program_id(0) == 0)
        def _init():
            o_ref[...] = jnp.zeros_like(o_ref)

        o_ref[...] += x_ref[...] @ b_ref[...]

    return pl.pallas_call(
        kernel,
        grid=(p // bp,),
        in_specs=[
            pl.BlockSpec((n, bp), lambda j: (0, j)),
            pl.BlockSpec((bp, m), lambda j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((n, m), lambda j: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, m), x.dtype),
        interpret=INTERPRET,
    )(x, b)


def tmatmat(x, h, *, block_p: int | None = None):
    """``G = Xᵀ H`` for the multinomial working residual ``H (n × m)``."""
    n, p = x.shape
    m = h.shape[1]
    bp = _pick_block(p, n, block_p)

    def kernel(x_ref, h_ref, o_ref):
        o_ref[...] = x_ref[...].T @ h_ref[...]

    return pl.pallas_call(
        kernel,
        grid=(p // bp,),
        in_specs=[
            pl.BlockSpec((n, bp), lambda j: (0, j)),
            pl.BlockSpec((n, m), lambda j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bp, m), lambda j: (j, 0)),
        out_shape=jax.ShapeDtypeStruct((p, m), x.dtype),
        interpret=INTERPRET,
    )(x, h)


def screen_cumsum_blocks(c_sorted, lam, *, block: int = 1024):
    """Phase 1 of the screening criterion ``cumsum(c − λ)``: per-block
    inclusive cumsums and block totals. Phase 2 (cross-block offsets) is a
    ~p/block-sized jnp cumsum — see :func:`screen_cumsum`."""
    (p,) = c_sorted.shape
    bs = min(block, p)
    while p % bs != 0:
        bs -= 1

    def kernel(c_ref, l_ref, cs_ref, tot_ref):
        z = c_ref[...] - l_ref[...]
        cs = jnp.cumsum(z)
        cs_ref[...] = cs
        tot_ref[...] = cs[-1:]

    return pl.pallas_call(
        kernel,
        grid=(p // bs,),
        in_specs=[
            pl.BlockSpec((bs,), lambda j: (j,)),
            pl.BlockSpec((bs,), lambda j: (j,)),
        ],
        out_specs=[
            pl.BlockSpec((bs,), lambda j: (j,)),
            pl.BlockSpec((1,), lambda j: (j,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((p,), c_sorted.dtype),
            jax.ShapeDtypeStruct((p // bs,), c_sorted.dtype),
        ],
        interpret=INTERPRET,
    )(c_sorted, lam)


def screen_cumsum(c_sorted, lam, *, block: int = 1024):
    """Full screening criterion ``cumsum(c_sorted − λ)`` (Algorithm 1's
    running sum) as a two-phase Pallas scan."""
    block_cs, totals = screen_cumsum_blocks(c_sorted, lam, block=block)
    offsets = jnp.concatenate([jnp.zeros((1,), totals.dtype), jnp.cumsum(totals)[:-1]])
    (p,) = c_sorted.shape
    bs = block_cs.shape[0] // offsets.shape[0]
    return block_cs + jnp.repeat(offsets, bs)


@functools.partial(jax.jit, static_argnames=("block_p",))
def gradient_gaussian(x, beta, y, block_p=None):
    """``∇f = Xᵀ(Xβ − y)`` for OLS (paper's primary benchmark family)."""
    eta = matvec(x, beta, block_p=block_p)
    return tmatvec(x, eta - y, block_p=block_p)


@functools.partial(jax.jit, static_argnames=("block_p",))
def gradient_binomial(x, beta, y, block_p=None):
    """``∇f = Xᵀ(σ(Xβ) − y)`` for logistic regression."""
    eta = matvec(x, beta, block_p=block_p)
    return tmatvec(x, jax.nn.sigmoid(eta) - y, block_p=block_p)


@functools.partial(jax.jit, static_argnames=("block_p",))
def gradient_poisson(x, beta, y, block_p=None):
    """``∇f = Xᵀ(exp(Xβ) − y)`` for Poisson regression."""
    eta = matvec(x, beta, block_p=block_p)
    return tmatvec(x, jnp.exp(eta) - y, block_p=block_p)


@functools.partial(jax.jit, static_argnames=("block_p",))
def gradient_multinomial(x, beta, y_onehot, block_p=None):
    """``∇f = Xᵀ(softmax(XB) − Y)`` for multinomial regression; `beta`
    is (p, m), `y_onehot` is (n, m); returns (p, m)."""
    eta = matmat(x, beta, block_p=block_p)
    probs = jax.nn.softmax(eta, axis=1)
    return tmatmat(x, probs - y_onehot, block_p=block_p)

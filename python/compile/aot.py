"""AOT driver: lower the Layer-2 gradient graphs to HLO *text* artifacts.

HLO text — not ``.serialize()`` — is the interchange format: jax ≥ 0.5
emits HloModuleProto with 64-bit instruction ids that the runtime's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Artifacts are emitted per (family, n, p[, m]) shape bucket; every shape is
pre-padded by the Rust runtime to multiples of 64 so the Pallas tiles
divide evenly (zero rows/columns contribute exactly zero to Xᵀh(Xβ, y) for
all four families — DESIGN.md §8). ``manifest.json`` indexes the artifacts
for the runtime.

Usage: ``python -m compile.aot --out ../artifacts [--full]``
"""

import argparse
import json
import os

# float64 end-to-end (see model.py).
import jax

jax.config.update("jax_enable_x64", True)

from jax._src.lib import xla_client as xc  # noqa: E402

from . import model  # noqa: E402


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def round64(x: int) -> int:
    """Round up to the next multiple of 64 (minimum 64)."""
    return max(64, (x + 63) // 64 * 64)


# The curated artifact set: shapes the integration tests, the examples and
# the XLA-engine CLI paths exercise. (n, p) are already bucketed. `--full`
# adds the complete experiment matrix of DESIGN.md §5.
CORE_SHAPES = [
    # family, n, p, m
    ("gaussian", 128, 512, 1),       # quickstart / integration tests
    ("binomial", 128, 512, 1),
    ("poisson", 128, 512, 1),
    ("multinomial", 128, 512, 3),
    ("gaussian", 256, 5056, 1),      # Fig 1 / Fig 6 bucket
    ("binomial", 64, 7168, 1),       # golub (38 × 7129)
    ("gaussian", 256, 20032, 1),     # Fig 4 / Table 1 OLS bucket
]

FULL_SHAPES = CORE_SHAPES + [
    ("binomial", 256, 20032, 1),     # Fig 4 logistic
    ("poisson", 256, 20032, 1),      # Fig 4 poisson
    ("multinomial", 256, 20032, 3),  # Fig 4 multinomial
    ("gaussian", 256, 10048, 1),     # Fig 2
    ("gaussian", 128, 64, 1),        # Fig 3 buckets
    ("gaussian", 128, 128, 1),
    ("gaussian", 128, 512, 1),
    ("gaussian", 128, 1024, 1),
    ("gaussian", 128, 9920, 1),      # arcene
    ("multinomial", 256, 256, 10),   # zipcode
    ("poisson", 4416, 64, 1),        # physician (4406 × 25)
    ("gaussian", 8192, 64, 1),       # cpusmall
]

SCREEN_SIZES = [512, 5056, 20032]


def emit(out_dir: str, full: bool) -> None:
    os.makedirs(out_dir, exist_ok=True)
    shapes = FULL_SHAPES if full else CORE_SHAPES
    # dedupe while preserving order
    seen = set()
    entries = []
    for family, n, p, m in shapes:
        key = (family, n, p, m)
        if key in seen:
            continue
        seen.add(key)
        fn = model.gradient_fn(family)
        args = model.abstract_args(family, n, p, m)
        lowered = jax.jit(fn).lower(*args)
        text = to_hlo_text(lowered)
        name = f"grad_{family}_n{n}_p{p}" + (f"_m{m}" if family == "multinomial" else "")
        path = os.path.join(out_dir, name + ".hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        entries.append(
            {
                "kind": "grad",
                "family": family,
                "n": n,
                "p": p,
                "m": m,
                "file": name + ".hlo.txt",
            }
        )
        print(f"wrote {path} ({len(text)} chars)")

    for p in SCREEN_SIZES:
        fn = model.screen_fn()
        lowered = jax.jit(fn).lower(*model.abstract_screen_args(p))
        name = f"screen_p{p}"
        path = os.path.join(out_dir, name + ".hlo.txt")
        with open(path, "w") as f:
            f.write(to_hlo_text(lowered))
        entries.append({"kind": "screen", "family": "", "n": 0, "p": p, "m": 1,
                        "file": name + ".hlo.txt"})
        print(f"wrote {path}")

    manifest = {"version": 1, "dtype": "f64", "pad_multiple": 64, "entries": entries}
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"manifest: {len(entries)} artifacts")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--full", action="store_true", help="emit the complete experiment matrix")
    args = ap.parse_args()
    emit(args.out, args.full)


if __name__ == "__main__":
    main()

"""AOT pipeline: manifest emission, artifact naming, HLO-text stability."""

import json
import os

import numpy as np
import pytest

import jax

from compile import aot, model


def test_emit_core_to_tmpdir(tmp_path):
    """A reduced emission (monkeypatched shape list) produces loadable HLO
    text files plus a manifest whose entries point at them."""
    shapes = [("gaussian", 64, 64, 1), ("multinomial", 64, 64, 3)]
    orig = aot.CORE_SHAPES
    aot.CORE_SHAPES = shapes
    try:
        aot.SCREEN_SIZES, orig_screen = [64], aot.SCREEN_SIZES
        try:
            aot.emit(str(tmp_path), full=False)
        finally:
            aot.SCREEN_SIZES = orig_screen
    finally:
        aot.CORE_SHAPES = orig
    manifest = json.loads((tmp_path / "manifest.json").read_text())
    assert manifest["dtype"] == "f64"
    assert manifest["pad_multiple"] == 64
    entries = manifest["entries"]
    assert len(entries) == 3  # 2 grads + 1 screen
    for e in entries:
        path = tmp_path / e["file"]
        assert path.exists(), e
        text = path.read_text()
        assert "ENTRY" in text
        assert "HloModule" in text


def test_grad_artifact_names_encode_shape(tmp_path):
    shapes = [("binomial", 128, 192, 1)]
    orig = aot.CORE_SHAPES
    aot.CORE_SHAPES = shapes
    orig_screen = aot.SCREEN_SIZES
    aot.SCREEN_SIZES = []
    try:
        aot.emit(str(tmp_path), full=False)
    finally:
        aot.CORE_SHAPES = orig
        aot.SCREEN_SIZES = orig_screen
    assert (tmp_path / "grad_binomial_n128_p192.hlo.txt").exists()


def test_hlo_text_is_deterministic():
    """Two lowerings of the same graph produce identical HLO text — the
    artifact cache key (`make` mtime rule) is sound."""
    fn = model.gradient_fn("gaussian")
    args = model.abstract_args("gaussian", 64, 64)
    t1 = aot.to_hlo_text(jax.jit(fn).lower(*args))
    t2 = aot.to_hlo_text(jax.jit(fn).lower(*args))
    assert t1 == t2


def test_full_matrix_is_superset_of_core():
    core = {tuple(s) for s in aot.CORE_SHAPES}
    full = {tuple(s) for s in aot.FULL_SHAPES}
    assert core <= full


def test_executable_numerics_via_jax_roundtrip():
    """Compile the lowered gradient back through JAX's own runtime and
    compare against the oracle — guards the lowering itself (the Rust side
    re-checks the same contract through PJRT in integration_runtime.rs)."""
    from compile.kernels import ref

    rng = np.random.default_rng(7)
    n, p = 64, 64
    x = rng.standard_normal((n, p)) * 0.2
    beta = rng.standard_normal(p) * 0.4
    y = rng.standard_normal(n)
    fn = model.gradient_fn("gaussian")
    compiled = jax.jit(fn).lower(x, beta, y).compile()
    (got,) = compiled(x, beta, y)
    np.testing.assert_allclose(got, ref.gradient_gaussian(x, beta, y), rtol=1e-12, atol=1e-10)


@pytest.mark.parametrize(
    "n,p,expected",
    [(1, 1, (64, 64)), (100, 5000, (128, 5056)), (200, 20000, (256, 20032))],
)
def test_bucket_rounding(n, p, expected):
    assert (aot.round64(n), aot.round64(p)) == expected

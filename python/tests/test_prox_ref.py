"""Cross-language prox agreement: the Python reference prox (ref.py)
mirrors the Rust stack algorithm; hypothesis verifies its optimality
conditions independently, so the two implementations are pinned to the
same mathematical object from both sides."""

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from compile.kernels import ref


def sl1_norm(b, lam):
    mags = np.sort(np.abs(b))[::-1]
    return float(np.sum(mags * lam[: len(mags)]))


def prox_objective(b, v, lam):
    return 0.5 * float(np.sum((b - v) ** 2)) + sl1_norm(b, lam)


vec = st.integers(min_value=1, max_value=25).flatmap(
    lambda p: st.tuples(
        st.lists(
            st.floats(min_value=-5, max_value=5, allow_nan=False),
            min_size=p,
            max_size=p,
        ),
        st.lists(
            st.floats(min_value=0, max_value=3, allow_nan=False),
            min_size=p,
            max_size=p,
        ),
    )
)


@settings(max_examples=150, deadline=None)
@given(data=vec, seed=st.integers(0, 10_000))
def test_prox_minimizes_objective(data, seed):
    v, lam_raw = data
    v = np.asarray(v)
    lam = np.sort(np.asarray(lam_raw))[::-1].copy()
    b = ref.prox_sorted_l1(v, lam)
    f_star = prox_objective(b, v, lam)
    rng = np.random.default_rng(seed)
    for eps in (1e-3, 1e-2, 0.1):
        for _ in range(6):
            cand = b + eps * rng.standard_normal(len(v))
            assert prox_objective(cand, v, lam) >= f_star - 1e-9


@settings(max_examples=150, deadline=None)
@given(data=vec)
def test_prox_magnitude_order_preserved(data):
    v, lam_raw = data
    v = np.asarray(v)
    lam = np.sort(np.asarray(lam_raw))[::-1].copy()
    b = ref.prox_sorted_l1(v, lam)
    order = np.argsort(-np.abs(v), kind="stable")
    mags = np.abs(b)[order]
    assert np.all(np.diff(mags) <= 1e-12)


def test_prox_known_clusters():
    got = ref.prox_sorted_l1([5.0, 4.9, 0.1], [3.0, 1.0, 0.5])
    # z = (2, 3.9, -0.4) violates monotonicity: first two pool to 2.95.
    np.testing.assert_allclose(got[:2], [2.95, 2.95])
    assert got[2] == 0.0

"""Layer-2 correctness: the model builders and the AOT lowering contract."""

import numpy as np
import pytest

import jax

from compile import model
from compile.aot import round64, to_hlo_text
from compile.kernels import ref


@pytest.mark.parametrize("family", ["gaussian", "binomial", "poisson"])
def test_gradient_fn_returns_tuple_matching_ref(family):
    rng = np.random.default_rng(3)
    n, p = 17, 33
    x = rng.standard_normal((n, p)) * 0.3
    beta = rng.standard_normal(p) * 0.5
    y = {
        "gaussian": rng.standard_normal(n),
        "binomial": (rng.random(n) < 0.5).astype(np.float64),
        "poisson": rng.poisson(1.0, n).astype(np.float64),
    }[family]
    fn = model.gradient_fn(family)
    (got,) = fn(x, beta, y)
    want = getattr(ref, f"gradient_{family}")(x, beta, y)
    np.testing.assert_allclose(got, want, rtol=1e-10, atol=1e-9)


def test_gradient_fn_multinomial():
    rng = np.random.default_rng(4)
    n, p, m = 11, 9, 4
    x = rng.standard_normal((n, p)) * 0.3
    beta = rng.standard_normal((p, m)) * 0.5
    y = np.eye(m)[rng.integers(0, m, n)]
    (got,) = model.gradient_fn("multinomial")(x, beta, y)
    want = ref.gradient_multinomial(x, beta, y)
    np.testing.assert_allclose(got, want, rtol=1e-10, atol=1e-9)


def test_unknown_family_raises():
    with pytest.raises(ValueError):
        model.gradient_fn("tweedie")


def test_abstract_args_shapes():
    args = model.abstract_args("gaussian", 64, 128)
    assert [a.shape for a in args] == [(64, 128), (128,), (64,)]
    args = model.abstract_args("multinomial", 64, 128, 5)
    assert [a.shape for a in args] == [(64, 128), (128, 5), (64, 5)]
    assert all(str(a.dtype) == "float64" for a in args)


def test_round64():
    assert round64(1) == 64
    assert round64(64) == 64
    assert round64(65) == 128
    assert round64(20000) == 20032


@pytest.mark.parametrize("family", model.FAMILIES)
def test_lowering_produces_hlo_text(family):
    """The AOT contract: every family lowers to parseable HLO text with an
    ENTRY computation and a tuple root (what the Rust loader expects)."""
    m = 3 if family == "multinomial" else 1
    fn = model.gradient_fn(family)
    lowered = jax.jit(fn).lower(*model.abstract_args(family, 64, 64, m))
    text = to_hlo_text(lowered)
    assert "ENTRY" in text
    assert "f64" in text
    # return_tuple=True: root is a tuple
    assert "tuple(" in text.replace(" ", "") or "(f64[" in text


def test_screen_fn_matches_ref():
    rng = np.random.default_rng(5)
    p = 100
    c = np.sort(np.abs(rng.standard_normal(p)))[::-1].copy()
    lam = np.sort(np.abs(rng.standard_normal(p)))[::-1].copy()
    (got,) = model.screen_fn()(c, lam)
    np.testing.assert_allclose(got, ref.screen_cumsum(c, lam), rtol=1e-10, atol=1e-10)

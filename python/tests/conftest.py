"""Test session config: float64 everywhere (must precede any tracing)."""

import jax

jax.config.update("jax_enable_x64", True)

"""Test session config: put `python/` on the import path (the `compile`
package is not installed) and force float64 everywhere (must precede any
tracing)."""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

import jax

jax.config.update("jax_enable_x64", True)

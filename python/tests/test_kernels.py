"""Layer-1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

Hypothesis sweeps shapes (including non-multiples of the tile size within
the padding contract), dtypes stay f64 per the AOT contract, and values
span several orders of magnitude.
"""

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels import slope_grad as k

RTOL = 1e-12
ATOL = 1e-10


def rand(rng, *shape, scale=1.0):
    return scale * rng.standard_normal(shape)


dims = st.tuples(
    st.integers(min_value=1, max_value=40),  # n
    st.integers(min_value=1, max_value=96),  # p
)


@settings(max_examples=40, deadline=None)
@given(dims=dims, seed=st.integers(0, 2**31 - 1), block=st.sampled_from([None, 16, 64]))
def test_matvec_matches_ref(dims, seed, block):
    n, p = dims
    rng = np.random.default_rng(seed)
    x = rand(rng, n, p)
    b = rand(rng, p, scale=3.0)
    got = k.matvec(x, b, block_p=block)
    np.testing.assert_allclose(got, ref.matvec(x, b), rtol=RTOL, atol=ATOL)


@settings(max_examples=40, deadline=None)
@given(dims=dims, seed=st.integers(0, 2**31 - 1), block=st.sampled_from([None, 16, 64]))
def test_tmatvec_matches_ref(dims, seed, block):
    n, p = dims
    rng = np.random.default_rng(seed)
    x = rand(rng, n, p)
    h = rand(rng, n)
    got = k.tmatvec(x, h, block_p=block)
    np.testing.assert_allclose(got, ref.tmatvec(x, h), rtol=RTOL, atol=ATOL)


@settings(max_examples=25, deadline=None)
@given(
    dims=dims,
    m=st.integers(min_value=2, max_value=6),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmat_tmatmat_match_ref(dims, m, seed):
    n, p = dims
    rng = np.random.default_rng(seed)
    x = rand(rng, n, p)
    b = rand(rng, p, m)
    h = rand(rng, n, m)
    np.testing.assert_allclose(k.matmat(x, b), ref.matmat(x, b), rtol=RTOL, atol=ATOL)
    np.testing.assert_allclose(k.tmatmat(x, h), ref.tmatmat(x, h), rtol=RTOL, atol=ATOL)


@settings(max_examples=40, deadline=None)
@given(
    p=st.integers(min_value=1, max_value=400),
    seed=st.integers(0, 2**31 - 1),
    block=st.sampled_from([7, 64, 1024]),
)
def test_screen_cumsum_matches_ref(p, seed, block):
    rng = np.random.default_rng(seed)
    c = np.sort(np.abs(rand(rng, p, scale=2.0)))[::-1].copy()
    lam = np.sort(np.abs(rand(rng, p)))[::-1].copy()
    got = k.screen_cumsum(c, lam, block=block)
    np.testing.assert_allclose(got, ref.screen_cumsum(c, lam), rtol=1e-10, atol=1e-9)


@pytest.mark.parametrize("family", ["gaussian", "binomial", "poisson"])
@settings(max_examples=20, deadline=None)
@given(dims=dims, seed=st.integers(0, 2**31 - 1))
def test_gradient_kernels_match_ref(family, dims, seed):
    n, p = dims
    rng = np.random.default_rng(seed)
    x = rand(rng, n, p, scale=0.3)
    beta = rand(rng, p, scale=0.5)
    if family == "gaussian":
        y = rand(rng, n)
        got, want = k.gradient_gaussian(x, beta, y), ref.gradient_gaussian(x, beta, y)
    elif family == "binomial":
        y = (rng.random(n) < 0.5).astype(np.float64)
        got, want = k.gradient_binomial(x, beta, y), ref.gradient_binomial(x, beta, y)
    else:
        y = rng.poisson(1.0, n).astype(np.float64)
        got, want = k.gradient_poisson(x, beta, y), ref.gradient_poisson(x, beta, y)
    np.testing.assert_allclose(got, want, rtol=1e-10, atol=1e-9)


@settings(max_examples=15, deadline=None)
@given(
    dims=dims,
    m=st.integers(min_value=2, max_value=5),
    seed=st.integers(0, 2**31 - 1),
)
def test_gradient_multinomial_matches_ref(dims, m, seed):
    n, p = dims
    rng = np.random.default_rng(seed)
    x = rand(rng, n, p, scale=0.3)
    beta = rand(rng, p, m, scale=0.5)
    labels = rng.integers(0, m, n)
    y = np.eye(m)[labels]
    got = k.gradient_multinomial(x, beta, y)
    want = ref.gradient_multinomial(x, beta, y)
    np.testing.assert_allclose(got, want, rtol=1e-10, atol=1e-9)


def test_zero_padding_preserves_gradient():
    """The runtime padding contract (DESIGN.md §8): zero rows/columns added
    to X (and zeros to β/y) leave the gradient of the real coordinates
    unchanged, for every family."""
    rng = np.random.default_rng(0)
    n, p, n2, p2 = 13, 21, 64, 64
    x = rand(rng, n, p, scale=0.3)
    beta = rand(rng, p, scale=0.5)
    xp = np.zeros((n2, p2))
    xp[:n, :p] = x
    bp = np.zeros(p2)
    bp[:p] = beta

    for family, make_y in [
        ("gaussian", lambda: rand(rng, n)),
        ("binomial", lambda: (rng.random(n) < 0.5).astype(np.float64)),
        ("poisson", lambda: rng.poisson(1.0, n).astype(np.float64)),
    ]:
        y = make_y()
        yp = np.zeros(n2)
        yp[:n] = y
        fn = getattr(k, f"gradient_{family}")
        small = fn(x, beta, y)
        padded = fn(xp, bp, yp)
        np.testing.assert_allclose(padded[:p], small, rtol=1e-10, atol=1e-9)
        np.testing.assert_allclose(padded[p:], 0.0, atol=1e-12)


def test_zero_padding_multinomial():
    rng = np.random.default_rng(1)
    n, p, m, n2, p2 = 9, 17, 3, 64, 64
    x = rand(rng, n, p, scale=0.3)
    beta = rand(rng, p, m, scale=0.5)
    labels = rng.integers(0, m, n)
    y = np.eye(m)[labels]
    xp = np.zeros((n2, p2))
    xp[:n, :p] = x
    bp = np.zeros((p2, m))
    bp[:p] = beta
    yp = np.zeros((n2, m))
    yp[:n] = y
    small = k.gradient_multinomial(x, beta, y)
    padded = k.gradient_multinomial(xp, bp, yp)
    np.testing.assert_allclose(padded[:p], small, rtol=1e-10, atol=1e-9)
    np.testing.assert_allclose(padded[p:], 0.0, atol=1e-12)


def test_prox_reference_soft_thresholds():
    got = ref.prox_sorted_l1([3.0, -1.0, 0.5, -4.0], [1.0, 1.0, 1.0, 1.0])
    np.testing.assert_allclose(got, [2.0, 0.0, 0.0, -3.0])


def test_prox_reference_clusters():
    got = ref.prox_sorted_l1([3.0, 2.5], [2.0, 1.0])
    np.testing.assert_allclose(got, [1.25, 1.25])

//! Serve-layer throughput: requests/sec through the in-process server
//! core for cold fits (cache off) vs warm-start-cached repeats (cache
//! on), for both `fit_path` and `fit_point`, plus a concurrent burst that
//! exercises request coalescing and the bounded scheduler.
//!
//! Writes `results/serve_throughput.csv` and the machine-readable
//! `BENCH_serve.json` at the repository root — the serve perf trajectory
//! is tracked from this file.
//!
//! Run: `cargo bench --bench serve_throughput -- --requests 20`

use std::sync::Arc;
use std::time::Instant;

use slope_screen::benchkit::Table;
use slope_screen::cli::Args;
use slope_screen::jsonio::Json;
use slope_screen::serve::protocol::{request_line, synth_dataset_json};
use slope_screen::serve::{Server, ServerConfig};

struct Scenario {
    name: &'static str,
    requests: usize,
    total_s: f64,
}

impl Scenario {
    fn req_per_s(&self) -> f64 {
        self.requests as f64 / self.total_s.max(1e-12)
    }
}

fn drive(server: &Server, lines: &[String]) -> f64 {
    let t0 = Instant::now();
    for line in lines {
        let resp = server.handle_line(line);
        assert!(
            resp.contains("\"ok\":true"),
            "request failed in bench: {resp}"
        );
    }
    t0.elapsed().as_secs_f64()
}

fn main() {
    let parsed = Args::new("serve throughput: warm-start cache on vs off")
        .opt("n", "100", "observations")
        .opt("p", "1000", "predictors")
        .opt("k", "10", "true support size")
        .opt("requests", "20", "requests per scenario")
        .opt("q", "0.05", "BH parameter")
        .opt("path-length", "20", "path length for fit_path scenarios")
        .opt("threads", "0", "server worker threads (0 = auto)")
        .opt("seed", "2020", "dataset seed")
        .flag("bench", "(cargo bench compatibility)")
        .parse();
    let n = parsed.usize("n");
    let p = parsed.usize("p");
    let k = parsed.usize("k");
    let requests = parsed.usize("requests").max(2);
    let q = parsed.f64("q");
    let path_length = parsed.usize("path-length");
    let threads = parsed.usize("threads");
    let seed = parsed.u64("seed");

    let dataset = || synth_dataset_json(n, p, k, 0.2, "gaussian", seed);
    let fit_path_line = |id: u64| {
        request_line(
            id,
            "fit_path",
            vec![
                ("dataset", dataset()),
                ("q", Json::Num(q)),
                ("path_length", Json::Num(path_length as f64)),
            ],
        )
    };
    let fit_point_line = |id: u64, ratio: f64| {
        request_line(
            id,
            "fit_point",
            vec![
                ("dataset", dataset()),
                ("q", Json::Num(q)),
                ("sigma_ratio", Json::Num(ratio)),
            ],
        )
    };

    let mut scenarios: Vec<Scenario> = Vec::new();

    // fit_path, cache disabled: every request is a full cold fit.
    {
        let server = Server::new(ServerConfig { threads, queue: 64, cache: false, fit_threads: 0, ..Default::default() });
        let lines: Vec<String> = (0..requests).map(|i| fit_path_line(i as u64)).collect();
        let total_s = drive(&server, &lines);
        scenarios.push(Scenario { name: "fit_path_cold", requests, total_s });
    }
    // fit_path, cache enabled: one cold fit, then warm-start-cached hits.
    {
        let server = Server::new(ServerConfig { threads, queue: 64, cache: true, fit_threads: 0, ..Default::default() });
        let lines: Vec<String> = (0..requests).map(|i| fit_path_line(i as u64)).collect();
        let total_s = drive(&server, &lines);
        scenarios.push(Scenario { name: "fit_path_warm_cache", requests, total_s });
    }
    // fit_point, cache disabled: every point re-solved from σ_max.
    {
        let server = Server::new(ServerConfig { threads, queue: 64, cache: false, fit_threads: 0, ..Default::default() });
        let lines: Vec<String> = (0..requests)
            .map(|i| fit_point_line(i as u64, 0.5 - 0.2 * (i % 5) as f64 / 5.0))
            .collect();
        let total_s = drive(&server, &lines);
        scenarios.push(Scenario { name: "fit_point_cold", requests, total_s });
    }
    // fit_point, cache enabled: each request warm-starts from the last
    // point's coefficients, gradient and screened support.
    {
        let server = Server::new(ServerConfig { threads, queue: 64, cache: true, fit_threads: 0, ..Default::default() });
        let lines: Vec<String> = (0..requests)
            .map(|i| fit_point_line(i as u64, 0.5 - 0.2 * (i % 5) as f64 / 5.0))
            .collect();
        let total_s = drive(&server, &lines);
        scenarios.push(Scenario { name: "fit_point_warm_cache", requests, total_s });
    }
    // concurrent burst: 4 connections ask for the same cold model at
    // once — coalescing runs one fit and shares it.
    {
        let server = Arc::new(Server::new(ServerConfig { threads, queue: 64, cache: true, fit_threads: 0, ..Default::default() }));
        let t0 = Instant::now();
        std::thread::scope(|scope| {
            for c in 0..4 {
                let server = Arc::clone(&server);
                let line = fit_path_line(100 + c);
                scope.spawn(move || {
                    let resp = server.handle_line(&line);
                    assert!(resp.contains("\"ok\":true"), "{resp}");
                });
            }
        });
        let total_s = t0.elapsed().as_secs_f64();
        let cold = server.metrics.counters.cold_fits.load(std::sync::atomic::Ordering::Relaxed);
        assert_eq!(cold, 1, "coalescing must run exactly one cold fit");
        scenarios.push(Scenario { name: "fit_path_burst4_coalesced", requests: 4, total_s });
    }

    let mut table = Table::new(
        &format!("serve throughput (n={n}, p={p}, {requests} requests/scenario)"),
        &["scenario", "requests", "total_s", "req_per_s"],
    );
    for s in &scenarios {
        table.row(vec![
            s.name.to_string(),
            s.requests.to_string(),
            format!("{:.4}", s.total_s),
            format!("{:.2}", s.req_per_s()),
        ]);
    }
    table.print();
    let csv = table.write_csv("serve_throughput").expect("csv");
    println!("\nwrote {}", csv.display());

    let find = |name: &str| scenarios.iter().find(|s| s.name == name).expect("scenario");
    let path_speedup = find("fit_path_warm_cache").req_per_s() / find("fit_path_cold").req_per_s();
    let point_speedup =
        find("fit_point_warm_cache").req_per_s() / find("fit_point_cold").req_per_s();
    println!(
        "speedup: fit_path warm-cache {path_speedup:.1}x, fit_point warm-cache {point_speedup:.1}x"
    );
    assert!(
        path_speedup > 1.0,
        "warm-start cache must beat cold fits (got {path_speedup:.2}x)"
    );

    let payload = Json::obj(vec![
        ("bench", Json::Str("serve_throughput".to_string())),
        (
            "config",
            Json::obj(vec![
                ("n", Json::Num(n as f64)),
                ("p", Json::Num(p as f64)),
                ("k", Json::Num(k as f64)),
                ("q", Json::Num(q)),
                ("path_length", Json::Num(path_length as f64)),
                ("requests", Json::Num(requests as f64)),
            ]),
        ),
        (
            "scenarios",
            Json::Arr(
                scenarios
                    .iter()
                    .map(|s| {
                        Json::obj(vec![
                            ("name", Json::Str(s.name.to_string())),
                            ("requests", Json::Num(s.requests as f64)),
                            ("total_s", Json::Num(s.total_s)),
                            ("req_per_s", Json::Num(s.req_per_s())),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "speedup",
            Json::obj(vec![
                ("fit_path_warm_over_cold", Json::Num(path_speedup)),
                ("fit_point_warm_over_cold", Json::Num(point_speedup)),
            ]),
        ),
        ("table", table.to_json()),
    ]);
    let out_path =
        slope_screen::benchkit::write_bench_json("serve", &payload).expect("BENCH_serve.json");
    println!("wrote {}", out_path.display());
}

//! Serve-layer throughput: requests/sec through the in-process server
//! core for cold fits (cache off) vs warm-start-cached repeats (cache
//! on), for both `fit_path` and `fit_point`, a concurrent burst that
//! exercises request coalescing, and the cross-request batching axis
//! (DESIGN.md §14): Zipf-popular warm `fit_point` traffic from
//! concurrent clients against a gather window of 0 (batching off) vs
//! 2 ms (batching on), with p50/p99 latency.
//!
//! Writes `results/serve_throughput.csv` and the machine-readable
//! `BENCH_serve.json` at the repository root — the serve perf trajectory
//! is tracked from this file.
//!
//! Run: `cargo bench --bench serve_throughput -- --requests 20`
//! CI:  `cargo bench --bench serve_throughput -- --smoke`
//! (`--smoke` shrinks every dimension and skips the perf gates — it
//! checks the harness, not the machine.)

use std::sync::Arc;
use std::time::Instant;

use slope_screen::benchkit::{Table, Timing};
use slope_screen::jsonio::Json;
use slope_screen::obs::registry as obsreg;
use slope_screen::cli::Args;
use slope_screen::serve::protocol::{request_line, synth_dataset_json};
use slope_screen::serve::{Server, ServerConfig};

struct Scenario {
    name: &'static str,
    requests: usize,
    total_s: f64,
}

impl Scenario {
    fn req_per_s(&self) -> f64 {
        self.requests as f64 / self.total_s.max(1e-12)
    }
}

fn drive(server: &Server, lines: &[String]) -> f64 {
    let t0 = Instant::now();
    for line in lines {
        let resp = server.handle_line(line);
        assert!(
            resp.contains("\"ok\":true"),
            "request failed in bench: {resp}"
        );
    }
    t0.elapsed().as_secs_f64()
}

/// Inverse-CDF sampler over a Zipf(s) popularity law on `n` items —
/// item 0 is the hot head (~45% of draws at s=1.1, n=6). The xorshift
/// stream is seeded, so a run replays.
struct Zipf {
    cum: Vec<f64>,
    state: u64,
}

impl Zipf {
    fn new(n: usize, s: f64, seed: u64) -> Zipf {
        let mut cum = Vec::with_capacity(n);
        let mut total = 0.0;
        for i in 0..n {
            total += 1.0 / ((i + 1) as f64).powf(s);
            cum.push(total);
        }
        for c in cum.iter_mut() {
            *c /= total;
        }
        Zipf { cum, state: seed | 1 }
    }

    fn next(&mut self) -> usize {
        let mut x = self.state;
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        self.state = x;
        let u = (x >> 11) as f64 / (1u64 << 53) as f64;
        self.cum.iter().position(|&c| u < c).unwrap_or(self.cum.len() - 1)
    }
}

/// One side of the batched-vs-unbatched axis.
struct ZipfOutcome {
    requests: usize,
    total_s: f64,
    p50_ms: f64,
    p99_ms: f64,
    batches: u64,
    batched_requests: u64,
}

impl ZipfOutcome {
    fn req_per_s(&self) -> f64 {
        self.requests as f64 / self.total_s.max(1e-12)
    }
}

/// Warm same-dataset traffic under a Zipf popularity law: `clients`
/// concurrent closed-loop threads each fire `per_client` `fit_point`
/// requests, dataset drawn Zipf(1.1) from a pool of `datasets`, σ-ratio
/// sweeping a descending grid (the request pattern a path explorer
/// produces). Every dataset is pre-warmed so the measured window is all
/// warm traffic — the regime the gather window is built for.
#[allow(clippy::too_many_arguments)]
fn zipf_load(
    server: &Arc<Server>,
    clients: usize,
    per_client: usize,
    datasets: usize,
    n: usize,
    p: usize,
    k: usize,
    q: f64,
    seed: u64,
) -> ZipfOutcome {
    let line = |id: u64, d: usize, ratio: f64| {
        request_line(
            id,
            "fit_point",
            vec![
                ("dataset", synth_dataset_json(n, p, k, 0.2, "gaussian", seed + d as u64)),
                ("q", Json::Num(q)),
                ("sigma_ratio", Json::Num(ratio)),
            ],
        )
    };
    const GRID: [f64; 5] = [0.5, 0.45, 0.4, 0.35, 0.3];
    // Pre-warm: one point fit per dataset seeds the warm-start cache.
    for d in 0..datasets {
        let resp = server.handle_line(&line(d as u64, d, GRID[0]));
        assert!(resp.contains("\"ok\":true"), "warmup failed: {resp}");
    }
    let batches0 = obsreg::SERVE_BATCHES.get();
    let members0 = obsreg::SERVE_BATCHED_REQUESTS.get();
    let t0 = Instant::now();
    let mut samples: Vec<f64> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..clients)
            .map(|c| {
                let server = Arc::clone(server);
                let line = &line;
                scope.spawn(move || {
                    let mut zipf = Zipf::new(datasets, 1.1, seed ^ (c as u64 + 1) * 0x9E37);
                    let mut lat = Vec::with_capacity(per_client);
                    for i in 0..per_client {
                        let d = zipf.next();
                        let req =
                            line((1000 + c * per_client + i) as u64, d, GRID[i % GRID.len()]);
                        let t = Instant::now();
                        let resp = server.handle_line(&req);
                        lat.push(t.elapsed().as_secs_f64());
                        assert!(resp.contains("\"ok\":true"), "zipf request failed: {resp}");
                    }
                    lat
                })
            })
            .collect();
        handles.into_iter().flat_map(|h| h.join().unwrap()).collect()
    });
    let total_s = t0.elapsed().as_secs_f64();
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let timing = Timing::from_samples(samples);
    ZipfOutcome {
        requests: clients * per_client,
        total_s,
        p50_ms: timing.quantile(0.5) * 1e3,
        p99_ms: timing.quantile(0.99) * 1e3,
        batches: obsreg::SERVE_BATCHES.get() - batches0,
        batched_requests: obsreg::SERVE_BATCHED_REQUESTS.get() - members0,
    }
}

fn main() {
    let parsed = Args::new("serve throughput: warm-start cache and cross-request batching")
        .opt("n", "100", "observations")
        .opt("p", "1000", "predictors")
        .opt("k", "10", "true support size")
        .opt("requests", "20", "requests per scenario")
        .opt("q", "0.05", "BH parameter")
        .opt("path-length", "20", "path length for fit_path scenarios")
        .opt("threads", "0", "server worker threads (0 = auto)")
        .opt("clients", "8", "concurrent client threads for the Zipf axis (gate needs >= 4)")
        .opt("zipf-requests", "30", "requests per client on the Zipf axis")
        .opt("zipf-datasets", "6", "dataset pool size for the Zipf axis")
        .opt("seed", "2020", "dataset seed")
        .flag("smoke", "tiny sizes, perf gates skipped (CI harness check)")
        .flag("bench", "(cargo bench compatibility)")
        .parse();
    let smoke = parsed.bool("smoke");
    let n = if smoke { 40 } else { parsed.usize("n") };
    let p = if smoke { 120 } else { parsed.usize("p") };
    let k = if smoke { 4 } else { parsed.usize("k") };
    let requests = if smoke { 4 } else { parsed.usize("requests").max(2) };
    let q = parsed.f64("q");
    let path_length = if smoke { 6 } else { parsed.usize("path-length") };
    let threads = parsed.usize("threads");
    let clients = if smoke { 4 } else { parsed.usize("clients").max(1) };
    let zipf_requests = if smoke { 4 } else { parsed.usize("zipf-requests").max(1) };
    let zipf_datasets = if smoke { 3 } else { parsed.usize("zipf-datasets").max(1) };
    let seed = parsed.u64("seed");

    let dataset = || synth_dataset_json(n, p, k, 0.2, "gaussian", seed);
    let fit_path_line = |id: u64| {
        request_line(
            id,
            "fit_path",
            vec![
                ("dataset", dataset()),
                ("q", Json::Num(q)),
                ("path_length", Json::Num(path_length as f64)),
            ],
        )
    };
    let fit_point_line = |id: u64, ratio: f64| {
        request_line(
            id,
            "fit_point",
            vec![
                ("dataset", dataset()),
                ("q", Json::Num(q)),
                ("sigma_ratio", Json::Num(ratio)),
            ],
        )
    };

    let mut scenarios: Vec<Scenario> = Vec::new();

    // fit_path, cache disabled: every request is a full cold fit.
    {
        let server = Server::new(ServerConfig { threads, queue: 64, cache: false, fit_threads: 0, ..Default::default() });
        let lines: Vec<String> = (0..requests).map(|i| fit_path_line(i as u64)).collect();
        let total_s = drive(&server, &lines);
        scenarios.push(Scenario { name: "fit_path_cold", requests, total_s });
    }
    // fit_path, cache enabled: one cold fit, then warm-start-cached hits.
    {
        let server = Server::new(ServerConfig { threads, queue: 64, cache: true, fit_threads: 0, ..Default::default() });
        let lines: Vec<String> = (0..requests).map(|i| fit_path_line(i as u64)).collect();
        let total_s = drive(&server, &lines);
        scenarios.push(Scenario { name: "fit_path_warm_cache", requests, total_s });
    }
    // fit_point, cache disabled: every point re-solved from σ_max.
    {
        let server = Server::new(ServerConfig { threads, queue: 64, cache: false, fit_threads: 0, ..Default::default() });
        let lines: Vec<String> = (0..requests)
            .map(|i| fit_point_line(i as u64, 0.5 - 0.2 * (i % 5) as f64 / 5.0))
            .collect();
        let total_s = drive(&server, &lines);
        scenarios.push(Scenario { name: "fit_point_cold", requests, total_s });
    }
    // fit_point, cache enabled: each request warm-starts from the last
    // point's coefficients, gradient and screened support.
    {
        let server = Server::new(ServerConfig { threads, queue: 64, cache: true, fit_threads: 0, ..Default::default() });
        let lines: Vec<String> = (0..requests)
            .map(|i| fit_point_line(i as u64, 0.5 - 0.2 * (i % 5) as f64 / 5.0))
            .collect();
        let total_s = drive(&server, &lines);
        scenarios.push(Scenario { name: "fit_point_warm_cache", requests, total_s });
    }
    // concurrent burst: 4 connections ask for the same cold model at
    // once — coalescing runs one fit and shares it.
    {
        let server = Arc::new(Server::new(ServerConfig { threads, queue: 64, cache: true, fit_threads: 0, ..Default::default() }));
        let t0 = Instant::now();
        std::thread::scope(|scope| {
            for c in 0..4 {
                let server = Arc::clone(&server);
                let line = fit_path_line(100 + c);
                scope.spawn(move || {
                    let resp = server.handle_line(&line);
                    assert!(resp.contains("\"ok\":true"), "{resp}");
                });
            }
        });
        let total_s = t0.elapsed().as_secs_f64();
        let cold = server.metrics.counters.cold_fits.load(std::sync::atomic::Ordering::Relaxed);
        assert_eq!(cold, 1, "coalescing must run exactly one cold fit");
        scenarios.push(Scenario { name: "fit_path_burst4_coalesced", requests: 4, total_s });
    }

    // The batching axis: identical Zipf traffic against a gather window
    // of 0 (every request its own job) vs 2 ms (same-dataset
    // coalescing). Same seeds, same request streams — only the window
    // differs.
    let zipf_cfg = |gather_window_ms: u64| ServerConfig {
        threads,
        queue: 64,
        cache: true,
        fit_threads: 0,
        gather_window_ms,
        max_batch: 32,
        ..Default::default()
    };
    let unbatched = {
        let server = Arc::new(Server::new(zipf_cfg(0)));
        zipf_load(&server, clients, zipf_requests, zipf_datasets, n, p, k, q, seed)
    };
    let batched = {
        let server = Arc::new(Server::new(zipf_cfg(2)));
        zipf_load(&server, clients, zipf_requests, zipf_datasets, n, p, k, q, seed)
    };

    let mut table = Table::new(
        &format!("serve throughput (n={n}, p={p}, {requests} requests/scenario)"),
        &["scenario", "requests", "total_s", "req_per_s"],
    );
    for s in &scenarios {
        table.row(vec![
            s.name.to_string(),
            s.requests.to_string(),
            format!("{:.4}", s.total_s),
            format!("{:.2}", s.req_per_s()),
        ]);
    }
    for (name, z) in [("zipf_unbatched", &unbatched), ("zipf_batched_2ms", &batched)] {
        table.row(vec![
            name.to_string(),
            z.requests.to_string(),
            format!("{:.4}", z.total_s),
            format!("{:.2}", z.req_per_s()),
        ]);
    }
    table.print();
    println!(
        "zipf ({clients} clients x {zipf_requests} reqs, {zipf_datasets} datasets): \
         unbatched {:.2} req/s p50 {:.1}ms p99 {:.1}ms | batched {:.2} req/s p50 {:.1}ms p99 {:.1}ms \
         ({} batches, {} coalesced members)",
        unbatched.req_per_s(),
        unbatched.p50_ms,
        unbatched.p99_ms,
        batched.req_per_s(),
        batched.p50_ms,
        batched.p99_ms,
        batched.batches,
        batched.batched_requests,
    );
    let csv = table.write_csv("serve_throughput").expect("csv");
    println!("\nwrote {}", csv.display());

    let find = |name: &str| scenarios.iter().find(|s| s.name == name).expect("scenario");
    let path_speedup = find("fit_path_warm_cache").req_per_s() / find("fit_path_cold").req_per_s();
    let point_speedup =
        find("fit_point_warm_cache").req_per_s() / find("fit_point_cold").req_per_s();
    let batch_speedup = batched.req_per_s() / unbatched.req_per_s().max(1e-12);
    println!(
        "speedup: fit_path warm-cache {path_speedup:.1}x, fit_point warm-cache {point_speedup:.1}x, \
         zipf batched-over-unbatched {batch_speedup:.2}x"
    );
    if !smoke {
        assert!(
            path_speedup > 1.0,
            "warm-start cache must beat cold fits (got {path_speedup:.2}x)"
        );
        // The batching acceptance gate: on warm same-dataset Zipf
        // traffic from >= 4 concurrent clients, coalescing must at
        // least double throughput. Under --smoke the sizes are too
        // small for the ratio to mean anything, so only the full run
        // gates.
        assert!(
            clients >= 4,
            "the batching gate needs >= 4 concurrent clients (got {clients})"
        );
        assert!(
            batch_speedup >= 2.0,
            "batched Zipf traffic must run >= 2x unbatched (got {batch_speedup:.2}x)"
        );
    }

    let zipf_json = |z: &ZipfOutcome| {
        Json::obj(vec![
            ("requests", Json::Num(z.requests as f64)),
            ("total_s", Json::Num(z.total_s)),
            ("req_per_s", Json::Num(z.req_per_s())),
            ("p50_ms", Json::Num(z.p50_ms)),
            ("p99_ms", Json::Num(z.p99_ms)),
            ("batches", Json::Num(z.batches as f64)),
            ("batched_requests", Json::Num(z.batched_requests as f64)),
        ])
    };
    let payload = Json::obj(vec![
        ("bench", Json::Str("serve_throughput".to_string())),
        (
            "config",
            Json::obj(vec![
                ("n", Json::Num(n as f64)),
                ("p", Json::Num(p as f64)),
                ("k", Json::Num(k as f64)),
                ("q", Json::Num(q)),
                ("path_length", Json::Num(path_length as f64)),
                ("requests", Json::Num(requests as f64)),
                ("clients", Json::Num(clients as f64)),
                ("zipf_requests", Json::Num(zipf_requests as f64)),
                ("zipf_datasets", Json::Num(zipf_datasets as f64)),
                ("smoke", Json::Bool(smoke)),
            ]),
        ),
        (
            "scenarios",
            Json::Arr(
                scenarios
                    .iter()
                    .map(|s| {
                        Json::obj(vec![
                            ("name", Json::Str(s.name.to_string())),
                            ("requests", Json::Num(s.requests as f64)),
                            ("total_s", Json::Num(s.total_s)),
                            ("req_per_s", Json::Num(s.req_per_s())),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "zipf",
            Json::obj(vec![
                ("unbatched", zipf_json(&unbatched)),
                ("batched_2ms", zipf_json(&batched)),
                ("batched_over_unbatched", Json::Num(batch_speedup)),
            ]),
        ),
        (
            "speedup",
            Json::obj(vec![
                ("fit_path_warm_over_cold", Json::Num(path_speedup)),
                ("fit_point_warm_over_cold", Json::Num(point_speedup)),
                ("zipf_batched_over_unbatched", Json::Num(batch_speedup)),
            ]),
        ),
        ("table", table.to_json()),
    ]);
    let out_path =
        slope_screen::benchkit::write_bench_json("serve", &payload).expect("BENCH_serve.json");
    println!("wrote {}", out_path.display());
}

//! Figure 6: strong-set algorithm (Alg. 3) vs previous-set algorithm
//! (Alg. 4) across correlation strength.
//!
//! Paper setup: OLS, n = 200, p = 5000, k = 50, β ~ N(0, 1),
//! ρ ∈ {0, 0.1, …, 0.8}, 100 repetitions. The previous-set strategy wins
//! for large ρ, where the strong rule turns excessively conservative.
//! Run: `cargo bench --bench fig6_algorithms -- --scale 1 --reps 5`

use std::time::Instant;

use slope_screen::benchkit::Table;
use slope_screen::cli::Args;
use slope_screen::data::synth::{BetaSpec, DesignKind, SyntheticSpec};
use slope_screen::rng::Pcg64;
use slope_screen::slope::family::Family;
use slope_screen::slope::lambda::{LambdaKind, PathConfig};
use slope_screen::slope::path::{fit_path, NativeGradient, PathOptions, Strategy};

fn main() {
    let parsed = Args::new("Figure 6: strong-set vs previous-set algorithm")
        .opt("scale", "0.3", "problem scale (1 = paper: n=200, p=5000)")
        .opt("rhos", "0,0.2,0.4,0.6,0.8", "correlation grid")
        .opt("reps", "2", "repetitions (paper: 100)")
        .opt("qs", "1e-4,1e-2", "BH parameter grid (paper discusses both; 1e-2 provokes mass clustering)")
        .opt("seed", "2025", "rng seed")
        .flag("bench", "(cargo bench compatibility)")
        .parse();
    let scale = parsed.f64("scale");
    let n = (200.0 * scale).round().max(20.0) as usize;
    let p = (5000.0 * scale).round().max(100.0) as usize;
    let k = 50.min(p / 4).max(2);
    let reps = parsed.usize("reps");

    let mut table = Table::new(
        &format!("Figure 6 — algorithm comparison (OLS, n={n}, p={p}, k={k})"),
        &["q", "rho", "strategy", "mean_s", "ci95_s", "mean_violations"],
    );
    let mut master = Pcg64::new(parsed.u64("seed"));
    for q in parsed.f64_list("qs") {
    for rho in parsed.f64_list("rhos") {
        // One problem instance per rep, shared by both strategies: the
        // comparison must be paired (same data) to be meaningful.
        let problems: Vec<_> = (0..reps)
            .map(|rep| {
                let mut rng = master.derive((rep as u64) << 8 | (rho * 10.0) as u64);
                SyntheticSpec {
                    n,
                    p,
                    rho,
                    design: DesignKind::Compound,
                    beta: BetaSpec::Normal { k },
                    family: Family::Gaussian,
                    noise_sd: 1.0,
                    standardize: true,
                }
                .generate(&mut rng)
            })
            .collect();
        for strategy in [Strategy::StrongSet, Strategy::PreviousSet] {
            let mut times = Vec::new();
            let mut viols = Vec::new();
            for prob in &problems {
                let cfg = PathConfig::new(LambdaKind::Bh { q });
                let opts = PathOptions::new(cfg).with_strategy(strategy);
                let t = Instant::now();
                let fit = fit_path(prob, &opts, &NativeGradient(prob));
                times.push(t.elapsed().as_secs_f64());
                viols.push(fit.total_violations as f64);
            }
            let timing = slope_screen::benchkit::Timing::from_samples(times);
            println!(
                "q={q:<6} rho={rho:<4} {:<9} mean={:.3}s ±{:.3} (viol {:.1})",
                strategy.name(),
                timing.mean(),
                timing.ci95(),
                slope_screen::linalg::ops::mean(&viols)
            );
            table.row(vec![
                format!("{q}"),
                format!("{rho}"),
                strategy.name().to_string(),
                format!("{:.4}", timing.mean()),
                format!("{:.4}", timing.ci95()),
                format!("{:.2}", slope_screen::linalg::ops::mean(&viols)),
            ]);
        }
    }
    }
    table.print();
    let path = table.write_csv("fig6_algorithms").expect("csv");
    println!("\nwrote {}", path.display());
    println!("(paper: similar for rho <= 0.6; previous-set wins at high rho)");
}

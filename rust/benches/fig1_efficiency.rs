//! Figure 1: screened-set vs active-set size along the path for the
//! strong rule and the (gap-)safe rule, under compound-symmetric
//! correlation ρ ∈ {0, 0.2, 0.4, 0.6, 0.8}.
//!
//! Paper setup: OLS, n = 200, p = 5000, k = p/4, β ~ N(0,1), q = 0.005.
//! Run: `cargo bench --bench fig1_efficiency -- --scale 1`

use slope_screen::benchkit::Table;
use slope_screen::cli::Args;
use slope_screen::data::synth::{BetaSpec, DesignKind, SyntheticSpec};
use slope_screen::rng::Pcg64;
use slope_screen::slope::family::Family;
use slope_screen::slope::lambda::{LambdaKind, PathConfig};
use slope_screen::slope::path::{fit_path, NativeGradient, PathOptions};

fn main() {
    let parsed = Args::new("Figure 1: strong vs safe screening efficiency along the path")
        .opt("scale", "0.5", "problem scale (1 = paper: n=200, p=5000)")
        .opt("rhos", "0,0.2,0.4,0.6,0.8", "correlation grid")
        .opt("q", "0.005", "BH parameter")
        .opt("seed", "2020", "rng seed")
        .flag("bench", "(cargo bench compatibility)")
        .parse();
    let scale = parsed.f64("scale");
    let n = (200.0 * scale).round().max(20.0) as usize;
    let p = (5000.0 * scale).round().max(100.0) as usize;

    let mut table = Table::new(
        &format!("Figure 1 — screening efficiency (OLS, n={n}, p={p}, k=p/4)"),
        &["rho", "step", "sigma_ratio", "active", "strong", "safe"],
    );
    for rho in parsed.f64_list("rhos") {
        let spec = SyntheticSpec {
            n,
            p,
            rho,
            design: DesignKind::Compound,
            beta: BetaSpec::Normal { k: p / 4 },
            family: Family::Gaussian,
            noise_sd: 1.0,
            standardize: true,
        };
        let prob = spec.generate(&mut Pcg64::new(parsed.u64("seed")));
        let cfg = PathConfig::new(LambdaKind::Bh { q: parsed.f64("q") });
        let mut opts = PathOptions::new(cfg);
        opts.record_safe = true;
        let fit = fit_path(&prob, &opts, &NativeGradient(&prob));
        let smax = fit.sigmas[0];
        for (i, s) in fit.steps.iter().enumerate() {
            table.row(vec![
                format!("{rho}"),
                i.to_string(),
                format!("{:.4}", s.sigma / smax),
                s.n_active.to_string(),
                s.n_screened_rule.to_string(),
                s.n_safe.map(|v| v.to_string()).unwrap_or_default(),
            ]);
        }
        println!(
            "rho={rho}: {} steps, violations={}, max strong set={}",
            fit.steps.len(),
            fit.total_violations,
            fit.steps.iter().map(|s| s.n_screened_rule).max().unwrap_or(0)
        );
    }
    table.print();
    let path = table.write_csv("fig1_efficiency").expect("csv");
    println!("\nwrote {}", path.display());
}

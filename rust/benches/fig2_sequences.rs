//! Figure 2: screened-set vs active-set size for the three penalty
//! sequence shapes (BH, OSCAR, lasso) across correlation levels.
//!
//! Paper setup: OLS, n = 200, p = 10000, k = 10, β ∈ {−2, 2},
//! q = n/(10p), ρ ∈ {0, 0.4, 0.8}.
//! Run: `cargo bench --bench fig2_sequences -- --scale 1`

use slope_screen::benchkit::Table;
use slope_screen::cli::Args;
use slope_screen::data::synth::{BetaSpec, DesignKind, SyntheticSpec};
use slope_screen::rng::Pcg64;
use slope_screen::slope::family::Family;
use slope_screen::slope::lambda::{LambdaKind, PathConfig};
use slope_screen::slope::path::{fit_path, NativeGradient, PathOptions};

fn main() {
    let parsed = Args::new("Figure 2: screening efficiency per penalty sequence")
        .opt("scale", "1", "problem scale (1 = paper: n=200, p=10000)")
        .opt("rhos", "0,0.4,0.8", "correlation grid")
        .opt("seed", "2021", "rng seed")
        .flag("bench", "(cargo bench compatibility)")
        .parse();
    let scale = parsed.f64("scale");
    let n = (200.0 * scale).round().max(20.0) as usize;
    let p = (10_000.0 * scale).round().max(100.0) as usize;
    let q = n as f64 / (10.0 * p as f64);

    let mut table = Table::new(
        &format!("Figure 2 — screened vs active per sequence (OLS, n={n}, p={p}, k=10)"),
        &["sequence", "rho", "step", "active", "screened"],
    );
    for rho in parsed.f64_list("rhos") {
        let spec = SyntheticSpec {
            n,
            p,
            rho,
            design: DesignKind::Compound,
            beta: BetaSpec::PlusMinus { k: 10, scale: 2.0 },
            family: Family::Gaussian,
            noise_sd: 1.0,
            standardize: true,
        };
        let prob = spec.generate(&mut Pcg64::new(parsed.u64("seed")));
        for kind in [
            LambdaKind::Bh { q },
            LambdaKind::Oscar { q },
            LambdaKind::Lasso,
        ] {
            let cfg = PathConfig::new(kind);
            let opts = PathOptions::new(cfg);
            let fit = fit_path(&prob, &opts, &NativeGradient(&prob));
            for (i, s) in fit.steps.iter().enumerate() {
                table.row(vec![
                    kind.name().to_string(),
                    format!("{rho}"),
                    i.to_string(),
                    s.n_active.to_string(),
                    s.n_screened_rule.to_string(),
                ]);
            }
            let eff = slope_screen::slope::path::mean_efficiency(&fit);
            println!(
                "rho={rho} seq={:<6}: {} steps, mean screened/active = {eff:.2}, violations={}",
                kind.name(),
                fit.steps.len(),
                fit.total_violations
            );
        }
    }
    table.print();
    let path = table.write_csv("fig2_sequences").expect("csv");
    println!("\nwrote {}", path.display());
}

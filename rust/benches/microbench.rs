//! Microbenchmarks of the hot kernels (the §Perf iteration log in
//! EXPERIMENTS.md is built on these): sorted-ℓ1 prox, gemv/gemv_t,
//! Algorithm 2, the KKT flagger, the packed vs gather reduced-design
//! kernels, CV fold extraction (fresh vs pooled scratch), and the
//! full-gradient engines (native vs XLA artifact).
//!
//! Run: `cargo bench --bench microbench`

use slope_screen::benchkit::{fmt_secs, Table, Timing};
use slope_screen::cli::Args;
use slope_screen::data::synth::{BetaSpec, DesignKind, SyntheticSpec};
use slope_screen::linalg::ops::abs_sorted_desc;
use slope_screen::linalg::{PackedDesign, ParConfig};
use slope_screen::rng::Pcg64;
use slope_screen::runtime::{default_artifact_dir, ArtifactGradient, Manifest};
use slope_screen::slope::family::Family;
use slope_screen::slope::lambda::bh_sequence;
use slope_screen::slope::path::FullGradient;
use slope_screen::slope::prox::{prox_sorted_l1, prox_sorted_l1_into, ProxWorkspace};
use slope_screen::slope::screen::{
    algorithm2_k, strong_set_resort_reference, strong_set_with, StrongWorkspace,
};

fn main() {
    let parsed = Args::new("microbenchmarks of the hot kernels")
        .opt("p", "20000", "vector dimension")
        .opt("n", "200", "rows for gemv/gradient")
        .opt("reps", "50", "timed repetitions")
        .flag("bench", "(cargo bench compatibility)")
        .parse();
    let p = parsed.usize("p");
    let n = parsed.usize("n");
    let reps = parsed.usize("reps");
    let mut rng = Pcg64::new(0xbead);

    let mut table = Table::new("microbench", &["kernel", "dim", "median", "per_elem_ns"]);
    let mut record = |name: &str, dim: usize, t: &Timing| {
        println!("{name:<24} {:>10}  median {}", dim, fmt_secs(t.median()));
        table.row(vec![
            name.to_string(),
            dim.to_string(),
            format!("{:.6}", t.median()),
            format!("{:.2}", t.median() * 1e9 / dim as f64),
        ]);
    };

    // prox
    let v: Vec<f64> = (0..p).map(|_| rng.normal() * 2.0).collect();
    let lam = bh_sequence(p, 0.05);
    let mut out = vec![0.0; p];
    let mut ws = ProxWorkspace::new(p);
    let t = Timing::measure(3, reps, || {
        prox_sorted_l1_into(&v, &lam, &mut ws, &mut out);
        std::hint::black_box(&out);
    });
    record("prox_sorted_l1", p, &t);

    // the FISTA hot-loop prox, alloc-free (persistent workspace: the
    // pair sort runs in workspace buffers) vs the allocating entry point
    // it replaced (fresh order/pair vectors per call — the old per-
    // iteration cost)
    let t = Timing::measure(3, reps, || {
        prox_sorted_l1_into(&v, &lam, &mut ws, &mut out);
        std::hint::black_box(&out);
    });
    record("fista iter alloc-free", p, &t);
    let t = Timing::measure(3, reps, || {
        std::hint::black_box(prox_sorted_l1(&v, &lam));
    });
    record("fista iter alloc-ref", p, &t);

    // algorithm 2
    let c = abs_sorted_desc(&v);
    let t = Timing::measure(3, reps, || {
        std::hint::black_box(algorithm2_k(&c, &lam));
    });
    record("algorithm2_k", p, &t);

    // sort (the p log p part of screening)
    let t = Timing::measure(3, reps, || {
        std::hint::black_box(abs_sorted_desc(&v));
    });
    record("sort_desc_abs", p, &t);

    // the strong rule itself: fused single-workspace ordering vs the
    // allocate-and-re-sort implementation it replaced (σ-scaled penalty
    // pair, the path driver's case — the fused form skips the second
    // sort entirely there)
    let lam_prev: Vec<f64> = lam.iter().map(|l| l * 0.9).collect();
    let lam_next: Vec<f64> = lam.iter().map(|l| l * 0.8).collect();
    let mut sws = StrongWorkspace::default();
    let t = Timing::measure(3, reps, || {
        std::hint::black_box(strong_set_with(&v, &lam_prev, &lam_next, &mut sws));
    });
    record("strong_set fused", p, &t);
    assert_eq!(
        strong_set_with(&v, &lam_prev, &lam_next, &mut sws),
        strong_set_resort_reference(&v, &lam_prev, &lam_next),
        "fused strong set must match the reference it replaced"
    );
    let t = Timing::measure(3, reps, || {
        std::hint::black_box(strong_set_resort_reference(&v, &lam_prev, &lam_next));
    });
    record("strong_set resort-ref", p, &t);

    // gemv / gemv_t on a dense design
    let prob = SyntheticSpec {
        n,
        p,
        rho: 0.0,
        design: DesignKind::Iid,
        beta: BetaSpec::PlusMinus { k: 10, scale: 1.0 },
        family: Family::Gaussian,
        noise_sd: 1.0,
        standardize: true,
    }
    .generate(&mut Pcg64::new(1));
    let beta: Vec<f64> = (0..p).map(|_| rng.normal()).collect();
    let mut eta = vec![0.0; n];
    let t = Timing::measure(3, reps, || {
        prob.x.gemv(&beta, &mut eta);
        std::hint::black_box(&eta);
    });
    record("gemv (X*b)", n * p, &t);

    let h: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let mut grad = vec![0.0; p];
    let t = Timing::measure(3, reps, || {
        prob.x.gemv_t(&h, &mut grad);
        std::hint::black_box(&grad);
    });
    record("gemv_t (X'h)", n * p, &t);

    // the same kernels through the threaded backend (machine budget)
    let par = ParConfig::with_threads(0);
    let t = Timing::measure(3, reps, || {
        prob.x.gemv_with(&beta, &mut eta, par);
        std::hint::black_box(&eta);
    });
    record("gemv parallel", n * p, &t);
    let t = Timing::measure(3, reps, || {
        prob.x.gemv_t_with(&h, &mut grad, par);
        std::hint::black_box(&grad);
    });
    record("gemv_t parallel", n * p, &t);

    // reduced-design engines on a screened subset (|E| ≈ p/40, the
    // screened-path regime): gather-indexed subset kernels vs the packed
    // contiguous slab, plus the one-off cost of materializing the slab
    let n_sub = (p / 40).max(4).min(p);
    let stride = (p / n_sub).max(1);
    let cols: Vec<usize> = (0..p).step_by(stride).take(n_sub).collect();
    let vsub: Vec<f64> = cols.iter().map(|&j| beta[j]).collect();
    let mut gsub = vec![0.0; cols.len()];
    let t = Timing::measure(3, reps, || {
        std::hint::black_box(PackedDesign::pack(&prob.x, &cols, ParConfig::serial()));
    });
    record("pack materialize", n * cols.len(), &t);
    let pack = PackedDesign::pack(&prob.x, &cols, ParConfig::serial());
    let t = Timing::measure(3, reps, || {
        prob.x.gemv_subset(&cols, &vsub, &mut eta);
        std::hint::black_box(&eta);
    });
    record("gemv gather-subset", n * cols.len(), &t);
    let t = Timing::measure(3, reps, || {
        pack.gemv(&vsub, &mut eta);
        std::hint::black_box(&eta);
    });
    record("gemv packed", n * cols.len(), &t);
    let t = Timing::measure(3, reps, || {
        prob.x.gemv_t_subset(&cols, &h, &mut gsub);
        std::hint::black_box(&gsub);
    });
    record("gemv_t gather-subset", n * cols.len(), &t);
    let t = Timing::measure(3, reps, || {
        pack.gemv_t(&h, &mut gsub);
        std::hint::black_box(&gsub);
    });
    record("gemv_t packed", n * cols.len(), &t);

    // CV fold extraction: fresh allocation per fold vs the pooled
    // scratch buffer route (coordinator::cv's FoldScratch)
    if let Some(x) = prob.x.as_dense() {
        let rows: Vec<usize> = (0..n).filter(|i| i % 5 != 0).collect(); // a 5-fold train split
        let t = Timing::measure(3, reps, || {
            std::hint::black_box(x.subset_rows(&rows));
        });
        record("subset_rows fresh", rows.len() * p, &t);
        let mut fold_buf: Vec<f64> = Vec::new();
        let t = Timing::measure(3, reps, || {
            x.subset_rows_into(&rows, &mut fold_buf);
            std::hint::black_box(&fold_buf);
        });
        record("subset_rows scratch", rows.len() * p, &t);
    }

    // gradient engines, when artifacts cover the shape
    if let Ok(manifest) = Manifest::load(&default_artifact_dir()) {
        let small = SyntheticSpec {
            n: 100,
            p: 400,
            rho: 0.0,
            design: DesignKind::Iid,
            beta: BetaSpec::PlusMinus { k: 10, scale: 1.0 },
            family: Family::Gaussian,
            noise_sd: 1.0,
            standardize: true,
        }
        .generate(&mut Pcg64::new(2));
        if let Ok(xla) = ArtifactGradient::new(&manifest, &small) {
            let beta: Vec<f64> = (0..small.p()).map(|_| rng.normal()).collect();
            let mut eta = vec![0.0; small.n()];
            small.eta(&beta, &mut eta);
            let mut h = vec![0.0; small.n()];
            small.family.h_loss(&eta, &small.y, &mut h);
            let mut g = vec![0.0; small.p()];
            let t = Timing::measure(3, reps, || {
                small.gradient_from_h(&h, &mut g);
                std::hint::black_box(&g);
            });
            record("full_grad native", small.n() * small.p(), &t);
            let t = Timing::measure(3, reps.min(20), || {
                xla.full_grad(&beta, &h, &mut g);
                std::hint::black_box(&g);
            });
            record("full_grad xla-artifact", small.n() * small.p(), &t);
        }
    }

    table.print();
    table.write_csv("microbench").expect("csv");
}

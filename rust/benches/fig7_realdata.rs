//! Figure 7 + Table 2: screening efficiency and violations on the four
//! real-data stand-ins (arcene, dorothea, gisette, golub), each fit with
//! sorted-ℓ1 penalized OLS *and* logistic regression.
//!
//! Table 2 reports the mean screened-set and active-set sizes over the
//! path; Figure 7 the per-step proportion screened/active.
//! Run: `cargo bench --bench fig7_realdata -- --datasets golub,arcene`

use slope_screen::benchkit::Table;
use slope_screen::cli::Args;
use slope_screen::data::real::RealDataset;
use slope_screen::slope::family::Family;
use slope_screen::slope::lambda::{LambdaKind, PathConfig};
use slope_screen::slope::path::{fit_path, NativeGradient, PathOptions};

fn main() {
    let parsed = Args::new("Figure 7 / Table 2: efficiency on real-data stand-ins")
        .opt(
            "datasets",
            "golub,arcene,dorothea",
            "datasets (gisette, 6000x4955 dense, is opt-in: its saturated OLS path takes tens of minutes)",
        )
        .opt("q", "0.01", "BH parameter")
        .flag("bench", "(cargo bench compatibility)")
        .parse();

    let mut fig = Table::new(
        "Figure 7 — screened/active proportion along the path",
        &["dataset", "model", "step", "active", "screened"],
    );
    let mut tab2 = Table::new(
        "Table 2 — mean screened and active set sizes",
        &["dataset", "n", "p", "model", "screened", "active", "violations"],
    );

    for name in parsed.get("datasets").split(',') {
        let ds = RealDataset::all()
            .into_iter()
            .find(|d| d.name() == name)
            .unwrap_or_else(|| panic!("unknown dataset {name}"));
        for family in [Family::Gaussian, Family::Binomial] {
            let prob = ds.load_with(family, 0x7ab2e + ds.dims().1 as u64);
            let cfg = PathConfig::new(LambdaKind::Bh { q: parsed.f64("q") });
            let opts = PathOptions::new(cfg);
            let fit = fit_path(&prob, &opts, &NativeGradient(&prob));
            let mut s_sum = 0.0;
            let mut a_sum = 0.0;
            let steps = fit.steps.len().saturating_sub(1).max(1) as f64;
            for (i, s) in fit.steps.iter().enumerate() {
                if i > 0 {
                    s_sum += s.n_screened_rule as f64;
                    a_sum += s.n_active as f64;
                }
                fig.row(vec![
                    ds.name().to_string(),
                    family.name().to_string(),
                    i.to_string(),
                    s.n_active.to_string(),
                    s.n_screened_rule.to_string(),
                ]);
            }
            tab2.row(vec![
                ds.name().to_string(),
                prob.n().to_string(),
                prob.p().to_string(),
                family.name().to_string(),
                format!("{:.1}", s_sum / steps),
                format!("{:.2}", a_sum / steps),
                fit.total_violations.to_string(),
            ]);
            println!(
                "{:<9} {:<9} {} steps, mean screened {:.1}, mean active {:.2}, violations {}",
                ds.name(),
                family.name(),
                fit.steps.len(),
                s_sum / steps,
                a_sum / steps,
                fit.total_violations
            );
        }
    }
    fig.print();
    tab2.print();
    fig.write_csv("fig7_realdata").expect("csv");
    tab2.write_csv("table2_realdata").expect("csv");
    println!("\n(paper Table 2: screened/active ratios of roughly 1.5-4x; no violations)");
}

//! Table 3: wall-clock time fitting the four Table-3 datasets with their
//! canonical families, with and without the strong screening rule.
//!
//! Paper rows: cpusmall/OLS (8192×12), golub/logistic (38×7129),
//! physician/poisson (4406×25), zipcode/multinomial (200×256, 10 cls).
//! The claim: big wins when p ≫ n, no noticeable drawback when n ≫ p.
//!
//! `--datasets` entries are [`DataSource`] specs, so file-backed data
//! runs through the same harness as the stand-ins:
//!
//!   cargo bench --bench tab3_realdata_perf -- \
//!     --datasets cpusmall,file:/tmp/standins/golub.csv@binomial
//!
//! (`slope-screen export --dataset golub --out /tmp/standins` writes the
//! file; see EXPERIMENTS.md §"Reproducing Table 3 from files".)
//!
//! Run: `cargo bench --bench tab3_realdata_perf`

use std::time::Instant;

use slope_screen::benchkit::{fmt_secs, Table};
use slope_screen::cli::Args;
use slope_screen::coordinator::DataSource;
use slope_screen::slope::lambda::{LambdaKind, PathConfig};
use slope_screen::slope::path::{fit_path, NativeGradient, PathOptions, Strategy};

fn main() {
    let parsed = Args::new("Table 3: real-data wall time with/without screening")
        .opt(
            "datasets",
            "cpusmall,golub,physician,zipcode",
            "stand-in names and/or file:PATH[@family[:classes]] specs",
        )
        .opt("q", "0.05", "BH parameter")
        .flag("bench", "(cargo bench compatibility)")
        .parse();

    let mut tab = Table::new(
        "Table 3 — wall-clock seconds per path fit",
        &["dataset", "model", "n", "p", "no_screening_s", "screening_s", "speedup"],
    );
    for spec in parsed.get("datasets").split(',') {
        let src = DataSource::parse(spec).unwrap_or_else(|e| panic!("--datasets: {e}"));
        let prob = src.load().unwrap_or_else(|e| panic!("--datasets {spec}: {e}"));
        let name = src.name();
        let cfg = PathConfig::new(LambdaKind::Bh { q: parsed.f64("q") });
        let mut secs = [0.0f64; 2];
        for (i, strategy) in [Strategy::NoScreening, Strategy::StrongSet].iter().enumerate() {
            let opts = PathOptions::new(cfg.clone()).with_strategy(*strategy);
            let t = Instant::now();
            let fit = fit_path(&prob, &opts, &NativeGradient(&prob));
            secs[i] = t.elapsed().as_secs_f64();
            println!(
                "{:<10} {:<12} {:<9} {} ({} steps, viol={})",
                name,
                prob.family.name(),
                strategy.name(),
                fmt_secs(secs[i]),
                fit.steps.len(),
                fit.total_violations
            );
        }
        tab.row(vec![
            name,
            prob.family.name().to_string(),
            prob.n().to_string(),
            prob.p().to_string(),
            format!("{:.3}", secs[0]),
            format!("{:.3}", secs[1]),
            format!("{:.1}", secs[0] / secs[1]),
        ]);
    }
    tab.print();
    let path = tab.write_csv("table3_realdata_perf").expect("csv");
    println!("\nwrote {}", path.display());
    println!("(paper Table 3: golub 10.24s -> 0.357s; cpusmall/physician ~unchanged)");
}

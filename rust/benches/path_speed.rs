//! Path-level wall-clock benchmark of the parallel compute backend:
//! full-path Gaussian fits, serial vs threaded `linalg::par` kernels,
//! cold vs warm-started, across p ∈ {1k, 10k, 100k} at n = 200 (the
//! paper's p ≫ n regime, where the post-solve `Xᵀr` KKT sweep dominates).
//!
//! Correctness is asserted, not assumed: serial and parallel fits must
//! produce identical violation counts and coefficients to 1e-10 (the
//! dense parallel kernels are in fact bitwise-deterministic), and the
//! full run gates on a ≥ 2× parallel speedup at the largest size when at
//! least 4 threads are available.
//!
//! Writes `results/path_speed.csv` and the machine-readable
//! `BENCH_path.json` at the repository root — the perf trajectory of the
//! hot path is tracked from this file.
//!
//! Run:   `cargo bench --bench path_speed`
//! Smoke: `cargo bench --bench path_speed -- --smoke` (bounded sizes,
//!        no speedup gate — the CI job that keeps this harness alive).


use slope_screen::benchkit::{fmt_secs, Table};
use slope_screen::cli::Args;
use slope_screen::data::synth::{BetaSpec, DesignKind, SyntheticSpec};
use slope_screen::jsonio::Json;
use slope_screen::linalg::par;
use slope_screen::rng::Pcg64;
use slope_screen::slope::family::{Family, Problem};
use slope_screen::slope::lambda::{LambdaKind, PathConfig};
use slope_screen::slope::path::{
    fit_path, fit_path_seeded, NativeGradient, PathFit, PathOptions, Strategy,
};

struct Run {
    p: usize,
    backend: &'static str,
    start: &'static str,
    threads: usize,
    wall_s: f64,
    steps: usize,
    violations: usize,
}

fn make_problem(n: usize, p: usize, k: usize, rho: f64, seed: u64) -> Problem {
    SyntheticSpec {
        n,
        p,
        rho,
        design: DesignKind::Compound,
        beta: BetaSpec::PlusMinus { k, scale: 2.0 },
        family: Family::Gaussian,
        noise_sd: 1.0,
        standardize: true,
    }
    .generate(&mut Pcg64::new(seed))
}

fn opts(q: f64, length: usize, threads: usize) -> PathOptions {
    let mut cfg = PathConfig::new(LambdaKind::Bh { q });
    cfg.length = length;
    PathOptions::new(cfg)
        .with_strategy(Strategy::StrongSet)
        .with_threads(threads)
}

/// Serial and parallel fits of the same problem must be interchangeable:
/// same grid, same violation counts, coefficients equal to `tol`.
fn assert_identical(serial: &PathFit, parallel: &PathFit, p: usize, tol: f64) {
    assert_eq!(
        serial.steps.len(),
        parallel.steps.len(),
        "p={p}: step counts diverged"
    );
    assert_eq!(
        serial.total_violations, parallel.total_violations,
        "p={p}: violation counts diverged"
    );
    for (m, (a, b)) in serial.steps.iter().zip(&parallel.steps).enumerate() {
        assert_eq!(
            a.violations, b.violations,
            "p={p} step {m}: per-step violations diverged"
        );
    }
    let mut max_dev = 0.0f64;
    for (a, b) in serial.final_beta.iter().zip(&parallel.final_beta) {
        max_dev = max_dev.max((a - b).abs());
    }
    assert!(
        max_dev <= tol,
        "p={p}: coefficients diverged by {max_dev:e} (> {tol:e})"
    );
}

fn main() {
    let parsed = Args::new("path-level benchmark: serial vs parallel compute backend")
        .opt("n", "200", "observations")
        .opt("ps", "1000,10000,100000", "predictor grid")
        .opt("k", "20", "true support size")
        .opt("rho", "0.1", "pairwise correlation")
        .opt("q", "0.1", "BH parameter")
        .opt("path-length", "50", "path points")
        .opt("threads", "0", "parallel-backend threads (0 = all cores)")
        .opt("seed", "2020", "dataset seed")
        .flag("smoke", "bounded sizes for CI; skips the speedup gate")
        .flag("bench", "(cargo bench compatibility)")
        .parse();
    let smoke = parsed.bool("smoke");
    let n = parsed.usize("n");
    let ps: Vec<usize> = if smoke { vec![500, 2000] } else { parsed.usize_list("ps") };
    let k = parsed.usize("k");
    let rho = parsed.f64("rho");
    let q = parsed.f64("q");
    let path_length = if smoke { 15 } else { parsed.usize("path-length") };
    let threads = {
        let t = parsed.usize("threads");
        if t == 0 {
            par::global_threads()
        } else {
            t
        }
    };
    let seed = parsed.u64("seed");

    println!(
        "path_speed: n={n}, p in {ps:?}, path-length={path_length}, parallel backend = {threads} threads{}",
        if smoke { " [smoke]" } else { "" }
    );

    let mut runs: Vec<Run> = Vec::new();
    for (pi, &p) in ps.iter().enumerate() {
        let prob = make_problem(n, p, k.min(p / 2).max(1), rho, seed + pi as u64);
        let o_serial = opts(q, path_length, 1);
        let o_par = opts(q, path_length, threads);
        let ng = NativeGradient(&prob);

        let cold_serial = fit_path(&prob, &o_serial, &ng);
        let cold_par = fit_path(&prob, &o_par, &ng);
        assert_identical(&cold_serial, &cold_par, p, 1e-10);

        let warm_serial = fit_path_seeded(&prob, &o_serial, &ng, Some(&cold_serial.seed()));
        let warm_par = fit_path_seeded(&prob, &o_par, &ng, Some(&cold_par.seed()));
        assert_identical(&warm_serial, &warm_par, p, 1e-10);

        for (fit, backend, start, t) in [
            (&cold_serial, "serial", "cold", 1),
            (&cold_par, "parallel", "cold", threads),
            (&warm_serial, "serial", "warm", 1),
            (&warm_par, "parallel", "warm", threads),
        ] {
            println!(
                "  p={p:<7} {backend:<8} {start}  {}  ({} steps, {} violations)",
                fmt_secs(fit.wall_time),
                fit.steps.len(),
                fit.total_violations
            );
            runs.push(Run {
                p,
                backend,
                start,
                threads: t,
                wall_s: fit.wall_time,
                steps: fit.steps.len(),
                violations: fit.total_violations,
            });
        }
    }

    let mut table = Table::new(
        &format!("path_speed (gaussian, n={n}, strong set, {threads}-thread backend)"),
        &["p", "backend", "start", "threads", "wall_s", "steps", "violations"],
    );
    for r in &runs {
        table.row(vec![
            r.p.to_string(),
            r.backend.to_string(),
            r.start.to_string(),
            r.threads.to_string(),
            format!("{:.4}", r.wall_s),
            r.steps.to_string(),
            r.violations.to_string(),
        ]);
    }
    table.print();
    let csv = table.write_csv("path_speed").expect("csv");
    println!("\nwrote {}", csv.display());

    let find = |p: usize, backend: &str, start: &str| {
        runs.iter()
            .find(|r| r.p == p && r.backend == backend && r.start == start)
            .expect("run")
    };
    let p_max = *ps.iter().max().expect("non-empty p grid");
    let cold_speedup = find(p_max, "serial", "cold").wall_s
        / find(p_max, "parallel", "cold").wall_s.max(1e-12);
    let warm_speedup = find(p_max, "serial", "warm").wall_s
        / find(p_max, "parallel", "warm").wall_s.max(1e-12);
    println!(
        "speedup at p={p_max}: cold {cold_speedup:.2}x, warm {warm_speedup:.2}x ({threads} threads)"
    );
    // The acceptance gate: ≥ 2× on the full-path fit at the largest size
    // whenever ≥ 4 threads back the parallel runs. Smoke runs (CI) keep
    // the correctness asserts but skip the timing gate — shared runners
    // make wall-clock guarantees meaningless there.
    if !smoke && threads >= 4 {
        assert!(
            cold_speedup >= 2.0,
            "parallel backend must be >= 2x at p={p_max} on {threads} threads, got {cold_speedup:.2}x"
        );
    }

    let payload = Json::obj(vec![
        ("bench", Json::Str("path_speed".to_string())),
        (
            "config",
            Json::obj(vec![
                ("n", Json::Num(n as f64)),
                ("ps", Json::Arr(ps.iter().map(|&p| Json::Num(p as f64)).collect())),
                ("k", Json::Num(k as f64)),
                ("rho", Json::Num(rho)),
                ("q", Json::Num(q)),
                ("path_length", Json::Num(path_length as f64)),
                ("threads", Json::Num(threads as f64)),
                ("smoke", Json::Bool(smoke)),
            ]),
        ),
        (
            "runs",
            Json::Arr(
                runs.iter()
                    .map(|r| {
                        Json::obj(vec![
                            ("p", Json::Num(r.p as f64)),
                            ("backend", Json::Str(r.backend.to_string())),
                            ("start", Json::Str(r.start.to_string())),
                            ("threads", Json::Num(r.threads as f64)),
                            ("wall_s", Json::Num(r.wall_s)),
                            ("steps", Json::Num(r.steps as f64)),
                            ("violations", Json::Num(r.violations as f64)),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "speedup",
            Json::obj(vec![
                ("p", Json::Num(p_max as f64)),
                ("cold_parallel_over_serial", Json::Num(cold_speedup)),
                ("warm_parallel_over_serial", Json::Num(warm_speedup)),
            ]),
        ),
        ("table", table.to_json()),
    ]);
    let out_path =
        slope_screen::benchkit::write_bench_json("path", &payload).expect("BENCH_path.json");
    println!("wrote {}", out_path.display());
}

//! Path-level wall-clock benchmark of the hot-path engines: full-path
//! Gaussian fits across p ∈ {1k, 10k, 100k} at n = 200 (the paper's
//! p ≫ n regime), on three axes:
//!
//! * **backend** — serial vs threaded `linalg::par` kernels;
//! * **engine** — `gather` (subset kernels chasing a column list through
//!   the full design) vs `packed` (screened columns materialized into a
//!   contiguous slab per step, with a per-problem `PackCache` so warm
//!   re-fits adopt the cold fit's slabs — the serve registry's case). At
//!   p = 100k the late-path screened sets reach the hundreds, the regime
//!   the packed engine targets.
//! * **screen** — the `strong` KKT-safeguarded baseline vs the `hybrid`
//!   duality-gap strategy (safe universe + gap certificates, DESIGN.md
//!   §10), which replaces most full-p gradient sweeps with partial
//!   universe sweeps.
//!
//! Sweep work is read from the `obs::registry` counters
//! (`grad_full_sweeps` / `grad_partial_sweeps` / `grad_sweep_cols`,
//! differenced around each fit), not hand-threaded through the solver's
//! return value — and each cell asserts the registry agrees with the
//! solver's own `PathFit::total_grad_sweeps` bookkeeping, so the two
//! accounting paths check each other. Pack-cache hit/miss deltas ride
//! along per cell, and the bench gates on the observability contract
//! itself: with tracing off, a span is a single relaxed load, and a
//! million disabled spans must cost nanoseconds each.
//!
//! Correctness is asserted, not assumed: across backends *and* engines,
//! fits must produce identical violation counts and coefficients to
//! 1e-10 (the dense kernels of both engines are bitwise-deterministic
//! and order-matched, so the real difference is zero); hybrid fits must
//! match the strong baseline exactly on violations and to 1e-9 on
//! coefficients (1e-6 in smoke runs — the stopping rules differ, so the
//! contract is certificate-level, not bitwise). The full run gates on
//! ≥ 2× parallel-over-serial (cold), ≥ 1.3× packed-over-gather (warm,
//! parallel), and ≥ 30% fewer full-gradient sweeps for hybrid vs strong
//! (warm, parallel) at the largest size when at least 4 threads are
//! available.
//!
//! Writes `results/path_speed.csv` and the machine-readable
//! `BENCH_path.json` at the repository root — the perf trajectory of the
//! hot path is tracked from this file.
//!
//! Run:      `cargo bench --bench path_speed`
//! Smoke:    `cargo bench --bench path_speed -- --smoke` (bounded sizes,
//!           no speedup/sweep gates — the CI job that keeps this harness
//!           alive).
//! Gather:   `cargo bench --bench path_speed -- --no-pack` (gather engine
//!           only; CI smokes this too so both code paths stay exercised).
//! Policy:   `cargo bench --bench path_speed -- --screen hybrid` (one
//!           screening policy only; default `both` runs the comparison).

use std::sync::Arc;
use std::time::Instant;

use slope_screen::benchkit::{fmt_secs, Table};
use slope_screen::cli::Args;
use slope_screen::data::synth::{BetaSpec, DesignKind, SyntheticSpec};
use slope_screen::jsonio::Json;
use slope_screen::linalg::par;
use slope_screen::linalg::PackCache;
use slope_screen::obs::registry as obsreg;
use slope_screen::obs::trace;
use slope_screen::rng::Pcg64;
use slope_screen::slope::cancel::CancelToken;
use slope_screen::slope::family::{Family, Problem};
use slope_screen::slope::lambda::{LambdaKind, PathConfig};
use slope_screen::slope::path::{
    fit_path, fit_path_checkpointed, fit_path_seeded, CheckpointConfig, NativeGradient, PathFit,
    PathOptions, Strategy,
};

/// Registry counters a bench cell cares about, captured as deltas around
/// each fit (the cells are process-global; this harness is sequential, so
/// before/after differencing attributes counts exactly).
#[derive(Clone, Copy, Default)]
struct Obs {
    full_sweeps: u64,
    partial_sweeps: u64,
    sweep_cols: u64,
    pack_hits: u64,
    pack_misses: u64,
}

impl Obs {
    fn mark() -> Obs {
        Obs {
            full_sweeps: obsreg::GRAD_FULL_SWEEPS.get(),
            partial_sweeps: obsreg::GRAD_PARTIAL_SWEEPS.get(),
            sweep_cols: obsreg::GRAD_SWEEP_COLS.get(),
            pack_hits: obsreg::PACK_CACHE_HITS.get(),
            pack_misses: obsreg::PACK_CACHE_MISSES.get(),
        }
    }

    fn since(before: Obs) -> Obs {
        let now = Obs::mark();
        Obs {
            full_sweeps: now.full_sweeps - before.full_sweeps,
            partial_sweeps: now.partial_sweeps - before.partial_sweeps,
            sweep_cols: now.sweep_cols - before.sweep_cols,
            pack_hits: now.pack_hits - before.pack_hits,
            pack_misses: now.pack_misses - before.pack_misses,
        }
    }

    /// Sweep work in p-equivalents: a full sweep touches p columns, a
    /// partial sweep its universe — `grad_sweep_cols / p` is the same
    /// quantity `PathFit::total_grad_sweeps` accumulates term by term.
    fn sweep_p_equiv(&self, p: usize) -> f64 {
        self.sweep_cols as f64 / p.max(1) as f64
    }
}

/// Run `f`, capture the registry deltas it produced, and assert the
/// registry's sweep accounting matches the solver's own — the counters
/// are the source of truth for the report, the solver field the
/// cross-check.
fn with_obs<F: FnOnce() -> PathFit>(p: usize, what: &str, f: F) -> (PathFit, Obs) {
    let before = Obs::mark();
    let fit = f();
    let obs = Obs::since(before);
    let reg = obs.sweep_p_equiv(p);
    assert!(
        (reg - fit.total_grad_sweeps).abs() <= 1e-6 * fit.total_grad_sweeps.max(1.0),
        "{what}: registry sweep columns ({reg:.6} p-equivalents) disagree with \
         PathFit::total_grad_sweeps ({:.6})",
        fit.total_grad_sweeps
    );
    (fit, obs)
}

/// The observability overhead contract: with tracing off, `span()` is one
/// relaxed atomic load returning an inert guard. A million disabled spans
/// (with a field write each) must be unmeasurable next to any fit — the
/// bound is three orders of magnitude above the real cost so it never
/// flakes on loaded runners, while still catching an accidental
/// allocation or lock on the disabled path.
fn assert_disabled_tracing_is_free() -> f64 {
    assert!(trace::disabled(), "bench must run with tracing off");
    const REPS: u64 = 1_000_000;
    let t = Instant::now();
    for i in 0..REPS {
        let mut s = trace::span(std::hint::black_box("bench_noop"));
        s.u("i", std::hint::black_box(i));
    }
    let per_ns = t.elapsed().as_secs_f64() * 1e9 / REPS as f64;
    println!("disabled-span overhead: {per_ns:.1} ns/span over {REPS} spans");
    assert!(
        per_ns < 1000.0,
        "disabled span cost {per_ns:.0} ns — the tracing-off path must stay free"
    );
    per_ns
}

struct Run {
    p: usize,
    engine: &'static str,
    backend: &'static str,
    start: &'static str,
    screen: &'static str,
    threads: usize,
    wall_s: f64,
    steps: usize,
    violations: usize,
    /// Registry-derived sweep work in p-equivalents
    /// (`grad_sweep_cols / p`; asserted equal to the solver's count).
    full_grad_sweeps: f64,
    full_sweeps: u64,
    partial_sweeps: u64,
    pack_hits: u64,
    pack_misses: u64,
}

fn make_problem(n: usize, p: usize, k: usize, rho: f64, seed: u64) -> Problem {
    SyntheticSpec {
        n,
        p,
        rho,
        design: DesignKind::Compound,
        beta: BetaSpec::PlusMinus { k, scale: 2.0 },
        family: Family::Gaussian,
        noise_sd: 1.0,
        standardize: true,
    }
    .generate(&mut Pcg64::new(seed))
}

fn opts(
    q: f64,
    length: usize,
    threads: usize,
    packing: bool,
    strategy: Strategy,
) -> PathOptions {
    let mut cfg = PathConfig::new(LambdaKind::Bh { q });
    cfg.length = length;
    PathOptions::new(cfg)
        .with_strategy(strategy)
        .with_threads(threads)
        .with_packing(packing)
}

/// Any two fits of the same problem in this matrix must be
/// interchangeable: same grid, same violation counts, coefficients equal
/// to `tol`.
fn assert_identical(a: &PathFit, b: &PathFit, what: &str, tol: f64) {
    assert_eq!(a.steps.len(), b.steps.len(), "{what}: step counts diverged");
    assert_eq!(
        a.total_violations, b.total_violations,
        "{what}: violation counts diverged"
    );
    for (m, (sa, sb)) in a.steps.iter().zip(&b.steps).enumerate() {
        assert_eq!(
            sa.violations, sb.violations,
            "{what} step {m}: per-step violations diverged"
        );
    }
    let mut max_dev = 0.0f64;
    for (x, y) in a.final_beta.iter().zip(&b.final_beta) {
        max_dev = max_dev.max((x - y).abs());
    }
    assert!(
        max_dev <= tol,
        "{what}: coefficients diverged by {max_dev:e} (> {tol:e})"
    );
}

fn main() {
    let parsed = Args::new("path-level benchmark: serial vs parallel, packed vs gather")
        .opt("n", "200", "observations")
        .opt("ps", "1000,10000,100000", "predictor grid")
        .opt("k", "20", "true support size")
        .opt("rho", "0.1", "pairwise correlation")
        .opt("q", "0.1", "BH parameter")
        .opt("path-length", "50", "path points")
        .opt("threads", "0", "parallel-backend threads (0 = all cores)")
        .opt("seed", "2020", "dataset seed")
        .opt("screen", "both", "screening policy axis: strong|hybrid|both")
        .flag("smoke", "bounded sizes for CI; skips the speedup gates")
        .flag("no-pack", "gather engine only (skip the packed runs)")
        .flag("bench", "(cargo bench compatibility)")
        .parse();
    let smoke = parsed.bool("smoke");
    let no_pack = parsed.bool("no-pack");
    let (run_strong, run_hybrid) = match parsed.get("screen") {
        "strong" => (true, false),
        "hybrid" => (false, true),
        "both" => (true, true),
        s => panic!("unknown --screen {s} (expected strong|hybrid|both)"),
    };
    let n = parsed.usize("n");
    let ps: Vec<usize> = if smoke { vec![500, 2000] } else { parsed.usize_list("ps") };
    let k = parsed.usize("k");
    let rho = parsed.f64("rho");
    let q = parsed.f64("q");
    let path_length = if smoke { 15 } else { parsed.usize("path-length") };
    let threads = {
        let t = parsed.usize("threads");
        if t == 0 {
            par::global_threads()
        } else {
            t
        }
    };
    let seed = parsed.u64("seed");
    let engines: &[&'static str] = if no_pack { &["gather"] } else { &["gather", "packed"] };

    println!(
        "path_speed: n={n}, p in {ps:?}, path-length={path_length}, engines {engines:?}, screens strong={run_strong}/hybrid={run_hybrid}, parallel backend = {threads} threads{}",
        if smoke { " [smoke]" } else { "" }
    );
    let default_engine = if no_pack { "gather" } else { "packed" };

    // The observability contract gates the bench before anything is
    // measured: if disabled spans cost real time, every number below
    // would be polluted.
    let span_overhead_ns = assert_disabled_tracing_is_free();

    let mut runs: Vec<Run> = Vec::new();
    for (pi, &p) in ps.iter().enumerate() {
        let prob = make_problem(n, p, k.min(p / 2).max(1), rho, seed + pi as u64);
        let ng = NativeGradient(&prob);
        let with_cache = |o: PathOptions, packing: bool| {
            if packing {
                // One pack cache per cell: the cold fit deposits each
                // step's slab, the warm re-fit adopts it — packing drops
                // out of the warm path exactly as for warm serve
                // requests. Generous bounds: the bench must measure
                // kernels and cache adoption, not eviction policy.
                let cache = PackCache::new(4 * path_length).with_max_bytes(512 << 20);
                o.with_pack_cache(Arc::new(cache))
            } else {
                o
            }
        };
        // cold/serial, cold/parallel, warm/serial, warm/parallel for one
        // (engine, strategy) cell — each fit wrapped in a registry-delta
        // capture — with the serial-vs-parallel identity check every cell
        // must pass.
        let run_cell = |packing: bool, strategy: Strategy, what: &str| -> [(PathFit, Obs); 4] {
            let o_serial = with_cache(opts(q, path_length, 1, packing, strategy), packing);
            let o_par = with_cache(opts(q, path_length, threads, packing, strategy), packing);
            let cold_serial =
                with_obs(p, &format!("p={p} {what} cold/serial"), || fit_path(&prob, &o_serial, &ng));
            let cold_par =
                with_obs(p, &format!("p={p} {what} cold/parallel"), || fit_path(&prob, &o_par, &ng));
            assert_identical(&cold_serial.0, &cold_par.0, &format!("p={p} {what} cold"), 1e-10);
            let warm_serial = with_obs(p, &format!("p={p} {what} warm/serial"), || {
                fit_path_seeded(&prob, &o_serial, &ng, Some(&cold_serial.0.seed()))
            });
            let warm_par = with_obs(p, &format!("p={p} {what} warm/parallel"), || {
                fit_path_seeded(&prob, &o_par, &ng, Some(&cold_par.0.seed()))
            });
            assert_identical(&warm_serial.0, &warm_par.0, &format!("p={p} {what} warm"), 1e-10);
            [cold_serial, cold_par, warm_serial, warm_par]
        };
        let labels = ["cold/serial", "cold/parallel", "warm/serial", "warm/parallel"];
        let mut record =
            |engine: &'static str, screen: &'static str, fits: &[(PathFit, Obs); 4]| {
                for ((fit, obs), start, backend, t) in [
                    (&fits[0], "cold", "serial", 1),
                    (&fits[1], "cold", "parallel", threads),
                    (&fits[2], "warm", "serial", 1),
                    (&fits[3], "warm", "parallel", threads),
                ] {
                    println!(
                        "  p={p:<7} {engine:<7} {screen:<7} {backend:<8} {start}  {}  ({} steps, {} violations, {:.2} sweeps = {}F+{}P, pack {}h/{}m)",
                        fmt_secs(fit.wall_time),
                        fit.steps.len(),
                        fit.total_violations,
                        obs.sweep_p_equiv(p),
                        obs.full_sweeps,
                        obs.partial_sweeps,
                        obs.pack_hits,
                        obs.pack_misses,
                    );
                    runs.push(Run {
                        p,
                        engine,
                        backend,
                        start,
                        screen,
                        threads: t,
                        wall_s: fit.wall_time,
                        steps: fit.steps.len(),
                        violations: fit.total_violations,
                        full_grad_sweeps: obs.sweep_p_equiv(p),
                        full_sweeps: obs.full_sweeps,
                        partial_sweeps: obs.partial_sweeps,
                        pack_hits: obs.pack_hits,
                        pack_misses: obs.pack_misses,
                    });
                }
            };

        let mut strong_default: Option<[(PathFit, Obs); 4]> = None;
        if run_strong {
            let mut per_engine: Vec<(&'static str, [(PathFit, Obs); 4])> = Vec::new();
            for &engine in engines {
                let packing = engine == "packed";
                let fits = run_cell(packing, Strategy::StrongSet, &format!("{engine} strong"));
                per_engine.push((engine, fits));
            }
            // Cross-engine identity: the packed engine must be a pure
            // performance transformation of the gather one.
            if let [(_, gather), (_, packed)] = per_engine.as_slice() {
                for (i, label) in labels.iter().enumerate() {
                    assert_identical(
                        &gather[i].0,
                        &packed[i].0,
                        &format!("p={p} gather-vs-packed {label}"),
                        1e-10,
                    );
                }
            }
            for &(engine, ref fits) in &per_engine {
                record(engine, "strong", fits);
            }
            strong_default = per_engine
                .iter()
                .position(|&(e, _)| e == default_engine)
                .map(|i| per_engine.swap_remove(i).1);
        }
        if run_hybrid {
            // The screening-policy axis runs on the default engine only —
            // the engine comparison above already isolates gather vs
            // packed, and the policies share those kernels.
            let packing = default_engine == "packed";
            let fits = run_cell(packing, Strategy::GapHybrid, &format!("{default_engine} hybrid"));
            // Hybrid vs strong: coefficients to the certificate tolerance
            // (the stopping rules differ, so this is a solver-level
            // contract, not bitwise) and — on full runs — exact violation
            // counts. Smoke compares coefficients only: the two
            // strategies build their rule covers from different inputs
            // (exact vs bound-inflated strong sets), so a genuine
            // strong-rule violation can legitimately be attributed
            // differently, and the acceptance gate is defined at the full
            // sizes anyway.
            if let Some(strong) = &strong_default {
                for (i, label) in labels.iter().enumerate() {
                    let (a, b) = (&strong[i].0, &fits[i].0);
                    let what = format!("p={p} strong-vs-hybrid {label}");
                    if smoke {
                        assert_eq!(a.steps.len(), b.steps.len(), "{what}: step counts diverged");
                        let mut max_dev = 0.0f64;
                        for (x, y) in a.final_beta.iter().zip(&b.final_beta) {
                            max_dev = max_dev.max((x - y).abs());
                        }
                        assert!(max_dev <= 1e-6, "{what}: coefficients diverged by {max_dev:e}");
                    } else {
                        assert_identical(a, b, &what, 1e-9);
                    }
                }
            }
            record(default_engine, "hybrid", &fits);
        }
    }

    let mut table = Table::new(
        &format!("path_speed (gaussian, n={n}, {threads}-thread backend)"),
        &[
            "p",
            "engine",
            "screen",
            "backend",
            "start",
            "threads",
            "wall_s",
            "steps",
            "violations",
            "full_grad_sweeps",
            "full_sweeps",
            "partial_sweeps",
            "pack_hits",
            "pack_misses",
        ],
    );
    for r in &runs {
        table.row(vec![
            r.p.to_string(),
            r.engine.to_string(),
            r.screen.to_string(),
            r.backend.to_string(),
            r.start.to_string(),
            r.threads.to_string(),
            format!("{:.4}", r.wall_s),
            r.steps.to_string(),
            r.violations.to_string(),
            format!("{:.3}", r.full_grad_sweeps),
            r.full_sweeps.to_string(),
            r.partial_sweeps.to_string(),
            r.pack_hits.to_string(),
            r.pack_misses.to_string(),
        ]);
    }
    table.print();
    let csv = table.write_csv("path_speed").expect("csv");
    println!("\nwrote {}", csv.display());

    let base_screen = if run_strong { "strong" } else { "hybrid" };
    let find = |p: usize, engine: &str, screen: &str, backend: &str, start: &str| {
        runs.iter()
            .find(|r| {
                r.p == p
                    && r.engine == engine
                    && r.screen == screen
                    && r.backend == backend
                    && r.start == start
            })
            .expect("run")
    };
    let p_max = *ps.iter().max().expect("non-empty p grid");
    let cold_speedup = find(p_max, default_engine, base_screen, "serial", "cold").wall_s
        / find(p_max, default_engine, base_screen, "parallel", "cold").wall_s.max(1e-12);
    let warm_speedup = find(p_max, default_engine, base_screen, "serial", "warm").wall_s
        / find(p_max, default_engine, base_screen, "parallel", "warm").wall_s.max(1e-12);
    println!(
        "speedup at p={p_max} ({default_engine}, {base_screen}): cold {cold_speedup:.2}x, warm {warm_speedup:.2}x ({threads} threads)"
    );
    let warm_pack_speedup = if no_pack || !run_strong {
        None
    } else {
        let s = find(p_max, "gather", "strong", "parallel", "warm").wall_s
            / find(p_max, "packed", "strong", "parallel", "warm").wall_s.max(1e-12);
        let w = find(p_max, "packed", "strong", "parallel", "warm");
        println!(
            "packed over gather at p={p_max} (warm, parallel): {s:.2}x (pack cache {}h/{}m on the warm fit)",
            w.pack_hits, w.pack_misses
        );
        Some(s)
    };
    // The screening-policy comparison: full-gradient sweep work on the
    // warm parallel path at the largest size — the quantity the hybrid
    // strategy exists to reduce. Both sides come from the registry deltas
    // captured around those fits.
    let sweep_reduction = if run_strong && run_hybrid {
        let strong = find(p_max, default_engine, "strong", "parallel", "warm");
        let hybrid = find(p_max, default_engine, "hybrid", "parallel", "warm");
        let reduction = 1.0 - hybrid.full_grad_sweeps / strong.full_grad_sweeps.max(1e-12);
        println!(
            "full-gradient sweeps at p={p_max} (warm, parallel): strong {:.2} ({}F), hybrid {:.2} ({}F+{}P, {:.0}% fewer p-equivalents)",
            strong.full_grad_sweeps,
            strong.full_sweeps,
            hybrid.full_grad_sweeps,
            hybrid.full_sweeps,
            hybrid.partial_sweeps,
            reduction * 100.0
        );
        Some(reduction)
    } else {
        None
    };
    // The acceptance gates, at the largest size whenever ≥ 4 threads back
    // the parallel runs: ≥ 2× parallel-over-serial on the cold path,
    // ≥ 1.3× packed-over-gather on the warm path (where the pack cache
    // removes packing and the blocked kernels carry the solve), and
    // ≥ 30% fewer full-gradient sweeps for the gap-certified hybrid on
    // the warm parallel path. Smoke runs (CI) keep the correctness
    // asserts but skip the gates — shared runners make wall-clock
    // guarantees meaningless there, and the smoke sizes are below the
    // regime the sweep gate targets.
    if !smoke && threads >= 4 {
        assert!(
            cold_speedup >= 2.0,
            "parallel backend must be >= 2x at p={p_max} on {threads} threads, got {cold_speedup:.2}x"
        );
        if let Some(s) = warm_pack_speedup {
            assert!(
                s >= 1.3,
                "packed engine must be >= 1.3x over gather on the warm path at p={p_max}, got {s:.2}x"
            );
        }
        if let Some(r) = sweep_reduction {
            assert!(
                r >= 0.30,
                "hybrid screening must cut >= 30% of full-gradient sweeps at p={p_max} (warm, parallel), got {:.0}%",
                r * 100.0
            );
        }
    }

    // Resilience contract (DESIGN.md §12): threading a live-but-never-
    // firing deadline token through a fit must be near-free — the polls
    // are one relaxed load per FISTA iteration and per σ-step — and
    // bitwise invisible. Measured warm/parallel at the largest size,
    // best of 3 per arm.
    let cancel_overhead = {
        let pi_max = ps.iter().position(|&p| p == p_max).expect("p_max in grid");
        let prob = make_problem(n, p_max, k.min(p_max / 2).max(1), rho, seed + pi_max as u64);
        let ng = NativeGradient(&prob);
        let o_plain =
            opts(q, path_length, threads, default_engine == "packed", Strategy::StrongSet);
        // One hour out: the token is polled on every check but never fires.
        let o_token = o_plain.clone().with_cancel(CancelToken::with_deadline_ms(3_600_000));
        let warm_seed = fit_path(&prob, &o_plain, &ng).seed();
        let best_of_3 = |o: &PathOptions| {
            let mut best_s = f64::INFINITY;
            let mut last = None;
            for _ in 0..3 {
                let fit = fit_path_seeded(&prob, o, &ng, Some(&warm_seed));
                best_s = best_s.min(fit.wall_time);
                last = Some(fit);
            }
            (best_s, last.expect("three reps"))
        };
        let (plain_s, plain_fit) = best_of_3(&o_plain);
        let (token_s, token_fit) = best_of_3(&o_token);
        let bits = |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<u64>>();
        assert_eq!(
            bits(&plain_fit.final_beta),
            bits(&token_fit.final_beta),
            "a never-firing cancel token must be bitwise invisible (beta)"
        );
        assert_eq!(
            bits(&plain_fit.final_grad),
            bits(&token_fit.final_grad),
            "a never-firing cancel token must be bitwise invisible (grad)"
        );
        let overhead = token_s / plain_s.max(1e-12) - 1.0;
        println!(
            "cancellation-check overhead at p={p_max} (warm, parallel, best of 3): {:.2}% ({token_s:.4}s with token vs {plain_s:.4}s without)",
            overhead * 100.0
        );
        if !smoke && threads >= 4 {
            assert!(
                overhead < 0.01,
                "cancellation checks must cost < 1% on the warm parallel path at p={p_max}, got {:.2}%",
                overhead * 100.0
            );
        }
        overhead
    };

    // Durable-state contract (DESIGN.md §13): snapshotting every 5 σ-steps
    // — the default `fit --checkpoint` cadence — must be near-free next to
    // the solve it protects, and bitwise invisible: a checkpointed fit is
    // the same fit, plus files. Measured warm/parallel at the largest
    // size, best of 3 per arm, like the cancellation cell above.
    let checkpoint_overhead = {
        let pi_max = ps.iter().position(|&p| p == p_max).expect("p_max in grid");
        let prob = make_problem(n, p_max, k.min(p_max / 2).max(1), rho, seed + pi_max as u64);
        let ng = NativeGradient(&prob);
        let o = opts(q, path_length, threads, default_engine == "packed", Strategy::StrongSet);
        let warm_seed = fit_path(&prob, &o, &ng).seed();
        let ckpt = CheckpointConfig {
            path: std::env::temp_dir()
                .join(format!("slope-bench-ckpt-{}.bin", std::process::id())),
            every: 5,
            dataset_fingerprint: 0xBE7C_0CCE,
        };
        let best_of_3 = |f: &dyn Fn() -> PathFit| {
            let mut best_s = f64::INFINITY;
            let mut last = None;
            for _ in 0..3 {
                let fit = f();
                best_s = best_s.min(fit.wall_time);
                last = Some(fit);
            }
            (best_s, last.expect("three reps"))
        };
        let (plain_s, plain_fit) =
            best_of_3(&|| fit_path_seeded(&prob, &o, &ng, Some(&warm_seed)));
        let (ckpt_s, ckpt_fit) =
            best_of_3(&|| fit_path_checkpointed(&prob, &o, &ng, Some(&warm_seed), &ckpt));
        let bits = |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<u64>>();
        assert_eq!(
            bits(&plain_fit.final_beta),
            bits(&ckpt_fit.final_beta),
            "checkpointing must be bitwise invisible (beta)"
        );
        assert_eq!(
            bits(&plain_fit.final_grad),
            bits(&ckpt_fit.final_grad),
            "checkpointing must be bitwise invisible (grad)"
        );
        for suffix in ["", ".prev", ".tmp"] {
            let mut p = ckpt.path.clone().into_os_string();
            p.push(suffix);
            let _ = std::fs::remove_file(std::path::PathBuf::from(p));
        }
        let overhead = ckpt_s / plain_s.max(1e-12) - 1.0;
        println!(
            "checkpoint overhead at p={p_max} (every 5 steps, warm, parallel, best of 3): {:.2}% ({ckpt_s:.4}s with snapshots vs {plain_s:.4}s without)",
            overhead * 100.0
        );
        if !smoke && threads >= 4 {
            assert!(
                overhead < 0.02,
                "checkpointing every 5 steps must cost < 2% at p={p_max}, got {:.2}%",
                overhead * 100.0
            );
        }
        overhead
    };

    let mut speedup_fields = vec![
        ("p", Json::Num(p_max as f64)),
        ("engine", Json::Str(default_engine.to_string())),
        ("cold_parallel_over_serial", Json::Num(cold_speedup)),
        ("warm_parallel_over_serial", Json::Num(warm_speedup)),
    ];
    if let Some(s) = warm_pack_speedup {
        speedup_fields.push(("warm_packed_over_gather", Json::Num(s)));
    }
    if let Some(r) = sweep_reduction {
        speedup_fields.push(("hybrid_sweep_reduction", Json::Num(r)));
    }
    let payload = Json::obj(vec![
        ("bench", Json::Str("path_speed".to_string())),
        (
            "config",
            Json::obj(vec![
                ("n", Json::Num(n as f64)),
                ("ps", Json::Arr(ps.iter().map(|&p| Json::Num(p as f64)).collect())),
                ("k", Json::Num(k as f64)),
                ("rho", Json::Num(rho)),
                ("q", Json::Num(q)),
                ("path_length", Json::Num(path_length as f64)),
                ("threads", Json::Num(threads as f64)),
                ("smoke", Json::Bool(smoke)),
                ("no_pack", Json::Bool(no_pack)),
                ("screen", Json::Str(parsed.get("screen").to_string())),
            ]),
        ),
        (
            "runs",
            Json::Arr(
                runs.iter()
                    .map(|r| {
                        Json::obj(vec![
                            ("p", Json::Num(r.p as f64)),
                            ("engine", Json::Str(r.engine.to_string())),
                            ("screen", Json::Str(r.screen.to_string())),
                            ("backend", Json::Str(r.backend.to_string())),
                            ("start", Json::Str(r.start.to_string())),
                            ("threads", Json::Num(r.threads as f64)),
                            ("wall_s", Json::Num(r.wall_s)),
                            ("steps", Json::Num(r.steps as f64)),
                            ("violations", Json::Num(r.violations as f64)),
                            ("full_grad_sweeps", Json::Num(r.full_grad_sweeps)),
                            ("full_sweeps", Json::Num(r.full_sweeps as f64)),
                            ("partial_sweeps", Json::Num(r.partial_sweeps as f64)),
                            ("pack_hits", Json::Num(r.pack_hits as f64)),
                            ("pack_misses", Json::Num(r.pack_misses as f64)),
                        ])
                    })
                    .collect(),
            ),
        ),
        ("speedup", Json::obj(speedup_fields)),
        (
            "resilience",
            Json::obj(vec![
                ("cancel_check_overhead", Json::Num(cancel_overhead)),
                ("checkpoint_overhead", Json::Num(checkpoint_overhead)),
            ]),
        ),
        (
            "obs",
            Json::obj(vec![("disabled_span_ns", Json::Num(span_overhead_ns))]),
        ),
        ("table", table.to_json()),
    ]);
    let out_path =
        slope_screen::benchkit::write_bench_json("path", &payload).expect("BENCH_path.json");
    println!("wrote {}", out_path.display());
}

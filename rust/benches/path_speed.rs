//! Path-level wall-clock benchmark of the hot-path engines: full-path
//! Gaussian fits across p ∈ {1k, 10k, 100k} at n = 200 (the paper's
//! p ≫ n regime), on three axes:
//!
//! * **backend** — serial vs threaded `linalg::par` kernels;
//! * **engine** — `gather` (subset kernels chasing a column list through
//!   the full design) vs `packed` (screened columns materialized into a
//!   contiguous slab per step, with a per-problem `PackCache` so warm
//!   re-fits adopt the cold fit's slabs — the serve registry's case). At
//!   p = 100k the late-path screened sets reach the hundreds, the regime
//!   the packed engine targets.
//! * **screen** — the `strong` KKT-safeguarded baseline vs the `hybrid`
//!   duality-gap strategy (safe universe + gap certificates, DESIGN.md
//!   §10), which replaces most full-p gradient sweeps with partial
//!   universe sweeps. Each cell records `full_grad_sweeps`
//!   (p-equivalents) so the sweep reduction is tracked, not inferred
//!   from wall time.
//!
//! Correctness is asserted, not assumed: across backends *and* engines,
//! fits must produce identical violation counts and coefficients to
//! 1e-10 (the dense kernels of both engines are bitwise-deterministic
//! and order-matched, so the real difference is zero); hybrid fits must
//! match the strong baseline exactly on violations and to 1e-9 on
//! coefficients (1e-6 in smoke runs — the stopping rules differ, so the
//! contract is certificate-level, not bitwise). The full run gates on
//! ≥ 2× parallel-over-serial (cold), ≥ 1.3× packed-over-gather (warm,
//! parallel), and ≥ 30% fewer full-gradient sweeps for hybrid vs strong
//! (warm, parallel) at the largest size when at least 4 threads are
//! available.
//!
//! Writes `results/path_speed.csv` and the machine-readable
//! `BENCH_path.json` at the repository root — the perf trajectory of the
//! hot path is tracked from this file.
//!
//! Run:      `cargo bench --bench path_speed`
//! Smoke:    `cargo bench --bench path_speed -- --smoke` (bounded sizes,
//!           no speedup/sweep gates — the CI job that keeps this harness
//!           alive).
//! Gather:   `cargo bench --bench path_speed -- --no-pack` (gather engine
//!           only; CI smokes this too so both code paths stay exercised).
//! Policy:   `cargo bench --bench path_speed -- --screen hybrid` (one
//!           screening policy only; default `both` runs the comparison).

use std::sync::Arc;

use slope_screen::benchkit::{fmt_secs, Table};
use slope_screen::cli::Args;
use slope_screen::data::synth::{BetaSpec, DesignKind, SyntheticSpec};
use slope_screen::jsonio::Json;
use slope_screen::linalg::par;
use slope_screen::linalg::PackCache;
use slope_screen::rng::Pcg64;
use slope_screen::slope::family::{Family, Problem};
use slope_screen::slope::lambda::{LambdaKind, PathConfig};
use slope_screen::slope::path::{
    fit_path, fit_path_seeded, NativeGradient, PathFit, PathOptions, Strategy,
};

struct Run {
    p: usize,
    engine: &'static str,
    backend: &'static str,
    start: &'static str,
    screen: &'static str,
    threads: usize,
    wall_s: f64,
    steps: usize,
    violations: usize,
    full_grad_sweeps: f64,
}

fn make_problem(n: usize, p: usize, k: usize, rho: f64, seed: u64) -> Problem {
    SyntheticSpec {
        n,
        p,
        rho,
        design: DesignKind::Compound,
        beta: BetaSpec::PlusMinus { k, scale: 2.0 },
        family: Family::Gaussian,
        noise_sd: 1.0,
        standardize: true,
    }
    .generate(&mut Pcg64::new(seed))
}

fn opts(
    q: f64,
    length: usize,
    threads: usize,
    packing: bool,
    strategy: Strategy,
) -> PathOptions {
    let mut cfg = PathConfig::new(LambdaKind::Bh { q });
    cfg.length = length;
    PathOptions::new(cfg)
        .with_strategy(strategy)
        .with_threads(threads)
        .with_packing(packing)
}

/// Any two fits of the same problem in this matrix must be
/// interchangeable: same grid, same violation counts, coefficients equal
/// to `tol`.
fn assert_identical(a: &PathFit, b: &PathFit, what: &str, tol: f64) {
    assert_eq!(a.steps.len(), b.steps.len(), "{what}: step counts diverged");
    assert_eq!(
        a.total_violations, b.total_violations,
        "{what}: violation counts diverged"
    );
    for (m, (sa, sb)) in a.steps.iter().zip(&b.steps).enumerate() {
        assert_eq!(
            sa.violations, sb.violations,
            "{what} step {m}: per-step violations diverged"
        );
    }
    let mut max_dev = 0.0f64;
    for (x, y) in a.final_beta.iter().zip(&b.final_beta) {
        max_dev = max_dev.max((x - y).abs());
    }
    assert!(
        max_dev <= tol,
        "{what}: coefficients diverged by {max_dev:e} (> {tol:e})"
    );
}

fn main() {
    let parsed = Args::new("path-level benchmark: serial vs parallel, packed vs gather")
        .opt("n", "200", "observations")
        .opt("ps", "1000,10000,100000", "predictor grid")
        .opt("k", "20", "true support size")
        .opt("rho", "0.1", "pairwise correlation")
        .opt("q", "0.1", "BH parameter")
        .opt("path-length", "50", "path points")
        .opt("threads", "0", "parallel-backend threads (0 = all cores)")
        .opt("seed", "2020", "dataset seed")
        .opt("screen", "both", "screening policy axis: strong|hybrid|both")
        .flag("smoke", "bounded sizes for CI; skips the speedup gates")
        .flag("no-pack", "gather engine only (skip the packed runs)")
        .flag("bench", "(cargo bench compatibility)")
        .parse();
    let smoke = parsed.bool("smoke");
    let no_pack = parsed.bool("no-pack");
    let (run_strong, run_hybrid) = match parsed.get("screen") {
        "strong" => (true, false),
        "hybrid" => (false, true),
        "both" => (true, true),
        s => panic!("unknown --screen {s} (expected strong|hybrid|both)"),
    };
    let n = parsed.usize("n");
    let ps: Vec<usize> = if smoke { vec![500, 2000] } else { parsed.usize_list("ps") };
    let k = parsed.usize("k");
    let rho = parsed.f64("rho");
    let q = parsed.f64("q");
    let path_length = if smoke { 15 } else { parsed.usize("path-length") };
    let threads = {
        let t = parsed.usize("threads");
        if t == 0 {
            par::global_threads()
        } else {
            t
        }
    };
    let seed = parsed.u64("seed");
    let engines: &[&'static str] = if no_pack { &["gather"] } else { &["gather", "packed"] };

    println!(
        "path_speed: n={n}, p in {ps:?}, path-length={path_length}, engines {engines:?}, screens strong={run_strong}/hybrid={run_hybrid}, parallel backend = {threads} threads{}",
        if smoke { " [smoke]" } else { "" }
    );
    let default_engine = if no_pack { "gather" } else { "packed" };

    let mut runs: Vec<Run> = Vec::new();
    for (pi, &p) in ps.iter().enumerate() {
        let prob = make_problem(n, p, k.min(p / 2).max(1), rho, seed + pi as u64);
        let ng = NativeGradient(&prob);
        let with_cache = |o: PathOptions, packing: bool| {
            if packing {
                // One pack cache per cell: the cold fit deposits each
                // step's slab, the warm re-fit adopts it — packing drops
                // out of the warm path exactly as for warm serve
                // requests. Generous bounds: the bench must measure
                // kernels and cache adoption, not eviction policy.
                let cache = PackCache::new(4 * path_length).with_max_bytes(512 << 20);
                o.with_pack_cache(Arc::new(cache))
            } else {
                o
            }
        };
        // cold/serial, cold/parallel, warm/serial, warm/parallel for one
        // (engine, strategy) cell, with the serial-vs-parallel identity
        // check every cell must pass.
        let run_cell = |packing: bool, strategy: Strategy, what: &str| -> [PathFit; 4] {
            let o_serial = with_cache(opts(q, path_length, 1, packing, strategy), packing);
            let o_par = with_cache(opts(q, path_length, threads, packing, strategy), packing);
            let cold_serial = fit_path(&prob, &o_serial, &ng);
            let cold_par = fit_path(&prob, &o_par, &ng);
            assert_identical(&cold_serial, &cold_par, &format!("p={p} {what} cold"), 1e-10);
            let warm_serial = fit_path_seeded(&prob, &o_serial, &ng, Some(&cold_serial.seed()));
            let warm_par = fit_path_seeded(&prob, &o_par, &ng, Some(&cold_par.seed()));
            assert_identical(&warm_serial, &warm_par, &format!("p={p} {what} warm"), 1e-10);
            [cold_serial, cold_par, warm_serial, warm_par]
        };
        let labels = ["cold/serial", "cold/parallel", "warm/serial", "warm/parallel"];
        let mut record = |engine: &'static str, screen: &'static str, fits: &[PathFit; 4]| {
            for (fit, start, backend, t) in [
                (&fits[0], "cold", "serial", 1),
                (&fits[1], "cold", "parallel", threads),
                (&fits[2], "warm", "serial", 1),
                (&fits[3], "warm", "parallel", threads),
            ] {
                println!(
                    "  p={p:<7} {engine:<7} {screen:<7} {backend:<8} {start}  {}  ({} steps, {} violations, {:.2} sweeps)",
                    fmt_secs(fit.wall_time),
                    fit.steps.len(),
                    fit.total_violations,
                    fit.total_grad_sweeps
                );
                runs.push(Run {
                    p,
                    engine,
                    backend,
                    start,
                    screen,
                    threads: t,
                    wall_s: fit.wall_time,
                    steps: fit.steps.len(),
                    violations: fit.total_violations,
                    full_grad_sweeps: fit.total_grad_sweeps,
                });
            }
        };

        let mut strong_default: Option<[PathFit; 4]> = None;
        if run_strong {
            let mut per_engine: Vec<(&'static str, [PathFit; 4])> = Vec::new();
            for &engine in engines {
                let packing = engine == "packed";
                let fits = run_cell(packing, Strategy::StrongSet, &format!("{engine} strong"));
                per_engine.push((engine, fits));
            }
            // Cross-engine identity: the packed engine must be a pure
            // performance transformation of the gather one.
            if let [(_, gather), (_, packed)] = per_engine.as_slice() {
                for (i, label) in labels.iter().enumerate() {
                    assert_identical(
                        &gather[i],
                        &packed[i],
                        &format!("p={p} gather-vs-packed {label}"),
                        1e-10,
                    );
                }
            }
            for &(engine, ref fits) in &per_engine {
                record(engine, "strong", fits);
            }
            strong_default = per_engine
                .iter()
                .position(|&(e, _)| e == default_engine)
                .map(|i| per_engine.swap_remove(i).1);
        }
        if run_hybrid {
            // The screening-policy axis runs on the default engine only —
            // the engine comparison above already isolates gather vs
            // packed, and the policies share those kernels.
            let packing = default_engine == "packed";
            let fits = run_cell(packing, Strategy::GapHybrid, &format!("{default_engine} hybrid"));
            // Hybrid vs strong: coefficients to the certificate tolerance
            // (the stopping rules differ, so this is a solver-level
            // contract, not bitwise) and — on full runs — exact violation
            // counts. Smoke compares coefficients only: the two
            // strategies build their rule covers from different inputs
            // (exact vs bound-inflated strong sets), so a genuine
            // strong-rule violation can legitimately be attributed
            // differently, and the acceptance gate is defined at the full
            // sizes anyway.
            if let Some(strong) = &strong_default {
                for (i, label) in labels.iter().enumerate() {
                    let (a, b) = (&strong[i], &fits[i]);
                    let what = format!("p={p} strong-vs-hybrid {label}");
                    if smoke {
                        assert_eq!(a.steps.len(), b.steps.len(), "{what}: step counts diverged");
                        let mut max_dev = 0.0f64;
                        for (x, y) in a.final_beta.iter().zip(&b.final_beta) {
                            max_dev = max_dev.max((x - y).abs());
                        }
                        assert!(max_dev <= 1e-6, "{what}: coefficients diverged by {max_dev:e}");
                    } else {
                        assert_identical(a, b, &what, 1e-9);
                    }
                }
            }
            record(default_engine, "hybrid", &fits);
        }
    }

    let mut table = Table::new(
        &format!("path_speed (gaussian, n={n}, {threads}-thread backend)"),
        &[
            "p",
            "engine",
            "screen",
            "backend",
            "start",
            "threads",
            "wall_s",
            "steps",
            "violations",
            "full_grad_sweeps",
        ],
    );
    for r in &runs {
        table.row(vec![
            r.p.to_string(),
            r.engine.to_string(),
            r.screen.to_string(),
            r.backend.to_string(),
            r.start.to_string(),
            r.threads.to_string(),
            format!("{:.4}", r.wall_s),
            r.steps.to_string(),
            r.violations.to_string(),
            format!("{:.3}", r.full_grad_sweeps),
        ]);
    }
    table.print();
    let csv = table.write_csv("path_speed").expect("csv");
    println!("\nwrote {}", csv.display());

    let base_screen = if run_strong { "strong" } else { "hybrid" };
    let find = |p: usize, engine: &str, screen: &str, backend: &str, start: &str| {
        runs.iter()
            .find(|r| {
                r.p == p
                    && r.engine == engine
                    && r.screen == screen
                    && r.backend == backend
                    && r.start == start
            })
            .expect("run")
    };
    let p_max = *ps.iter().max().expect("non-empty p grid");
    let cold_speedup = find(p_max, default_engine, base_screen, "serial", "cold").wall_s
        / find(p_max, default_engine, base_screen, "parallel", "cold").wall_s.max(1e-12);
    let warm_speedup = find(p_max, default_engine, base_screen, "serial", "warm").wall_s
        / find(p_max, default_engine, base_screen, "parallel", "warm").wall_s.max(1e-12);
    println!(
        "speedup at p={p_max} ({default_engine}, {base_screen}): cold {cold_speedup:.2}x, warm {warm_speedup:.2}x ({threads} threads)"
    );
    let warm_pack_speedup = if no_pack || !run_strong {
        None
    } else {
        let s = find(p_max, "gather", "strong", "parallel", "warm").wall_s
            / find(p_max, "packed", "strong", "parallel", "warm").wall_s.max(1e-12);
        println!("packed over gather at p={p_max} (warm, parallel): {s:.2}x");
        Some(s)
    };
    // The screening-policy comparison: full-gradient sweep work on the
    // warm parallel path at the largest size — the quantity the hybrid
    // strategy exists to reduce.
    let sweep_reduction = if run_strong && run_hybrid {
        let strong = find(p_max, default_engine, "strong", "parallel", "warm").full_grad_sweeps;
        let hybrid = find(p_max, default_engine, "hybrid", "parallel", "warm").full_grad_sweeps;
        let reduction = 1.0 - hybrid / strong.max(1e-12);
        println!(
            "full-gradient sweeps at p={p_max} (warm, parallel): strong {strong:.2}, hybrid {hybrid:.2} ({:.0}% fewer)",
            reduction * 100.0
        );
        Some(reduction)
    } else {
        None
    };
    // The acceptance gates, at the largest size whenever ≥ 4 threads back
    // the parallel runs: ≥ 2× parallel-over-serial on the cold path,
    // ≥ 1.3× packed-over-gather on the warm path (where the pack cache
    // removes packing and the blocked kernels carry the solve), and
    // ≥ 30% fewer full-gradient sweeps for the gap-certified hybrid on
    // the warm parallel path. Smoke runs (CI) keep the correctness
    // asserts but skip the gates — shared runners make wall-clock
    // guarantees meaningless there, and the smoke sizes are below the
    // regime the sweep gate targets.
    if !smoke && threads >= 4 {
        assert!(
            cold_speedup >= 2.0,
            "parallel backend must be >= 2x at p={p_max} on {threads} threads, got {cold_speedup:.2}x"
        );
        if let Some(s) = warm_pack_speedup {
            assert!(
                s >= 1.3,
                "packed engine must be >= 1.3x over gather on the warm path at p={p_max}, got {s:.2}x"
            );
        }
        if let Some(r) = sweep_reduction {
            assert!(
                r >= 0.30,
                "hybrid screening must cut >= 30% of full-gradient sweeps at p={p_max} (warm, parallel), got {:.0}%",
                r * 100.0
            );
        }
    }

    let mut speedup_fields = vec![
        ("p", Json::Num(p_max as f64)),
        ("engine", Json::Str(default_engine.to_string())),
        ("cold_parallel_over_serial", Json::Num(cold_speedup)),
        ("warm_parallel_over_serial", Json::Num(warm_speedup)),
    ];
    if let Some(s) = warm_pack_speedup {
        speedup_fields.push(("warm_packed_over_gather", Json::Num(s)));
    }
    if let Some(r) = sweep_reduction {
        speedup_fields.push(("hybrid_sweep_reduction", Json::Num(r)));
    }
    let payload = Json::obj(vec![
        ("bench", Json::Str("path_speed".to_string())),
        (
            "config",
            Json::obj(vec![
                ("n", Json::Num(n as f64)),
                ("ps", Json::Arr(ps.iter().map(|&p| Json::Num(p as f64)).collect())),
                ("k", Json::Num(k as f64)),
                ("rho", Json::Num(rho)),
                ("q", Json::Num(q)),
                ("path_length", Json::Num(path_length as f64)),
                ("threads", Json::Num(threads as f64)),
                ("smoke", Json::Bool(smoke)),
                ("no_pack", Json::Bool(no_pack)),
                ("screen", Json::Str(parsed.get("screen").to_string())),
            ]),
        ),
        (
            "runs",
            Json::Arr(
                runs.iter()
                    .map(|r| {
                        Json::obj(vec![
                            ("p", Json::Num(r.p as f64)),
                            ("engine", Json::Str(r.engine.to_string())),
                            ("screen", Json::Str(r.screen.to_string())),
                            ("backend", Json::Str(r.backend.to_string())),
                            ("start", Json::Str(r.start.to_string())),
                            ("threads", Json::Num(r.threads as f64)),
                            ("wall_s", Json::Num(r.wall_s)),
                            ("steps", Json::Num(r.steps as f64)),
                            ("violations", Json::Num(r.violations as f64)),
                            ("full_grad_sweeps", Json::Num(r.full_grad_sweeps)),
                        ])
                    })
                    .collect(),
            ),
        ),
        ("speedup", Json::obj(speedup_fields)),
        ("table", table.to_json()),
    ]);
    let out_path =
        slope_screen::benchkit::write_bench_json("path", &payload).expect("BENCH_path.json");
    println!("wrote {}", out_path.display());
}

//! Figure 4 + Table 1: wall-clock time for fitting the path with vs
//! without the strong rule, across families and correlation levels, on
//! the chain design X_j ~ N(ρ X_{j−1}, I).
//!
//! Paper setup: p = 20000, n = 200, k = 20, ρ ∈ {0, 0.5, 0.99, 0.999};
//! OLS/logistic: β from {1..20} w/o replacement, ε ~ N(0, 20I);
//! Poisson: β from {1/40..20/40}; multinomial: 3 classes.
//! Table 1 = ratio of the two strategies' times.
//! Run: `cargo bench --bench fig4_performance -- --scale 1 --reps 3`

use std::time::Instant;

use slope_screen::benchkit::{fmt_secs, Table};
use slope_screen::cli::Args;
use slope_screen::data::synth::{
    draw_response, chain_design, multinomial_beta, BetaSpec,
};
use slope_screen::linalg::Design;
use slope_screen::rng::Pcg64;
use slope_screen::slope::family::{Family, Problem};
use slope_screen::slope::lambda::{LambdaKind, PathConfig};
use slope_screen::slope::path::{fit_path, NativeGradient, PathOptions, Strategy};

fn make_problem(rng: &mut Pcg64, n: usize, p: usize, k: usize, rho: f64, family: Family) -> Problem {
    let mut x = chain_design(rng, n, p, rho);
    let beta = match family {
        Family::Gaussian | Family::Binomial => {
            BetaSpec::Ladder { k, step: 1.0 }.draw(rng, p)
        }
        Family::Poisson => BetaSpec::Ladder { k, step: 1.0 / 40.0 }.draw(rng, p),
        Family::Multinomial { classes } => multinomial_beta(rng, p, k, classes),
    };
    let noise = (20.0f64).sqrt();
    let y = draw_response(rng, &x, &beta, family, noise);
    x.standardize(true, true);
    let mut y = y;
    if family == Family::Gaussian {
        let m = slope_screen::linalg::ops::mean(&y);
        y.iter_mut().for_each(|v| *v -= m);
    }
    Problem::new(Design::Dense(x), y, family)
}

fn main() {
    let parsed = Args::new("Figure 4 / Table 1: path wall-time with vs without screening")
        .opt("scale", "0.25", "problem scale (1 = paper: n=200, p=20000)")
        .opt("rhos", "0,0.5,0.99,0.999", "correlation grid")
        .opt("reps", "1", "repetitions (paper uses boxplots over many)")
        .opt("families", "gaussian,binomial,poisson,multinomial", "family list")
        .opt("q", "0.005", "BH parameter")
        .opt("seed", "2023", "rng seed")
        .flag("bench", "(cargo bench compatibility)")
        .parse();
    let scale = parsed.f64("scale");
    let n = (200.0 * scale).round().max(20.0) as usize;
    let p = (20_000.0 * scale).round().max(200.0) as usize;
    let k = 20.min(p / 10).max(2);
    let reps = parsed.usize("reps");

    let families: Vec<Family> = parsed
        .get("families")
        .split(',')
        .map(|f| match f {
            "gaussian" => Family::Gaussian,
            "binomial" => Family::Binomial,
            "poisson" => Family::Poisson,
            "multinomial" => Family::Multinomial { classes: 3 },
            other => panic!("unknown family {other}"),
        })
        .collect();

    let mut fig = Table::new(
        &format!("Figure 4 — wall time per fit (n={n}, p={p}, k={k})"),
        &["family", "rho", "rep", "strategy", "seconds", "violations"],
    );
    let mut tab1 = Table::new(
        "Table 1 — relative speed-up (no screening / screening)",
        &["model", "rho", "speedup"],
    );

    let mut master = Pcg64::new(parsed.u64("seed"));
    for family in &families {
        for rho in parsed.f64_list("rhos") {
            let mut t_screen = Vec::new();
            let mut t_none = Vec::new();
            for rep in 0..reps {
                let mut rng = master.derive(rep as u64);
                let prob = make_problem(&mut rng, n, p, k, rho, *family);
                let cfg = PathConfig::new(LambdaKind::Bh { q: parsed.f64("q") });
                for strategy in [Strategy::StrongSet, Strategy::NoScreening] {
                    let opts = PathOptions::new(cfg.clone()).with_strategy(strategy);
                    let t = Instant::now();
                    let fit = fit_path(&prob, &opts, &NativeGradient(&prob));
                    let secs = t.elapsed().as_secs_f64();
                    fig.row(vec![
                        family.name().to_string(),
                        format!("{rho}"),
                        rep.to_string(),
                        strategy.name().to_string(),
                        format!("{secs:.4}"),
                        fit.total_violations.to_string(),
                    ]);
                    match strategy {
                        Strategy::StrongSet => t_screen.push(secs),
                        _ => t_none.push(secs),
                    }
                    println!(
                        "{:<12} rho={rho:<6} rep={rep} {:<8} {} ({} steps, viol={})",
                        family.name(),
                        strategy.name(),
                        fmt_secs(secs),
                        fit.steps.len(),
                        fit.total_violations
                    );
                }
            }
            let speedup = slope_screen::linalg::ops::mean(&t_none)
                / slope_screen::linalg::ops::mean(&t_screen);
            tab1.row(vec![
                family.name().to_string(),
                format!("{rho}"),
                format!("{speedup:.1}"),
            ]);
        }
    }
    fig.print();
    tab1.print();
    fig.write_csv("fig4_performance").expect("csv");
    tab1.write_csv("table1_speedup").expect("csv");
    println!("\n(paper Table 1: speed-ups of roughly 8-29x at n=200, p=20000)");
}

//! Figure 3: prevalence of strong-rule violations across p, on a full
//! (no-early-stop) path of 100 σ values.
//!
//! Paper setup: OLS, n = 100, p ∈ {20, 50, 100, 500, 1000}, ρ = 0.5,
//! k = p/4, β ∈ {−2, 2}, 100 repetitions. Violations counted per path.
//! Run: `cargo bench --bench fig3_violations -- --reps 100`

use slope_screen::benchkit::Table;
use slope_screen::cli::Args;
use slope_screen::coordinator::{run_grid, GridSpec};
use slope_screen::data::synth::{BetaSpec, DesignKind, SyntheticSpec};
use slope_screen::rng::Pcg64;
use slope_screen::slope::family::Family;
use slope_screen::slope::lambda::{LambdaKind, PathConfig};
use slope_screen::slope::path::{fit_path, NativeGradient, PathOptions};

fn main() {
    let parsed = Args::new("Figure 3: violation prevalence across p")
        .opt("n", "100", "observations")
        .opt("ps", "20,50,100,500,1000", "p grid")
        .opt("rho", "0.5", "correlation")
        .opt("reps", "25", "repetitions per p (paper: 100)")
        .opt("q", "0.1", "BH parameter")
        .opt("kkt-tol", "1e-5", "violation-detection tolerance (relative to sigma*lambda_1)")
        .opt("seed", "2022", "rng seed")
        .flag("bench", "(cargo bench compatibility)")
        .parse();
    let n = parsed.usize("n");
    let rho = parsed.f64("rho");
    let reps = parsed.usize("reps");
    let q = parsed.f64("q");
    let kkt_tol = parsed.f64("kkt-tol");

    let labels: Vec<String> = parsed.usize_list("ps").iter().map(|p| p.to_string()).collect();
    let spec = GridSpec::new(labels, reps, parsed.u64("seed"));
    let results = run_grid(&spec, |gp| {
        let p: usize = gp.label.parse().unwrap();
        let prob = SyntheticSpec {
            n,
            p,
            rho,
            design: DesignKind::Compound,
            beta: BetaSpec::PlusMinus { k: p / 4, scale: 2.0 },
            family: Family::Gaussian,
            noise_sd: 1.0,
            standardize: true,
        }
        .generate(&mut Pcg64::new(gp.seed));
        // Full 100-step path, premature-stop rules disabled (§3.2.2).
        let cfg = PathConfig::new(LambdaKind::Bh { q }).without_early_stopping();
        let mut opts = PathOptions::new(cfg);
        opts.kkt_tol = kkt_tol;
        let fit = fit_path(&prob, &opts, &NativeGradient(&prob));
        // A violation reported from a step whose inner solve never
        // certified is solver noise, not a screening-rule failure —
        // count those steps so they can't contaminate the figure.
        let nonconverged = fit.steps.iter().filter(|s| !s.solver_converged).count();
        (fit.total_violations, fit.steps.len(), nonconverged)
    });

    let mut table = Table::new(
        &format!("Figure 3 — violations per full 100-step path (n={n}, rho={rho}, {reps} reps)"),
        &["p", "mean_violations", "paths_with_violation", "nonconverged_steps", "reps"],
    );
    let mut total_nonconverged = 0usize;
    for p_label in parsed.usize_list("ps") {
        let vals: Vec<&(usize, usize, usize)> = results
            .iter()
            .filter(|(gp, _)| gp.label == p_label.to_string())
            .map(|(_, v)| v)
            .collect();
        let mean_v =
            vals.iter().map(|(v, _, _)| *v as f64).sum::<f64>() / vals.len().max(1) as f64;
        let any = vals.iter().filter(|(v, _, _)| *v > 0).count();
        let nonconv: usize = vals.iter().map(|(_, _, nc)| *nc).sum();
        total_nonconverged += nonconv;
        table.row(vec![
            p_label.to_string(),
            format!("{mean_v:.4}"),
            any.to_string(),
            nonconv.to_string(),
            vals.len().to_string(),
        ]);
    }
    table.print();
    let path = table.write_csv("fig3_violations").expect("csv");
    println!("\nwrote {}", path.display());
    println!("(paper: violations rare overall, concentrated at small p)");
    if total_nonconverged > 0 {
        println!(
            "warning: {total_nonconverged} path steps hit max_iter before certifying — \
             their violation counts are untrustworthy; raise fista.max_iter or loosen --kkt-tol"
        );
    } else {
        println!("all inner solves certified: violation counts are solver-noise free");
    }
}

//! Figure 5: wall time vs p at fixed n — the "no overhead when n ≫ p"
//! claim. iid design, k = p/10, OLS.
//!
//! Paper setup: n = 1000, p varying, 100 repetitions with 95% bands.
//! The crossover where screening starts to pay sits near p ≈ 2n.
//! Run: `cargo bench --bench fig5_scaling -- --reps 5`

use std::time::Instant;

use slope_screen::benchkit::Table;
use slope_screen::cli::Args;
use slope_screen::data::synth::{BetaSpec, DesignKind, SyntheticSpec};
use slope_screen::rng::Pcg64;
use slope_screen::slope::family::Family;
use slope_screen::slope::lambda::{LambdaKind, PathConfig};
use slope_screen::slope::path::{fit_path, NativeGradient, PathOptions, Strategy};

fn main() {
    let parsed = Args::new("Figure 5: time vs p at fixed n (overhead check)")
        .opt("n", "1000", "observations (paper: 1000)")
        .opt("ps", "100,200,500,1000,2000,4000", "p grid")
        .opt("reps", "3", "repetitions (paper: 100)")
        .opt("q", "0.01", "BH parameter")
        .opt("seed", "2024", "rng seed")
        .flag("bench", "(cargo bench compatibility)")
        .parse();
    let n = parsed.usize("n");
    let reps = parsed.usize("reps");

    let mut table = Table::new(
        &format!("Figure 5 — path time vs p (OLS, n={n}, k=p/10, iid design)"),
        &["p", "strategy", "mean_s", "ci95_s", "reps"],
    );
    let mut master = Pcg64::new(parsed.u64("seed"));
    for p in parsed.usize_list("ps") {
        // Paired comparison: the same instances for both strategies.
        let problems: Vec<_> = (0..reps)
            .map(|rep| {
                let mut rng = master.derive((p * 31 + rep) as u64);
                SyntheticSpec {
                    n,
                    p,
                    rho: 0.0,
                    design: DesignKind::Iid,
                    beta: BetaSpec::PlusMinus { k: (p / 10).max(1), scale: 2.0 },
                    family: Family::Gaussian,
                    noise_sd: 1.0,
                    standardize: true,
                }
                .generate(&mut rng)
            })
            .collect();
        for strategy in [Strategy::StrongSet, Strategy::NoScreening] {
            let mut times = Vec::with_capacity(reps);
            for prob in &problems {
                let cfg = PathConfig::new(LambdaKind::Bh { q: parsed.f64("q") });
                let opts = PathOptions::new(cfg).with_strategy(strategy);
                let t = Instant::now();
                let fit = fit_path(prob, &opts, &NativeGradient(prob));
                times.push(t.elapsed().as_secs_f64());
                std::hint::black_box(fit.total_violations);
            }
            let timing = slope_screen::benchkit::Timing::from_samples(times);
            println!(
                "p={p:<6} {:<8} mean={:.3}s ±{:.3}",
                strategy.name(),
                timing.mean(),
                timing.ci95()
            );
            table.row(vec![
                p.to_string(),
                strategy.name().to_string(),
                format!("{:.4}", timing.mean()),
                format!("{:.4}", timing.ci95()),
                reps.to_string(),
            ]);
        }
    }
    table.print();
    let path = table.write_csv("fig5_scaling").expect("csv");
    println!("\nwrote {}", path.display());
    println!("(paper: no penalty at any p; screening starts to win near p ≈ 2n)");
}

//! Repeated k-fold cross-validation over SLOPE paths — the paper's §1
//! motivating workload (`Kkl` fits) — parallelized over the worker pool.

use std::sync::Mutex;

use crate::linalg::{Design, Mat};
use crate::pool::par_for_each;
use crate::rng::Pcg64;
use crate::slope::family::Problem;
use crate::slope::path::{fit_path, NativeGradient, PathFit, PathOptions};

/// Pool of reusable column-major buffers for dense fold extraction.
/// `K·k` fold jobs run over the CV, but only `threads` are in flight at
/// once — so the pool converges to at most `threads` buffers, instead of
/// one fresh `(n − n/k)·p` allocation (plus fault-in) per fold. Fold
/// jobs `take` a buffer, fill it through [`Mat::subset_rows_into`], wrap
/// it in the training [`Problem`], and `put` it back after the fit (see
/// the `subset_rows fresh` vs `subset_rows scratch` microbench rows).
#[derive(Default)]
struct FoldScratch {
    bufs: Mutex<Vec<Vec<f64>>>,
}

impl FoldScratch {
    fn take(&self) -> Vec<f64> {
        self.bufs.lock().unwrap().pop().unwrap_or_default()
    }

    fn put(&self, buf: Vec<f64>) {
        self.bufs.lock().unwrap().push(buf);
    }
}

/// [`subset_problem`] with a pooled buffer for the dense design copy
/// (sparse designs build exactly-sized CSC buffers either way).
fn subset_problem_pooled(prob: &Problem, rows: &[usize], scratch: &FoldScratch) -> Problem {
    let x = match &prob.x {
        Design::Dense(m) => {
            let mut buf = scratch.take();
            m.subset_rows_into(rows, &mut buf);
            Design::Dense(Mat::from_col_major(rows.len(), m.ncols(), buf))
        }
        Design::Sparse(s) => Design::Sparse(s.subset_rows(rows)),
    };
    let y: Vec<f64> = rows.iter().map(|&i| prob.y[i]).collect();
    Problem::new(x, y, prob.family)
}

/// Cross-validation configuration.
#[derive(Clone, Debug)]
pub struct CvConfig {
    /// Folds per repeat (`k`).
    pub folds: usize,
    /// Repeats (`K`).
    pub repeats: usize,
    /// Worker threads (0 = machine default).
    pub threads: usize,
    /// Master seed for the fold shuffles.
    pub seed: u64,
}

impl Default for CvConfig {
    fn default() -> Self {
        Self { folds: 5, repeats: 1, threads: 0, seed: 0xcf01d }
    }
}

/// Per-(repeat, fold) outcome.
#[derive(Clone, Debug)]
pub struct FoldResult {
    /// Repeat index.
    pub repeat: usize,
    /// Fold index.
    pub fold: usize,
    /// Validation deviance per path step (aligned with `sigmas`).
    pub val_deviance: Vec<f64>,
    /// σ grid of this fold's path.
    pub sigmas: Vec<f64>,
    /// Wall time of the path fit (seconds).
    pub fit_time: f64,
    /// Violations encountered.
    pub violations: usize,
}

/// Aggregated cross-validation result.
#[derive(Clone, Debug)]
pub struct CvResult {
    /// All fold results.
    pub folds: Vec<FoldResult>,
    /// Common σ grid (truncated to the shortest fold path).
    pub sigmas: Vec<f64>,
    /// Mean validation deviance per σ.
    pub mean_deviance: Vec<f64>,
    /// Standard error of the validation deviance per σ.
    pub se_deviance: Vec<f64>,
    /// Index of the best σ (minimum mean validation deviance).
    pub best_index: usize,
    /// Total wall time (seconds).
    pub wall_time: f64,
}

/// Run repeated k-fold CV of a SLOPE path on `prob`.
///
/// Every fold fits a full path with `opts` on the training split and
/// scores deviance on the held-out split. Fold jobs run concurrently on a
/// scoped worker pool; each derives an independent RNG stream keyed by
/// `(repeat, fold)`, so results do not depend on scheduling order.
pub fn cross_validate(prob: &Problem, opts: &PathOptions, cfg: &CvConfig) -> CvResult {
    let t0 = std::time::Instant::now();
    let n = prob.n();
    assert!(cfg.folds >= 2, "need at least 2 folds");
    assert!(n >= cfg.folds, "more folds than observations");

    // Pre-draw fold assignments per repeat (deterministic).
    let mut master = Pcg64::new(cfg.seed);
    let assignments: Vec<Vec<usize>> = (0..cfg.repeats)
        .map(|r| {
            let mut rng = master.derive(r as u64);
            let mut idx: Vec<usize> = (0..n).collect();
            rng.shuffle(&mut idx);
            let mut fold_of = vec![0usize; n];
            for (pos, &i) in idx.iter().enumerate() {
                fold_of[i] = pos % cfg.folds;
            }
            fold_of
        })
        .collect();

    let jobs: Vec<(usize, usize)> = (0..cfg.repeats)
        .flat_map(|r| (0..cfg.folds).map(move |f| (r, f)))
        .collect();
    let results: Mutex<Vec<FoldResult>> = Mutex::new(Vec::with_capacity(jobs.len()));
    let threads = if cfg.threads == 0 {
        crate::linalg::par::detected_parallelism()
    } else {
        cfg.threads
    };
    // Fold jobs already saturate the pool; give each fit the per-job
    // share of the kernel-thread budget so the two parallel layers don't
    // multiply (an explicit opts.threads wins).
    let mut fold_opts = if opts.threads == 0 {
        opts.clone().with_threads(crate::pool::fit_thread_budget(threads.min(jobs.len())))
    } else {
        opts.clone()
    };
    // A pack cache is keyed by screened set on ONE design; fold fits run
    // on K different training subsets, so a shared cache could hand one
    // fold another fold's packed columns. Folds pack locally instead.
    fold_opts.pack_cache = None;
    // Same design-identity argument for shared column norms: the parent
    // design's norms do not describe a row-subset training design, and
    // the gap-driven sphere tests must never certify discards from the
    // wrong geometry. Folds compute their own.
    fold_opts.col_norms = None;

    let scratch = FoldScratch::default();
    par_for_each(jobs.len(), threads, |j| {
        let (repeat, fold) = jobs[j];
        let fold_of = &assignments[repeat];
        let train: Vec<usize> = (0..n).filter(|&i| fold_of[i] != fold).collect();
        let valid: Vec<usize> = (0..n).filter(|&i| fold_of[i] == fold).collect();
        let sub = subset_problem_pooled(prob, &train, &scratch);
        let fit = fit_path(&sub, &fold_opts, &NativeGradient(&sub));
        let val = validation_deviance(prob, &valid, &fit);
        // Reclaim the training-design buffer for the next fold job.
        if let Design::Dense(m) = sub.x {
            scratch.put(m.into_data());
        }
        let fr = FoldResult {
            repeat,
            fold,
            val_deviance: val,
            sigmas: fit.sigmas.clone(),
            fit_time: fit.wall_time,
            violations: fit.total_violations,
        };
        results.lock().unwrap().push(fr);
    });

    let mut folds = results.into_inner().unwrap();
    folds.sort_by_key(|f| (f.repeat, f.fold));

    // Align on the shortest path (early stopping may shorten folds).
    let min_len = folds.iter().map(|f| f.sigmas.len()).min().unwrap_or(0);
    let sigmas: Vec<f64> = folds
        .first()
        .map(|f| f.sigmas[..min_len].to_vec())
        .unwrap_or_default();
    let mut mean = vec![0.0; min_len];
    let mut se = vec![0.0; min_len];
    for s in 0..min_len {
        let vals: Vec<f64> = folds.iter().map(|f| f.val_deviance[s]).collect();
        let m = crate::linalg::ops::mean(&vals);
        mean[s] = m;
        let var = vals.iter().map(|v| (v - m) * (v - m)).sum::<f64>()
            / (vals.len().max(2) - 1) as f64;
        se[s] = (var / vals.len() as f64).sqrt();
    }
    // total_cmp: a NaN fold deviance (diverged fit) must never panic the
    // selection — NaN orders last, so a finite σ still wins when any
    // fold produced one.
    let best_index = mean
        .iter()
        .enumerate()
        .min_by(|a, b| a.1.total_cmp(b.1))
        .map(|(i, _)| i)
        .unwrap_or(0);

    CvResult {
        folds,
        sigmas,
        mean_deviance: mean,
        se_deviance: se,
        best_index,
        wall_time: t0.elapsed().as_secs_f64(),
    }
}

/// Restrict a problem to a row subset.
pub fn subset_problem(prob: &Problem, rows: &[usize]) -> Problem {
    let x = match &prob.x {
        Design::Dense(m) => Design::Dense(m.subset_rows(rows)),
        Design::Sparse(s) => Design::Sparse(s.subset_rows(rows)),
    };
    let y: Vec<f64> = rows.iter().map(|&i| prob.y[i]).collect();
    Problem::new(x, y, prob.family)
}

/// Held-out deviance of each path step's solution.
fn validation_deviance(prob: &Problem, valid: &[usize], fit: &PathFit) -> Vec<f64> {
    let sub = subset_problem(prob, valid);
    let pt = prob.p_total();
    let m = prob.family.n_classes();
    let nv = valid.len();
    let mut out = Vec::with_capacity(fit.sigmas.len());
    let mut eta = vec![0.0; nv * m];
    let mut h = vec![0.0; nv * m];
    for step in 0..fit.sigmas.len() {
        let beta = fit.beta_at(step, pt);
        sub.eta(&beta, &mut eta);
        let loss = sub.family.h_loss(&eta, &sub.y, &mut h);
        out.push(sub.family.deviance(loss, &sub.y) / nv as f64);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{BetaSpec, DesignKind, SyntheticSpec};
    use crate::slope::family::Family;
    use crate::slope::lambda::{LambdaKind, PathConfig};

    fn toy_problem(seed: u64) -> Problem {
        SyntheticSpec {
            n: 60,
            p: 30,
            rho: 0.2,
            design: DesignKind::Compound,
            beta: BetaSpec::PlusMinus { k: 4, scale: 2.0 },
            family: Family::Gaussian,
            noise_sd: 0.5,
            standardize: true,
        }
        .generate(&mut Pcg64::new(seed))
    }

    fn toy_opts() -> PathOptions {
        let mut cfg = PathConfig::new(LambdaKind::Bh { q: 0.1 });
        cfg.length = 12;
        PathOptions::new(cfg)
    }

    #[test]
    fn cv_runs_all_folds() {
        let prob = toy_problem(1);
        let cfg = CvConfig { folds: 4, repeats: 2, threads: 4, seed: 7 };
        let res = cross_validate(&prob, &toy_opts(), &cfg);
        assert_eq!(res.folds.len(), 8);
        assert!(!res.sigmas.is_empty());
        assert_eq!(res.mean_deviance.len(), res.sigmas.len());
        assert!(res.best_index < res.sigmas.len());
    }

    #[test]
    fn cv_is_deterministic_across_thread_counts() {
        let prob = toy_problem(2);
        let cfg1 = CvConfig { folds: 3, repeats: 1, threads: 1, seed: 9 };
        let cfg4 = CvConfig { folds: 3, repeats: 1, threads: 4, seed: 9 };
        let r1 = cross_validate(&prob, &toy_opts(), &cfg1);
        let r4 = cross_validate(&prob, &toy_opts(), &cfg4);
        assert_eq!(r1.mean_deviance.len(), r4.mean_deviance.len());
        for (a, b) in r1.mean_deviance.iter().zip(&r4.mean_deviance) {
            assert!((a - b).abs() < 1e-12, "{a} vs {b}");
        }
    }

    #[test]
    fn cv_selects_interior_sigma_for_signal_data() {
        // With real signal, the best σ should not be the very first
        // (all-zero model) grid point.
        let prob = toy_problem(3);
        let cfg = CvConfig { folds: 5, repeats: 1, threads: 4, seed: 11 };
        let res = cross_validate(&prob, &toy_opts(), &cfg);
        assert!(res.best_index > 0, "best_index = {}", res.best_index);
    }

    #[test]
    fn pooled_subset_matches_fresh_subset() {
        let prob = toy_problem(6);
        let scratch = FoldScratch::default();
        let rows: Vec<usize> = (0..prob.n()).filter(|i| i % 3 != 0).collect();
        let fresh = subset_problem(&prob, &rows);
        let pooled = subset_problem_pooled(&prob, &rows, &scratch);
        assert_eq!(pooled.y, fresh.y);
        let (a, b) = (pooled.x.as_dense().unwrap(), fresh.x.as_dense().unwrap());
        assert_eq!(a.data(), b.data());
        // returning the buffer and extracting again reuses it
        if let Design::Dense(m) = pooled.x {
            scratch.put(m.into_data());
        }
        let again = subset_problem_pooled(&prob, &[0, 2, 4], &scratch);
        assert_eq!(again.n(), 3);
        assert_eq!(again.x.as_dense().unwrap().data(), prob.x.as_dense().unwrap().subset_rows(&[0, 2, 4]).data());
    }

    #[test]
    fn subset_problem_shapes() {
        let prob = toy_problem(4);
        let sub = subset_problem(&prob, &[0, 5, 10]);
        assert_eq!(sub.n(), 3);
        assert_eq!(sub.p(), prob.p());
        assert_eq!(sub.y[1], prob.y[5]);
    }

    #[test]
    fn validation_deviance_decreases_from_null() {
        let prob = toy_problem(5);
        let cfg = CvConfig { folds: 3, repeats: 1, threads: 2, seed: 13 };
        let res = cross_validate(&prob, &toy_opts(), &cfg);
        // best mean deviance beats the null (first step) deviance
        assert!(res.mean_deviance[res.best_index] < res.mean_deviance[0]);
    }
}

//! Layer-3 coordination: cross-validation and experiment orchestration.
//!
//! The paper's motivating workload (§1) is `K`-times repeated `k`-fold
//! cross-validation over a full regularization path — `K·k·l` model fits.
//! [`cv`] runs the fold×repeat grid over the [`crate::pool`] worker pool
//! with per-job derived RNG streams (bit-reproducible regardless of
//! scheduling), and [`experiment`] provides the shared simulation driver
//! the paper-figure benches are built on. [`report`] renders/persists
//! result tables.

pub mod cv;
pub mod experiment;
pub mod report;

pub use cv::{cross_validate, CvConfig, CvResult};
pub use experiment::{run_grid, DataSource, GridPoint, GridSpec};

//! Result persistence: JSON experiment records under `results/`.

use std::path::PathBuf;

use anyhow::{Context, Result};

use crate::jsonio::Json;

/// Write an experiment record to `results/<name>.json` and return the
/// path. Records are append-friendly: each run overwrites its own file,
/// EXPERIMENTS.md references them by name.
pub fn write_json(name: &str, payload: Json) -> Result<PathBuf> {
    let dir = crate::benchkit::results_dir();
    std::fs::create_dir_all(&dir).context("creating results dir")?;
    let path = dir.join(format!("{name}.json"));
    std::fs::write(&path, payload.to_string())
        .with_context(|| format!("writing {}", path.display()))?;
    Ok(path)
}

/// Read an experiment record back (used by tests and the CLI `report`
/// subcommand).
pub fn read_json(name: &str) -> Result<Json> {
    let path = crate::benchkit::results_dir().join(format!("{name}.json"));
    let text =
        std::fs::read_to_string(&path).with_context(|| format!("reading {}", path.display()))?;
    Json::parse(&text).map_err(|e| anyhow::anyhow!("parsing {}: {e}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_record() {
        let payload = Json::obj(vec![
            ("experiment", Json::Str("selftest".into())),
            ("values", Json::nums(&[1.0, 2.5])),
        ]);
        let path = write_json("_report_selftest", payload.clone()).unwrap();
        let back = read_json("_report_selftest").unwrap();
        assert_eq!(back, payload);
        let _ = std::fs::remove_file(path);
    }
}

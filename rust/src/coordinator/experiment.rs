//! Shared simulation driver for the paper-figure benches: run a grid of
//! (parameter, repetition) jobs over the worker pool with derived RNG
//! streams and collect per-job summaries.

use std::sync::Mutex;

use crate::pool::par_for_each;
use crate::rng::Pcg64;

/// One cell of a parameter grid.
#[derive(Clone, Debug)]
pub struct GridPoint {
    /// Human-readable label (e.g. `"rho=0.5"`).
    pub label: String,
    /// Repetition index.
    pub rep: usize,
    /// Derived RNG seed for this job.
    pub seed: u64,
}

/// Grid specification: labels × repetitions.
#[derive(Clone, Debug)]
pub struct GridSpec {
    /// Cell labels.
    pub labels: Vec<String>,
    /// Repetitions per cell.
    pub reps: usize,
    /// Master seed.
    pub seed: u64,
    /// Worker threads (0 = default).
    pub threads: usize,
}

impl GridSpec {
    /// Build from labels.
    pub fn new(labels: Vec<String>, reps: usize, seed: u64) -> GridSpec {
        GridSpec { labels, reps, seed, threads: 0 }
    }

    /// Expand into concrete jobs with derived seeds.
    pub fn jobs(&self) -> Vec<GridPoint> {
        let mut master = Pcg64::new(self.seed);
        let mut out = Vec::with_capacity(self.labels.len() * self.reps);
        for (ci, label) in self.labels.iter().enumerate() {
            for rep in 0..self.reps {
                let seed = master.derive((ci * self.reps + rep) as u64).next_u64();
                out.push(GridPoint { label: label.clone(), rep, seed });
            }
        }
        out
    }
}

/// Run `f` for every grid job in parallel, collecting `(job, result)`
/// pairs in deterministic (label, rep) order.
pub fn run_grid<T, F>(spec: &GridSpec, f: F) -> Vec<(GridPoint, T)>
where
    T: Send,
    F: Fn(&GridPoint) -> T + Sync,
{
    let jobs = spec.jobs();
    let threads = if spec.threads == 0 {
        crate::linalg::par::detected_parallelism()
    } else {
        spec.threads
    };
    let slots: Vec<Mutex<Option<T>>> = jobs.iter().map(|_| Mutex::new(None)).collect();
    par_for_each(jobs.len(), threads, |i| {
        let out = f(&jobs[i]);
        *slots[i].lock().unwrap() = Some(out);
    });
    jobs.into_iter()
        .zip(slots)
        .map(|(j, s)| (j, s.into_inner().unwrap().expect("grid job unfilled")))
        .collect()
}

/// Aggregate per-label means over repetitions: returns
/// `(label, mean, sd)` triples in first-appearance order.
pub fn summarize_by_label<T, F>(results: &[(GridPoint, T)], metric: F) -> Vec<(String, f64, f64)>
where
    F: Fn(&T) -> f64,
{
    let mut order: Vec<String> = Vec::new();
    for (gp, _) in results {
        if !order.contains(&gp.label) {
            order.push(gp.label.clone());
        }
    }
    order
        .into_iter()
        .map(|label| {
            let vals: Vec<f64> = results
                .iter()
                .filter(|(gp, _)| gp.label == label)
                .map(|(_, t)| metric(t))
                .collect();
            let m = crate::linalg::ops::mean(&vals);
            let sd = if vals.len() > 1 {
                (vals.iter().map(|v| (v - m) * (v - m)).sum::<f64>()
                    / (vals.len() - 1) as f64)
                    .sqrt()
            } else {
                0.0
            };
            (label, m, sd)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn jobs_expand_deterministically() {
        let spec = GridSpec::new(vec!["a".into(), "b".into()], 3, 42);
        let j1 = spec.jobs();
        let j2 = spec.jobs();
        assert_eq!(j1.len(), 6);
        for (a, b) in j1.iter().zip(&j2) {
            assert_eq!(a.seed, b.seed);
            assert_eq!(a.label, b.label);
        }
        // seeds distinct
        let mut seeds: Vec<u64> = j1.iter().map(|j| j.seed).collect();
        seeds.sort_unstable();
        seeds.dedup();
        assert_eq!(seeds.len(), 6);
    }

    #[test]
    fn run_grid_preserves_order_and_runs_all() {
        let spec = GridSpec::new(vec!["x".into(), "y".into()], 4, 1);
        let results = run_grid(&spec, |gp| gp.seed as f64);
        assert_eq!(results.len(), 8);
        assert_eq!(results[0].0.label, "x");
        assert_eq!(results[7].0.label, "y");
        for (gp, v) in &results {
            assert_eq!(*v, gp.seed as f64);
        }
    }

    #[test]
    fn summarize_groups_by_label() {
        let spec = GridSpec::new(vec!["a".into(), "b".into()], 2, 3);
        let results = run_grid(&spec, |gp| if gp.label == "a" { 1.0 } else { 3.0 });
        let summary = summarize_by_label(&results, |&v| v);
        assert_eq!(summary.len(), 2);
        assert_eq!(summary[0], ("a".to_string(), 1.0, 0.0));
        assert_eq!(summary[1].1, 3.0);
    }
}

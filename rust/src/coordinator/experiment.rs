//! Shared simulation driver for the paper-figure benches: run a grid of
//! (parameter, repetition) jobs over the worker pool with derived RNG
//! streams and collect per-job summaries — plus [`DataSource`], the
//! uniform way benches and experiments name a dataset (a simulated
//! stand-in *or* a user-supplied file ingested through
//! [`crate::ingest`]).

use std::path::PathBuf;
use std::sync::Mutex;

use crate::data::real::RealDataset;
use crate::ingest::{self, IngestOptions};
use crate::pool::par_for_each;
use crate::rng::Pcg64;
use crate::slope::family::{Family, Problem};

/// Where an experiment's dataset comes from.
///
/// Spec grammar (the benches' `--datasets` entries):
///
/// * a stand-in name — `golub`, `dorothea`, … (loaded with its Table-3
///   family and the benches' canonical seeds);
/// * `file:PATH` — ingest a dense CSV or sparse svmlight file, gaussian
///   response;
/// * `file:PATH@FAMILY` / `file:PATH@multinomial:CLASSES` — explicit
///   response family.
#[derive(Clone, Debug)]
pub enum DataSource {
    /// One of the seven simulated real-dataset stand-ins.
    Standin(RealDataset),
    /// A data file ingested through [`crate::ingest`].
    File {
        /// File path (`.csv` dense, `.svm`/`.svmlight`/`.libsvm` sparse).
        path: PathBuf,
        /// Response family for the fit.
        family: Family,
    },
}

impl DataSource {
    /// Parse a `--datasets` entry (see the type-level grammar).
    pub fn parse(spec: &str) -> Result<DataSource, String> {
        if let Some(rest) = spec.strip_prefix("file:") {
            let (path, fam_spec) = match rest.rsplit_once('@') {
                Some((p, f)) => (p, f),
                None => (rest, "gaussian"),
            };
            if path.is_empty() {
                return Err(format!("`{spec}`: empty file path"));
            }
            let (name, classes) = match fam_spec.split_once(':') {
                Some((f, c)) => (
                    f,
                    c.parse::<usize>().map_err(|e| format!("`{spec}`: classes: {e}"))?,
                ),
                None => (fam_spec, 2),
            };
            let family = Family::parse(name, classes).map_err(|e| format!("`{spec}`: {e}"))?;
            Ok(DataSource::File { path: PathBuf::from(path), family })
        } else {
            RealDataset::all()
                .into_iter()
                .find(|d| d.name() == spec)
                .map(DataSource::Standin)
                .ok_or_else(|| {
                    format!("unknown dataset `{spec}` (expected a stand-in name or file:PATH[@family])")
                })
        }
    }

    /// Display name for tables and logs.
    pub fn name(&self) -> String {
        match self {
            DataSource::Standin(ds) => ds.name().to_string(),
            DataSource::File { path, .. } => path
                .file_name()
                .and_then(|n| n.to_str())
                .unwrap_or("file")
                .to_string(),
        }
    }

    /// Materialize the problem. Stand-ins load with their Table-3 family
    /// under the real-data benches' canonical seeds (golub keeps its
    /// default binomial load), so the existing bench rows are unchanged;
    /// files are ingested with standardization on (re-standardizing an
    /// already-standardized export is numerically a no-op at the 1e-16
    /// level).
    pub fn load(&self) -> Result<Problem, String> {
        match self {
            DataSource::Standin(ds) => Ok(match ds {
                RealDataset::Golub => ds.load(),
                _ => ds.load_with(ds.table3_family(), 0x7ab3 + ds.dims().0 as u64),
            }),
            DataSource::File { path, family } => {
                let opts = IngestOptions::default().with_family(*family);
                ingest::load_path(path, &opts)
                    .map(|ing| ing.problem)
                    .map_err(|e| format!("{}: {e}", path.display()))
            }
        }
    }
}

/// One cell of a parameter grid.
#[derive(Clone, Debug)]
pub struct GridPoint {
    /// Human-readable label (e.g. `"rho=0.5"`).
    pub label: String,
    /// Repetition index.
    pub rep: usize,
    /// Derived RNG seed for this job.
    pub seed: u64,
}

/// Grid specification: labels × repetitions.
#[derive(Clone, Debug)]
pub struct GridSpec {
    /// Cell labels.
    pub labels: Vec<String>,
    /// Repetitions per cell.
    pub reps: usize,
    /// Master seed.
    pub seed: u64,
    /// Worker threads (0 = default).
    pub threads: usize,
}

impl GridSpec {
    /// Build from labels.
    pub fn new(labels: Vec<String>, reps: usize, seed: u64) -> GridSpec {
        GridSpec { labels, reps, seed, threads: 0 }
    }

    /// Expand into concrete jobs with derived seeds.
    pub fn jobs(&self) -> Vec<GridPoint> {
        let mut master = Pcg64::new(self.seed);
        let mut out = Vec::with_capacity(self.labels.len() * self.reps);
        for (ci, label) in self.labels.iter().enumerate() {
            for rep in 0..self.reps {
                let seed = master.derive((ci * self.reps + rep) as u64).next_u64();
                out.push(GridPoint { label: label.clone(), rep, seed });
            }
        }
        out
    }
}

/// Run `f` for every grid job in parallel, collecting `(job, result)`
/// pairs in deterministic (label, rep) order.
pub fn run_grid<T, F>(spec: &GridSpec, f: F) -> Vec<(GridPoint, T)>
where
    T: Send,
    F: Fn(&GridPoint) -> T + Sync,
{
    let jobs = spec.jobs();
    let threads = if spec.threads == 0 {
        crate::linalg::par::detected_parallelism()
    } else {
        spec.threads
    };
    let slots: Vec<Mutex<Option<T>>> = jobs.iter().map(|_| Mutex::new(None)).collect();
    par_for_each(jobs.len(), threads, |i| {
        let out = f(&jobs[i]);
        *slots[i].lock().unwrap() = Some(out);
    });
    jobs.into_iter()
        .zip(slots)
        .map(|(j, s)| (j, s.into_inner().unwrap().expect("grid job unfilled")))
        .collect()
}

/// Aggregate per-label means over repetitions: returns
/// `(label, mean, sd)` triples in first-appearance order.
pub fn summarize_by_label<T, F>(results: &[(GridPoint, T)], metric: F) -> Vec<(String, f64, f64)>
where
    F: Fn(&T) -> f64,
{
    let mut order: Vec<String> = Vec::new();
    for (gp, _) in results {
        if !order.contains(&gp.label) {
            order.push(gp.label.clone());
        }
    }
    order
        .into_iter()
        .map(|label| {
            let vals: Vec<f64> = results
                .iter()
                .filter(|(gp, _)| gp.label == label)
                .map(|(_, t)| metric(t))
                .collect();
            let m = crate::linalg::ops::mean(&vals);
            let sd = if vals.len() > 1 {
                (vals.iter().map(|v| (v - m) * (v - m)).sum::<f64>()
                    / (vals.len() - 1) as f64)
                    .sqrt()
            } else {
                0.0
            };
            (label, m, sd)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn data_source_parses_standins_and_files() {
        assert!(matches!(
            DataSource::parse("golub"),
            Ok(DataSource::Standin(RealDataset::Golub))
        ));
        match DataSource::parse("file:/tmp/x.svm@binomial").unwrap() {
            DataSource::File { path, family } => {
                assert_eq!(path, PathBuf::from("/tmp/x.svm"));
                assert_eq!(family, Family::Binomial);
            }
            other => panic!("wrong source: {other:?}"),
        }
        match DataSource::parse("file:/tmp/z.csv@multinomial:10").unwrap() {
            DataSource::File { family, .. } => {
                assert_eq!(family, Family::Multinomial { classes: 10 });
            }
            other => panic!("wrong source: {other:?}"),
        }
        // default family is gaussian
        match DataSource::parse("file:/tmp/a.csv").unwrap() {
            DataSource::File { family, .. } => assert_eq!(family, Family::Gaussian),
            other => panic!("wrong source: {other:?}"),
        }
        assert!(DataSource::parse("nosuch").is_err());
        assert!(DataSource::parse("file:").is_err());
        assert!(DataSource::parse("file:/tmp/a.csv@tobit").is_err());
    }

    #[test]
    fn data_source_file_load_round_trips_an_export() {
        let path = std::env::temp_dir()
            .join(format!("slope-datasource-{}.csv", std::process::id()));
        std::fs::write(&path, "x1,x2,y\n0.5,1,2\n-0.5,0,1\n0.25,2,0\n").unwrap();
        let src = DataSource::parse(&format!("file:{}", path.display())).unwrap();
        assert_eq!(src.name(), path.file_name().unwrap().to_str().unwrap());
        let prob = src.load().unwrap();
        assert_eq!((prob.n(), prob.p()), (3, 2));
        assert_eq!(prob.family, Family::Gaussian);
        let _ = std::fs::remove_file(&path);
        assert!(src.load().is_err());
    }

    #[test]
    fn jobs_expand_deterministically() {
        let spec = GridSpec::new(vec!["a".into(), "b".into()], 3, 42);
        let j1 = spec.jobs();
        let j2 = spec.jobs();
        assert_eq!(j1.len(), 6);
        for (a, b) in j1.iter().zip(&j2) {
            assert_eq!(a.seed, b.seed);
            assert_eq!(a.label, b.label);
        }
        // seeds distinct
        let mut seeds: Vec<u64> = j1.iter().map(|j| j.seed).collect();
        seeds.sort_unstable();
        seeds.dedup();
        assert_eq!(seeds.len(), 6);
    }

    #[test]
    fn run_grid_preserves_order_and_runs_all() {
        let spec = GridSpec::new(vec!["x".into(), "y".into()], 4, 1);
        let results = run_grid(&spec, |gp| gp.seed as f64);
        assert_eq!(results.len(), 8);
        assert_eq!(results[0].0.label, "x");
        assert_eq!(results[7].0.label, "y");
        for (gp, v) in &results {
            assert_eq!(*v, gp.seed as f64);
        }
    }

    #[test]
    fn summarize_groups_by_label() {
        let spec = GridSpec::new(vec!["a".into(), "b".into()], 2, 3);
        let results = run_grid(&spec, |gp| if gp.label == "a" { 1.0 } else { 3.0 });
        let summary = summarize_by_label(&results, |&v| v);
        assert_eq!(summary.len(), 2);
        assert_eq!(summary[0], ("a".to_string(), 1.0, 0.0));
        assert_eq!(summary[1].1, 3.0);
    }
}

//! The XLA-served full-design gradient: implements
//! [`crate::slope::path::FullGradient`] on top of a compiled artifact.
//!
//! Construction pads the (dense) design matrix to its manifest bucket and
//! uploads it to the device **once**; every call afterwards uploads only
//! the `p·m` coefficient vector and downloads the `p·m` gradient — the
//! O(np) product itself runs inside the AOT-compiled JAX/Pallas program.
//! Zero padding is exact for all four families (DESIGN.md §8, verified in
//! `python/tests/test_kernels.py::test_zero_padding_preserves_gradient`).

use anyhow::{anyhow, Context, Result};

use crate::slope::family::{Family, Problem};
use crate::slope::path::FullGradient;

use super::artifact::Manifest;
use super::pjrt::{execute_f64, Engine};

/// Family code used by the Python side.
pub fn family_code(f: Family) -> &'static str {
    match f {
        Family::Gaussian => "gaussian",
        Family::Binomial => "binomial",
        Family::Poisson => "poisson",
        Family::Multinomial { .. } => "multinomial",
    }
}

/// Artifact-backed gradient evaluator.
pub struct ArtifactGradient {
    exe: xla::PjRtLoadedExecutable,
    engine: Engine,
    x_buf: xla::PjRtBuffer,
    y_buf: xla::PjRtBuffer,
    /// true dims
    n: usize,
    p: usize,
    m: usize,
    /// bucket dims
    nb: usize,
    pb: usize,
}

impl ArtifactGradient {
    /// Build for a (dense) problem from the artifact directory. Fails with
    /// a clear message when no bucket covers the shape (re-run
    /// `make artifacts` or `aot.py --full`).
    pub fn new(manifest: &Manifest, prob: &Problem) -> Result<ArtifactGradient> {
        let engine = Engine::cpu()?;
        Self::with_engine(engine, manifest, prob)
    }

    /// Build reusing an existing engine.
    pub fn with_engine(
        engine: Engine,
        manifest: &Manifest,
        prob: &Problem,
    ) -> Result<ArtifactGradient> {
        let x = prob
            .x
            .as_dense()
            .ok_or_else(|| anyhow!("XLA gradient engine requires a dense design"))?;
        let (n, p) = (prob.n(), prob.p());
        let m = prob.family.n_classes();
        let code = family_code(prob.family);
        let entry = manifest.find_grad(code, n, p, m).ok_or_else(|| {
            anyhow!(
                "no artifact bucket for family={code} n={n} p={p} m={m}; \
                 run `python -m compile.aot --full`"
            )
        })?;
        let (nb, pb) = (entry.n, entry.p);
        let exe = engine.load_hlo(&manifest.path_of(entry))?;

        // Pad X (row-major for XLA) once.
        let mut xpad = vec![0.0f64; nb * pb];
        for i in 0..n {
            for j in 0..p {
                xpad[i * pb + j] = x.get(i, j);
            }
        }
        let x_buf = engine.upload(&xpad, &[nb, pb])?;

        // Pad y once. Multinomial expects one-hot (nb, m); padded rows are
        // all-zero (their X row is zero, so they contribute nothing).
        let y_buf = if m == 1 {
            let mut ypad = vec![0.0f64; nb];
            ypad[..n].copy_from_slice(&prob.y);
            engine.upload(&ypad, &[nb])?
        } else {
            let mut ypad = vec![0.0f64; nb * m];
            for (i, &cls) in prob.y.iter().enumerate() {
                ypad[i * m + cls as usize] = 1.0;
            }
            engine.upload(&ypad, &[nb, m])?
        };

        Ok(ArtifactGradient { exe, engine, x_buf, y_buf, n, p, m, nb, pb })
    }

    /// The padded bucket shape (for diagnostics / EXPERIMENTS.md).
    pub fn bucket(&self) -> (usize, usize) {
        (self.nb, self.pb)
    }

    /// Padding overhead factor in FLOPs (`nb·pb / (n·p)`).
    pub fn padding_overhead(&self) -> f64 {
        (self.nb * self.pb) as f64 / (self.n * self.p) as f64
    }

    fn run(&self, beta: &[f64]) -> Result<Vec<f64>> {
        // beta arrives flattened class-major `[class][predictor]`; the
        // artifact wants (p, m) row-major = predictor-major.
        let beta_buf = if self.m == 1 {
            let mut bpad = vec![0.0f64; self.pb];
            bpad[..self.p].copy_from_slice(beta);
            self.engine.upload(&bpad, &[self.pb])?
        } else {
            let mut bpad = vec![0.0f64; self.pb * self.m];
            for l in 0..self.m {
                for j in 0..self.p {
                    bpad[j * self.m + l] = beta[l * self.p + j];
                }
            }
            self.engine.upload(&bpad, &[self.pb, self.m])?
        };
        let out = execute_f64(&self.exe, &[&self.x_buf, &beta_buf, &self.y_buf])
            .context("artifact gradient execution")?;
        // unpad (and transpose back for multinomial)
        let mut grad = vec![0.0f64; self.p * self.m];
        if self.m == 1 {
            grad.copy_from_slice(&out[..self.p]);
        } else {
            for l in 0..self.m {
                for j in 0..self.p {
                    grad[l * self.p + j] = out[j * self.m + l];
                }
            }
        }
        Ok(grad)
    }
}

impl FullGradient for ArtifactGradient {
    fn full_grad(&self, beta: &[f64], _h: &[f64], grad: &mut [f64]) {
        let out = self
            .run(beta)
            .expect("artifact gradient execution failed (see stderr)");
        grad.copy_from_slice(&out);
    }

    fn label(&self) -> &'static str {
        "xla-artifact"
    }
}

/// Screening-criterion scan served by the `screen_p*` artifact: computes
/// `cumsum(c↓ − λ)` on-device. Exposed for the quickstart and tests; the
/// production path keeps this O(p) step native since sorting already
/// happens host-side.
pub struct ScreenExecutor {
    exe: xla::PjRtLoadedExecutable,
    engine: Engine,
    pb: usize,
}

impl ScreenExecutor {
    /// Load the smallest screen artifact covering `p`.
    pub fn new(manifest: &Manifest, p: usize) -> Result<ScreenExecutor> {
        let engine = Engine::cpu()?;
        let entry = manifest
            .find_screen(p)
            .ok_or_else(|| anyhow!("no screen artifact covers p={p}"))?;
        let exe = engine.load_hlo(&manifest.path_of(entry))?;
        Ok(ScreenExecutor { exe, engine, pb: entry.p })
    }

    /// `cumsum(c_sorted − λ)` (length = true p). Padding uses c = 0 and
    /// λ = λ_min so padded entries never flip the criterion sign upward.
    pub fn cumsum(&self, c_sorted: &[f64], lambda: &[f64]) -> Result<Vec<f64>> {
        let p = c_sorted.len();
        let mut cpad = vec![0.0f64; self.pb];
        cpad[..p].copy_from_slice(c_sorted);
        let mut lpad = vec![*lambda.last().unwrap_or(&0.0); self.pb];
        lpad[..p].copy_from_slice(&lambda[..p]);
        let cb = self.engine.upload(&cpad, &[self.pb])?;
        let lb = self.engine.upload(&lpad, &[self.pb])?;
        let out = execute_f64(&self.exe, &[&cb, &lb])?;
        Ok(out[..p].to_vec())
    }
}

//! Thin wrapper over the `xla` crate's PJRT CPU client.
//!
//! Follows /opt/xla-example/load_hlo: HLO **text** → `HloModuleProto`
//! (the text parser reassigns instruction ids, sidestepping the 64-bit-id
//! incompatibility between jax ≥ 0.5 protos and xla_extension 0.5.1) →
//! `XlaComputation` → `PjRtLoadedExecutable`.

use std::path::Path;

use anyhow::{Context, Result};

/// A PJRT client plus compile helpers.
pub struct Engine {
    client: xla::PjRtClient,
}

impl Engine {
    /// Create the CPU PJRT client.
    pub fn cpu() -> Result<Engine> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine { client })
    }

    /// Platform string (e.g. `"cpu"`), for logs.
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Borrow the underlying client.
    pub fn client(&self) -> &xla::PjRtClient {
        &self.client
    }

    /// Load an HLO-text artifact and compile it for this client.
    pub fn load_hlo(&self, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))
    }

    /// Upload an f64 host buffer to the device.
    pub fn upload(&self, data: &[f64], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer::<f64>(data, dims, None)
            .context("uploading buffer")
    }
}

/// Execute with device buffers and return the first output as a flat f64
/// vector (artifacts are lowered with `return_tuple=True`, so the single
/// result sits inside a 1-tuple).
pub fn execute_f64(
    exe: &xla::PjRtLoadedExecutable,
    args: &[&xla::PjRtBuffer],
) -> Result<Vec<f64>> {
    let out = exe.execute_b(args).context("executing artifact")?;
    let lit = out[0][0].to_literal_sync().context("fetching result")?;
    let tup = lit.to_tuple1().context("unwrapping 1-tuple result")?;
    tup.to_vec::<f64>().context("converting result to f64")
}

//! Artifact manifest: the handshake between `python/compile/aot.py` and
//! the Rust runtime. Parses `manifest.json`, resolves the smallest shape
//! bucket covering a problem, and exposes the padding contract.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::jsonio::Json;

/// One artifact entry from the manifest.
#[derive(Clone, Debug, PartialEq)]
pub struct Entry {
    /// `"grad"` or `"screen"`.
    pub kind: String,
    /// Family code (`gaussian`/`binomial`/`poisson`/`multinomial`).
    pub family: String,
    /// Padded row bucket.
    pub n: usize,
    /// Padded predictor bucket.
    pub p: usize,
    /// Classes (1 except multinomial).
    pub m: usize,
    /// File name relative to the artifact directory.
    pub file: String,
}

/// Parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    /// Artifact directory.
    pub dir: PathBuf,
    /// Element dtype (always `f64` — see DESIGN.md §8).
    pub dtype: String,
    /// Shapes are padded to multiples of this.
    pub pad_multiple: usize,
    /// All artifacts.
    pub entries: Vec<Entry>,
}

impl Manifest {
    /// Load `manifest.json` from an artifact directory.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`)", path.display()))?;
        let json = Json::parse(&text).map_err(|e| anyhow!("manifest parse: {e}"))?;
        let entries = json
            .field("entries")
            .ok_or_else(|| anyhow!("manifest missing `entries`"))?
            .items()
            .iter()
            .map(|e| -> Result<Entry> {
                Ok(Entry {
                    kind: field_str(e, "kind")?,
                    family: field_str(e, "family")?,
                    n: field_usize(e, "n")?,
                    p: field_usize(e, "p")?,
                    m: field_usize(e, "m")?,
                    file: field_str(e, "file")?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(Manifest {
            dir: dir.to_path_buf(),
            dtype: field_str(&json, "dtype").unwrap_or_else(|_| "f64".into()),
            pad_multiple: field_usize(&json, "pad_multiple").unwrap_or(64),
            entries,
        })
    }

    /// Find the smallest gradient bucket covering `(family, n, p, m)`.
    pub fn find_grad(&self, family: &str, n: usize, p: usize, m: usize) -> Option<&Entry> {
        self.entries
            .iter()
            .filter(|e| {
                e.kind == "grad" && e.family == family && e.m == m && e.n >= n && e.p >= p
            })
            .min_by_key(|e| e.n * e.p)
    }

    /// Find the smallest screening-scan bucket covering `p`.
    pub fn find_screen(&self, p: usize) -> Option<&Entry> {
        self.entries
            .iter()
            .filter(|e| e.kind == "screen" && e.p >= p)
            .min_by_key(|e| e.p)
    }

    /// Absolute path of an entry's HLO file.
    pub fn path_of(&self, entry: &Entry) -> PathBuf {
        self.dir.join(&entry.file)
    }
}

fn field_str(j: &Json, k: &str) -> Result<String> {
    j.field(k)
        .and_then(|v| v.as_str())
        .map(str::to_string)
        .ok_or_else(|| anyhow!("manifest entry missing `{k}`"))
}

fn field_usize(j: &Json, k: &str) -> Result<usize> {
    j.field(k)
        .and_then(|v| v.as_usize())
        .ok_or_else(|| anyhow!("manifest entry missing `{k}`"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_manifest() -> Manifest {
        let dir = std::env::temp_dir().join("slope_screen_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"version":1,"dtype":"f64","pad_multiple":64,"entries":[
              {"kind":"grad","family":"gaussian","n":128,"p":512,"m":1,"file":"a.hlo.txt"},
              {"kind":"grad","family":"gaussian","n":256,"p":5056,"m":1,"file":"b.hlo.txt"},
              {"kind":"grad","family":"multinomial","n":128,"p":512,"m":3,"file":"c.hlo.txt"},
              {"kind":"screen","family":"","n":0,"p":512,"m":1,"file":"s.hlo.txt"}
            ]}"#,
        )
        .unwrap();
        Manifest::load(&dir).unwrap()
    }

    #[test]
    fn parses_and_indexes() {
        let m = toy_manifest();
        assert_eq!(m.entries.len(), 4);
        assert_eq!(m.pad_multiple, 64);
        assert_eq!(m.dtype, "f64");
    }

    #[test]
    fn bucket_selection_prefers_smallest_cover() {
        let m = toy_manifest();
        let e = m.find_grad("gaussian", 100, 500, 1).unwrap();
        assert_eq!(e.file, "a.hlo.txt");
        let e2 = m.find_grad("gaussian", 200, 500, 1).unwrap();
        assert_eq!(e2.file, "b.hlo.txt");
        assert!(m.find_grad("gaussian", 300, 500, 1).is_none());
        assert!(m.find_grad("poisson", 10, 10, 1).is_none());
    }

    #[test]
    fn multinomial_requires_matching_m() {
        let m = toy_manifest();
        assert!(m.find_grad("multinomial", 100, 500, 3).is_some());
        assert!(m.find_grad("multinomial", 100, 500, 4).is_none());
    }

    #[test]
    fn screen_lookup() {
        let m = toy_manifest();
        assert_eq!(m.find_screen(300).unwrap().p, 512);
        assert!(m.find_screen(1000).is_none());
    }

    #[test]
    fn missing_manifest_is_helpful() {
        let err = Manifest::load(Path::new("/nonexistent")).unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }
}

//! PJRT runtime: loads the AOT-compiled JAX/Pallas gradient artifacts
//! (`artifacts/*.hlo.txt`) and serves full-design gradients on the
//! screening/KKT hot path.
//!
//! Python is **never** on this path: `make artifacts` lowers the Layer-2
//! graphs once; afterwards the Rust binary is self-contained — it parses
//! the HLO text with the `xla` crate, compiles it on the PJRT CPU client
//! at startup, and from then on executes device-resident computations
//! only.

pub mod artifact;
pub mod gradient;
pub mod pjrt;

pub use artifact::Manifest;
pub use gradient::ArtifactGradient;
pub use pjrt::Engine;

/// Default artifact directory (crate root `artifacts/`).
pub fn default_artifact_dir() -> std::path::PathBuf {
    std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

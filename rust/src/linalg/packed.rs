//! Packed reduced designs: the screened columns of a [`Design`]
//! materialized into one contiguous column-major buffer, with blocked
//! kernels tuned for the FISTA inner loop.
//!
//! The reduced solver used to pay gather-indexed traffic on every
//! iteration: `gemv_subset`/`gemv_t_subset` chase a `cols: &[usize]` list
//! through a design that, at p = 100k, spans hundreds of megabytes while
//! the screened set touches well under a megabyte of it. A
//! [`PackedDesign`] copies those columns out **once per path step** into a
//! dense slab the inner loop then streams:
//!
//! * **Packing** is one pass over the screened columns (`O(n·|E|)` — the
//!   cost of a single reduced product), parallel over column blocks.
//! * **Incremental append**: when the KKT safeguard admits violators, the
//!   new columns are appended to the slab — no re-pack of the columns
//!   already present. A merged traversal order keeps kernel semantics in
//!   ascending-column order (see below), so appended packs produce
//!   bitwise-identical results to freshly packed ones.
//! * **Blocked kernels**: `gemv` walks four columns per pass over the
//!   output; `gemv_t` computes four column dots per pass over the input,
//!   each dot with the exact lane pattern of [`dense::dot`]. Both have
//!   `*_with` parallel forms on the [`ParConfig`] slab machinery that are
//!   bitwise identical to their serial forms.
//!
//! **Ordering contract.** Kernel inputs/outputs are aligned with the
//! *ascending* column list (the order `Reduced` keeps its coefficients
//! in), regardless of the physical slot order appends produce. Per output
//! element, contributions accumulate in ascending-column order and each
//! column dot uses [`dense::dot`]'s lane pattern — exactly the orders of
//! the dense gather kernels — so on a dense design the packed engine is
//! bitwise interchangeable with the gather engine on finite data (sparse
//! designs agree to rounding: the gather kernels there skip structural
//! zeros, the packed slab streams them).
//!
//! [`PackCache`] keys finished packs by their screened set so fits with
//! stable supports (the serve layer's warm-start case) skip packing
//! entirely; `serve::registry` holds one cache per interned dataset.

use std::collections::{HashMap, VecDeque};
use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use super::dense::dot;
use super::par::{chunk_size, ParConfig};
use super::Design;
use crate::obs::registry as obsreg;

/// Count one packed-kernel dispatch: the invocation, its element-work,
/// and the serial/parallel classification of its plan.
#[inline]
fn note_packed(calls: &obsreg::Counter, rows: usize, cols: usize, chunks: usize) {
    calls.inc();
    obsreg::PACKED_CELLS.add((rows as u64).saturating_mul(cols as u64));
    if chunks > 1 {
        obsreg::PARALLEL_CALLS.inc();
    } else {
        obsreg::SERIAL_CALLS.inc();
    }
}

/// A contiguous column-major copy of a subset of a design's columns.
#[derive(Clone, Debug, PartialEq)]
pub struct PackedDesign {
    nrows: usize,
    /// Design column held in each physical slot (initial pack ascending;
    /// appended batches land after, each batch ascending).
    cols: Vec<usize>,
    /// Slot traversal order sorting `cols` ascending — the order every
    /// kernel walks, so results never depend on append history.
    order: Vec<u32>,
    /// Column-major slab: `data[s * nrows..(s + 1) * nrows]` is slot `s`.
    data: Vec<f64>,
}

impl PackedDesign {
    /// Materialize `cols` (ascending design columns) out of `design`.
    /// Packing parallelizes over column blocks under `par`.
    pub fn pack(design: &Design, cols: &[usize], par: ParConfig) -> PackedDesign {
        debug_assert!(cols.windows(2).all(|w| w[0] < w[1]), "cols must be ascending");
        let nrows = design.nrows();
        let mut data = vec![0.0; nrows * cols.len()];
        fill_columns(design, cols, &mut data, nrows, par);
        PackedDesign {
            nrows,
            cols: cols.to_vec(),
            order: (0..cols.len() as u32).collect(),
            data,
        }
    }

    /// Append further columns (ascending, disjoint from the ones already
    /// packed) without touching the existing slab — the safeguard-loop
    /// path when KKT violations widen the screened set.
    pub fn append(&mut self, design: &Design, extra: &[usize], par: ParConfig) {
        debug_assert!(extra.windows(2).all(|w| w[0] < w[1]), "extra must be ascending");
        debug_assert_eq!(design.nrows(), self.nrows);
        if extra.is_empty() {
            return;
        }
        let old = self.cols.len();
        self.cols.extend_from_slice(extra);
        self.data.resize(self.nrows * self.cols.len(), 0.0);
        fill_columns(design, extra, &mut self.data[old * self.nrows..], self.nrows, par);
        // Merge the two ascending runs (existing traversal order + the new
        // slots) so kernels keep walking columns in ascending order.
        let mut merged = Vec::with_capacity(self.cols.len());
        let (mut i, mut s) = (0usize, old);
        while i < old || s < self.cols.len() {
            let take_new = match (self.order.get(i), self.cols.get(s)) {
                (Some(&slot), Some(&new_col)) => {
                    debug_assert_ne!(self.cols[slot as usize], new_col, "duplicate column");
                    self.cols[slot as usize] > new_col
                }
                (None, Some(_)) => true,
                _ => false,
            };
            if take_new {
                merged.push(s as u32);
                s += 1;
            } else {
                merged.push(self.order[i]);
                i += 1;
            }
        }
        self.order = merged;
    }

    /// Observations.
    #[inline]
    pub fn nrows(&self) -> usize {
        self.nrows
    }

    /// Packed columns.
    #[inline]
    pub fn ncols(&self) -> usize {
        self.cols.len()
    }

    /// True when no columns are packed.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.cols.is_empty()
    }

    /// The design column at ascending rank `t` (the index the `t`-th
    /// kernel coordinate corresponds to).
    #[inline]
    pub fn col_at_rank(&self, t: usize) -> usize {
        self.cols[self.order[t] as usize]
    }

    /// The packed column set in ascending order (allocates; used for
    /// cache verification and tests).
    pub fn sorted_cols(&self) -> Vec<usize> {
        self.order.iter().map(|&s| self.cols[s as usize]).collect()
    }

    #[inline]
    fn slot(&self, t: usize) -> &[f64] {
        let s = self.order[t] as usize;
        &self.data[s * self.nrows..(s + 1) * self.nrows]
    }

    /// `out = P v` where `v` is aligned with the ascending column list.
    pub fn gemv(&self, v: &[f64], out: &mut [f64]) {
        assert_eq!(v.len(), self.cols.len());
        assert_eq!(out.len(), self.nrows);
        note_packed(&obsreg::PACKED_GEMV_CALLS, self.nrows, self.cols.len(), 1);
        out.fill(0.0);
        self.gemv_rows(v, out, 0);
    }

    /// [`PackedDesign::gemv`] with a thread budget: contiguous row slabs
    /// of the output, each walking the columns in ascending order —
    /// bitwise identical to the serial form.
    pub fn gemv_with(&self, v: &[f64], out: &mut [f64], par: ParConfig) {
        assert_eq!(v.len(), self.cols.len());
        assert_eq!(out.len(), self.nrows);
        let chunks = par.plan(self.nrows, self.cols.len());
        if chunks <= 1 {
            self.gemv(v, out);
            return;
        }
        note_packed(&obsreg::PACKED_GEMV_CALLS, self.nrows, self.cols.len(), chunks);
        let slab = chunk_size(self.nrows, chunks);
        std::thread::scope(|scope| {
            for (ci, rows) in out.chunks_mut(slab).enumerate() {
                let r0 = ci * slab;
                scope.spawn(move || {
                    rows.fill(0.0);
                    self.gemv_rows(v, rows, r0);
                });
            }
        });
    }

    /// Accumulate `P v` into the row window `rows` starting at `r0`,
    /// four columns per pass over the window. Each output element
    /// receives its contributions in ascending-column order (the dense
    /// gather kernels' per-element order).
    fn gemv_rows(&self, v: &[f64], rows: &mut [f64], r0: usize) {
        let k = self.cols.len();
        let len = rows.len();
        let mut t = 0;
        while t + 4 <= k {
            let (v0, v1, v2, v3) = (v[t], v[t + 1], v[t + 2], v[t + 3]);
            if v0 == 0.0 && v1 == 0.0 && v2 == 0.0 && v3 == 0.0 {
                t += 4;
                continue; // sparse iterates are common on screened paths
            }
            let c0 = &self.slot(t)[r0..r0 + len];
            let c1 = &self.slot(t + 1)[r0..r0 + len];
            let c2 = &self.slot(t + 2)[r0..r0 + len];
            let c3 = &self.slot(t + 3)[r0..r0 + len];
            for i in 0..len {
                // Sequential adds, column order — not one fused sum — so
                // the accumulation order matches the unblocked kernels.
                let mut o = rows[i];
                o += v0 * c0[i];
                o += v1 * c1[i];
                o += v2 * c2[i];
                o += v3 * c3[i];
                rows[i] = o;
            }
            t += 4;
        }
        while t < k {
            let vt = v[t];
            if vt != 0.0 {
                let c = &self.slot(t)[r0..r0 + len];
                for (o, &x) in rows.iter_mut().zip(c) {
                    *o += vt * x;
                }
            }
            t += 1;
        }
    }

    /// `out = Pᵀ v`, aligned with the ascending column list.
    pub fn gemv_t(&self, v: &[f64], out: &mut [f64]) {
        assert_eq!(v.len(), self.nrows);
        assert_eq!(out.len(), self.cols.len());
        note_packed(&obsreg::PACKED_GEMV_T_CALLS, self.nrows, self.cols.len(), 1);
        self.gemv_t_ranks(v, out, 0);
    }

    /// [`PackedDesign::gemv_t`] with a thread budget: contiguous rank
    /// slabs of the output, independent column dots — bitwise identical
    /// to the serial form.
    pub fn gemv_t_with(&self, v: &[f64], out: &mut [f64], par: ParConfig) {
        assert_eq!(v.len(), self.nrows);
        assert_eq!(out.len(), self.cols.len());
        let chunks = par.plan(self.cols.len(), self.nrows);
        if chunks <= 1 {
            self.gemv_t(v, out);
            return;
        }
        note_packed(&obsreg::PACKED_GEMV_T_CALLS, self.nrows, self.cols.len(), chunks);
        let slab = chunk_size(self.cols.len(), chunks);
        std::thread::scope(|scope| {
            for (ci, ranks) in out.chunks_mut(slab).enumerate() {
                let t0 = ci * slab;
                scope.spawn(move || {
                    self.gemv_t_ranks(v, ranks, t0);
                });
            }
        });
    }

    /// Column dots for ranks `t0..t0 + out.len()`, four columns per pass
    /// over `v`. Each dot uses exactly [`dot`]'s lane pattern, so a rank
    /// computed inside a 4-block equals the same rank computed alone.
    fn gemv_t_ranks(&self, v: &[f64], out: &mut [f64], t0: usize) {
        let mut t = 0;
        while t + 4 <= out.len() {
            let quad = dot4(
                [
                    self.slot(t0 + t),
                    self.slot(t0 + t + 1),
                    self.slot(t0 + t + 2),
                    self.slot(t0 + t + 3),
                ],
                v,
            );
            out[t..t + 4].copy_from_slice(&quad);
            t += 4;
        }
        while t < out.len() {
            out[t] = dot(self.slot(t0 + t), v);
            t += 1;
        }
    }

    /// Bytes held by the packed slab (cache accounting / diagnostics).
    pub fn bytes(&self) -> usize {
        self.data.len() * std::mem::size_of::<f64>()
    }
}

/// Four simultaneous column dots in one pass over `v`. Per column the
/// accumulation replicates [`dot`] exactly — four lane accumulators over
/// row quads, `(s0 + s1) + (s2 + s3)`, then the tail in order — so each
/// result is bitwise identical to `dot(col, v)`.
#[inline]
fn dot4(cols: [&[f64]; 4], v: &[f64]) -> [f64; 4] {
    let len = v.len();
    let quads = len / 4;
    let mut s = [[0.0f64; 4]; 4];
    for q in 0..quads {
        let i = q * 4;
        for (c, col) in cols.iter().enumerate() {
            s[c][0] += col[i] * v[i];
            s[c][1] += col[i + 1] * v[i + 1];
            s[c][2] += col[i + 2] * v[i + 2];
            s[c][3] += col[i + 3] * v[i + 3];
        }
    }
    let mut out = [0.0f64; 4];
    for (c, col) in cols.iter().enumerate() {
        let mut acc = (s[c][0] + s[c][1]) + (s[c][2] + s[c][3]);
        for i in quads * 4..len {
            acc += col[i] * v[i];
        }
        out[c] = acc;
    }
    out
}

/// Score a stacked batch of prediction rows against one coefficient
/// class: `out[r] = intercept + Σ_j rows[r·p + j] · beta[j]`, four rows
/// per pass so `beta` streams from cache once per quad instead of once
/// per row. `rows` is row-major (`r·p..(r+1)·p` is row `r`).
///
/// **Ordering contract** (same doctrine as the packed kernels): each
/// row's score is its own scalar accumulator, seeded with `intercept`
/// and receiving `row[j] · beta[j]` contributions in strictly ascending
/// `j` — exactly the serve layer's one-row-at-a-time loop — so scoring a
/// coalesced batch is bitwise identical to scoring its rows one request
/// at a time, regardless of how many rows share the pass.
pub fn score_rows(rows: &[f64], p: usize, beta: &[f64], intercept: f64, out: &mut [f64]) {
    assert_eq!(beta.len(), p, "beta length must match the row width");
    assert_eq!(rows.len(), p * out.len(), "rows slab must be out.len() × p");
    let nrows = out.len();
    note_packed(&obsreg::PACKED_GEMV_CALLS, nrows, p, 1);
    let mut r = 0;
    while r + 4 <= nrows {
        let r0 = &rows[r * p..(r + 1) * p];
        let r1 = &rows[(r + 1) * p..(r + 2) * p];
        let r2 = &rows[(r + 2) * p..(r + 3) * p];
        let r3 = &rows[(r + 3) * p..(r + 4) * p];
        let (mut a0, mut a1, mut a2, mut a3) = (intercept, intercept, intercept, intercept);
        for (j, &b) in beta.iter().enumerate() {
            // Independent accumulators, one per row: lane j of each chain
            // is `+ row[j]·beta[j]`, the per-request loop's exact order.
            a0 += r0[j] * b;
            a1 += r1[j] * b;
            a2 += r2[j] * b;
            a3 += r3[j] * b;
        }
        out[r] = a0;
        out[r + 1] = a1;
        out[r + 2] = a2;
        out[r + 3] = a3;
        r += 4;
    }
    while r < nrows {
        let row = &rows[r * p..(r + 1) * p];
        let mut s = intercept;
        for (j, &b) in beta.iter().enumerate() {
            s += row[j] * b;
        }
        out[r] = s;
        r += 1;
    }
}

/// Copy screened columns into a pre-sized destination slab, parallel over
/// column blocks (disjoint `chunks_mut` spans — bitwise deterministic).
fn fill_columns(design: &Design, cols: &[usize], dst: &mut [f64], nrows: usize, par: ParConfig) {
    debug_assert_eq!(dst.len(), nrows * cols.len());
    if nrows == 0 || cols.is_empty() {
        return;
    }
    let chunks = par.plan(cols.len(), nrows);
    if chunks <= 1 {
        for (slot, &j) in cols.iter().enumerate() {
            copy_col(design, j, &mut dst[slot * nrows..(slot + 1) * nrows]);
        }
        return;
    }
    let span = chunk_size(cols.len(), chunks);
    std::thread::scope(|scope| {
        for (ci, block) in dst.chunks_mut(span * nrows).enumerate() {
            let sub = &cols[ci * span..ci * span + block.len() / nrows];
            scope.spawn(move || {
                for (slot, &j) in sub.iter().enumerate() {
                    copy_col(design, j, &mut block[slot * nrows..(slot + 1) * nrows]);
                }
            });
        }
    });
}

/// One column into a dense destination (sparse columns scatter over a
/// zero fill).
fn copy_col(design: &Design, j: usize, dst: &mut [f64]) {
    match design {
        Design::Dense(m) => dst.copy_from_slice(m.col(j)),
        Design::Sparse(s) => s.scatter_col(j, dst),
    }
}

/// A finished pack of one screened coefficient set: the set (ascending
/// flattened coefficient indices — the cache identity) plus one
/// [`PackedDesign`] per class (single-response families have one). The
/// class split convention is `slope::fista::Reduced`'s: coefficient `c`
/// maps to class `c / p`, design column `c % p`.
#[derive(Clone, Debug)]
pub struct PackedSet {
    /// Ascending flattened coefficient indices.
    pub coefs: Vec<usize>,
    /// Per-class packed designs.
    pub packs: Vec<Arc<PackedDesign>>,
}

impl PackedSet {
    /// Total slab bytes across classes (cache accounting).
    pub fn bytes(&self) -> usize {
        self.packs.iter().map(|p| p.bytes()).sum()
    }
}

/// FNV-1a over an ascending index set (length-prefixed so prefixes can't
/// collide trivially).
pub fn set_hash(sorted: &[usize]) -> u64 {
    const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const PRIME: u64 = 0x0000_0100_0000_01b3;
    let mut h = OFFSET;
    let mut mix = |x: u64| {
        for b in x.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(PRIME);
        }
    };
    mix(sorted.len() as u64);
    for &c in sorted {
        mix(c as u64);
    }
    h
}

/// Default byte budget for a [`PackCache`] (the entry capacity still
/// applies; whichever bound is hit first evicts).
pub const DEFAULT_PACK_CACHE_BYTES: usize = 64 << 20;

#[derive(Default)]
struct CacheInner {
    slots: HashMap<u64, Arc<PackedSet>>,
    /// Insertion order — eviction is FIFO, so a full-path fit that
    /// deposits one set per σ-step retires the oldest steps first and a
    /// warm re-fit walking the same path in order still hits.
    order: VecDeque<u64>,
    bytes: usize,
}

/// Bounded, thread-safe store of finished [`PackedSet`]s keyed by their
/// screened set, bounded both by entry count and by slab bytes (FIFO
/// eviction). The serve registry holds one per interned dataset, so a
/// warm-start request whose support matches a previous fit's adopts the
/// cached slab and skips packing entirely. Hash collisions are harmless:
/// a hit is only returned when the stored set equals the requested one.
///
/// **Contract:** a cache belongs to exactly one design/problem — the key
/// is the screened set alone, so sharing a cache across different
/// designs would serve wrong columns. `slope::path::build_reduced`
/// additionally refuses hits whose slab row count disagrees with the
/// problem, and the CV fold runner strips the cache from fold options
/// (folds fit different training subsets).
pub struct PackCache {
    inner: Mutex<CacheInner>,
    capacity: usize,
    max_bytes: usize,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl PackCache {
    /// Cache holding at most `capacity` packed sets within
    /// [`DEFAULT_PACK_CACHE_BYTES`]; see [`PackCache::with_max_bytes`].
    pub fn new(capacity: usize) -> PackCache {
        PackCache {
            inner: Mutex::new(CacheInner::default()),
            capacity: capacity.max(1),
            max_bytes: DEFAULT_PACK_CACHE_BYTES,
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    /// Builder: override the slab byte budget.
    pub fn with_max_bytes(mut self, max_bytes: usize) -> PackCache {
        self.max_bytes = max_bytes.max(1);
        self
    }

    /// The pack for exactly this ascending coefficient set, if cached.
    pub fn lookup(&self, sorted_coefs: &[usize]) -> Option<Arc<PackedSet>> {
        let key = set_hash(sorted_coefs);
        let inner = self.inner.lock().unwrap();
        match inner.slots.get(&key) {
            Some(set) if set.coefs == sorted_coefs => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                obsreg::PACK_CACHE_HITS.inc();
                Some(Arc::clone(set))
            }
            _ => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                obsreg::PACK_CACHE_MISSES.inc();
                None
            }
        }
    }

    /// Store a finished pack under its set identity, evicting oldest
    /// entries past either bound. A set that alone exceeds the byte
    /// budget is refused outright — inserting it would flush every
    /// existing entry (itself included) for nothing.
    pub fn store(&self, set: Arc<PackedSet>) {
        debug_assert!(set.coefs.windows(2).all(|w| w[0] < w[1]), "coefs must be ascending");
        let key = set_hash(&set.coefs);
        let add = set.bytes();
        if add > self.max_bytes {
            return;
        }
        obsreg::PACK_CACHE_STORES.inc();
        let mut inner = self.inner.lock().unwrap();
        match inner.slots.insert(key, set) {
            Some(old) => {
                // replaced in place: the order entry stays where it was
                inner.bytes = inner.bytes + add - old.bytes();
            }
            None => {
                inner.bytes += add;
                inner.order.push_back(key);
            }
        }
        while inner.slots.len() > self.capacity || inner.bytes > self.max_bytes {
            match inner.order.pop_front() {
                Some(oldest) => {
                    if let Some(rm) = inner.slots.remove(&oldest) {
                        inner.bytes -= rm.bytes();
                        obsreg::PACK_CACHE_EVICTIONS.inc();
                    }
                }
                None => break,
            }
        }
    }

    /// Cached set count.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().slots.len()
    }

    /// True when nothing is cached.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total slab bytes currently held.
    pub fn bytes(&self) -> usize {
        self.inner.lock().unwrap().bytes
    }

    /// `(hits, misses)` since construction.
    pub fn stats(&self) -> (u64, u64) {
        (self.hits.load(Ordering::Relaxed), self.misses.load(Ordering::Relaxed))
    }
}

impl fmt::Debug for PackCache {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let (hits, misses) = self.stats();
        f.debug_struct("PackCache")
            .field("entries", &self.len())
            .field("capacity", &self.capacity)
            .field("bytes", &self.bytes())
            .field("max_bytes", &self.max_bytes)
            .field("hits", &hits)
            .field("misses", &misses)
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{Csc, Mat};
    use crate::rng::Pcg64;

    fn random_design(seed: u64, n: usize, p: usize, sparse: bool) -> Design {
        let mut rng = Pcg64::new(seed);
        let mut m = Mat::zeros(n, p);
        for j in 0..p {
            for i in 0..n {
                if !sparse || rng.bernoulli(0.4) {
                    m.set(i, j, rng.normal());
                }
            }
        }
        if sparse {
            Design::Sparse(Csc::from_dense(&m))
        } else {
            Design::Dense(m)
        }
    }

    #[test]
    fn packed_gemv_matches_gather_bitwise_dense() {
        let design = random_design(1, 23, 17, false);
        let cols = vec![0usize, 2, 5, 6, 7, 11, 16];
        let pack = PackedDesign::pack(&design, &cols, ParConfig::serial());
        let mut rng = Pcg64::new(2);
        let v: Vec<f64> = cols.iter().map(|_| rng.normal()).collect();
        let w: Vec<f64> = (0..23).map(|_| rng.normal()).collect();
        let (mut a, mut b) = (vec![0.0; 23], vec![0.0; 23]);
        design.gemv_subset(&cols, &v, &mut a);
        pack.gemv(&v, &mut b);
        assert_eq!(a, b, "gemv must be bitwise identical to the dense gather kernel");
        let (mut c, mut d) = (vec![0.0; cols.len()], vec![0.0; cols.len()]);
        design.gemv_t_subset(&cols, &w, &mut c);
        pack.gemv_t(&w, &mut d);
        assert_eq!(c, d, "gemv_t must be bitwise identical to the dense gather kernel");
    }

    #[test]
    fn parallel_packed_kernels_bitwise_match_serial() {
        for sparse in [false, true] {
            let design = random_design(3, 29, 21, sparse);
            let cols: Vec<usize> = (0..21).filter(|j| j % 3 != 1).collect();
            let pack = PackedDesign::pack(&design, &cols, ParConfig::serial());
            let mut rng = Pcg64::new(4);
            let v: Vec<f64> = cols.iter().map(|_| rng.normal()).collect();
            let w: Vec<f64> = (0..29).map(|_| rng.normal()).collect();
            for t in [2usize, 3, 7, 32] {
                let par = ParConfig::exact(t);
                let (mut a, mut b) = (vec![0.0; 29], vec![0.0; 29]);
                pack.gemv(&v, &mut a);
                pack.gemv_with(&v, &mut b, par);
                assert_eq!(a, b, "gemv t={t} sparse={sparse}");
                let (mut c, mut d) = (vec![0.0; cols.len()], vec![0.0; cols.len()]);
                pack.gemv_t(&w, &mut c);
                pack.gemv_t_with(&w, &mut d, par);
                assert_eq!(c, d, "gemv_t t={t} sparse={sparse}");
            }
        }
    }

    #[test]
    fn append_is_bitwise_equal_to_fresh_pack() {
        let design = random_design(5, 19, 30, false);
        let base = vec![3usize, 8, 9, 20];
        let batch1 = vec![1usize, 12, 28];
        let batch2 = vec![0usize, 10, 29];
        let mut inc = PackedDesign::pack(&design, &base, ParConfig::serial());
        inc.append(&design, &batch1, ParConfig::serial());
        inc.append(&design, &batch2, ParConfig::exact(3));
        let mut all: Vec<usize> = base.iter().chain(&batch1).chain(&batch2).copied().collect();
        all.sort_unstable();
        let fresh = PackedDesign::pack(&design, &all, ParConfig::serial());
        assert_eq!(inc.sorted_cols(), all);
        assert_eq!(fresh.sorted_cols(), all);
        let mut rng = Pcg64::new(6);
        let v: Vec<f64> = all.iter().map(|_| rng.normal()).collect();
        let w: Vec<f64> = (0..19).map(|_| rng.normal()).collect();
        let (mut a, mut b) = (vec![0.0; 19], vec![0.0; 19]);
        inc.gemv(&v, &mut a);
        fresh.gemv(&v, &mut b);
        assert_eq!(a, b, "appended gemv must equal fresh pack bitwise");
        let (mut c, mut d) = (vec![0.0; all.len()], vec![0.0; all.len()]);
        inc.gemv_t(&w, &mut c);
        fresh.gemv_t(&w, &mut d);
        assert_eq!(c, d, "appended gemv_t must equal fresh pack bitwise");
        for t in 0..all.len() {
            assert_eq!(inc.col_at_rank(t), all[t]);
        }
    }

    #[test]
    fn degenerate_shapes() {
        // no rows
        let design = Design::Dense(Mat::zeros(0, 4));
        let pack = PackedDesign::pack(&design, &[1, 3], ParConfig::exact(7));
        let mut out: Vec<f64> = Vec::new();
        pack.gemv(&[1.0, 2.0], &mut out);
        let mut g = vec![9.0; 2];
        pack.gemv_t(&[], &mut g);
        assert_eq!(g, vec![0.0, 0.0]);
        // empty subset
        let design = random_design(7, 5, 3, false);
        let pack = PackedDesign::pack(&design, &[], ParConfig::serial());
        assert!(pack.is_empty());
        let mut out = vec![1.0; 5];
        pack.gemv(&[], &mut out);
        assert_eq!(out, vec![0.0; 5]);
        // single column
        let pack = PackedDesign::pack(&design, &[2], ParConfig::exact(4));
        let mut out = vec![0.0; 5];
        pack.gemv(&[2.0], &mut out);
        let mut want = vec![0.0; 5];
        design.gemv_subset(&[2], &[2.0], &mut want);
        assert_eq!(out, want);
    }

    #[test]
    fn cache_round_trip_and_bounds() {
        let design = random_design(8, 6, 10, false);
        let cache = PackCache::new(2);
        assert!(cache.lookup(&[0, 1]).is_none());
        for cols in [vec![0usize, 1], vec![2usize, 3], vec![4usize, 5]] {
            let pack = Arc::new(PackedDesign::pack(&design, &cols, ParConfig::serial()));
            cache.store(Arc::new(PackedSet { coefs: cols, packs: vec![pack] }));
        }
        assert!(cache.len() <= 2, "cache must stay bounded");
        // FIFO: the oldest set was evicted, the two newest survive
        assert!(cache.lookup(&[0, 1]).is_none(), "oldest entry must be evicted first");
        let hit = cache.lookup(&[4, 5]).expect("newest set must be cached");
        assert_eq!(hit.coefs, vec![4, 5]);
        assert_eq!(hit.packs[0].sorted_cols(), vec![4, 5]);
        assert!(cache.lookup(&[2, 3]).is_some(), "second-newest must survive");
        let (hits, misses) = cache.stats();
        assert!(hits >= 1 && misses >= 1);
        assert_eq!(cache.bytes(), 2 * (6 * 2 * 8), "byte accounting must track slabs");
    }

    #[test]
    fn cache_byte_budget_evicts_oldest() {
        let design = random_design(10, 8, 12, false);
        // each 3-column pack is 8 rows × 3 cols × 8 bytes = 192 bytes;
        // budget fits exactly two of them
        let cache = PackCache::new(100).with_max_bytes(2 * 192);
        for cols in [vec![0usize, 1, 2], vec![3usize, 4, 5], vec![6usize, 7, 8]] {
            let pack = Arc::new(PackedDesign::pack(&design, &cols, ParConfig::serial()));
            cache.store(Arc::new(PackedSet { coefs: cols, packs: vec![pack] }));
        }
        assert_eq!(cache.len(), 2);
        assert!(cache.bytes() <= 2 * 192);
        assert!(cache.lookup(&[0, 1, 2]).is_none());
        assert!(cache.lookup(&[6, 7, 8]).is_some());
        // replacing an existing key adjusts bytes instead of duplicating
        let pack = Arc::new(PackedDesign::pack(&design, &[6, 7, 8], ParConfig::serial()));
        cache.store(Arc::new(PackedSet { coefs: vec![6, 7, 8], packs: vec![pack] }));
        assert_eq!(cache.len(), 2);
        assert!(cache.bytes() <= 2 * 192);
        // a set that alone busts the budget is refused, not allowed to
        // flush the whole cache
        let big = Arc::new(PackedDesign::pack(
            &design,
            &(0..12).collect::<Vec<_>>(),
            ParConfig::serial(),
        ));
        cache.store(Arc::new(PackedSet { coefs: (0..12).collect(), packs: vec![big] }));
        assert_eq!(cache.len(), 2, "oversized set must not evict existing entries");
        assert!(cache.lookup(&(0..12).collect::<Vec<_>>()).is_none());
    }

    #[test]
    fn set_hash_discriminates() {
        assert_ne!(set_hash(&[1, 2, 3]), set_hash(&[1, 2]));
        assert_ne!(set_hash(&[1, 2, 3]), set_hash(&[1, 2, 4]));
        assert_eq!(set_hash(&[]), set_hash(&[]));
    }

    #[test]
    fn bytes_accounts_for_slab() {
        let design = random_design(9, 7, 5, false);
        let pack = PackedDesign::pack(&design, &[0, 2, 4], ParConfig::serial());
        assert_eq!(pack.bytes(), 7 * 3 * 8);
    }

    /// The serve layer's one-row scoring loop, verbatim — the reference
    /// `score_rows` must match bitwise.
    fn score_one(row: &[f64], beta: &[f64], intercept: f64) -> f64 {
        let mut s = intercept;
        for (j, &v) in row.iter().enumerate() {
            s += v * beta[j];
        }
        s
    }

    #[test]
    fn score_rows_bitwise_matches_per_row_loop() {
        let mut rng = Pcg64::new(11);
        // Row counts straddling the quad boundary (tail of 0..3 rows) and
        // widths straddling any lane assumptions.
        for &(nrows, p) in &[(1usize, 7usize), (3, 16), (4, 5), (5, 1), (7, 33), (12, 8)] {
            let rows: Vec<f64> = (0..nrows * p).map(|_| rng.normal()).collect();
            let beta: Vec<f64> = (0..p).map(|_| rng.normal()).collect();
            let intercept = rng.normal();
            let mut out = vec![0.0; nrows];
            score_rows(&rows, p, &beta, intercept, &mut out);
            for r in 0..nrows {
                let want = score_one(&rows[r * p..(r + 1) * p], &beta, intercept);
                assert_eq!(
                    out[r].to_bits(),
                    want.to_bits(),
                    "row {r} of {nrows}×{p} must match the per-row loop bitwise"
                );
            }
        }
    }

    #[test]
    fn score_rows_degenerate_shapes() {
        // no rows: nothing written, no panic
        let mut out: Vec<f64> = Vec::new();
        score_rows(&[], 4, &[1.0, 2.0, 3.0, 4.0], 0.5, &mut out);
        // zero-width rows: every score is exactly the intercept
        let mut out = vec![0.0; 3];
        score_rows(&[], 0, &[], 2.25, &mut out);
        assert_eq!(out, vec![2.25; 3]);
    }
}

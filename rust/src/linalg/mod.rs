//! Dense and sparse linear-algebra substrate.
//!
//! No BLAS or `ndarray` is available offline, so the kernels the SLOPE
//! solver needs are implemented here:
//!
//! * [`Mat`] — dense column-major `f64` matrix. Column-major because every
//!   hot operation in a lasso/SLOPE solver is column-oriented: `Xᵀr`
//!   (per-column dot products), column subsetting for screened sets, and
//!   column standardization.
//! * [`Mat::gemv`] / [`Mat::gemv_t`] — `Xv` and `Xᵀv` with 4-way unrolled
//!   inner loops (the L3 hot path; see EXPERIMENTS.md §Perf).
//! * [`sparse::Csc`] — compressed sparse column matrix for the
//!   dorothea-like sparse binary designs.
//! * [`Design`] — a dense-or-sparse design wrapper so the solver and the
//!   screening rule are storage-agnostic.
//! * [`par`] — the parallel-execution layer: every hot kernel has a
//!   `*_with` variant taking a [`ParConfig`] thread budget (hand-rolled
//!   `std::thread::scope` partitioning; no `rayon` offline).
//! * [`packed`] — screened columns materialized into one contiguous slab
//!   ([`PackedDesign`]) with blocked kernels, incremental append for the
//!   KKT safeguard loop, and a per-dataset [`PackCache`] so warm-start
//!   fits with stable supports skip packing (DESIGN.md §5).

pub mod dense;
pub mod ops;
pub mod packed;
pub mod par;
pub mod sparse;

pub use dense::Mat;
pub use packed::{PackCache, PackedDesign, PackedSet};
pub use par::ParConfig;
pub use sparse::Csc;

use crate::obs::registry as obsreg;

/// Count one gather-kernel dispatch: the invocation, its element-work
/// (`rows × cols` cells; for sparse designs this over-counts actual
/// nonzero work but keeps one definition across storage), and whether
/// the parallel plan split it (`chunks > 1`) or it ran serially.
#[inline]
fn note_gather(calls: &obsreg::Counter, rows: usize, cols: usize, chunks: usize) {
    calls.inc();
    obsreg::GATHER_CELLS.add((rows as u64).saturating_mul(cols as u64));
    if chunks > 1 {
        obsreg::PARALLEL_CALLS.inc();
    } else {
        obsreg::SERIAL_CALLS.inc();
    }
}

/// A design matrix: dense or sparse, plus optional column subsetting used
/// by the screened subproblems.
#[derive(Clone, Debug)]
pub enum Design {
    /// Dense column-major storage.
    Dense(Mat),
    /// Compressed sparse column storage.
    Sparse(Csc),
}

impl Design {
    /// Number of rows (observations).
    pub fn nrows(&self) -> usize {
        match self {
            Design::Dense(m) => m.nrows(),
            Design::Sparse(m) => m.nrows(),
        }
    }

    /// Number of columns (predictors).
    pub fn ncols(&self) -> usize {
        match self {
            Design::Dense(m) => m.ncols(),
            Design::Sparse(m) => m.ncols(),
        }
    }

    /// `out = X v` (dense result).
    pub fn gemv(&self, v: &[f64], out: &mut [f64]) {
        note_gather(&obsreg::GEMV_CALLS, self.nrows(), self.ncols(), 1);
        match self {
            Design::Dense(m) => m.gemv(v, out),
            Design::Sparse(m) => m.gemv(v, out),
        }
    }

    /// `out = X v` with a [`ParConfig`] thread budget.
    pub fn gemv_with(&self, v: &[f64], out: &mut [f64], par: ParConfig) {
        note_gather(
            &obsreg::GEMV_CALLS,
            self.nrows(),
            self.ncols(),
            par.plan(self.nrows(), self.ncols()),
        );
        match self {
            Design::Dense(m) => m.gemv_with(v, out, par),
            Design::Sparse(m) => m.gemv_with(v, out, par),
        }
    }

    /// `out = Xᵀ v`.
    pub fn gemv_t(&self, v: &[f64], out: &mut [f64]) {
        note_gather(&obsreg::GEMV_T_CALLS, self.nrows(), self.ncols(), 1);
        match self {
            Design::Dense(m) => m.gemv_t(v, out),
            Design::Sparse(m) => m.gemv_t(v, out),
        }
    }

    /// `out = Xᵀ v` with a thread budget — the full-gradient KKT sweep
    /// kernel, the dominant per-path-step cost once screening works.
    pub fn gemv_t_with(&self, v: &[f64], out: &mut [f64], par: ParConfig) {
        note_gather(
            &obsreg::GEMV_T_CALLS,
            self.nrows(),
            self.ncols(),
            par.plan(self.ncols(), self.nrows()),
        );
        match self {
            Design::Dense(m) => m.gemv_t_with(v, out, par),
            Design::Sparse(m) => m.gemv_t_with(v, out, par),
        }
    }

    /// `out = X[:, cols] v` for a column subset.
    pub fn gemv_subset(&self, cols: &[usize], v: &[f64], out: &mut [f64]) {
        note_gather(&obsreg::GEMV_SUBSET_CALLS, self.nrows(), cols.len(), 1);
        match self {
            Design::Dense(m) => m.gemv_subset(cols, v, out),
            Design::Sparse(m) => m.gemv_subset(cols, v, out),
        }
    }

    /// `out = X[:, cols] v` with a thread budget (dense designs split by
    /// row slab; sparse subsets have no disjoint partition and stay
    /// serial — screened subsets are small by construction).
    pub fn gemv_subset_with(&self, cols: &[usize], v: &[f64], out: &mut [f64], par: ParConfig) {
        let chunks = match self {
            Design::Dense(_) => par.plan(self.nrows(), cols.len()),
            Design::Sparse(_) => 1,
        };
        note_gather(&obsreg::GEMV_SUBSET_CALLS, self.nrows(), cols.len(), chunks);
        match self {
            Design::Dense(m) => m.gemv_subset_with(cols, v, out, par),
            Design::Sparse(m) => m.gemv_subset(cols, v, out),
        }
    }

    /// `out = X[:, cols]ᵀ v`.
    pub fn gemv_t_subset(&self, cols: &[usize], v: &[f64], out: &mut [f64]) {
        note_gather(&obsreg::GEMV_T_SUBSET_CALLS, self.nrows(), cols.len(), 1);
        match self {
            Design::Dense(m) => m.gemv_t_subset(cols, v, out),
            Design::Sparse(m) => m.gemv_t_subset(cols, v, out),
        }
    }

    /// `out = X[:, cols]ᵀ v` with a thread budget.
    pub fn gemv_t_subset_with(&self, cols: &[usize], v: &[f64], out: &mut [f64], par: ParConfig) {
        note_gather(
            &obsreg::GEMV_T_SUBSET_CALLS,
            self.nrows(),
            cols.len(),
            par.plan(cols.len(), self.nrows()),
        );
        match self {
            Design::Dense(m) => m.gemv_t_subset_with(cols, v, out, par),
            Design::Sparse(m) => m.gemv_t_subset_with(cols, v, out, par),
        }
    }

    /// Squared Euclidean norm of each column.
    pub fn col_sq_norms(&self) -> Vec<f64> {
        match self {
            Design::Dense(m) => m.col_sq_norms(),
            Design::Sparse(m) => m.col_sq_norms(),
        }
    }

    /// Squared Euclidean norm of each column, with a thread budget.
    pub fn col_sq_norms_with(&self, par: ParConfig) -> Vec<f64> {
        match self {
            Design::Dense(m) => m.col_sq_norms_with(par),
            Design::Sparse(m) => m.col_sq_norms_with(par),
        }
    }

    /// Column Euclidean norms `‖x_j‖₂` — the one definition every
    /// consumer (safe sphere tests, the gap-safe diagnostic, the serve
    /// registry's per-dataset cache) shares.
    pub fn col_norms_with(&self, par: ParConfig) -> Vec<f64> {
        self.col_sq_norms_with(par).iter().map(|c| c.sqrt()).collect()
    }

    /// Center (dense only) and scale columns to unit ℓ2 norm, as in the
    /// paper's setup (§3.1): `x̄_j = 0`, `‖x_j‖₂ = 1`.
    ///
    /// Sparse designs are scaled but not centered (centering would
    /// densify); this matches standard practice for sparse lasso solvers.
    pub fn standardize(&mut self) {
        match self {
            Design::Dense(m) => m.standardize(true, true),
            Design::Sparse(m) => m.scale_columns(),
        }
    }

    /// [`Design::standardize`] with a thread budget.
    pub fn standardize_with(&mut self, par: ParConfig) {
        match self {
            Design::Dense(m) => m.standardize_with(true, true, par),
            Design::Sparse(m) => m.scale_columns_with(par),
        }
    }

    /// Borrow the dense matrix, if dense.
    pub fn as_dense(&self) -> Option<&Mat> {
        match self {
            Design::Dense(m) => Some(m),
            Design::Sparse(_) => None,
        }
    }

    /// An upper bound on the spectral norm squared `‖X‖₂²` via the Frobenius
    /// norm (`‖X‖₂² ≤ ‖X‖_F²`); used to initialize FISTA step sizes.
    pub fn spectral_bound(&self) -> f64 {
        self.col_sq_norms().iter().sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_design() -> (Design, Design) {
        // 3x2 matrix [[1,0],[2,1],[0,3]]
        let dense = Mat::from_rows(&[&[1.0, 0.0], &[2.0, 1.0], &[0.0, 3.0]]);
        let sparse = Csc::from_dense(&dense);
        (Design::Dense(dense), Design::Sparse(sparse))
    }

    #[test]
    fn dense_sparse_gemv_agree() {
        let (d, s) = small_design();
        let v = [2.0, -1.0];
        let mut od = [0.0; 3];
        let mut os = [0.0; 3];
        d.gemv(&v, &mut od);
        s.gemv(&v, &mut os);
        assert_eq!(od, os);
        assert_eq!(od, [2.0, 3.0, -3.0]);
    }

    #[test]
    fn dense_sparse_gemv_t_agree() {
        let (d, s) = small_design();
        let v = [1.0, 1.0, 1.0];
        let mut od = [0.0; 2];
        let mut os = [0.0; 2];
        d.gemv_t(&v, &mut od);
        s.gemv_t(&v, &mut os);
        assert_eq!(od, os);
        assert_eq!(od, [3.0, 4.0]);
    }

    #[test]
    fn subset_matches_full_on_all_columns() {
        let (d, _) = small_design();
        let v = [2.0, -1.0];
        let mut full = [0.0; 3];
        let mut sub = [0.0; 3];
        d.gemv(&v, &mut full);
        d.gemv_subset(&[0, 1], &v, &mut sub);
        assert_eq!(full, sub);
    }

    #[test]
    fn spectral_bound_dominates_column_norms() {
        let (d, _) = small_design();
        let bound = d.spectral_bound();
        for &c in &d.col_sq_norms() {
            assert!(bound >= c);
        }
    }
}

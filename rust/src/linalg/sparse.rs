//! Compressed sparse column (CSC) matrix for sparse binary designs like
//! dorothea (800 × 88119, ~1% density).
//!
//! The hot kernels carry `*_with` variants taking a
//! [`ParConfig`](super::par::ParConfig) thread budget, partitioned by
//! column ranges. `Xᵀv`-shaped kernels write disjoint output slabs and are
//! bitwise identical to their serial forms; `Xv` scatters by row index, so
//! its parallel form reduces per-thread partial accumulators at the
//! barrier — sums are regrouped and agreement with serial is to rounding.

use super::dense::Mat;
use super::par::{chunk_size, ParConfig};

/// CSC sparse matrix: `colptr[j]..colptr[j+1]` indexes the nonzeros of
/// column `j` in `(rowidx, values)`.
#[derive(Clone, Debug)]
pub struct Csc {
    nrows: usize,
    ncols: usize,
    colptr: Vec<usize>,
    rowidx: Vec<u32>,
    values: Vec<f64>,
}

impl Csc {
    /// Build from column triplets: for each column a list of `(row, value)`.
    pub fn from_columns(nrows: usize, cols: &[Vec<(usize, f64)>]) -> Self {
        let ncols = cols.len();
        let mut colptr = Vec::with_capacity(ncols + 1);
        let mut rowidx = Vec::new();
        let mut values = Vec::new();
        colptr.push(0);
        for col in cols {
            let mut entries = col.clone();
            entries.sort_unstable_by_key(|&(r, _)| r);
            for &(r, v) in &entries {
                assert!(r < nrows, "row index out of range");
                if v != 0.0 {
                    rowidx.push(r as u32);
                    values.push(v);
                }
            }
            colptr.push(rowidx.len());
        }
        Self { nrows, ncols, colptr, rowidx, values }
    }

    /// Assemble from raw CSC buffers — the streaming ingest layer's
    /// two-pass builder writes exactly-sized `colptr`/`rowidx`/`values`
    /// directly and hands them over here, never materializing per-column
    /// triplet vectors. Validates the invariants every kernel relies on
    /// (monotone `colptr` covering the buffers, row indices in range and
    /// strictly increasing within each column) in one O(nnz) sweep;
    /// violations panic, because the builders construct these
    /// deterministically — a violation is a builder bug, not bad input.
    pub fn from_parts(
        nrows: usize,
        ncols: usize,
        colptr: Vec<usize>,
        rowidx: Vec<u32>,
        values: Vec<f64>,
    ) -> Self {
        assert_eq!(colptr.len(), ncols + 1, "colptr must have ncols + 1 entries");
        assert_eq!(colptr[0], 0, "colptr must start at 0");
        assert_eq!(*colptr.last().unwrap(), rowidx.len(), "colptr must cover the buffers");
        assert_eq!(rowidx.len(), values.len(), "rowidx/values length mismatch");
        for j in 0..ncols {
            assert!(colptr[j] <= colptr[j + 1], "colptr must be non-decreasing");
            let col = &rowidx[colptr[j]..colptr[j + 1]];
            for (k, &r) in col.iter().enumerate() {
                assert!((r as usize) < nrows, "row index out of range");
                assert!(
                    k == 0 || col[k - 1] < r,
                    "row indices must be strictly increasing within a column"
                );
            }
        }
        Self { nrows, ncols, colptr, rowidx, values }
    }

    /// Densify a `Mat` into CSC form (test/interop convenience).
    pub fn from_dense(m: &Mat) -> Self {
        let cols: Vec<Vec<(usize, f64)>> = (0..m.ncols())
            .map(|j| {
                m.col(j)
                    .iter()
                    .enumerate()
                    .filter(|(_, &v)| v != 0.0)
                    .map(|(i, &v)| (i, v))
                    .collect()
            })
            .collect();
        Self::from_columns(m.nrows(), &cols)
    }

    /// Convert to a dense matrix.
    pub fn to_dense(&self) -> Mat {
        let mut m = Mat::zeros(self.nrows, self.ncols);
        for j in 0..self.ncols {
            for k in self.colptr[j]..self.colptr[j + 1] {
                m.set(self.rowidx[k] as usize, j, self.values[k]);
            }
        }
        m
    }

    /// Number of rows.
    #[inline]
    pub fn nrows(&self) -> usize {
        self.nrows
    }

    /// Number of columns.
    #[inline]
    pub fn ncols(&self) -> usize {
        self.ncols
    }

    /// Number of stored nonzeros.
    #[inline]
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// The stored values buffer (finiteness audits, diagnostics).
    #[inline]
    pub fn values(&self) -> &[f64] {
        &self.values
    }

    /// Iterate column `j`'s stored `(row, value)` entries in ascending
    /// row order (the export writers walk columns through this).
    pub fn col_entries(&self, j: usize) -> impl Iterator<Item = (usize, f64)> + '_ {
        let span = self.colptr[j]..self.colptr[j + 1];
        self.rowidx[span.clone()]
            .iter()
            .zip(&self.values[span])
            .map(|(&r, &v)| (r as usize, v))
    }

    /// `out = X v`.
    pub fn gemv(&self, v: &[f64], out: &mut [f64]) {
        assert_eq!(v.len(), self.ncols);
        assert_eq!(out.len(), self.nrows);
        out.fill(0.0);
        for j in 0..self.ncols {
            let vj = v[j];
            if vj == 0.0 {
                continue;
            }
            for k in self.colptr[j]..self.colptr[j + 1] {
                out[self.rowidx[k] as usize] += vj * self.values[k];
            }
        }
    }

    /// Mean stored nonzeros per column — the work estimate the parallel
    /// planner uses.
    #[inline]
    fn avg_nnz_per_col(&self) -> usize {
        self.values.len() / self.ncols.max(1)
    }

    /// `out = X v` with a thread budget. Column ranges go to scoped
    /// threads, each accumulating into a private length-`n` buffer that is
    /// reduced into `out` at the barrier (the scattered row writes admit
    /// no disjoint output partition). The reduction regroups sums, so the
    /// result agrees with [`Csc::gemv`] to rounding, not bitwise.
    pub fn gemv_with(&self, v: &[f64], out: &mut [f64], par: ParConfig) {
        assert_eq!(v.len(), self.ncols);
        assert_eq!(out.len(), self.nrows);
        let mut chunks = par.plan(self.ncols, self.avg_nnz_per_col());
        if par.grain > 0 {
            // Each extra thread costs an O(n) accumulator + reduction;
            // don't split further than the nonzeros can repay.
            chunks = chunks.min((self.values.len() / self.nrows.max(1)).max(1));
        }
        if chunks <= 1 {
            self.gemv(v, out);
            return;
        }
        let span = chunk_size(self.ncols, chunks);
        let mut partials: Vec<Vec<f64>> = Vec::with_capacity(chunks);
        std::thread::scope(|scope| {
            // Step by span (not 0..chunks) so ceil rounding can't spawn
            // empty-range threads that still allocate O(n) accumulators.
            let handles: Vec<_> = (0..self.ncols)
                .step_by(span)
                .map(|j0| {
                    let j1 = (j0 + span).min(self.ncols);
                    scope.spawn(move || {
                        let mut acc = vec![0.0; self.nrows];
                        for j in j0..j1 {
                            let vj = v[j];
                            if vj == 0.0 {
                                continue;
                            }
                            for k in self.colptr[j]..self.colptr[j + 1] {
                                acc[self.rowidx[k] as usize] += vj * self.values[k];
                            }
                        }
                        acc
                    })
                })
                .collect();
            for h in handles {
                partials.push(h.join().expect("gemv worker panicked"));
            }
        });
        out.fill(0.0);
        for acc in &partials {
            for (o, &a) in out.iter_mut().zip(acc) {
                *o += a;
            }
        }
    }

    /// `out = Xᵀ v`.
    pub fn gemv_t(&self, v: &[f64], out: &mut [f64]) {
        assert_eq!(v.len(), self.nrows);
        assert_eq!(out.len(), self.ncols);
        for j in 0..self.ncols {
            let mut acc = 0.0;
            for k in self.colptr[j]..self.colptr[j + 1] {
                acc += self.values[k] * v[self.rowidx[k] as usize];
            }
            out[j] = acc;
        }
    }

    /// `out = Xᵀ v` with a thread budget (disjoint output slabs; bitwise
    /// identical to [`Csc::gemv_t`]).
    pub fn gemv_t_with(&self, v: &[f64], out: &mut [f64], par: ParConfig) {
        assert_eq!(v.len(), self.nrows);
        assert_eq!(out.len(), self.ncols);
        let chunks = par.plan(self.ncols, self.avg_nnz_per_col());
        if chunks <= 1 {
            self.gemv_t(v, out);
            return;
        }
        let span = chunk_size(self.ncols, chunks);
        std::thread::scope(|scope| {
            for (ci, slab) in out.chunks_mut(span).enumerate() {
                let j0 = ci * span;
                scope.spawn(move || {
                    for (o, j) in slab.iter_mut().zip(j0..) {
                        let mut acc = 0.0;
                        for k in self.colptr[j]..self.colptr[j + 1] {
                            acc += self.values[k] * v[self.rowidx[k] as usize];
                        }
                        *o = acc;
                    }
                });
            }
        });
    }

    /// `out = X[:, cols] v`.
    pub fn gemv_subset(&self, cols: &[usize], v: &[f64], out: &mut [f64]) {
        assert_eq!(v.len(), cols.len());
        assert_eq!(out.len(), self.nrows);
        out.fill(0.0);
        for (&j, &vj) in cols.iter().zip(v) {
            if vj == 0.0 {
                continue;
            }
            for k in self.colptr[j]..self.colptr[j + 1] {
                out[self.rowidx[k] as usize] += vj * self.values[k];
            }
        }
    }

    /// `out = X[:, cols]ᵀ v`.
    pub fn gemv_t_subset(&self, cols: &[usize], v: &[f64], out: &mut [f64]) {
        assert_eq!(out.len(), cols.len());
        for (o, &j) in out.iter_mut().zip(cols) {
            let mut acc = 0.0;
            for k in self.colptr[j]..self.colptr[j + 1] {
                acc += self.values[k] * v[self.rowidx[k] as usize];
            }
            *o = acc;
        }
    }

    /// `out = X[:, cols]ᵀ v` with a thread budget (disjoint output
    /// slabs; bitwise identical to [`Csc::gemv_t_subset`]).
    pub fn gemv_t_subset_with(&self, cols: &[usize], v: &[f64], out: &mut [f64], par: ParConfig) {
        assert_eq!(out.len(), cols.len());
        assert_eq!(v.len(), self.nrows);
        let chunks = par.plan(cols.len(), self.avg_nnz_per_col());
        if chunks <= 1 {
            self.gemv_t_subset(cols, v, out);
            return;
        }
        let span = chunk_size(cols.len(), chunks);
        std::thread::scope(|scope| {
            for (ci, slab) in out.chunks_mut(span).enumerate() {
                let sub = &cols[ci * span..ci * span + slab.len()];
                scope.spawn(move || {
                    for (o, &j) in slab.iter_mut().zip(sub) {
                        let mut acc = 0.0;
                        for k in self.colptr[j]..self.colptr[j + 1] {
                            acc += self.values[k] * v[self.rowidx[k] as usize];
                        }
                        *o = acc;
                    }
                });
            }
        });
    }

    /// Squared ℓ2 norm of every column.
    pub fn col_sq_norms(&self) -> Vec<f64> {
        (0..self.ncols)
            .map(|j| {
                self.values[self.colptr[j]..self.colptr[j + 1]]
                    .iter()
                    .map(|v| v * v)
                    .sum()
            })
            .collect()
    }

    /// Squared ℓ2 norm of every column, with a thread budget.
    pub fn col_sq_norms_with(&self, par: ParConfig) -> Vec<f64> {
        let chunks = par.plan(self.ncols, self.avg_nnz_per_col());
        if chunks <= 1 {
            return self.col_sq_norms();
        }
        let mut out = vec![0.0; self.ncols];
        let span = chunk_size(self.ncols, chunks);
        std::thread::scope(|scope| {
            for (ci, slab) in out.chunks_mut(span).enumerate() {
                let j0 = ci * span;
                scope.spawn(move || {
                    for (o, j) in slab.iter_mut().zip(j0..) {
                        *o = self.values[self.colptr[j]..self.colptr[j + 1]]
                            .iter()
                            .map(|v| v * v)
                            .sum();
                    }
                });
            }
        });
        out
    }

    /// Scale columns to unit ℓ2 norm (no centering: it would densify).
    pub fn scale_columns(&mut self) {
        for j in 0..self.ncols {
            let norm: f64 = self.values[self.colptr[j]..self.colptr[j + 1]]
                .iter()
                .map(|v| v * v)
                .sum::<f64>()
                .sqrt();
            if norm > 0.0 {
                let inv = 1.0 / norm;
                for v in &mut self.values[self.colptr[j]..self.colptr[j + 1]] {
                    *v *= inv;
                }
            }
        }
    }

    /// [`Csc::scale_columns`] with a thread budget. Column ranges map to
    /// contiguous disjoint spans of the value buffer (`split_at_mut`), so
    /// threads scale without sharing; per-column arithmetic is unchanged.
    pub fn scale_columns_with(&mut self, par: ParConfig) {
        let chunks = par.plan(self.ncols, 2 * self.avg_nnz_per_col());
        if chunks <= 1 {
            self.scale_columns();
            return;
        }
        let ncols = self.ncols;
        let span = chunk_size(ncols, chunks);
        let colptr = &self.colptr;
        let mut rest: &mut [f64] = &mut self.values;
        let mut offset = 0usize;
        std::thread::scope(|scope| {
            let mut j0 = 0usize;
            while j0 < ncols {
                let j1 = (j0 + span).min(ncols);
                let end = colptr[j1];
                let (head, tail) = std::mem::take(&mut rest).split_at_mut(end - offset);
                rest = tail;
                let base = offset;
                offset = end;
                let ptrs = &colptr[j0..=j1];
                scope.spawn(move || {
                    for w in ptrs.windows(2) {
                        let seg = &mut head[w[0] - base..w[1] - base];
                        let norm: f64 = seg.iter().map(|v| v * v).sum::<f64>().sqrt();
                        if norm > 0.0 {
                            let inv = 1.0 / norm;
                            for v in seg.iter_mut() {
                                *v *= inv;
                            }
                        }
                    }
                });
                j0 = j1;
            }
        });
    }

    /// Scatter column `j` into a dense destination: zero fill, then the
    /// stored nonzeros. The packed-design engine materializes screened
    /// sparse columns through this.
    pub fn scatter_col(&self, j: usize, dst: &mut [f64]) {
        debug_assert_eq!(dst.len(), self.nrows);
        dst.fill(0.0);
        for k in self.colptr[j]..self.colptr[j + 1] {
            dst[self.rowidx[k] as usize] = self.values[k];
        }
    }

    /// Extract rows into a new CSC matrix (CV fold splitting).
    ///
    /// Direct two-pass build (count, then fill) into exactly-sized
    /// buffers — the old per-column triplet vectors allocated `2·ncols`
    /// temporaries per CV fold, which dominated fold setup on wide sparse
    /// designs. Ascending `rows` (every CV fold split) need no
    /// per-column re-sort; a permuted subset sorts each column span
    /// through one reusable scratch buffer.
    pub fn subset_rows(&self, rows: &[usize]) -> Csc {
        // map original row -> new position (or none)
        let mut map = vec![u32::MAX; self.nrows];
        for (new, &old) in rows.iter().enumerate() {
            map[old] = new as u32;
        }
        let mut colptr = Vec::with_capacity(self.ncols + 1);
        colptr.push(0usize);
        let mut nnz = 0usize;
        for j in 0..self.ncols {
            for k in self.colptr[j]..self.colptr[j + 1] {
                if map[self.rowidx[k] as usize] != u32::MAX {
                    nnz += 1;
                }
            }
            colptr.push(nnz);
        }
        let ascending = rows.windows(2).all(|w| w[0] < w[1]);
        let mut rowidx = Vec::with_capacity(nnz);
        let mut values = Vec::with_capacity(nnz);
        let mut scratch: Vec<(u32, f64)> = Vec::new();
        for j in 0..self.ncols {
            let start = rowidx.len();
            for k in self.colptr[j]..self.colptr[j + 1] {
                let m = map[self.rowidx[k] as usize];
                if m != u32::MAX {
                    rowidx.push(m);
                    values.push(self.values[k]);
                }
            }
            if !ascending {
                scratch.clear();
                scratch.extend(rowidx[start..].iter().copied().zip(values[start..].iter().copied()));
                scratch.sort_unstable_by_key(|&(r, _)| r);
                for (t, &(r, v)) in scratch.iter().enumerate() {
                    rowidx[start + t] = r;
                    values[start + t] = v;
                }
            }
        }
        Csc { nrows: rows.len(), ncols: self.ncols, colptr, rowidx, values }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn random_dense(rng: &mut Pcg64, n: usize, p: usize, density: f64) -> Mat {
        let mut m = Mat::zeros(n, p);
        for j in 0..p {
            for i in 0..n {
                if rng.bernoulli(density) {
                    m.set(i, j, rng.normal());
                }
            }
        }
        m
    }

    #[test]
    fn roundtrip_dense_sparse_dense() {
        let mut rng = Pcg64::new(1);
        let d = random_dense(&mut rng, 13, 7, 0.3);
        let s = Csc::from_dense(&d);
        assert_eq!(s.to_dense(), d);
    }

    #[test]
    fn sparse_ops_match_dense_random() {
        let mut rng = Pcg64::new(2);
        let d = random_dense(&mut rng, 17, 9, 0.25);
        let s = Csc::from_dense(&d);

        let v: Vec<f64> = (0..9).map(|_| rng.normal()).collect();
        let w: Vec<f64> = (0..17).map(|_| rng.normal()).collect();
        let (mut od, mut os) = (vec![0.0; 17], vec![0.0; 17]);
        d.gemv(&v, &mut od);
        s.gemv(&v, &mut os);
        for (a, b) in od.iter().zip(&os) {
            assert!((a - b).abs() < 1e-12);
        }
        let (mut td, mut ts) = (vec![0.0; 9], vec![0.0; 9]);
        d.gemv_t(&w, &mut td);
        s.gemv_t(&w, &mut ts);
        for (a, b) in td.iter().zip(&ts) {
            assert!((a - b).abs() < 1e-12);
        }
    }

    #[test]
    fn subset_rows_matches_dense() {
        let mut rng = Pcg64::new(3);
        let d = random_dense(&mut rng, 10, 5, 0.4);
        let s = Csc::from_dense(&d);
        let rows = [7, 2, 9, 0];
        assert_eq!(s.subset_rows(&rows).to_dense(), d.subset_rows(&rows));
    }

    #[test]
    fn subset_rows_ascending_matches_dense() {
        let mut rng = Pcg64::new(6);
        let d = random_dense(&mut rng, 12, 7, 0.4);
        let s = Csc::from_dense(&d);
        let rows = [0usize, 3, 4, 9, 11];
        assert_eq!(s.subset_rows(&rows).to_dense(), d.subset_rows(&rows));
    }

    #[test]
    fn scatter_col_round_trips() {
        let mut rng = Pcg64::new(7);
        let d = random_dense(&mut rng, 11, 5, 0.3);
        let s = Csc::from_dense(&d);
        let mut dst = vec![9.0; 11];
        for j in 0..5 {
            s.scatter_col(j, &mut dst);
            assert_eq!(&dst[..], d.col(j));
        }
    }

    #[test]
    fn scale_columns_unit_norm() {
        let mut rng = Pcg64::new(4);
        let d = random_dense(&mut rng, 20, 6, 0.5);
        let mut s = Csc::from_dense(&d);
        s.scale_columns();
        for norm in s.col_sq_norms() {
            if norm > 0.0 {
                assert!((norm - 1.0).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn parallel_kernels_match_serial() {
        use crate::linalg::par::ParConfig;
        let mut rng = Pcg64::new(5);
        let d = random_dense(&mut rng, 29, 13, 0.35);
        let s = Csc::from_dense(&d);
        let v: Vec<f64> = (0..13).map(|_| rng.normal()).collect();
        let w: Vec<f64> = (0..29).map(|_| rng.normal()).collect();
        let cols = [0usize, 3, 4, 9, 12];
        for t in [2usize, 3, 7, 32] {
            let par = ParConfig::exact(t);
            let (mut a, mut b) = (vec![0.0; 29], vec![0.0; 29]);
            s.gemv(&v, &mut a);
            s.gemv_with(&v, &mut b, par);
            for (x, y) in a.iter().zip(&b) {
                assert!((x - y).abs() < 1e-12, "gemv t={t}");
            }
            let (mut c, mut e) = (vec![0.0; 13], vec![0.0; 13]);
            s.gemv_t(&w, &mut c);
            s.gemv_t_with(&w, &mut e, par);
            assert_eq!(c, e, "gemv_t t={t}");
            let (mut f, mut g) = (vec![0.0; cols.len()], vec![0.0; cols.len()]);
            s.gemv_t_subset(&cols, &w, &mut f);
            s.gemv_t_subset_with(&cols, &w, &mut g, par);
            assert_eq!(f, g, "gemv_t_subset t={t}");
            assert_eq!(s.col_sq_norms(), s.col_sq_norms_with(par), "col_sq_norms t={t}");
            let mut ss = s.clone();
            let mut sp = s.clone();
            ss.scale_columns();
            sp.scale_columns_with(par);
            assert_eq!(ss.to_dense(), sp.to_dense(), "scale_columns t={t}");
        }
    }

    #[test]
    fn nnz_counts_stored() {
        let s = Csc::from_columns(3, &[vec![(0, 1.0), (2, 2.0)], vec![], vec![(1, 0.0)]]);
        assert_eq!(s.nnz(), 2); // explicit zero dropped
    }

    #[test]
    fn from_parts_matches_from_columns() {
        let cols = vec![vec![(0usize, 1.0), (2, 2.0)], vec![], vec![(1, 3.0)]];
        let a = Csc::from_columns(3, &cols);
        let b = Csc::from_parts(3, 3, vec![0, 2, 2, 3], vec![0, 2, 1], vec![1.0, 2.0, 3.0]);
        assert_eq!(a.to_dense(), b.to_dense());
        assert_eq!(b.nnz(), 3);
    }

    #[test]
    #[should_panic(expected = "strictly increasing")]
    fn from_parts_rejects_unsorted_rows() {
        Csc::from_parts(3, 1, vec![0, 2], vec![2, 0], vec![1.0, 2.0]);
    }

    #[test]
    fn col_entries_round_trips() {
        let mut rng = Pcg64::new(8);
        let d = random_dense(&mut rng, 9, 4, 0.4);
        let s = Csc::from_dense(&d);
        for j in 0..4 {
            for (i, v) in s.col_entries(j) {
                assert_eq!(d.get(i, j), v);
            }
            let rows: Vec<usize> = s.col_entries(j).map(|(i, _)| i).collect();
            assert!(rows.windows(2).all(|w| w[0] < w[1]));
        }
    }
}

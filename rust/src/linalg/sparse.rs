//! Compressed sparse column (CSC) matrix for sparse binary designs like
//! dorothea (800 × 88119, ~1% density).

use super::dense::Mat;

/// CSC sparse matrix: `colptr[j]..colptr[j+1]` indexes the nonzeros of
/// column `j` in `(rowidx, values)`.
#[derive(Clone, Debug)]
pub struct Csc {
    nrows: usize,
    ncols: usize,
    colptr: Vec<usize>,
    rowidx: Vec<u32>,
    values: Vec<f64>,
}

impl Csc {
    /// Build from column triplets: for each column a list of `(row, value)`.
    pub fn from_columns(nrows: usize, cols: &[Vec<(usize, f64)>]) -> Self {
        let ncols = cols.len();
        let mut colptr = Vec::with_capacity(ncols + 1);
        let mut rowidx = Vec::new();
        let mut values = Vec::new();
        colptr.push(0);
        for col in cols {
            let mut entries = col.clone();
            entries.sort_unstable_by_key(|&(r, _)| r);
            for &(r, v) in &entries {
                assert!(r < nrows, "row index out of range");
                if v != 0.0 {
                    rowidx.push(r as u32);
                    values.push(v);
                }
            }
            colptr.push(rowidx.len());
        }
        Self { nrows, ncols, colptr, rowidx, values }
    }

    /// Densify a `Mat` into CSC form (test/interop convenience).
    pub fn from_dense(m: &Mat) -> Self {
        let cols: Vec<Vec<(usize, f64)>> = (0..m.ncols())
            .map(|j| {
                m.col(j)
                    .iter()
                    .enumerate()
                    .filter(|(_, &v)| v != 0.0)
                    .map(|(i, &v)| (i, v))
                    .collect()
            })
            .collect();
        Self::from_columns(m.nrows(), &cols)
    }

    /// Convert to a dense matrix.
    pub fn to_dense(&self) -> Mat {
        let mut m = Mat::zeros(self.nrows, self.ncols);
        for j in 0..self.ncols {
            for k in self.colptr[j]..self.colptr[j + 1] {
                m.set(self.rowidx[k] as usize, j, self.values[k]);
            }
        }
        m
    }

    /// Number of rows.
    #[inline]
    pub fn nrows(&self) -> usize {
        self.nrows
    }

    /// Number of columns.
    #[inline]
    pub fn ncols(&self) -> usize {
        self.ncols
    }

    /// Number of stored nonzeros.
    #[inline]
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// `out = X v`.
    pub fn gemv(&self, v: &[f64], out: &mut [f64]) {
        assert_eq!(v.len(), self.ncols);
        assert_eq!(out.len(), self.nrows);
        out.fill(0.0);
        for j in 0..self.ncols {
            let vj = v[j];
            if vj == 0.0 {
                continue;
            }
            for k in self.colptr[j]..self.colptr[j + 1] {
                out[self.rowidx[k] as usize] += vj * self.values[k];
            }
        }
    }

    /// `out = Xᵀ v`.
    pub fn gemv_t(&self, v: &[f64], out: &mut [f64]) {
        assert_eq!(v.len(), self.nrows);
        assert_eq!(out.len(), self.ncols);
        for j in 0..self.ncols {
            let mut acc = 0.0;
            for k in self.colptr[j]..self.colptr[j + 1] {
                acc += self.values[k] * v[self.rowidx[k] as usize];
            }
            out[j] = acc;
        }
    }

    /// `out = X[:, cols] v`.
    pub fn gemv_subset(&self, cols: &[usize], v: &[f64], out: &mut [f64]) {
        assert_eq!(v.len(), cols.len());
        assert_eq!(out.len(), self.nrows);
        out.fill(0.0);
        for (&j, &vj) in cols.iter().zip(v) {
            if vj == 0.0 {
                continue;
            }
            for k in self.colptr[j]..self.colptr[j + 1] {
                out[self.rowidx[k] as usize] += vj * self.values[k];
            }
        }
    }

    /// `out = X[:, cols]ᵀ v`.
    pub fn gemv_t_subset(&self, cols: &[usize], v: &[f64], out: &mut [f64]) {
        assert_eq!(out.len(), cols.len());
        for (o, &j) in out.iter_mut().zip(cols) {
            let mut acc = 0.0;
            for k in self.colptr[j]..self.colptr[j + 1] {
                acc += self.values[k] * v[self.rowidx[k] as usize];
            }
            *o = acc;
        }
    }

    /// Squared ℓ2 norm of every column.
    pub fn col_sq_norms(&self) -> Vec<f64> {
        (0..self.ncols)
            .map(|j| {
                self.values[self.colptr[j]..self.colptr[j + 1]]
                    .iter()
                    .map(|v| v * v)
                    .sum()
            })
            .collect()
    }

    /// Scale columns to unit ℓ2 norm (no centering: it would densify).
    pub fn scale_columns(&mut self) {
        for j in 0..self.ncols {
            let norm: f64 = self.values[self.colptr[j]..self.colptr[j + 1]]
                .iter()
                .map(|v| v * v)
                .sum::<f64>()
                .sqrt();
            if norm > 0.0 {
                let inv = 1.0 / norm;
                for v in &mut self.values[self.colptr[j]..self.colptr[j + 1]] {
                    *v *= inv;
                }
            }
        }
    }

    /// Extract rows into a new CSC matrix (CV fold splitting).
    pub fn subset_rows(&self, rows: &[usize]) -> Csc {
        // map original row -> new position (or none)
        let mut map = vec![u32::MAX; self.nrows];
        for (new, &old) in rows.iter().enumerate() {
            map[old] = new as u32;
        }
        let mut cols: Vec<Vec<(usize, f64)>> = vec![Vec::new(); self.ncols];
        for j in 0..self.ncols {
            for k in self.colptr[j]..self.colptr[j + 1] {
                let m = map[self.rowidx[k] as usize];
                if m != u32::MAX {
                    cols[j].push((m as usize, self.values[k]));
                }
            }
        }
        Csc::from_columns(rows.len(), &cols)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn random_dense(rng: &mut Pcg64, n: usize, p: usize, density: f64) -> Mat {
        let mut m = Mat::zeros(n, p);
        for j in 0..p {
            for i in 0..n {
                if rng.bernoulli(density) {
                    m.set(i, j, rng.normal());
                }
            }
        }
        m
    }

    #[test]
    fn roundtrip_dense_sparse_dense() {
        let mut rng = Pcg64::new(1);
        let d = random_dense(&mut rng, 13, 7, 0.3);
        let s = Csc::from_dense(&d);
        assert_eq!(s.to_dense(), d);
    }

    #[test]
    fn sparse_ops_match_dense_random() {
        let mut rng = Pcg64::new(2);
        let d = random_dense(&mut rng, 17, 9, 0.25);
        let s = Csc::from_dense(&d);

        let v: Vec<f64> = (0..9).map(|_| rng.normal()).collect();
        let w: Vec<f64> = (0..17).map(|_| rng.normal()).collect();
        let (mut od, mut os) = (vec![0.0; 17], vec![0.0; 17]);
        d.gemv(&v, &mut od);
        s.gemv(&v, &mut os);
        for (a, b) in od.iter().zip(&os) {
            assert!((a - b).abs() < 1e-12);
        }
        let (mut td, mut ts) = (vec![0.0; 9], vec![0.0; 9]);
        d.gemv_t(&w, &mut td);
        s.gemv_t(&w, &mut ts);
        for (a, b) in td.iter().zip(&ts) {
            assert!((a - b).abs() < 1e-12);
        }
    }

    #[test]
    fn subset_rows_matches_dense() {
        let mut rng = Pcg64::new(3);
        let d = random_dense(&mut rng, 10, 5, 0.4);
        let s = Csc::from_dense(&d);
        let rows = [7, 2, 9, 0];
        assert_eq!(s.subset_rows(&rows).to_dense(), d.subset_rows(&rows));
    }

    #[test]
    fn scale_columns_unit_norm() {
        let mut rng = Pcg64::new(4);
        let d = random_dense(&mut rng, 20, 6, 0.5);
        let mut s = Csc::from_dense(&d);
        s.scale_columns();
        for norm in s.col_sq_norms() {
            if norm > 0.0 {
                assert!((norm - 1.0).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn nnz_counts_stored() {
        let s = Csc::from_columns(3, &[vec![(0, 1.0), (2, 2.0)], vec![], vec![(1, 0.0)]]);
        assert_eq!(s.nnz(), 2); // explicit zero dropped
    }
}

//! Small vector helpers shared across the solver and the screening rule.

/// `y += alpha * x`.
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, &xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

/// Squared Euclidean norm.
#[inline]
pub fn sq_norm(x: &[f64]) -> f64 {
    x.iter().map(|v| v * v).sum()
}

/// Euclidean norm.
#[inline]
pub fn norm(x: &[f64]) -> f64 {
    sq_norm(x).sqrt()
}

/// ℓ∞ norm.
#[inline]
pub fn inf_norm(x: &[f64]) -> f64 {
    x.iter().fold(0.0f64, |m, v| m.max(v.abs()))
}

/// Elementwise difference norm ‖a − b‖₂.
#[inline]
pub fn dist(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum::<f64>().sqrt()
}

/// Mean of a slice.
#[inline]
pub fn mean(x: &[f64]) -> f64 {
    if x.is_empty() {
        0.0
    } else {
        x.iter().sum::<f64>() / x.len() as f64
    }
}

/// Cumulative sum, as defined in the paper's §1.2.
pub fn cumsum(x: &[f64]) -> Vec<f64> {
    let mut out = Vec::with_capacity(x.len());
    let mut acc = 0.0;
    for &v in x {
        acc += v;
        out.push(acc);
    }
    out
}

/// Sort a copy of `|x|` in decreasing order (the paper's `|x|↓`).
///
/// Uses `f64::total_cmp`: a NaN in a gradient (a diverged solve, a bad
/// request) must not panic the sort — NaNs order first and the KKT
/// safeguard surfaces the bad fit instead.
pub fn abs_sorted_desc(x: &[f64]) -> Vec<f64> {
    let mut out: Vec<f64> = x.iter().map(|v| v.abs()).collect();
    out.sort_unstable_by(|a, b| b.total_cmp(a));
    out
}

/// Sort packed `(|value|, index)` pairs descending by magnitude with the
/// ascending-index tiebreak — **the** ordering comparator of the stack
/// (`total_cmp`, not `partial_cmp().unwrap()`: a NaN must not panic the
/// screening path). [`order_desc_abs`], the screening workspace's
/// ranking and the prox's in-workspace sort all share this one
/// definition, because their bitwise agreement is a pinned contract —
/// a comparator edit must change all of them at once.
#[inline]
pub fn sort_pairs_desc_abs(pairs: &mut [(f64, u32)]) {
    pairs.sort_unstable_by(|a, b| b.0.total_cmp(&a.0).then(a.1.cmp(&b.1)));
}

/// Permutation `O(x)` that sorts `|x|` in decreasing order: returns indices
/// `ord` such that `|x[ord[0]]| >= |x[ord[1]]| >= ...`. Ties are broken by
/// original index for determinism. (`sort_unstable_by` — the stable sort
/// allocates a temp buffer on every call, which showed up in the screening
/// phase profile; the explicit index tiebreak keeps the result
/// deterministic. See EXPERIMENTS.md §Perf.)
pub fn order_desc_abs(x: &[f64]) -> Vec<usize> {
    // Sort packed (|value|, index) pairs rather than indices with indirect
    // key lookups — direct key compares are ~2× faster on large p because
    // the comparator stops chasing pointers into `x` (§Perf).
    let mut pairs: Vec<(f64, u32)> = x
        .iter()
        .enumerate()
        .map(|(i, &v)| (v.abs(), i as u32))
        .collect();
    sort_pairs_desc_abs(&mut pairs);
    pairs.into_iter().map(|(_, i)| i as usize).collect()
}

/// Quantile of a sorted slice (linear interpolation, type-7 like R).
pub fn quantile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    let h = (sorted.len() - 1) as f64 * q.clamp(0.0, 1.0);
    let lo = h.floor() as usize;
    let hi = h.ceil() as usize;
    sorted[lo] + (h - lo as f64) * (sorted[hi] - sorted[lo])
}

/// Inverse standard normal CDF (Acklam's rational approximation, |err| <
/// 1.15e-9) — the probit `Φ⁻¹` needed by the BH λ-sequence (§3.1.1).
pub fn probit(p: f64) -> f64 {
    assert!(p > 0.0 && p < 1.0, "probit domain is (0,1), got {p}");
    const A: [f64; 6] = [
        -39.696_830_286_653_76,
        220.946_098_424_520_8,
        -275.928_510_446_968_96,
        138.357_751_867_269_17,
        -30.664_798_066_147_16,
        2.506_628_277_459_239,
    ];
    const B: [f64; 5] = [
        -54.476_098_798_224_058,
        161.585_836_858_040_97,
        -155.698_979_859_886_66,
        66.801_311_887_719_72,
        -13.280_681_552_885_721,
    ];
    const C: [f64; 6] = [
        -0.007_784_894_002_430_293,
        -0.322_396_458_041_136_4,
        -2.400_758_277_161_838,
        -2.549_732_539_343_734,
        4.374_664_141_464_968,
        2.938_163_982_698_783,
    ];
    const D: [f64; 4] = [
        0.007_784_695_709_041_462,
        0.322_467_129_070_039_8,
        2.445_134_137_142_996,
        3.754_408_661_907_416,
    ];
    const P_LOW: f64 = 0.024_25;
    const P_HIGH: f64 = 1.0 - P_LOW;
    if p < P_LOW {
        let q = (-2.0 * p.ln()).sqrt();
        (((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    } else if p <= P_HIGH {
        let q = p - 0.5;
        let r = q * q;
        (((((A[0] * r + A[1]) * r + A[2]) * r + A[3]) * r + A[4]) * r + A[5]) * q
            / (((((B[0] * r + B[1]) * r + B[2]) * r + B[3]) * r + B[4]) * r + 1.0)
    } else {
        -probit(1.0 - p)
    }
}

/// Standard normal CDF via `erf` (Abramowitz–Stegun 7.1.26, |err| < 1.5e-7;
/// used only in tests to sanity-check `probit`).
pub fn norm_cdf(x: f64) -> f64 {
    0.5 * (1.0 + erf(x / std::f64::consts::SQRT_2))
}

fn erf(x: f64) -> f64 {
    let sign = if x < 0.0 { -1.0 } else { 1.0 };
    let x = x.abs();
    let t = 1.0 / (1.0 + 0.327_591_1 * x);
    let y = 1.0
        - (((((1.061_405_429 * t - 1.453_152_027) * t) + 1.421_413_741) * t - 0.284_496_736)
            * t
            + 0.254_829_592)
            * t
            * (-x * x).exp();
    sign * y
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cumsum_basic() {
        assert_eq!(cumsum(&[1.0, 2.0, 3.0]), vec![1.0, 3.0, 6.0]);
        assert!(cumsum(&[]).is_empty());
    }

    #[test]
    fn abs_sorted_desc_basic() {
        assert_eq!(abs_sorted_desc(&[-3.0, 5.0, 3.0, 6.0]), vec![6.0, 5.0, 3.0, 3.0]);
    }

    #[test]
    fn order_desc_abs_matches_paper_example() {
        // Example 1 in the paper: beta = (-3, 5, 3, 6) => O = (4, 2, 1, 3)
        // (1-indexed). Our 0-indexed version is (3, 1, 0, 2).
        assert_eq!(order_desc_abs(&[-3.0, 5.0, 3.0, 6.0]), vec![3, 1, 0, 2]);
    }

    #[test]
    fn probit_roundtrips_with_cdf() {
        for &p in &[0.001, 0.01, 0.1, 0.25, 0.5, 0.75, 0.9, 0.99, 0.999] {
            let x = probit(p);
            assert!((norm_cdf(x) - p).abs() < 1e-6, "p={p} x={x}");
        }
    }

    #[test]
    fn probit_known_values() {
        assert!(probit(0.5).abs() < 1e-12);
        assert!((probit(0.975) - 1.959_963_985).abs() < 1e-6);
        assert!((probit(0.025) + 1.959_963_985).abs() < 1e-6);
    }

    #[test]
    fn quantile_endpoints() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(quantile_sorted(&xs, 0.0), 1.0);
        assert_eq!(quantile_sorted(&xs, 1.0), 4.0);
        assert_eq!(quantile_sorted(&xs, 0.5), 2.5);
    }

    #[test]
    fn norms() {
        assert_eq!(sq_norm(&[3.0, 4.0]), 25.0);
        assert_eq!(norm(&[3.0, 4.0]), 5.0);
        assert_eq!(inf_norm(&[-7.0, 2.0]), 7.0);
        assert_eq!(dist(&[1.0, 1.0], &[4.0, 5.0]), 5.0);
    }
}

//! Parallel-execution layer for the hot linear-algebra kernels.
//!
//! No `rayon` is available offline, so parallelism is hand-rolled on
//! `std::thread::scope`: each kernel partitions its output (or its column
//! range) into contiguous chunks and runs one scoped thread per chunk.
//! Two properties drive the design:
//!
//! * **Determinism.** Dense kernels partition the *output* (row slabs for
//!   `Xv`, column slabs for `Xᵀv`), so every output element is accumulated
//!   in exactly the serial order — parallel and serial results are bitwise
//!   identical. Only the sparse `Xv` kernel reduces per-thread partial
//!   accumulators (its scattered writes admit no disjoint output
//!   partition), which regroups floating-point sums; agreement there is to
//!   rounding, not bitwise.
//! * **No oversubscription.** A [`ParConfig`] is a per-call thread
//!   *budget*, not a pool: `threads == 0` defers to the process-wide
//!   setting ([`set_global_threads`], CLI `--threads`, or the machine
//!   default), and callers that already run on a worker pool (serve, CV)
//!   hand each job `total / workers` threads so kernels never multiply the
//!   pool's parallelism.
//!
//! Scoped threads are spawned per call (~10µs each); the `grain` floor
//! keeps small problems on the serial path so the reduced solves of a
//! well-screened path never pay spawn overhead for tiny `E`.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Process-wide thread budget override; 0 means "not set, use the
/// machine default".
static GLOBAL_THREADS: AtomicUsize = AtomicUsize::new(0);

/// Cap on the auto-detected thread count (matches the worker pool's cap).
pub const MAX_AUTO_THREADS: usize = 16;

/// Default minimum scalar operations per thread before a kernel splits.
/// Below this, thread-spawn latency dominates any parallel win.
pub const DEFAULT_GRAIN: usize = 32_768;

/// Set the process-wide default thread budget (0 restores auto-detect).
/// The CLI's `--threads` flag lands here.
pub fn set_global_threads(n: usize) {
    GLOBAL_THREADS.store(n, Ordering::Relaxed);
}

/// Resolve the process-wide thread budget: the explicit global setting if
/// one was made, else `available_parallelism` capped at
/// [`MAX_AUTO_THREADS`].
pub fn global_threads() -> usize {
    let n = GLOBAL_THREADS.load(Ordering::Relaxed);
    if n > 0 {
        n
    } else {
        detected_parallelism()
    }
}

/// The machine's parallelism, capped (1 if detection fails).
pub fn detected_parallelism() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .min(MAX_AUTO_THREADS)
}

/// Per-call parallel-execution budget for the linalg kernels.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ParConfig {
    /// Thread budget; 0 resolves to the process-wide setting at use time.
    pub threads: usize,
    /// Minimum scalar operations per thread before splitting (0 disables
    /// the floor — tests use this to force tiny problems onto the
    /// parallel path).
    pub grain: usize,
}

impl Default for ParConfig {
    fn default() -> Self {
        ParConfig { threads: 0, grain: DEFAULT_GRAIN }
    }
}

impl ParConfig {
    /// Always-serial configuration (the old kernel behavior).
    pub fn serial() -> ParConfig {
        ParConfig { threads: 1, grain: DEFAULT_GRAIN }
    }

    /// Budget of `threads` (0 = process-wide setting) with the default
    /// work floor.
    pub fn with_threads(threads: usize) -> ParConfig {
        ParConfig { threads, grain: DEFAULT_GRAIN }
    }

    /// Exactly `threads` chunks whenever the work has that many partition
    /// units, regardless of work size. For tests that must exercise the
    /// parallel code path on small shapes.
    pub fn exact(threads: usize) -> ParConfig {
        ParConfig { threads: threads.max(1), grain: 0 }
    }

    /// The thread budget with the process-wide default applied.
    pub fn resolved_threads(&self) -> usize {
        if self.threads == 0 {
            global_threads()
        } else {
            self.threads
        }
    }

    /// Number of chunks to split `units` partition units into, given
    /// `work_per_unit` scalar operations per unit. Returns 1 (serial)
    /// when the budget is 1, there is at most one unit, or the total work
    /// is below the grain floor.
    pub fn plan(&self, units: usize, work_per_unit: usize) -> usize {
        let t = self.resolved_threads();
        if t <= 1 || units <= 1 {
            return 1;
        }
        let cap = if self.grain == 0 {
            t
        } else {
            let total = units.saturating_mul(work_per_unit.max(1));
            (total / self.grain).max(1)
        };
        t.min(cap).min(units)
    }
}

/// `ceil(len / chunks)` — the slab size the kernels hand `chunks_mut`.
#[inline]
pub fn chunk_size(len: usize, chunks: usize) -> usize {
    debug_assert!(chunks >= 1);
    (len + chunks - 1) / chunks
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serial_config_never_splits() {
        let par = ParConfig::serial();
        assert_eq!(par.plan(1_000_000, 1_000), 1);
    }

    #[test]
    fn exact_config_splits_small_work() {
        let par = ParConfig::exact(7);
        assert_eq!(par.plan(100, 1), 7);
        // ...but never into more chunks than units
        assert_eq!(par.plan(3, 1), 3);
        assert_eq!(par.plan(1, 1), 1);
        assert_eq!(par.plan(0, 1), 1);
    }

    #[test]
    fn grain_floor_keeps_tiny_work_serial() {
        let par = ParConfig { threads: 8, grain: 1000 };
        assert_eq!(par.plan(10, 10), 1); // 100 ops < grain
        assert!(par.plan(1000, 1000) > 1); // 1e6 ops >> grain
    }

    #[test]
    fn plan_scales_with_work() {
        let par = ParConfig { threads: 8, grain: 100 };
        // 250 ops -> at most 2 chunks despite an 8-thread budget
        assert_eq!(par.plan(250, 1), 2);
    }

    #[test]
    fn chunk_size_covers_len() {
        for len in [0usize, 1, 5, 7, 8, 100] {
            for chunks in [1usize, 2, 3, 7] {
                let c = chunk_size(len, chunks);
                if len > 0 {
                    assert!(c * chunks >= len);
                    assert!(c * chunks < len + chunks);
                }
            }
        }
    }

    #[test]
    fn global_override_roundtrip() {
        // NB: global state — restore afterwards so test order can't leak.
        let before = GLOBAL_THREADS.load(Ordering::Relaxed);
        set_global_threads(3);
        assert_eq!(global_threads(), 3);
        assert_eq!(ParConfig::with_threads(0).resolved_threads(), 3);
        assert_eq!(ParConfig::with_threads(5).resolved_threads(), 5);
        set_global_threads(before);
    }

    #[test]
    fn detection_is_positive() {
        assert!(detected_parallelism() >= 1);
        assert!(detected_parallelism() <= MAX_AUTO_THREADS);
    }
}

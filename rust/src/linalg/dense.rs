//! Dense column-major matrix with the column-oriented kernels the SLOPE
//! path solver spends its time in.

/// Dense `f64` matrix, column-major (`data[j * nrows + i]` is `(i, j)`).
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    nrows: usize,
    ncols: usize,
    data: Vec<f64>,
}

impl Mat {
    /// Zero matrix of the given shape.
    pub fn zeros(nrows: usize, ncols: usize) -> Self {
        Self { nrows, ncols, data: vec![0.0; nrows * ncols] }
    }

    /// Build from a column-major buffer.
    pub fn from_col_major(nrows: usize, ncols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), nrows * ncols, "buffer/shape mismatch");
        Self { nrows, ncols, data }
    }

    /// Build from row slices (test convenience).
    pub fn from_rows(rows: &[&[f64]]) -> Self {
        let nrows = rows.len();
        let ncols = if nrows == 0 { 0 } else { rows[0].len() };
        let mut m = Self::zeros(nrows, ncols);
        for (i, row) in rows.iter().enumerate() {
            assert_eq!(row.len(), ncols, "ragged rows");
            for (j, &v) in row.iter().enumerate() {
                m.set(i, j, v);
            }
        }
        m
    }

    /// Number of rows.
    #[inline]
    pub fn nrows(&self) -> usize {
        self.nrows
    }

    /// Number of columns.
    #[inline]
    pub fn ncols(&self) -> usize {
        self.ncols
    }

    /// Element accessor.
    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        debug_assert!(i < self.nrows && j < self.ncols);
        self.data[j * self.nrows + i]
    }

    /// Element mutator.
    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        debug_assert!(i < self.nrows && j < self.ncols);
        self.data[j * self.nrows + i] = v;
    }

    /// Borrow column `j`.
    #[inline]
    pub fn col(&self, j: usize) -> &[f64] {
        &self.data[j * self.nrows..(j + 1) * self.nrows]
    }

    /// Mutably borrow column `j`.
    #[inline]
    pub fn col_mut(&mut self, j: usize) -> &mut [f64] {
        &mut self.data[j * self.nrows..(j + 1) * self.nrows]
    }

    /// The raw column-major buffer.
    #[inline]
    pub fn data(&self) -> &[f64] {
        &self.data
    }

    /// `out = X v`. Column-major axpy accumulation: for each column j,
    /// `out += v_j * x_j`, with the inner loop auto-vectorizing.
    pub fn gemv(&self, v: &[f64], out: &mut [f64]) {
        assert_eq!(v.len(), self.ncols);
        assert_eq!(out.len(), self.nrows);
        out.fill(0.0);
        for j in 0..self.ncols {
            let vj = v[j];
            if vj == 0.0 {
                continue; // sparse iterates are common on screened paths
            }
            let col = self.col(j);
            for (o, &x) in out.iter_mut().zip(col) {
                *o += vj * x;
            }
        }
    }

    /// `out = Xᵀ v`: one dot product per column, 4-way unrolled.
    pub fn gemv_t(&self, v: &[f64], out: &mut [f64]) {
        assert_eq!(v.len(), self.nrows);
        assert_eq!(out.len(), self.ncols);
        for j in 0..self.ncols {
            out[j] = dot(self.col(j), v);
        }
    }

    /// `out = X[:, cols] v` where `v.len() == cols.len()`.
    pub fn gemv_subset(&self, cols: &[usize], v: &[f64], out: &mut [f64]) {
        assert_eq!(v.len(), cols.len());
        assert_eq!(out.len(), self.nrows);
        out.fill(0.0);
        for (&j, &vj) in cols.iter().zip(v) {
            if vj == 0.0 {
                continue;
            }
            let col = self.col(j);
            for (o, &x) in out.iter_mut().zip(col) {
                *o += vj * x;
            }
        }
    }

    /// `out = X[:, cols]ᵀ v` where `out.len() == cols.len()`.
    pub fn gemv_t_subset(&self, cols: &[usize], v: &[f64], out: &mut [f64]) {
        assert_eq!(out.len(), cols.len());
        assert_eq!(v.len(), self.nrows);
        for (o, &j) in out.iter_mut().zip(cols) {
            *o = dot(self.col(j), v);
        }
    }

    /// Squared ℓ2 norm of every column.
    pub fn col_sq_norms(&self) -> Vec<f64> {
        (0..self.ncols).map(|j| dot(self.col(j), self.col(j))).collect()
    }

    /// Center columns to mean zero and/or scale to unit ℓ2 norm
    /// (the paper's §3.1 normalization). Constant columns are left at zero
    /// after centering (their norm would be 0).
    pub fn standardize(&mut self, center: bool, scale: bool) {
        let n = self.nrows as f64;
        for j in 0..self.ncols {
            let col = self.col_mut(j);
            if center {
                let mean = col.iter().sum::<f64>() / n;
                for x in col.iter_mut() {
                    *x -= mean;
                }
            }
            if scale {
                let norm = col.iter().map(|x| x * x).sum::<f64>().sqrt();
                if norm > 0.0 {
                    let inv = 1.0 / norm;
                    for x in col.iter_mut() {
                        *x *= inv;
                    }
                }
            }
        }
    }

    /// Extract rows into a new matrix (used by the CV fold splitter).
    pub fn subset_rows(&self, rows: &[usize]) -> Mat {
        let mut out = Mat::zeros(rows.len(), self.ncols);
        for j in 0..self.ncols {
            let src = self.col(j);
            let dst = out.col_mut(j);
            for (d, &i) in dst.iter_mut().zip(rows) {
                *d = src[i];
            }
        }
        out
    }

    /// Dense matrix product `A B` (n×k · k×m). Only used at build/test time
    /// (e.g. generating correlated designs), not on the solve path.
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.ncols, other.nrows);
        let mut out = Mat::zeros(self.nrows, other.ncols);
        for j in 0..other.ncols {
            let bcol = other.col(j);
            let ocol_start = j * out.nrows;
            for (l, &b) in bcol.iter().enumerate() {
                if b == 0.0 {
                    continue;
                }
                let acol = self.col(l);
                let ocol = &mut out.data[ocol_start..ocol_start + acol.len()];
                for (o, &a) in ocol.iter_mut().zip(acol) {
                    *o += b * a;
                }
            }
        }
        out
    }
}

/// 4-way unrolled dot product — the single hottest scalar kernel in the
/// solver (`Xᵀr` is a dot per column per iteration).
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let chunks = a.len() / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
    for c in 0..chunks {
        let i = c * 4;
        s0 += a[i] * b[i];
        s1 += a[i + 1] * b[i + 1];
        s2 += a[i + 2] * b[i + 2];
        s3 += a[i + 3] * b[i + 3];
    }
    let mut tail = (s0 + s1) + (s2 + s3);
    for i in chunks * 4..a.len() {
        tail += a[i] * b[i];
    }
    tail
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn get_set_roundtrip() {
        let mut m = Mat::zeros(3, 2);
        m.set(2, 1, 5.0);
        assert_eq!(m.get(2, 1), 5.0);
        assert_eq!(m.get(0, 0), 0.0);
    }

    #[test]
    fn gemv_known_values() {
        let m = Mat::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        let mut out = [0.0; 2];
        m.gemv(&[1.0, -1.0], &mut out);
        assert_eq!(out, [-1.0, -1.0]);
    }

    #[test]
    fn gemv_t_known_values() {
        let m = Mat::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        let mut out = [0.0; 2];
        m.gemv_t(&[1.0, 1.0], &mut out);
        assert_eq!(out, [4.0, 6.0]);
    }

    #[test]
    fn dot_matches_naive_for_odd_lengths() {
        for len in [0usize, 1, 3, 4, 5, 7, 8, 17] {
            let a: Vec<f64> = (0..len).map(|i| i as f64 * 0.5).collect();
            let b: Vec<f64> = (0..len).map(|i| (i as f64) - 2.0).collect();
            let naive: f64 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            assert!((dot(&a, &b) - naive).abs() < 1e-12, "len={len}");
        }
    }

    #[test]
    fn standardize_unit_columns() {
        let mut m = Mat::from_rows(&[&[1.0, 10.0], &[2.0, 20.0], &[3.0, 60.0]]);
        m.standardize(true, true);
        for j in 0..2 {
            let col = m.col(j);
            let mean: f64 = col.iter().sum::<f64>() / 3.0;
            let norm: f64 = col.iter().map(|x| x * x).sum::<f64>().sqrt();
            assert!(mean.abs() < 1e-12);
            assert!((norm - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn standardize_handles_constant_column() {
        let mut m = Mat::from_rows(&[&[5.0], &[5.0]]);
        m.standardize(true, true);
        assert_eq!(m.col(0), &[0.0, 0.0]);
    }

    #[test]
    fn subset_rows_extracts() {
        let m = Mat::from_rows(&[&[1.0, 2.0], &[3.0, 4.0], &[5.0, 6.0]]);
        let s = m.subset_rows(&[2, 0]);
        assert_eq!(s.nrows(), 2);
        assert_eq!(s.get(0, 0), 5.0);
        assert_eq!(s.get(1, 1), 2.0);
    }

    #[test]
    fn matmul_identity() {
        let a = Mat::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        let eye = Mat::from_rows(&[&[1.0, 0.0], &[0.0, 1.0]]);
        assert_eq!(a.matmul(&eye), a);
    }

    #[test]
    fn matmul_known() {
        let a = Mat::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        let b = Mat::from_rows(&[&[5.0], &[6.0]]);
        let c = a.matmul(&b);
        assert_eq!(c.get(0, 0), 17.0);
        assert_eq!(c.get(1, 0), 39.0);
    }
}

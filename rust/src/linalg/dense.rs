//! Dense column-major matrix with the column-oriented kernels the SLOPE
//! path solver spends its time in.
//!
//! Every hot kernel has a `*_with` variant taking a
//! [`ParConfig`](super::par::ParConfig) thread budget. The parallel forms
//! partition the *output* into contiguous slabs (rows for `Xv`, columns
//! for `Xᵀv`), so each element is accumulated in exactly the serial order
//! — parallel results are bitwise identical to serial ones.

use super::par::{chunk_size, ParConfig};

/// Dense `f64` matrix, column-major (`data[j * nrows + i]` is `(i, j)`).
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    nrows: usize,
    ncols: usize,
    data: Vec<f64>,
}

impl Mat {
    /// Zero matrix of the given shape.
    pub fn zeros(nrows: usize, ncols: usize) -> Self {
        Self { nrows, ncols, data: vec![0.0; nrows * ncols] }
    }

    /// Build from a column-major buffer.
    pub fn from_col_major(nrows: usize, ncols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), nrows * ncols, "buffer/shape mismatch");
        Self { nrows, ncols, data }
    }

    /// Build from row slices (test convenience).
    pub fn from_rows(rows: &[&[f64]]) -> Self {
        let nrows = rows.len();
        let ncols = if nrows == 0 { 0 } else { rows[0].len() };
        let mut m = Self::zeros(nrows, ncols);
        for (i, row) in rows.iter().enumerate() {
            assert_eq!(row.len(), ncols, "ragged rows");
            for (j, &v) in row.iter().enumerate() {
                m.set(i, j, v);
            }
        }
        m
    }

    /// Number of rows.
    #[inline]
    pub fn nrows(&self) -> usize {
        self.nrows
    }

    /// Number of columns.
    #[inline]
    pub fn ncols(&self) -> usize {
        self.ncols
    }

    /// Element accessor.
    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        debug_assert!(i < self.nrows && j < self.ncols);
        self.data[j * self.nrows + i]
    }

    /// Element mutator.
    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        debug_assert!(i < self.nrows && j < self.ncols);
        self.data[j * self.nrows + i] = v;
    }

    /// Borrow column `j`.
    #[inline]
    pub fn col(&self, j: usize) -> &[f64] {
        &self.data[j * self.nrows..(j + 1) * self.nrows]
    }

    /// Mutably borrow column `j`.
    #[inline]
    pub fn col_mut(&mut self, j: usize) -> &mut [f64] {
        &mut self.data[j * self.nrows..(j + 1) * self.nrows]
    }

    /// The raw column-major buffer.
    #[inline]
    pub fn data(&self) -> &[f64] {
        &self.data
    }

    /// `out = X v`. Column-major axpy accumulation: for each column j,
    /// `out += v_j * x_j`, with the inner loop auto-vectorizing.
    pub fn gemv(&self, v: &[f64], out: &mut [f64]) {
        assert_eq!(v.len(), self.ncols);
        assert_eq!(out.len(), self.nrows);
        out.fill(0.0);
        for j in 0..self.ncols {
            let vj = v[j];
            if vj == 0.0 {
                continue; // sparse iterates are common on screened paths
            }
            let col = self.col(j);
            for (o, &x) in out.iter_mut().zip(col) {
                *o += vj * x;
            }
        }
    }

    /// `out = X v` with a thread budget: the output rows are split into
    /// contiguous slabs, one scoped thread per slab. Each slab walks the
    /// columns in the serial order, so the result is bitwise identical to
    /// [`Mat::gemv`].
    pub fn gemv_with(&self, v: &[f64], out: &mut [f64], par: ParConfig) {
        assert_eq!(v.len(), self.ncols);
        assert_eq!(out.len(), self.nrows);
        let chunks = par.plan(self.nrows, self.ncols);
        if chunks <= 1 {
            self.gemv(v, out);
            return;
        }
        let slab = chunk_size(self.nrows, chunks);
        std::thread::scope(|scope| {
            for (ci, rows) in out.chunks_mut(slab).enumerate() {
                let r0 = ci * slab;
                scope.spawn(move || {
                    rows.fill(0.0);
                    for (j, &vj) in v.iter().enumerate() {
                        if vj == 0.0 {
                            continue;
                        }
                        let col = &self.col(j)[r0..r0 + rows.len()];
                        for (o, &x) in rows.iter_mut().zip(col) {
                            *o += vj * x;
                        }
                    }
                });
            }
        });
    }

    /// `out = Xᵀ v`: one dot product per column, 4-way unrolled.
    pub fn gemv_t(&self, v: &[f64], out: &mut [f64]) {
        assert_eq!(v.len(), self.nrows);
        assert_eq!(out.len(), self.ncols);
        for j in 0..self.ncols {
            out[j] = dot(self.col(j), v);
        }
    }

    /// `out = Xᵀ v` with a thread budget: independent per-column dots,
    /// the output split into contiguous column slabs. Bitwise identical
    /// to [`Mat::gemv_t`].
    pub fn gemv_t_with(&self, v: &[f64], out: &mut [f64], par: ParConfig) {
        assert_eq!(v.len(), self.nrows);
        assert_eq!(out.len(), self.ncols);
        let chunks = par.plan(self.ncols, self.nrows);
        if chunks <= 1 {
            self.gemv_t(v, out);
            return;
        }
        let slab = chunk_size(self.ncols, chunks);
        std::thread::scope(|scope| {
            for (ci, cols) in out.chunks_mut(slab).enumerate() {
                let j0 = ci * slab;
                scope.spawn(move || {
                    for (o, j) in cols.iter_mut().zip(j0..) {
                        *o = dot(self.col(j), v);
                    }
                });
            }
        });
    }

    /// `out = X[:, cols] v` where `v.len() == cols.len()`.
    pub fn gemv_subset(&self, cols: &[usize], v: &[f64], out: &mut [f64]) {
        assert_eq!(v.len(), cols.len());
        assert_eq!(out.len(), self.nrows);
        out.fill(0.0);
        for (&j, &vj) in cols.iter().zip(v) {
            if vj == 0.0 {
                continue;
            }
            let col = self.col(j);
            for (o, &x) in out.iter_mut().zip(col) {
                *o += vj * x;
            }
        }
    }

    /// `out = X[:, cols] v` with a thread budget (row slabs over the
    /// subset, serial accumulation order per element).
    pub fn gemv_subset_with(&self, cols: &[usize], v: &[f64], out: &mut [f64], par: ParConfig) {
        assert_eq!(v.len(), cols.len());
        assert_eq!(out.len(), self.nrows);
        let chunks = par.plan(self.nrows, cols.len());
        if chunks <= 1 {
            self.gemv_subset(cols, v, out);
            return;
        }
        let slab = chunk_size(self.nrows, chunks);
        std::thread::scope(|scope| {
            for (ci, rows) in out.chunks_mut(slab).enumerate() {
                let r0 = ci * slab;
                scope.spawn(move || {
                    rows.fill(0.0);
                    for (&j, &vj) in cols.iter().zip(v) {
                        if vj == 0.0 {
                            continue;
                        }
                        let col = &self.col(j)[r0..r0 + rows.len()];
                        for (o, &x) in rows.iter_mut().zip(col) {
                            *o += vj * x;
                        }
                    }
                });
            }
        });
    }

    /// `out = X[:, cols]ᵀ v` where `out.len() == cols.len()`.
    pub fn gemv_t_subset(&self, cols: &[usize], v: &[f64], out: &mut [f64]) {
        assert_eq!(out.len(), cols.len());
        assert_eq!(v.len(), self.nrows);
        for (o, &j) in out.iter_mut().zip(cols) {
            *o = dot(self.col(j), v);
        }
    }

    /// `out = X[:, cols]ᵀ v` with a thread budget (independent dots,
    /// contiguous slabs of the subset).
    pub fn gemv_t_subset_with(&self, cols: &[usize], v: &[f64], out: &mut [f64], par: ParConfig) {
        assert_eq!(out.len(), cols.len());
        assert_eq!(v.len(), self.nrows);
        let chunks = par.plan(cols.len(), self.nrows);
        if chunks <= 1 {
            self.gemv_t_subset(cols, v, out);
            return;
        }
        let slab = chunk_size(cols.len(), chunks);
        std::thread::scope(|scope| {
            for (ci, slice) in out.chunks_mut(slab).enumerate() {
                let sub = &cols[ci * slab..ci * slab + slice.len()];
                scope.spawn(move || {
                    for (o, &j) in slice.iter_mut().zip(sub) {
                        *o = dot(self.col(j), v);
                    }
                });
            }
        });
    }

    /// Squared ℓ2 norm of every column.
    pub fn col_sq_norms(&self) -> Vec<f64> {
        (0..self.ncols).map(|j| dot(self.col(j), self.col(j))).collect()
    }

    /// Squared ℓ2 norm of every column, with a thread budget.
    pub fn col_sq_norms_with(&self, par: ParConfig) -> Vec<f64> {
        let chunks = par.plan(self.ncols, self.nrows);
        if chunks <= 1 {
            return self.col_sq_norms();
        }
        let mut out = vec![0.0; self.ncols];
        let slab = chunk_size(self.ncols, chunks);
        std::thread::scope(|scope| {
            for (ci, cols) in out.chunks_mut(slab).enumerate() {
                let j0 = ci * slab;
                scope.spawn(move || {
                    for (o, j) in cols.iter_mut().zip(j0..) {
                        *o = dot(self.col(j), self.col(j));
                    }
                });
            }
        });
        out
    }

    /// Center columns to mean zero and/or scale to unit ℓ2 norm
    /// (the paper's §3.1 normalization). Constant columns are left at zero
    /// after centering (their norm would be 0).
    pub fn standardize(&mut self, center: bool, scale: bool) {
        let n = self.nrows as f64;
        for j in 0..self.ncols {
            standardize_column(self.col_mut(j), n, center, scale);
        }
    }

    /// [`Mat::standardize`] with a thread budget: columns are contiguous
    /// in the column-major buffer, so disjoint column blocks go to
    /// scoped threads. Per-column arithmetic is unchanged — bitwise
    /// identical to the serial form.
    pub fn standardize_with(&mut self, center: bool, scale: bool, par: ParConfig) {
        let chunks = par.plan(self.ncols, 2 * self.nrows);
        if chunks <= 1 || self.nrows == 0 {
            self.standardize(center, scale);
            return;
        }
        let nrows = self.nrows;
        let n = nrows as f64;
        let block_cols = chunk_size(self.ncols, chunks);
        std::thread::scope(|scope| {
            for block in self.data.chunks_mut(block_cols * nrows) {
                scope.spawn(move || {
                    for col in block.chunks_mut(nrows) {
                        standardize_column(col, n, center, scale);
                    }
                });
            }
        });
    }

    /// Extract rows into a new matrix (used by the CV fold splitter).
    pub fn subset_rows(&self, rows: &[usize]) -> Mat {
        let mut buf = Vec::new();
        self.subset_rows_into(rows, &mut buf);
        Mat::from_col_major(rows.len(), self.ncols, buf)
    }

    /// [`Mat::subset_rows`] into a caller-owned column-major buffer
    /// (cleared and resized) — the CV fold runner recycles one buffer per
    /// worker instead of allocating a fresh `n·p` matrix per fold.
    pub fn subset_rows_into(&self, rows: &[usize], buf: &mut Vec<f64>) {
        let nr = rows.len();
        buf.clear();
        buf.resize(nr * self.ncols, 0.0);
        for j in 0..self.ncols {
            let src = self.col(j);
            let dst = &mut buf[j * nr..(j + 1) * nr];
            for (d, &i) in dst.iter_mut().zip(rows) {
                *d = src[i];
            }
        }
    }

    /// Consume the matrix, returning its column-major buffer (so a fold
    /// scratch pool can reclaim it after the fit).
    pub fn into_data(self) -> Vec<f64> {
        self.data
    }

    /// Dense matrix product `A B` (n×k · k×m). Only used at build/test time
    /// (e.g. generating correlated designs), not on the solve path.
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.ncols, other.nrows);
        let mut out = Mat::zeros(self.nrows, other.ncols);
        for j in 0..other.ncols {
            let bcol = other.col(j);
            let ocol_start = j * out.nrows;
            for (l, &b) in bcol.iter().enumerate() {
                if b == 0.0 {
                    continue;
                }
                let acol = self.col(l);
                let ocol = &mut out.data[ocol_start..ocol_start + acol.len()];
                for (o, &a) in ocol.iter_mut().zip(acol) {
                    *o += b * a;
                }
            }
        }
        out
    }
}

/// Center and/or unit-scale one column (`n` = row count as f64).
#[inline]
fn standardize_column(col: &mut [f64], n: f64, center: bool, scale: bool) {
    if center {
        let mean = col.iter().sum::<f64>() / n;
        for x in col.iter_mut() {
            *x -= mean;
        }
    }
    if scale {
        let norm = col.iter().map(|x| x * x).sum::<f64>().sqrt();
        if norm > 0.0 {
            let inv = 1.0 / norm;
            for x in col.iter_mut() {
                *x *= inv;
            }
        }
    }
}

/// 4-way unrolled dot product — the single hottest scalar kernel in the
/// solver (`Xᵀr` is a dot per column per iteration).
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let chunks = a.len() / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
    for c in 0..chunks {
        let i = c * 4;
        s0 += a[i] * b[i];
        s1 += a[i + 1] * b[i + 1];
        s2 += a[i + 2] * b[i + 2];
        s3 += a[i + 3] * b[i + 3];
    }
    let mut tail = (s0 + s1) + (s2 + s3);
    for i in chunks * 4..a.len() {
        tail += a[i] * b[i];
    }
    tail
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn get_set_roundtrip() {
        let mut m = Mat::zeros(3, 2);
        m.set(2, 1, 5.0);
        assert_eq!(m.get(2, 1), 5.0);
        assert_eq!(m.get(0, 0), 0.0);
    }

    #[test]
    fn gemv_known_values() {
        let m = Mat::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        let mut out = [0.0; 2];
        m.gemv(&[1.0, -1.0], &mut out);
        assert_eq!(out, [-1.0, -1.0]);
    }

    #[test]
    fn gemv_t_known_values() {
        let m = Mat::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        let mut out = [0.0; 2];
        m.gemv_t(&[1.0, 1.0], &mut out);
        assert_eq!(out, [4.0, 6.0]);
    }

    #[test]
    fn dot_matches_naive_for_odd_lengths() {
        for len in [0usize, 1, 3, 4, 5, 7, 8, 17] {
            let a: Vec<f64> = (0..len).map(|i| i as f64 * 0.5).collect();
            let b: Vec<f64> = (0..len).map(|i| (i as f64) - 2.0).collect();
            let naive: f64 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            assert!((dot(&a, &b) - naive).abs() < 1e-12, "len={len}");
        }
    }

    #[test]
    fn standardize_unit_columns() {
        let mut m = Mat::from_rows(&[&[1.0, 10.0], &[2.0, 20.0], &[3.0, 60.0]]);
        m.standardize(true, true);
        for j in 0..2 {
            let col = m.col(j);
            let mean: f64 = col.iter().sum::<f64>() / 3.0;
            let norm: f64 = col.iter().map(|x| x * x).sum::<f64>().sqrt();
            assert!(mean.abs() < 1e-12);
            assert!((norm - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn standardize_handles_constant_column() {
        let mut m = Mat::from_rows(&[&[5.0], &[5.0]]);
        m.standardize(true, true);
        assert_eq!(m.col(0), &[0.0, 0.0]);
    }

    #[test]
    fn subset_rows_extracts() {
        let m = Mat::from_rows(&[&[1.0, 2.0], &[3.0, 4.0], &[5.0, 6.0]]);
        let s = m.subset_rows(&[2, 0]);
        assert_eq!(s.nrows(), 2);
        assert_eq!(s.get(0, 0), 5.0);
        assert_eq!(s.get(1, 1), 2.0);
    }

    #[test]
    fn matmul_identity() {
        let a = Mat::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        let eye = Mat::from_rows(&[&[1.0, 0.0], &[0.0, 1.0]]);
        assert_eq!(a.matmul(&eye), a);
    }

    #[test]
    fn parallel_kernels_bitwise_match_serial() {
        use crate::linalg::par::ParConfig;
        let n = 23;
        let p = 11;
        let data: Vec<f64> = (0..n * p).map(|i| ((i * 37 + 11) % 97) as f64 * 0.31 - 15.0).collect();
        let m = Mat::from_col_major(n, p, data);
        let v: Vec<f64> = (0..p).map(|j| (j as f64) - 4.0).collect();
        let w: Vec<f64> = (0..n).map(|i| 0.5 * (i as f64) - 6.0).collect();
        let cols = [0usize, 2, 3, 7, 10];
        let vc: Vec<f64> = cols.iter().map(|&j| v[j]).collect();
        for t in [2usize, 3, 7, 64] {
            let par = ParConfig::exact(t);
            let (mut a, mut b) = (vec![0.0; n], vec![0.0; n]);
            m.gemv(&v, &mut a);
            m.gemv_with(&v, &mut b, par);
            assert_eq!(a, b, "gemv t={t}");
            m.gemv_subset(&cols, &vc, &mut a);
            m.gemv_subset_with(&cols, &vc, &mut b, par);
            assert_eq!(a, b, "gemv_subset t={t}");
            let (mut c, mut d) = (vec![0.0; p], vec![0.0; p]);
            m.gemv_t(&w, &mut c);
            m.gemv_t_with(&w, &mut d, par);
            assert_eq!(c, d, "gemv_t t={t}");
            let (mut e, mut f) = (vec![0.0; cols.len()], vec![0.0; cols.len()]);
            m.gemv_t_subset(&cols, &w, &mut e);
            m.gemv_t_subset_with(&cols, &w, &mut f, par);
            assert_eq!(e, f, "gemv_t_subset t={t}");
            assert_eq!(m.col_sq_norms(), m.col_sq_norms_with(par), "col_sq_norms t={t}");
            let mut ms = m.clone();
            let mut mp = m.clone();
            ms.standardize(true, true);
            mp.standardize_with(true, true, par);
            assert_eq!(ms, mp, "standardize t={t}");
        }
    }

    #[test]
    fn parallel_kernels_handle_degenerate_shapes() {
        use crate::linalg::par::ParConfig;
        let par = ParConfig::exact(7);
        // n = 0
        let m = Mat::zeros(0, 3);
        let mut out: Vec<f64> = Vec::new();
        m.gemv_with(&[1.0, 2.0, 3.0], &mut out, par);
        let mut g = vec![9.0; 3];
        m.gemv_t_with(&[], &mut g, par);
        assert_eq!(g, vec![0.0; 3]);
        // p = 1, p < threads
        let m = Mat::from_rows(&[&[2.0], &[3.0]]);
        let mut out = vec![0.0; 2];
        m.gemv_with(&[2.0], &mut out, par);
        assert_eq!(out, vec![4.0, 6.0]);
        let mut g = vec![0.0; 1];
        m.gemv_t_with(&[1.0, 1.0], &mut g, par);
        assert_eq!(g, vec![5.0]);
    }

    #[test]
    fn matmul_known() {
        let a = Mat::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        let b = Mat::from_rows(&[&[5.0], &[6.0]]);
        let c = a.matmul(&b);
        assert_eq!(c.get(0, 0), 17.0);
        assert_eq!(c.get(1, 0), 39.0);
    }
}

//! Mini property-testing substrate (no `proptest` offline).
//!
//! Provides seeded generators over a [`Pcg64`] and a [`forall`] runner that
//! reports the failing case number, seed and a debug rendering of the
//! counterexample. Shrinking is intentionally "lite": on failure we retry
//! the property with simple size-reduced variants produced by the
//! generator's `shrink` hints (halving vector lengths), which in practice
//! localizes failures in the SLOPE invariants well enough.

use crate::rng::Pcg64;

/// Configuration for a property run.
#[derive(Clone, Copy, Debug)]
pub struct Config {
    /// Number of random cases.
    pub cases: usize,
    /// Master seed; every case derives its own stream.
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Self { cases: 128, seed: 0x5105_e5c4 }
    }
}

/// Run `prop` on `cases` random inputs from `gen`; panics with the seed and
/// debug-printed input on the first failure.
pub fn forall<T, G, P>(cfg: Config, gen: G, prop: P)
where
    T: std::fmt::Debug,
    G: Fn(&mut Pcg64) -> T,
    P: Fn(&T) -> Result<(), String>,
{
    let mut master = Pcg64::new(cfg.seed);
    for case in 0..cfg.cases {
        let mut rng = master.derive(case as u64);
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property failed at case {case}/{} (seed {:#x}):\n  {msg}\n  input: {input:?}",
                cfg.cases, cfg.seed
            );
        }
    }
}

/// Convenience assertion for properties.
pub fn ensure(cond: bool, msg: impl Into<String>) -> Result<(), String> {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

/// Assert two floats agree within `tol`.
pub fn close(a: f64, b: f64, tol: f64) -> Result<(), String> {
    if (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs())) {
        Ok(())
    } else {
        Err(format!("{a} != {b} (tol {tol})"))
    }
}

/// Assert two slices agree elementwise within `tol`.
pub fn all_close(a: &[f64], b: &[f64], tol: f64) -> Result<(), String> {
    ensure(a.len() == b.len(), format!("length {} vs {}", a.len(), b.len()))?;
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        close(*x, *y, tol).map_err(|e| format!("at index {i}: {e}"))?;
    }
    Ok(())
}

/// Generators for common inputs.
pub mod gen {
    use crate::rng::Pcg64;

    /// Vector of iid normals with random length in `[lo, hi]`.
    pub fn normal_vec(rng: &mut Pcg64, lo: usize, hi: usize) -> Vec<f64> {
        let len = lo + rng.below((hi - lo + 1) as u64) as usize;
        (0..len).map(|_| rng.normal() * (1.0 + 4.0 * rng.next_f64())).collect()
    }

    /// Vector with many exact ties and zeros — stresses the cluster logic in
    /// the SLOPE subdifferential.
    pub fn tied_vec(rng: &mut Pcg64, lo: usize, hi: usize) -> Vec<f64> {
        let len = lo + rng.below((hi - lo + 1) as u64) as usize;
        let levels: Vec<f64> = (0..1 + rng.below(4)).map(|_| (rng.normal() * 2.0).round()).collect();
        (0..len)
            .map(|_| {
                if rng.bernoulli(0.3) {
                    0.0
                } else {
                    let l = levels[rng.below(levels.len() as u64) as usize];
                    l * rng.sign()
                }
            })
            .collect()
    }

    /// Non-increasing non-negative λ sequence of the given length.
    pub fn lambda_seq(rng: &mut Pcg64, len: usize) -> Vec<f64> {
        let mut xs: Vec<f64> = (0..len).map(|_| rng.next_f64() * 3.0).collect();
        xs.sort_unstable_by(|a, b| b.partial_cmp(a).unwrap());
        xs
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivial_property() {
        forall(
            Config { cases: 32, seed: 1 },
            |rng| gen::normal_vec(rng, 1, 10),
            |xs| ensure(!xs.is_empty(), "empty"),
        );
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn forall_reports_failures() {
        forall(
            Config { cases: 32, seed: 2 },
            |rng| rng.next_f64(),
            |&x| ensure(x < 0.5, "x too big"),
        );
    }

    #[test]
    fn lambda_seq_is_sorted() {
        let mut rng = crate::rng::Pcg64::new(3);
        for _ in 0..20 {
            let l = gen::lambda_seq(&mut rng, 17);
            assert!(l.windows(2).all(|w| w[0] >= w[1]));
            assert!(l.iter().all(|&x| x >= 0.0));
        }
    }

    #[test]
    fn close_handles_relative_scale() {
        assert!(close(1e9, 1e9 + 1.0, 1e-8).is_ok());
        assert!(close(1.0, 1.1, 1e-8).is_err());
    }
}

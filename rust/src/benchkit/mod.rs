//! Wall-clock benchmarking substrate (no `criterion` offline).
//!
//! Each paper figure/table gets a `harness = false` bench binary built on
//! this module: timed repetitions with warmup, summary statistics
//! (median, mean, 95% band via percentiles), aligned table printing in the
//! paper's row format, and CSV emission under `results/`.

use std::io::Write as _;
use std::time::Instant;

/// Timing summary over repetitions, in seconds.
#[derive(Clone, Debug)]
pub struct Timing {
    /// Raw per-repetition durations (sorted ascending).
    pub samples: Vec<f64>,
}

impl Timing {
    /// Time `reps` calls of `f` after `warmup` untimed calls.
    pub fn measure<F: FnMut()>(warmup: usize, reps: usize, mut f: F) -> Timing {
        for _ in 0..warmup {
            f();
        }
        let mut samples = Vec::with_capacity(reps);
        for _ in 0..reps.max(1) {
            let t = Instant::now();
            f();
            samples.push(t.elapsed().as_secs_f64());
        }
        samples.sort_unstable_by(|a, b| a.partial_cmp(b).unwrap());
        Timing { samples }
    }

    /// Wrap already-collected samples.
    pub fn from_samples(mut samples: Vec<f64>) -> Timing {
        samples.sort_unstable_by(|a, b| a.partial_cmp(b).unwrap());
        Timing { samples }
    }

    /// Median duration in seconds.
    pub fn median(&self) -> f64 {
        crate::linalg::ops::quantile_sorted(&self.samples, 0.5)
    }

    /// Mean duration in seconds.
    pub fn mean(&self) -> f64 {
        crate::linalg::ops::mean(&self.samples)
    }

    /// Percentile (0..=1).
    pub fn quantile(&self, q: f64) -> f64 {
        crate::linalg::ops::quantile_sorted(&self.samples, q)
    }

    /// Half-width of a normal-approximation 95% CI on the mean.
    pub fn ci95(&self) -> f64 {
        let n = self.samples.len() as f64;
        if n < 2.0 {
            return 0.0;
        }
        let m = self.mean();
        let var = self.samples.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (n - 1.0);
        1.96 * (var / n).sqrt()
    }
}

/// A results table with aligned printing and CSV output.
pub struct Table {
    title: String,
    columns: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// New table with the given column headers.
    pub fn new(title: &str, columns: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            columns: columns.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row (stringified cells).
    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.columns.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    /// Print aligned to stdout, paper-style.
    pub fn print(&self) {
        let mut widths: Vec<usize> = self.columns.iter().map(|c| c.len()).collect();
        for row in &self.rows {
            for (w, cell) in widths.iter_mut().zip(row) {
                *w = (*w).max(cell.len());
            }
        }
        println!("\n== {} ==", self.title);
        let header: Vec<String> = self
            .columns
            .iter()
            .zip(&widths)
            .map(|(c, w)| format!("{c:>w$}"))
            .collect();
        println!("{}", header.join("  "));
        println!("{}", "-".repeat(header.join("  ").len()));
        for row in &self.rows {
            let line: Vec<String> = row
                .iter()
                .zip(&widths)
                .map(|(c, w)| format!("{c:>w$}"))
                .collect();
            println!("{}", line.join("  "));
        }
    }

    /// Machine-readable form: `{"title": ..., "columns": [...],
    /// "rows": [[...], ...]}` over [`crate::jsonio`] — benches persist
    /// these so the perf trajectory is diffable across PRs.
    pub fn to_json(&self) -> crate::jsonio::Json {
        use crate::jsonio::Json;
        Json::obj(vec![
            ("title", Json::Str(self.title.clone())),
            (
                "columns",
                Json::Arr(self.columns.iter().map(|c| Json::Str(c.clone())).collect()),
            ),
            (
                "rows",
                Json::Arr(
                    self.rows
                        .iter()
                        .map(|row| {
                            Json::Arr(row.iter().map(|cell| Json::Str(cell.clone())).collect())
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Write as CSV under `results/<name>.csv` (creates the directory).
    pub fn write_csv(&self, name: &str) -> std::io::Result<std::path::PathBuf> {
        let dir = results_dir();
        std::fs::create_dir_all(&dir)?;
        let path = dir.join(format!("{name}.csv"));
        let mut f = std::fs::File::create(&path)?;
        writeln!(f, "{}", self.columns.join(","))?;
        for row in &self.rows {
            writeln!(f, "{}", row.join(","))?;
        }
        Ok(path)
    }
}

/// Resolve the `results/` directory next to the crate root, independent of
/// the working directory cargo bench uses.
pub fn results_dir() -> std::path::PathBuf {
    std::path::PathBuf::from(env_root()).join("results")
}

/// Write a machine-readable `BENCH_<name>.json` at the repository root
/// (one line of JSON + newline) and return its path — the shared tail of
/// every bench that feeds the perf trajectory.
pub fn write_bench_json(
    name: &str,
    payload: &crate::jsonio::Json,
) -> std::io::Result<std::path::PathBuf> {
    let root = std::path::Path::new(env_root()).parent().ok_or_else(|| {
        std::io::Error::new(std::io::ErrorKind::NotFound, "crate root has no parent")
    })?;
    let path = root.join(format!("BENCH_{name}.json"));
    let mut f = std::fs::File::create(&path)?;
    writeln!(f, "{}", payload.to_string())?;
    Ok(path)
}

/// Resolve the repository root (`CARGO_MANIFEST_DIR` at compile time).
pub fn env_root() -> &'static str {
    env!("CARGO_MANIFEST_DIR")
}

/// Format seconds compactly (`ms` below 1s).
pub fn fmt_secs(s: f64) -> String {
    if s < 1.0 {
        format!("{:.1}ms", s * 1e3)
    } else {
        format!("{s:.2}s")
    }
}

/// Format a float with 3 significant decimals.
pub fn fmt3(x: f64) -> String {
    format!("{x:.3}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_measures_positive() {
        let t = Timing::measure(1, 5, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert_eq!(t.samples.len(), 5);
        assert!(t.median() >= 0.0);
        assert!(t.mean() >= 0.0);
    }

    #[test]
    fn timing_stats_from_known_samples() {
        let t = Timing::from_samples(vec![3.0, 1.0, 2.0]);
        assert_eq!(t.median(), 2.0);
        assert_eq!(t.mean(), 2.0);
        assert_eq!(t.quantile(0.0), 1.0);
        assert_eq!(t.quantile(1.0), 3.0);
        assert!(t.ci95() > 0.0);
    }

    #[test]
    fn table_roundtrip_csv() {
        let mut t = Table::new("test", &["a", "b"]);
        t.row(vec!["1".into(), "x".into()]);
        let path = t.write_csv("_benchkit_selftest").unwrap();
        let content = std::fs::read_to_string(&path).unwrap();
        assert_eq!(content, "a,b\n1,x\n");
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn table_to_json_round_trips() {
        let mut t = Table::new("perf", &["scenario", "req_per_s"]);
        t.row(vec!["warm".into(), "123.4".into()]);
        let j = t.to_json();
        assert_eq!(j.field("title").unwrap().as_str(), Some("perf"));
        assert_eq!(j.field("columns").unwrap().items().len(), 2);
        let rows = j.field("rows").unwrap().items();
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].items()[0].as_str(), Some("warm"));
        // serialized form parses back identically
        let re = crate::jsonio::Json::parse(&j.to_string()).unwrap();
        assert_eq!(re, j);
    }

    #[test]
    fn timing_single_sample_is_degenerate_but_defined() {
        let t = Timing::from_samples(vec![5.0]);
        assert_eq!(t.median(), 5.0);
        assert_eq!(t.mean(), 5.0);
        assert_eq!(t.quantile(0.0), 5.0);
        assert_eq!(t.quantile(0.95), 5.0);
        assert_eq!(t.quantile(1.0), 5.0);
        // one sample has no spread estimate: ci95 is 0 by definition
        assert_eq!(t.ci95(), 0.0);
    }

    #[test]
    fn timing_quantiles_interpolate_even_and_odd_lengths() {
        // odd length: the median is the middle sample, exactly
        let odd = Timing::from_samples(vec![3.0, 1.0, 2.0]);
        assert_eq!(odd.median(), 2.0);
        // h = (n-1)·q: q=0.25 on [1,2,3] lands at h=0.5 → 1.5
        assert!((odd.quantile(0.25) - 1.5).abs() < 1e-12);
        // even length: linear interpolation between the middle pair
        let even = Timing::from_samples(vec![4.0, 1.0, 3.0, 2.0]);
        assert_eq!(even.median(), 2.5);
        // q=0.25 → h=0.75 → 1.75; q=0.95 → h=2.85 → 3.85
        assert!((even.quantile(0.25) - 1.75).abs() < 1e-12);
        assert!((even.quantile(0.95) - 3.85).abs() < 1e-12);
        // out-of-range q clamps to the extremes
        assert_eq!(even.quantile(-0.5), 1.0);
        assert_eq!(even.quantile(1.5), 4.0);
    }

    #[test]
    fn timing_ci95_matches_hand_computation() {
        // [1,2,3]: mean 2, sample var 1 → 1.96·sqrt(1/3)
        let odd = Timing::from_samples(vec![1.0, 2.0, 3.0]);
        assert!((odd.ci95() - 1.96 * (1.0f64 / 3.0).sqrt()).abs() < 1e-12);
        // [1,2,3,4]: mean 2.5, sample var 5/3 → 1.96·sqrt(5/12)
        let even = Timing::from_samples(vec![1.0, 2.0, 3.0, 4.0]);
        assert!((even.ci95() - 1.96 * (5.0f64 / 12.0).sqrt()).abs() < 1e-12);
        // identical samples: zero spread
        let flat = Timing::from_samples(vec![2.0, 2.0, 2.0]);
        assert_eq!(flat.ci95(), 0.0);
    }

    #[test]
    fn table_to_json_escapes_cell_strings() {
        let mut t = Table::new(r#"quotes " and \ slashes"#, &["name", "value"]);
        t.row(vec![r#"he said "hi""#.into(), "a\\b\nc\td".into()]);
        let j = t.to_json();
        // the serialized line must parse back to the identical structure,
        // so every quote/backslash/control character survived escaping
        let text = j.to_string();
        let re = crate::jsonio::Json::parse(&text).unwrap();
        assert_eq!(re, j);
        let rows = re.field("rows").unwrap().items();
        assert_eq!(rows[0].items()[0].as_str(), Some(r#"he said "hi""#));
        assert_eq!(rows[0].items()[1].as_str(), Some("a\\b\nc\td"));
        assert_eq!(
            re.field("title").unwrap().as_str(),
            Some(r#"quotes " and \ slashes"#)
        );
    }

    #[test]
    fn fmt_helpers() {
        assert_eq!(fmt_secs(0.0123), "12.3ms");
        assert_eq!(fmt_secs(2.5), "2.50s");
        assert_eq!(fmt3(1.23456), "1.235");
    }
}

//! Export a [`Problem`] to the ingest formats.
//!
//! Values are written with Rust's `Display` for `f64`, which emits the
//! shortest decimal that parses back to exactly the same bits — so
//! export → ingest (with `standardize` off) reproduces the design and
//! response **bitwise**, and the paper's seven simulated stand-ins
//! double as round-trip fixtures for the readers (the proptests and the
//! differential gate in `tests/integration_ingest.rs` pin this).

use std::io::{self, BufWriter, Write};
use std::path::Path;

use crate::linalg::Design;
use crate::slope::family::Problem;

/// Write a dense problem as CSV: header `x1,…,xp,y`, one row per
/// observation, response last (the reader's default [`super::YCol`]).
/// Sparse designs are refused — use [`write_svmlight`], densifying a
/// dorothea-scale design would multiply the file by `1/density`.
pub fn write_csv(prob: &Problem, path: &Path) -> io::Result<()> {
    let m = prob.x.as_dense().ok_or_else(|| {
        io::Error::new(
            io::ErrorKind::InvalidInput,
            "write_csv needs a dense design; use write_svmlight for sparse problems",
        )
    })?;
    let mut w = BufWriter::new(std::fs::File::create(path)?);
    for j in 0..m.ncols() {
        write!(w, "x{},", j + 1)?;
    }
    writeln!(w, "y")?;
    for i in 0..m.nrows() {
        for j in 0..m.ncols() {
            write!(w, "{},", m.get(i, j))?;
        }
        writeln!(w, "{}", prob.y[i])?;
    }
    w.flush()
}

/// Write a problem (dense or sparse) as svmlight: a
/// `# slope-screen svmlight n=<n> p=<p>` header comment (so the reader
/// recovers `p` even when trailing columns are all-zero), then
/// `label idx:val …` rows with 1-based ascending indices. Only stored
/// nonzeros are emitted.
pub fn write_svmlight(prob: &Problem, path: &Path) -> io::Result<()> {
    let (n, p) = (prob.n(), prob.p());
    let mut w = BufWriter::new(std::fs::File::create(path)?);
    writeln!(w, "# slope-screen svmlight n={n} p={p}")?;
    match &prob.x {
        Design::Dense(m) => {
            for i in 0..n {
                write!(w, "{}", prob.y[i])?;
                for j in 0..p {
                    let v = m.get(i, j);
                    // bit test, not `v != 0.0`: -0.0 compares equal to
                    // zero but must be emitted (as `-0`) or the bitwise
                    // round-trip contract breaks for negative zeros.
                    if v.to_bits() != 0 {
                        write!(w, " {}:{}", j + 1, v)?;
                    }
                }
                writeln!(w)?;
            }
        }
        Design::Sparse(s) => {
            // CSC is column-major; bucket entries by row once (O(nnz)
            // memory, far below the densified design) to emit row-major.
            let mut rows: Vec<Vec<(u32, f64)>> = vec![Vec::new(); n];
            for j in 0..p {
                for (i, v) in s.col_entries(j) {
                    rows[i].push((j as u32, v));
                }
            }
            for (i, row) in rows.iter().enumerate() {
                write!(w, "{}", prob.y[i])?;
                for &(j, v) in row {
                    write!(w, " {}:{}", j + 1, v)?;
                }
                writeln!(w)?;
            }
        }
    }
    w.flush()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{Csc, Mat};
    use crate::slope::family::Family;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("slope-export-{}-{name}", std::process::id()))
    }

    #[test]
    fn csv_refuses_sparse_designs() {
        let csc = Csc::from_columns(2, &[vec![(0, 1.0)]]);
        let prob = Problem::new(Design::Sparse(csc), vec![0.0, 1.0], Family::Gaussian);
        assert!(write_csv(&prob, &tmp("refuse.csv")).is_err());
    }

    #[test]
    fn svmlight_emits_header_and_sorted_indices() {
        let m = Mat::from_rows(&[&[0.0, 2.0, 0.0], &[1.5, 0.0, -3.0]]);
        let prob = Problem::new(Design::Dense(m), vec![1.0, 0.0], Family::Binomial);
        let path = tmp("header.svm");
        write_svmlight(&prob, &path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines[0], "# slope-screen svmlight n=2 p=3");
        assert_eq!(lines[1], "1 2:2");
        assert_eq!(lines[2], "0 1:1.5 3:-3");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn svmlight_dense_branch_preserves_negative_zero() {
        let m = Mat::from_rows(&[&[-0.0, 1.0]]);
        let prob = Problem::new(Design::Dense(m), vec![0.5], Family::Gaussian);
        let path = tmp("negzero.svm");
        write_svmlight(&prob, &path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().nth(1), Some("0.5 1:-0 2:1"));
        let opts = crate::ingest::IngestOptions::default().with_standardize(false);
        let ing = crate::ingest::load_svmlight(&path, &opts).unwrap();
        let back = match &ing.problem.x {
            Design::Sparse(s) => s.to_dense(),
            Design::Dense(_) => panic!("svmlight must ingest sparse"),
        };
        assert_eq!(back.get(0, 0).to_bits(), (-0.0f64).to_bits());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn csv_layout_matches_reader_default() {
        let m = Mat::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        let prob = Problem::new(Design::Dense(m), vec![0.5, -0.5], Family::Gaussian);
        let path = tmp("layout.csv");
        write_csv(&prob, &path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "x1,x2,y\n1,2,0.5\n3,4,-0.5\n");
        let _ = std::fs::remove_file(&path);
    }
}

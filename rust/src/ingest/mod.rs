//! Streaming dataset ingestion: file-backed designs for the solver.
//!
//! The paper's real-data experiments (§3.3, Tables 2–3, Fig. 7) run on
//! file-based datasets — dorothea and friends ship as sparse
//! svmlight/libsvm files, the tabular sets as dense delimited text. This
//! layer turns such files into fit-ready [`Problem`]s:
//!
//! * [`csv`] — dense CSV: header or headerless, quoted fields (RFC-4180
//!   doubling), `#` comment lines, blank lines, CRLF or LF endings.
//! * [`svmlight`] — sparse svmlight/libsvm: `label idx:val …` with
//!   1-based, strictly increasing indices and `#` comments.
//! * [`export`] — the inverse direction: [`export::write_csv`] /
//!   [`export::write_svmlight`] serialize a [`Problem`] with Rust's
//!   shortest-round-trip float formatting, so export → ingest reproduces
//!   the matrix **bitwise** (the differential tests pin this).
//!
//! Design constraints, in order:
//!
//! 1. **Bounded memory.** Files are read line-by-line through a reused
//!    buffer (a dorothea-scale file never materializes as triplet
//!    vectors); sparse files go through a *two-pass* CSC builder — pass 1
//!    counts nonzeros per column, pass 2 fills exactly-sized
//!    `colptr`/`rowidx`/`values` buffers via per-column cursors
//!    ([`crate::linalg::Csc::from_parts`]). Dense CSV likewise counts
//!    rows first and fills one exact `n·p` column-major buffer. The only
//!    allocations proportional to the data are the final arrays.
//! 2. **Strict validation, typed errors.** Ragged rows, malformed
//!    fields, 0-based/duplicate/out-of-order sparse indices, non-finite
//!    values (including `nan`/`inf` literals, which `str::parse::<f64>`
//!    happily accepts) and family-incompatible responses are
//!    [`IngestError`]s — a bad file can never NaN-poison a fit. The same
//!    [`check_finite`] guard runs *after* standardization, closing the
//!    overflow hole where finite-but-huge inputs standardize to NaN
//!    (serve's inline datasets route through it too).
//! 3. **Content fingerprinting.** Both passes FNV-1a the raw bytes; the
//!    hashes must agree (a file mutating between passes is detected, not
//!    silently mis-assembled) and the result is the [`Ingested`]
//!    fingerprint the serve registry interns datasets by — so re-fits on
//!    the same file content hit the warm-start and pack caches no matter
//!    which path name the request used.
//!
//! Standardization routes through the [`ParConfig`] parallel backend
//! exactly like the in-memory dataset builders (dense: center + unit
//! ℓ2-scale; sparse: scale only — centering would densify), recording
//! the per-column transform so serve's `predict` can map raw client rows
//! into model coordinates.

pub mod csv;
pub mod export;
pub mod svmlight;

pub use csv::load_csv;
pub use export::{write_csv, write_svmlight};
pub use svmlight::load_svmlight;

use std::fmt;
use std::fs::File;
use std::io::{BufReader, Read};
use std::path::{Path, PathBuf};

use crate::linalg::{ops, Design, ParConfig};
use crate::slope::family::{Family, Problem};

/// 64-bit FNV-1a over a byte stream. The canonical implementation for
/// every content fingerprint in the crate (the serve layer re-exports
/// it).
pub fn fnv1a(seed: u64, bytes: &[u8]) -> u64 {
    let mut h = seed;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// FNV-1a initial basis.
pub const FNV_BASIS: u64 = 0xcbf2_9ce4_8422_2325;

/// FNV-1a a file's raw bytes in bounded chunks, continuing from `seed`.
/// The serve registry keys file-backed datasets by this (plus the spec
/// prefix), so equal content at different paths interns to one entry.
pub fn hash_file(seed: u64, path: &Path) -> std::io::Result<u64> {
    let mut reader = BufReader::with_capacity(64 << 10, File::open(path)?);
    let mut buf = [0u8; 64 << 10];
    let mut h = seed;
    loop {
        let n = reader.read(&mut buf)?;
        if n == 0 {
            return Ok(h);
        }
        h = fnv1a(h, &buf[..n]);
    }
}

/// A typed ingestion failure. Line numbers are 1-based; `line == 0`
/// means the problem surfaced after parsing (e.g. standardization
/// overflow) and has no single source line.
#[derive(Debug)]
pub enum IngestError {
    /// Underlying I/O failure (open, read, invalid UTF-8).
    Io {
        /// File being read.
        path: PathBuf,
        /// The OS error.
        err: std::io::Error,
    },
    /// A field or token failed to parse.
    Parse {
        /// 1-based source line.
        line: usize,
        /// What went wrong.
        msg: String,
    },
    /// The parsed data violates a structural rule (ragged rows, 0-based /
    /// duplicate / out-of-order sparse indices, index beyond the declared
    /// feature count).
    Structure {
        /// 1-based source line (0 = file-level).
        line: usize,
        /// Which rule broke.
        msg: String,
    },
    /// A non-finite value (`nan`/`inf` literal, an overflowing decimal
    /// like `1e999`, or a post-standardization overflow at `line == 0`).
    NonFinite {
        /// 1-based source line (0 = after standardization).
        line: usize,
        /// The offending value/location.
        msg: String,
    },
    /// The response column is invalid for the requested family.
    Response {
        /// Which constraint failed.
        msg: String,
    },
    /// The file contains no data rows.
    Empty {
        /// File being read.
        path: PathBuf,
    },
    /// The file changed between the two streaming passes (row counts or
    /// content hashes disagree).
    Changed {
        /// File being read.
        path: PathBuf,
    },
    /// The path's extension maps to no known format.
    Unsupported {
        /// File being read.
        path: PathBuf,
    },
}

impl fmt::Display for IngestError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            IngestError::Io { path, err } => write!(f, "{}: {err}", path.display()),
            IngestError::Parse { line, msg } => write!(f, "line {line}: {msg}"),
            IngestError::Structure { line: 0, msg } => write!(f, "{msg}"),
            IngestError::Structure { line, msg } => write!(f, "line {line}: {msg}"),
            IngestError::NonFinite { line: 0, msg } => {
                write!(f, "non-finite value after standardization: {msg}")
            }
            IngestError::NonFinite { line, msg } => {
                write!(f, "line {line}: non-finite value: {msg}")
            }
            IngestError::Response { msg } => write!(f, "response: {msg}"),
            IngestError::Empty { path } => write!(f, "{}: no data rows", path.display()),
            IngestError::Changed { path } => {
                write!(f, "{}: file changed between the two ingest passes", path.display())
            }
            IngestError::Unsupported { path } => write!(
                f,
                "{}: unsupported extension (expected .csv or .svm/.svmlight/.libsvm)",
                path.display()
            ),
        }
    }
}

impl std::error::Error for IngestError {}

/// Which CSV column holds the response.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum YCol {
    /// Response is the first column.
    First,
    /// Response is the last column (the default; matches
    /// [`export::write_csv`]).
    Last,
}

/// Detected/declared file format.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Format {
    /// Dense delimited text.
    Csv,
    /// Sparse svmlight/libsvm.
    Svmlight,
}

/// Ingestion configuration.
#[derive(Clone, Debug)]
pub struct IngestOptions {
    /// Response family the data is fitted with (drives response
    /// validation; binomial maps svmlight-style `-1` labels to `0`).
    pub family: Family,
    /// Standardize server-side: dense columns centered + unit ℓ2-scaled,
    /// sparse columns scaled only, gaussian `y` centered (the removed
    /// mean is recorded as [`Ingested::intercept`]). Pass `false` when
    /// the file is already in model coordinates (e.g. our own exports).
    pub standardize: bool,
    /// Authoritative feature count for sparse files (indices beyond it
    /// are errors). `None` infers `p` from the writer's `# … p=<p>`
    /// header comment or, failing that, the largest index seen.
    pub n_features: Option<usize>,
    /// Which CSV column holds the response.
    pub y_col: YCol,
    /// CSV header handling: `Some(true)` = first data line is a header,
    /// `Some(false)` = data starts immediately, `None` = auto-detect
    /// (header iff any first-line field fails to parse as a number).
    pub header: Option<bool>,
    /// Thread budget for the standardization kernels.
    pub par: ParConfig,
    /// I/O buffer capacity in bytes (the bound on bytes held per read).
    pub chunk_bytes: usize,
}

impl Default for IngestOptions {
    fn default() -> Self {
        IngestOptions {
            family: Family::Gaussian,
            standardize: true,
            n_features: None,
            y_col: YCol::Last,
            header: None,
            par: ParConfig::default(),
            chunk_bytes: 1 << 20,
        }
    }
}

impl IngestOptions {
    /// Builder: set the response family.
    pub fn with_family(mut self, family: Family) -> Self {
        self.family = family;
        self
    }

    /// Builder: enable/disable standardization.
    pub fn with_standardize(mut self, standardize: bool) -> Self {
        self.standardize = standardize;
        self
    }

    /// Builder: pin the sparse feature count.
    pub fn with_n_features(mut self, p: usize) -> Self {
        self.n_features = Some(p);
        self
    }

    /// Builder: set the CSV response column.
    pub fn with_y_col(mut self, y_col: YCol) -> Self {
        self.y_col = y_col;
        self
    }

    /// Builder: set the kernel thread budget for standardization.
    pub fn with_par(mut self, par: ParConfig) -> Self {
        self.par = par;
        self
    }
}

/// Per-column standardization applied at ingest (dense: mean + inverse
/// centered norm; sparse: means are all zero). Mirrors the serve layer's
/// `ColumnTransform` so file-backed datasets support `predict` on raw
/// client rows.
#[derive(Clone, Debug, PartialEq)]
pub struct ColumnStats {
    /// Mean subtracted from each column (zeros for sparse designs).
    pub means: Vec<f64>,
    /// Reciprocal of each column's (centered) ℓ2 norm; 0 for constant
    /// columns, matching [`crate::linalg::Mat::standardize`].
    pub inv_norms: Vec<f64>,
}

/// A successfully ingested dataset.
#[derive(Debug)]
pub struct Ingested {
    /// The fit-ready problem.
    pub problem: Problem,
    /// FNV-1a fingerprint of the file's raw bytes.
    pub fingerprint: u64,
    /// Which reader produced it.
    pub format: Format,
    /// Standardization applied (None when `standardize` was off).
    pub stats: Option<ColumnStats>,
    /// Mean removed from a gaussian response before the fit (0 unless
    /// standardizing a gaussian problem).
    pub intercept: f64,
}

/// Ingest a file, dispatching on extension: `.csv` → [`load_csv`],
/// `.svm`/`.svmlight`/`.libsvm` → [`load_svmlight`].
pub fn load_path(path: &Path, opts: &IngestOptions) -> Result<Ingested, IngestError> {
    let ext = path
        .extension()
        .and_then(|e| e.to_str())
        .map(str::to_ascii_lowercase)
        .unwrap_or_default();
    match ext.as_str() {
        "csv" => load_csv(path, opts),
        "svm" | "svmlight" | "libsvm" => load_svmlight(path, opts),
        _ => Err(IngestError::Unsupported { path: path.to_path_buf() }),
    }
}

/// Reject non-finite entries anywhere in a design/response pair. Raw file
/// values are already finite-checked at parse time; this closes the
/// remaining hole where finite-but-huge inputs overflow *during*
/// standardization (`mean = ∞` ⇒ centered column of `-∞` ⇒ `-∞ · 0 =
/// NaN`). The serve layer runs the same guard on inline request data.
pub fn check_finite(x: &Design, y: &[f64]) -> Result<(), String> {
    match x {
        Design::Dense(m) => {
            if let Some(idx) = m.data().iter().position(|v| !v.is_finite()) {
                let n = m.nrows().max(1);
                return Err(format!(
                    "design entry (row {}, column {}) is not finite",
                    idx % n,
                    idx / n
                ));
            }
        }
        Design::Sparse(s) => {
            if s.values().iter().any(|v| !v.is_finite()) {
                return Err("sparse design holds a non-finite value".to_string());
            }
        }
    }
    if let Some(i) = y.iter().position(|v| !v.is_finite()) {
        return Err(format!("response[{i}] is not finite"));
    }
    Ok(())
}

/// Validate a response vector against a family without constructing the
/// `Problem` (whose constructor panics — file input must error instead).
fn validate_response(family: Family, y: &[f64]) -> Result<(), IngestError> {
    let bad = |msg: String| Err(IngestError::Response { msg });
    match family {
        Family::Gaussian => Ok(()),
        Family::Binomial => {
            match y.iter().position(|&v| v != 0.0 && v != 1.0) {
                Some(i) => bad(format!("binomial response must be 0/1 (or ±1); row {i} is {}", y[i])),
                None => Ok(()),
            }
        }
        Family::Poisson => match y.iter().position(|&v| v < 0.0) {
            Some(i) => bad(format!("poisson response must be non-negative; row {i} is {}", y[i])),
            None => Ok(()),
        },
        Family::Multinomial { classes } => {
            if classes < 2 {
                return bad(format!("multinomial needs classes >= 2, got {classes}"));
            }
            match y
                .iter()
                .position(|&v| !(v >= 0.0 && v < classes as f64 && v.fract() == 0.0))
            {
                Some(i) => bad(format!(
                    "multinomial response must be class indices in 0..{classes}; row {i} is {}",
                    y[i]
                )),
                None => Ok(()),
            }
        }
    }
}

/// Standardize in place through the parallel backend, recording the
/// transform. The recorded means/norms replicate the kernels' exact
/// arithmetic (same summation order), so `stats.apply(raw_row)`
/// reproduces the standardized matrix bitwise.
///
/// The stats pass deliberately duplicates work `standardize_with`
/// redoes internally (same pattern as serve's inline datasets): the
/// recorded transform must be bitwise-exactly what the kernel applied,
/// and the kernel's API doesn't return it. The extra O(n·p) pass is a
/// one-off per ingest, well under the parse cost.
fn standardize_design(x: &mut Design, par: ParConfig) -> ColumnStats {
    match x {
        Design::Dense(m) => {
            let n = m.nrows() as f64;
            let p = m.ncols();
            let mut means = Vec::with_capacity(p);
            let mut inv_norms = Vec::with_capacity(p);
            for j in 0..p {
                let col = m.col(j);
                let mean = col.iter().sum::<f64>() / n;
                let norm = col.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>().sqrt();
                means.push(mean);
                inv_norms.push(if norm > 0.0 { 1.0 / norm } else { 0.0 });
            }
            m.standardize_with(true, true, par);
            ColumnStats { means, inv_norms }
        }
        Design::Sparse(s) => {
            let inv_norms: Vec<f64> = s
                .col_sq_norms_with(par)
                .iter()
                .map(|&q| {
                    let norm = q.sqrt();
                    if norm > 0.0 {
                        1.0 / norm
                    } else {
                        0.0
                    }
                })
                .collect();
            s.scale_columns_with(par);
            ColumnStats { means: vec![0.0; s.ncols()], inv_norms }
        }
    }
}

/// Shared tail of both loaders: map ±1 binomial labels, validate the
/// response, standardize, center gaussian `y`, and run the post-transform
/// finiteness guard.
fn finish(
    mut x: Design,
    mut y: Vec<f64>,
    opts: &IngestOptions,
) -> Result<(Problem, Option<ColumnStats>, f64), IngestError> {
    if opts.family == Family::Binomial {
        for v in y.iter_mut() {
            if *v == -1.0 {
                *v = 0.0;
            }
        }
    }
    validate_response(opts.family, &y)?;
    let stats = if opts.standardize { Some(standardize_design(&mut x, opts.par)) } else { None };
    let mut intercept = 0.0;
    if opts.standardize && opts.family == Family::Gaussian {
        intercept = ops::mean(&y);
        for v in y.iter_mut() {
            *v -= intercept;
        }
    }
    check_finite(&x, &y).map_err(|msg| IngestError::NonFinite { line: 0, msg })?;
    Ok((Problem::new(x, y, opts.family), stats, intercept))
}

/// Streaming line reader shared by both passes of both formats: reuses
/// one buffer (bounded memory), tracks 1-based line numbers, strips the
/// trailing `\n`/`\r\n`, and FNV-1a's the *raw* bytes so the two passes
/// can prove they read identical content.
pub(crate) struct LineReader {
    path: PathBuf,
    reader: BufReader<File>,
    buf: String,
    lineno: usize,
    hash: u64,
}

impl LineReader {
    pub(crate) fn open(path: &Path, chunk_bytes: usize) -> Result<LineReader, IngestError> {
        let file = File::open(path)
            .map_err(|err| IngestError::Io { path: path.to_path_buf(), err })?;
        Ok(LineReader {
            path: path.to_path_buf(),
            reader: BufReader::with_capacity(chunk_bytes.clamp(4096, 64 << 20), file),
            buf: String::new(),
            lineno: 0,
            hash: FNV_BASIS,
        })
    }

    /// Advance to the next line; `false` at EOF. The line (sans newline)
    /// is then available through [`LineReader::line`].
    pub(crate) fn next_line(&mut self) -> Result<bool, IngestError> {
        use std::io::BufRead as _;
        self.buf.clear();
        let n = self
            .reader
            .read_line(&mut self.buf)
            .map_err(|err| IngestError::Io { path: self.path.clone(), err })?;
        if n == 0 {
            return Ok(false);
        }
        self.hash = fnv1a(self.hash, self.buf.as_bytes());
        self.lineno += 1;
        if self.buf.ends_with('\n') {
            self.buf.pop();
        }
        if self.buf.ends_with('\r') {
            self.buf.pop();
        }
        Ok(true)
    }

    pub(crate) fn line(&self) -> &str {
        &self.buf
    }

    pub(crate) fn lineno(&self) -> usize {
        self.lineno
    }

    pub(crate) fn hash(&self) -> u64 {
        self.hash
    }
}

/// Parse one numeric field, rejecting the non-finite values `f64::from_str`
/// accepts (`nan`, `inf`, `infinity`, case-insensitive) and decimals that
/// overflow to infinity (`1e999`).
pub(crate) fn parse_finite(s: &str, line: usize) -> Result<f64, IngestError> {
    let t = s.trim();
    let v: f64 = t
        .parse()
        .map_err(|_| IngestError::Parse { line, msg: format!("`{t}` is not a number") })?;
    if !v.is_finite() {
        return Err(IngestError::NonFinite { line, msg: format!("`{t}`") });
    }
    Ok(v)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;

    #[test]
    fn fnv1a_matches_known_vector() {
        // FNV-1a("a") from the reference implementation.
        assert_eq!(fnv1a(FNV_BASIS, b"a"), 0xaf63dc4c8601ec8c);
        assert_ne!(fnv1a(FNV_BASIS, b"ab"), fnv1a(FNV_BASIS, b"ba"));
    }

    #[test]
    fn parse_finite_rejects_nan_and_overflow() {
        assert!(parse_finite("1.5", 1).is_ok());
        assert!(parse_finite(" -2e3 ", 1).is_ok());
        assert!(matches!(parse_finite("nan", 3), Err(IngestError::NonFinite { line: 3, .. })));
        assert!(matches!(parse_finite("inf", 4), Err(IngestError::NonFinite { .. })));
        assert!(matches!(parse_finite("1e999", 5), Err(IngestError::NonFinite { .. })));
        assert!(matches!(parse_finite("abc", 6), Err(IngestError::Parse { line: 6, .. })));
    }

    #[test]
    fn check_finite_catches_poisoned_designs() {
        let m = Mat::from_rows(&[&[1.0, f64::NAN], &[0.0, 1.0]]);
        assert!(check_finite(&Design::Dense(m), &[0.0, 1.0]).is_err());
        let ok = Mat::from_rows(&[&[1.0, 2.0]]);
        assert!(check_finite(&Design::Dense(ok.clone()), &[0.0]).is_ok());
        assert!(check_finite(&Design::Dense(ok), &[f64::INFINITY]).is_err());
    }

    #[test]
    fn binomial_pm1_labels_map_to_01() {
        let x = Design::Dense(Mat::from_rows(&[&[1.0], &[2.0]]));
        let opts = IngestOptions::default()
            .with_family(Family::Binomial)
            .with_standardize(false);
        let (prob, _, _) = finish(x, vec![-1.0, 1.0], &opts).unwrap();
        assert_eq!(prob.y, vec![0.0, 1.0]);
    }

    #[test]
    fn response_validation_is_typed_not_a_panic() {
        let x = Design::Dense(Mat::from_rows(&[&[1.0], &[2.0]]));
        let opts = IngestOptions::default()
            .with_family(Family::Poisson)
            .with_standardize(false);
        match finish(x, vec![1.0, -3.0], &opts) {
            Err(IngestError::Response { msg }) => assert!(msg.contains("non-negative")),
            other => panic!("expected Response error, got {other:?}"),
        }
    }

    #[test]
    fn standardization_overflow_is_rejected_not_nan() {
        // mean overflows to +inf, centering yields -inf, scaling by the
        // zero inverse-norm yields NaN — the post-transform guard fires.
        let x = Design::Dense(Mat::from_rows(&[&[1e308], &[1e308], &[-1e308]]));
        let opts = IngestOptions::default().with_standardize(true);
        match finish(x, vec![0.0, 1.0, 2.0], &opts) {
            Err(IngestError::NonFinite { line: 0, .. }) => {}
            other => panic!("expected post-standardization NonFinite, got {other:?}"),
        }
    }
}

//! Streaming dense-CSV reader.
//!
//! Dialect: comma-separated, optional header line (auto-detected by
//! default), RFC-4180 quoting (`"a,b"`, doubled `""` for a literal
//! quote), full-line `#` comments, blank lines, LF or CRLF endings.
//! One column is the response ([`IngestOptions::y_col`], last by
//! default — matching [`super::export::write_csv`]); every other column
//! is a predictor.
//!
//! Two passes over the file, both through the reused-buffer
//! [`LineReader`](super::LineReader):
//!
//! * **Pass 1 (skim)** — resolve the header question from the first data
//!   line, pin the field count, and count data rows. No numeric parsing
//!   beyond the first line, so this pass is I/O-bound.
//! * **Pass 2 (fill)** — allocate the exact `n·p` column-major buffer
//!   and the length-`n` response, then parse every field straight into
//!   place. Ragged rows and non-finite values abort with line-numbered
//!   typed errors.
//!
//! Both passes hash the raw bytes; a mismatch (file mutated mid-ingest)
//! is [`IngestError::Changed`].

use std::path::Path;

use crate::linalg::{Design, Mat};

use super::{parse_finite, Format, Ingested, IngestError, IngestOptions, LineReader, YCol};

/// Load a dense CSV file as a [`Problem`](crate::slope::family::Problem).
pub fn load_csv(path: &Path, opts: &IngestOptions) -> Result<Ingested, IngestError> {
    // ---- pass 1: header, field count, row count -------------------------
    let mut pass_span = crate::obs::trace::span("ingest_pass");
    pass_span.s("format", "csv");
    pass_span.u("pass", 1);
    crate::obs::registry::INGEST_PASSES.inc();
    let mut r1 = LineReader::open(path, opts.chunk_bytes)?;
    let mut n_rows = 0usize;
    let mut n_fields = 0usize;
    let mut has_header = false;
    let mut seen_first = false;
    let mut scratch = String::new();
    while r1.next_line()? {
        let Some(line) = data_line(r1.line()) else { continue };
        if !seen_first {
            seen_first = true;
            let mut any_non_numeric = false;
            n_fields = for_each_field(line, r1.lineno(), &mut scratch, |field, _| {
                if field.trim().parse::<f64>().is_err() {
                    any_non_numeric = true;
                }
                Ok(())
            })?;
            if n_fields < 2 {
                return Err(IngestError::Structure {
                    line: r1.lineno(),
                    msg: format!(
                        "need at least one feature column and one response column, got {n_fields} field(s)"
                    ),
                });
            }
            has_header = opts.header.unwrap_or(any_non_numeric);
            if !has_header {
                n_rows += 1;
            }
        } else {
            n_rows += 1;
        }
    }
    if n_rows == 0 {
        return Err(IngestError::Empty { path: path.to_path_buf() });
    }
    pass_span.u("rows", n_rows as u64);
    drop(pass_span);
    crate::obs::registry::INGEST_ROWS.add(n_rows as u64);

    // ---- pass 2: parse into exactly-sized buffers -----------------------
    let mut pass_span = crate::obs::trace::span("ingest_pass");
    pass_span.s("format", "csv");
    pass_span.u("pass", 2);
    crate::obs::registry::INGEST_PASSES.inc();
    let p = n_fields - 1;
    let y_idx = match opts.y_col {
        YCol::First => 0,
        YCol::Last => n_fields - 1,
    };
    let mut xbuf = vec![0.0f64; n_rows * p];
    let mut y = Vec::with_capacity(n_rows);
    let mut r2 = LineReader::open(path, opts.chunk_bytes)?;
    let mut row = 0usize;
    let mut skipped_header = false;
    while r2.next_line()? {
        let lineno = r2.lineno();
        let Some(line) = data_line(r2.line()) else { continue };
        if has_header && !skipped_header {
            skipped_header = true;
            continue;
        }
        if row >= n_rows {
            return Err(IngestError::Changed { path: path.to_path_buf() });
        }
        let count = for_each_field(line, lineno, &mut scratch, |field, k| {
            let v = parse_finite(field, lineno)?;
            if k == y_idx {
                y.push(v);
            } else if k < n_fields {
                let j = if k < y_idx { k } else { k - 1 };
                xbuf[j * n_rows + row] = v;
            }
            Ok(())
        })?;
        if count != n_fields {
            return Err(IngestError::Structure {
                line: lineno,
                msg: format!("row has {count} fields, expected {n_fields}"),
            });
        }
        row += 1;
    }
    if row != n_rows || y.len() != n_rows || r2.hash() != r1.hash() {
        return Err(IngestError::Changed { path: path.to_path_buf() });
    }
    pass_span.u("rows", row as u64);
    drop(pass_span);
    crate::obs::registry::INGEST_ROWS.add(row as u64);

    let x = Design::Dense(Mat::from_col_major(n_rows, p, xbuf));
    let (problem, stats, intercept) = super::finish(x, y, opts)?;
    Ok(Ingested { problem, fingerprint: r1.hash(), format: Format::Csv, stats, intercept })
}

/// Skip blank lines and full-line `#` comments.
fn data_line(line: &str) -> Option<&str> {
    let t = line.trim_start();
    if t.is_empty() || t.starts_with('#') {
        None
    } else {
        Some(line)
    }
}

/// Walk the comma-separated fields of one line, honoring RFC-4180 quoting
/// (embedded commas, doubled `""` escapes). Unquoted fields are trimmed.
/// Calls `f(field, index)` per field and returns the field count.
/// `scratch` backs unescaped quoted fields without per-line allocation.
fn for_each_field(
    line: &str,
    lineno: usize,
    scratch: &mut String,
    mut f: impl FnMut(&str, usize) -> Result<(), IngestError>,
) -> Result<usize, IngestError> {
    let bytes = line.as_bytes();
    let len = bytes.len();
    let mut pos = 0usize;
    let mut count = 0usize;
    loop {
        while pos < len && (bytes[pos] == b' ' || bytes[pos] == b'\t') {
            pos += 1;
        }
        if pos < len && bytes[pos] == b'"' {
            // quoted field
            pos += 1;
            scratch.clear();
            let mut start = pos;
            let mut escaped = false;
            loop {
                if pos >= len {
                    return Err(IngestError::Parse {
                        line: lineno,
                        msg: "unterminated quoted field".to_string(),
                    });
                }
                if bytes[pos] == b'"' {
                    if pos + 1 < len && bytes[pos + 1] == b'"' {
                        scratch.push_str(&line[start..pos]);
                        scratch.push('"');
                        pos += 2;
                        start = pos;
                        escaped = true;
                    } else {
                        break;
                    }
                } else {
                    pos += 1;
                }
            }
            if escaped {
                scratch.push_str(&line[start..pos]);
            }
            let field: &str = if escaped { scratch.as_str() } else { &line[start..pos] };
            pos += 1; // closing quote
            while pos < len && (bytes[pos] == b' ' || bytes[pos] == b'\t') {
                pos += 1;
            }
            if pos < len && bytes[pos] != b',' {
                return Err(IngestError::Parse {
                    line: lineno,
                    msg: "unexpected characters after closing quote".to_string(),
                });
            }
            f(field, count)?;
        } else {
            let start = pos;
            while pos < len && bytes[pos] != b',' {
                pos += 1;
            }
            f(line[start..pos].trim(), count)?;
        }
        count += 1;
        if pos >= len {
            break;
        }
        pos += 1; // the comma
        if pos >= len {
            // trailing comma: one final empty field (rejected downstream
            // by the numeric parse, with this line's number)
            f("", count)?;
            count += 1;
            break;
        }
    }
    Ok(count)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fields(line: &str) -> Vec<String> {
        let mut out = Vec::new();
        let mut scratch = String::new();
        for_each_field(line, 1, &mut scratch, |field, _| {
            out.push(field.to_string());
            Ok(())
        })
        .unwrap();
        out
    }

    #[test]
    fn splitter_handles_plain_fields() {
        assert_eq!(fields("1,2.5, -3 "), vec!["1", "2.5", "-3"]);
        assert_eq!(fields("solo"), vec!["solo"]);
    }

    #[test]
    fn splitter_handles_quotes_and_escapes() {
        assert_eq!(fields(r#""a,b",2"#), vec!["a,b", "2"]);
        assert_eq!(fields(r#""he said ""hi""",1"#), vec![r#"he said "hi""#, "1"]);
        assert_eq!(fields(r#" "3" , 4"#), vec!["3", "4"]);
    }

    #[test]
    fn splitter_rejects_malformed_quotes() {
        let mut scratch = String::new();
        assert!(matches!(
            for_each_field(r#""open,1"#, 7, &mut scratch, |_, _| Ok(())),
            Err(IngestError::Parse { line: 7, .. })
        ));
        assert!(for_each_field(r#""a"b,1"#, 1, &mut scratch, |_, _| Ok(())).is_err());
    }

    #[test]
    fn comments_and_blanks_are_skipped() {
        assert!(data_line("").is_none());
        assert!(data_line("   ").is_none());
        assert!(data_line("# note").is_none());
        assert!(data_line("  # indented note").is_none());
        assert!(data_line("1,2").is_some());
    }
}

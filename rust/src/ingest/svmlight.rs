//! Streaming svmlight/libsvm reader with the memory-budgeted two-pass
//! CSC builder.
//!
//! Record syntax: `label idx:val idx:val …` with whitespace separators,
//! **1-based** feature indices that must be strictly increasing within a
//! row (duplicates and out-of-order indices are typed errors — silently
//! reordering would mask writer bugs), and `#` starting a comment that
//! runs to end of line. Blank lines are skipped. Binomial `-1` labels
//! are mapped to `0` by the shared finish step, so both the ±1 and 0/1
//! label conventions ingest cleanly.
//!
//! The feature count `p` is resolved in priority order: an explicit
//! [`IngestOptions::n_features`], a `p=<p>` token in a *full-line*
//! comment before the first data line (our
//! [`super::export::write_svmlight`] emits
//! `# slope-screen svmlight n=<n> p=<p>`; trailing data-line comments
//! are never parsed for hints), else the largest index seen — bounded
//! by [`DEFAULT_MAX_FEATURES`] unless `n_features` raises it. The hint
//! matters: svmlight cannot represent trailing all-zero columns, and a
//! dorothea-scale design losing its last column would silently change
//! every fit.
//!
//! **Two passes, exact allocation.** A dorothea-scale file (~10⁵ columns,
//! ~10⁶ nonzeros) must not materialize per-column triplet vectors — the
//! seed's `Csc::from_columns` clones and sorts each column, tripling peak
//! memory. Instead pass 1 streams the file counting nonzeros per column
//! (labels and values are not even parsed), then `colptr` is the prefix
//! sum, `rowidx`/`values` are allocated at exactly `nnz`, and pass 2
//! streams again writing each entry through a per-column cursor. Rows
//! arrive in ascending row order, so every column's row indices are
//! built sorted — [`Csc::from_parts`] validates the invariants. Peak
//! transient memory beyond the final arrays: one line buffer plus the
//! `p`-length cursor vector.

use std::path::Path;

use crate::linalg::{Csc, Design};

use super::{parse_finite, Format, Ingested, IngestError, IngestOptions, LineReader};

/// Largest feature index accepted without an explicit
/// [`IngestOptions::n_features`]: pass 1 allocates a counts slot per
/// column, so an unbounded index would let one malformed token
/// (`1 999999999999:1`) abort the process on a terabyte allocation
/// instead of returning a typed error — fatal for the long-running fit
/// server, whose `dataset_from_file` op feeds this loader. 2²⁴ columns
/// (a 128 MB counts vector) is two orders of magnitude above the
/// paper's widest design; operators with genuinely wider data state it
/// explicitly via `n_features`, which is then the bound.
pub const DEFAULT_MAX_FEATURES: usize = 1 << 24;

/// Load an svmlight/libsvm file as a sparse
/// [`Problem`](crate::slope::family::Problem).
pub fn load_svmlight(path: &Path, opts: &IngestOptions) -> Result<Ingested, IngestError> {
    // ---- pass 1: per-column nonzero counts ------------------------------
    let mut pass_span = crate::obs::trace::span("ingest_pass");
    pass_span.s("format", "svmlight");
    pass_span.u("pass", 1);
    crate::obs::registry::INGEST_PASSES.inc();
    let mut r1 = LineReader::open(path, opts.chunk_bytes)?;
    let mut counts: Vec<usize> = Vec::new();
    let mut n_rows = 0usize;
    let mut p_hint = opts.n_features;
    let hint_is_authoritative = opts.n_features.is_some();
    let mut seen_data = false;
    let max_features = opts.n_features.unwrap_or(DEFAULT_MAX_FEATURES);
    while r1.next_line()? {
        let lineno = r1.lineno();
        let (data, comment) = split_comment(r1.line());
        let data = data.trim();
        if data.is_empty() {
            // The `p=` hint is honored only from *full-line* comments
            // before any data (the export header's position) — a stray
            // `p=<N>` in a trailing data-line comment must not silently
            // widen the design.
            if let Some(comment) = comment {
                if !seen_data && p_hint.is_none() {
                    if let Some(hint) = parse_p_hint(comment) {
                        if hint > max_features {
                            return Err(IngestError::Structure {
                                line: lineno,
                                msg: format!(
                                    "header p={hint} exceeds the feature cap {max_features} \
                                     (set IngestOptions::n_features to raise it)"
                                ),
                            });
                        }
                        p_hint = Some(hint);
                    }
                }
            }
            continue;
        }
        seen_data = true;
        n_rows += 1;
        let mut tokens = data.split_ascii_whitespace();
        let _label = tokens.next().expect("non-empty line has a first token");
        let mut prev = 0usize;
        for tok in tokens {
            let idx = parse_index(tok, lineno)?;
            if idx <= prev {
                return Err(IngestError::Structure {
                    line: lineno,
                    msg: format!(
                        "feature index {idx} after {prev}: indices must be strictly increasing \
                         (duplicate or out-of-order)"
                    ),
                });
            }
            prev = idx;
            if idx > max_features {
                let what = if hint_is_authoritative { "n_features" } else { "the feature cap" };
                return Err(IngestError::Structure {
                    line: lineno,
                    msg: format!(
                        "feature index {idx} exceeds {what} {max_features}{}",
                        if hint_is_authoritative {
                            ""
                        } else {
                            " (set IngestOptions::n_features to raise it)"
                        }
                    ),
                });
            }
            if idx > counts.len() {
                counts.resize(idx, 0);
            }
            counts[idx - 1] += 1;
        }
    }
    if n_rows == 0 {
        return Err(IngestError::Empty { path: path.to_path_buf() });
    }
    if n_rows > u32::MAX as usize {
        return Err(IngestError::Structure {
            line: 0,
            msg: format!("{n_rows} rows exceed the CSC row-index range"),
        });
    }
    // A header hint may only widen the design (declare trailing empty
    // columns); an index beyond it is a malformed file.
    if let Some(p) = p_hint {
        if counts.len() > p {
            return Err(IngestError::Structure {
                line: 0,
                msg: format!("feature index {} exceeds the declared p={p}", counts.len()),
            });
        }
    }
    let p = p_hint.unwrap_or(0).max(counts.len());
    counts.resize(p, 0);
    pass_span.u("rows", n_rows as u64);
    drop(pass_span);
    crate::obs::registry::INGEST_ROWS.add(n_rows as u64);

    // Exact-size CSC buffers: colptr as the prefix sum of the counts,
    // per-column write cursors starting at each column's span.
    let mut colptr = Vec::with_capacity(p + 1);
    colptr.push(0usize);
    let mut nnz = 0usize;
    for &c in &counts {
        nnz += c;
        colptr.push(nnz);
    }
    let mut cursor: Vec<usize> = colptr[..p].to_vec();
    let mut rowidx = vec![0u32; nnz];
    let mut values = vec![0.0f64; nnz];
    let mut y = Vec::with_capacity(n_rows);

    // ---- pass 2: fill ---------------------------------------------------
    let mut pass_span = crate::obs::trace::span("ingest_pass");
    pass_span.s("format", "svmlight");
    pass_span.u("pass", 2);
    crate::obs::registry::INGEST_PASSES.inc();
    let mut r2 = LineReader::open(path, opts.chunk_bytes)?;
    let mut row = 0usize;
    while r2.next_line()? {
        let lineno = r2.lineno();
        let (data, _comment) = split_comment(r2.line());
        let data = data.trim();
        if data.is_empty() {
            continue;
        }
        if row >= n_rows {
            return Err(IngestError::Changed { path: path.to_path_buf() });
        }
        let mut tokens = data.split_ascii_whitespace();
        let label = tokens.next().expect("non-empty line has a first token");
        y.push(parse_finite(label, lineno)?);
        for tok in tokens {
            let (idx_s, val_s) = tok.split_once(':').ok_or_else(|| IngestError::Parse {
                line: lineno,
                msg: format!("`{tok}`: expected `index:value`"),
            })?;
            let idx = parse_index_parts(idx_s, tok, lineno)?;
            let v = parse_finite(val_s, lineno)?;
            let j = idx - 1;
            if j >= p {
                // an index pass 1 never saw: the file changed
                return Err(IngestError::Changed { path: path.to_path_buf() });
            }
            let k = cursor[j];
            if k >= colptr[j + 1] {
                // more entries than pass 1 counted: the file changed
                return Err(IngestError::Changed { path: path.to_path_buf() });
            }
            rowidx[k] = row as u32;
            values[k] = v;
            cursor[j] += 1;
        }
        row += 1;
    }
    if row != n_rows || r2.hash() != r1.hash() {
        return Err(IngestError::Changed { path: path.to_path_buf() });
    }
    debug_assert!(cursor.iter().zip(colptr.iter().skip(1)).all(|(c, e)| c == e));
    pass_span.u("rows", row as u64);
    drop(pass_span);
    crate::obs::registry::INGEST_ROWS.add(row as u64);

    let x = Design::Sparse(Csc::from_parts(n_rows, p, colptr, rowidx, values));
    let (problem, stats, intercept) = super::finish(x, y, opts)?;
    Ok(Ingested { problem, fingerprint: r1.hash(), format: Format::Svmlight, stats, intercept })
}

/// Split a line at the first `#`: `(data, Some(comment))` or `(line, None)`.
fn split_comment(line: &str) -> (&str, Option<&str>) {
    match line.find('#') {
        Some(pos) => (&line[..pos], Some(&line[pos + 1..])),
        None => (line, None),
    }
}

/// Scan a comment for a `p=<usize>` token (the export header's feature
/// count, which svmlight data alone cannot represent when trailing
/// columns are all-zero).
fn parse_p_hint(comment: &str) -> Option<usize> {
    comment
        .split_ascii_whitespace()
        .find_map(|tok| tok.strip_prefix("p=").and_then(|v| v.parse().ok()))
}

/// Parse a `index:value` token's 1-based index (pass 1 never touches the
/// value — cheap skim).
fn parse_index(tok: &str, line: usize) -> Result<usize, IngestError> {
    let (idx_s, _) = tok.split_once(':').ok_or_else(|| IngestError::Parse {
        line,
        msg: format!("`{tok}`: expected `index:value`"),
    })?;
    parse_index_parts(idx_s, tok, line)
}

fn parse_index_parts(idx_s: &str, tok: &str, line: usize) -> Result<usize, IngestError> {
    let idx: usize = idx_s.parse().map_err(|_| IngestError::Parse {
        line,
        msg: format!("`{tok}`: `{idx_s}` is not a feature index"),
    })?;
    if idx == 0 {
        return Err(IngestError::Structure {
            line,
            msg: "svmlight feature indices are 1-based; got index 0".to_string(),
        });
    }
    Ok(idx)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn comment_splitting() {
        assert_eq!(split_comment("1 2:3 # note"), ("1 2:3 ", Some(" note")));
        assert_eq!(split_comment("1 2:3"), ("1 2:3", None));
        assert_eq!(split_comment("# all comment"), ("", Some(" all comment")));
    }

    #[test]
    fn p_hint_parses_from_header_comment() {
        assert_eq!(parse_p_hint(" slope-screen svmlight n=800 p=88119"), Some(88119));
        assert_eq!(parse_p_hint(" nothing here"), None);
        assert_eq!(parse_p_hint(" p=notanumber"), None);
    }

    #[test]
    fn index_validation() {
        assert_eq!(parse_index("3:1.5", 1).unwrap(), 3);
        assert!(matches!(parse_index("0:1", 2), Err(IngestError::Structure { line: 2, .. })));
        assert!(matches!(parse_index("x:1", 3), Err(IngestError::Parse { line: 3, .. })));
        assert!(matches!(parse_index("12", 4), Err(IngestError::Parse { line: 4, .. })));
    }
}

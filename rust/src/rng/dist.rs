//! Non-trivial distribution samplers: PTRS Poisson and categorical tables.

use super::Pcg64;

/// Poisson sampler for large rates via the PTRS transformed-rejection
/// algorithm (W. Hörmann, "The transformed rejection method for generating
/// Poisson random variables", 1993). Valid for `lambda >= 10`.
pub(crate) fn poisson_ptrs(rng: &mut Pcg64, lambda: f64) -> u64 {
    let slam = lambda.sqrt();
    let loglam = lambda.ln();
    let b = 0.931 + 2.53 * slam;
    let a = -0.059 + 0.02483 * b;
    let inv_alpha = 1.1239 + 1.1328 / (b - 3.4);
    let v_r = 0.9277 - 3.6224 / (b - 2.0);
    loop {
        let u = rng.next_f64() - 0.5;
        let v = rng.next_f64();
        let us = 0.5 - u.abs();
        let k = ((2.0 * a / us + b) * u + lambda + 0.43).floor();
        if us >= 0.07 && v <= v_r {
            return k as u64;
        }
        if k < 0.0 || (us < 0.013 && v > us) {
            continue;
        }
        let lhs = v.ln() + inv_alpha.ln() - (a / (us * us) + b).ln();
        let rhs = -lambda + k * loglam - ln_gamma(k + 1.0);
        if lhs <= rhs {
            return k as u64;
        }
    }
}

/// Natural log of the gamma function (Lanczos approximation, g=7, n=9).
pub fn ln_gamma(x: f64) -> f64 {
    const COEF: [f64; 9] = [
        0.999_999_999_999_809_93,
        676.520_368_121_885_1,
        -1259.139_216_722_402_8,
        771.323_428_777_653_13,
        -176.615_029_162_140_6,
        12.507_343_278_686_905,
        -0.138_571_095_265_720_12,
        9.984_369_578_019_572e-6,
        1.505_632_735_149_311_6e-7,
    ];
    if x < 0.5 {
        // Reflection formula.
        return std::f64::consts::PI.ln()
            - (std::f64::consts::PI * x).sin().ln()
            - ln_gamma(1.0 - x);
    }
    let x = x - 1.0;
    let mut acc = COEF[0];
    for (i, &c) in COEF.iter().enumerate().skip(1) {
        acc += c / (x + i as f64);
    }
    let t = x + 7.5;
    0.5 * (2.0 * std::f64::consts::PI).ln() + (x + 0.5) * t.ln() - t + acc.ln()
}

/// Categorical distribution over `k` classes with fixed probabilities,
/// sampled by inverse CDF (the class counts here are tiny, so a linear walk
/// beats building an alias table).
#[derive(Clone, Debug)]
pub struct Categorical {
    cdf: Vec<f64>,
}

impl Categorical {
    /// Build from (unnormalized) non-negative weights.
    pub fn new(weights: &[f64]) -> Self {
        assert!(!weights.is_empty());
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0 && total.is_finite(), "weights must sum to a positive finite value");
        let mut cdf = Vec::with_capacity(weights.len());
        let mut acc = 0.0;
        for &w in weights {
            assert!(w >= 0.0, "negative weight");
            acc += w / total;
            cdf.push(acc);
        }
        *cdf.last_mut().unwrap() = 1.0;
        Self { cdf }
    }

    /// Draw a class index in `0..k`.
    pub fn sample(&self, rng: &mut Pcg64) -> usize {
        let u = rng.next_f64();
        match self.cdf.iter().position(|&c| u < c) {
            Some(i) => i,
            None => self.cdf.len() - 1,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ln_gamma_matches_factorials() {
        for n in 1..15u64 {
            let fact: f64 = (1..n).map(|k| k as f64).product::<f64>().max(1.0);
            let lg = ln_gamma(n as f64);
            assert!((lg - fact.ln()).abs() < 1e-9, "n={n}: {lg} vs {}", fact.ln());
        }
    }

    #[test]
    fn ln_gamma_half() {
        // Gamma(1/2) = sqrt(pi)
        let expect = std::f64::consts::PI.sqrt().ln();
        assert!((ln_gamma(0.5) - expect).abs() < 1e-9);
    }

    #[test]
    fn categorical_frequencies() {
        let mut rng = Pcg64::new(23);
        let cat = Categorical::new(&[1.0, 2.0, 7.0]);
        let n = 100_000;
        let mut counts = [0usize; 3];
        for _ in 0..n {
            counts[cat.sample(&mut rng)] += 1;
        }
        let freqs: Vec<f64> = counts.iter().map(|&c| c as f64 / n as f64).collect();
        for (f, e) in freqs.iter().zip([0.1, 0.2, 0.7]) {
            assert!((f - e).abs() < 0.01, "freqs={freqs:?}");
        }
    }

    #[test]
    #[should_panic]
    fn categorical_rejects_all_zero() {
        Categorical::new(&[0.0, 0.0]);
    }
}

//! Pseudo-random number generation substrate.
//!
//! The offline build environment ships no `rand` crate, so this module
//! implements the generators the experiments need from scratch:
//!
//! * [`Pcg64`] — PCG XSL-RR 128/64 (O'Neill 2014), the same generator family
//!   used by NumPy's default `Generator`. 128-bit LCG state, 64-bit output.
//! * Distributions: uniform, standard normal (Box–Muller with caching),
//!   Poisson (Knuth for small means, PTRS transformed rejection for large),
//!   categorical, Bernoulli/sign.
//! * Combinatorics: Fisher–Yates shuffle, sampling without replacement.
//!
//! Every experiment seed in the benches derives from a master seed via
//! [`Pcg64::derive`], so runs are exactly reproducible.

mod dist;

pub use dist::Categorical;

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;
const PCG_INC: u128 = 0x5851_f42d_4c95_7f2d_1405_7b7e_f767_814f;

/// PCG XSL-RR 128/64 generator.
///
/// Deterministic, seedable, and fast (one 128-bit multiply-add per output).
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

impl Pcg64 {
    /// Create a generator from a 64-bit seed with the default stream.
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xda3e_39cb_94b9_5bdb)
    }

    /// Create a generator from a seed and a stream id (odd increment is
    /// derived internally, so any `stream` value is valid).
    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let inc = (((stream as u128) << 64) | PCG_INC) | 1;
        let mut rng = Self { state: 0, inc };
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng
    }

    /// Derive an independent child generator (used to hand one RNG per
    /// worker/repetition without sharing state).
    pub fn derive(&mut self, tag: u64) -> Pcg64 {
        let seed = self.next_u64() ^ tag.wrapping_mul(0x9e37_79b9_7f4a_7c15);
        let stream = self.next_u64() | 1;
        Pcg64::with_stream(seed, stream)
    }

    /// Next raw 64-bit output (XSL-RR output function).
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    /// Uniform `f64` in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, n)` (Lemire's nearly-divisionless method).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform `f64` in `[lo, hi)`.
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Standard normal deviate (Box–Muller; pairs are generated lazily and
    /// the spare is cached on the side).
    pub fn normal(&mut self) -> f64 {
        // Box-Muller without caching: marginally slower but state-free,
        // which keeps `derive`d streams independent of call parity.
        loop {
            let u1 = self.next_f64();
            if u1 > 1e-300 {
                let u2 = self.next_f64();
                let r = (-2.0 * u1.ln()).sqrt();
                return r * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Normal deviate with the given mean and standard deviation.
    #[inline]
    pub fn normal_ms(&mut self, mean: f64, sd: f64) -> f64 {
        mean + sd * self.normal()
    }

    /// Fill `out` with iid standard normal deviates.
    pub fn fill_normal(&mut self, out: &mut [f64]) {
        for v in out.iter_mut() {
            *v = self.normal();
        }
    }

    /// Poisson deviate with rate `lambda`.
    ///
    /// Uses Knuth's multiplication method for `lambda < 30` and the PTRS
    /// transformed-rejection sampler (Hörmann 1993) for larger rates.
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        assert!(lambda >= 0.0 && lambda.is_finite());
        if lambda == 0.0 {
            return 0;
        }
        if lambda < 30.0 {
            let l = (-lambda).exp();
            let mut k = 0u64;
            let mut p = 1.0;
            loop {
                p *= self.next_f64();
                if p <= l {
                    return k;
                }
                k += 1;
            }
        } else {
            dist::poisson_ptrs(self, lambda)
        }
    }

    /// Random sign: `-1.0` or `1.0` with equal probability.
    #[inline]
    pub fn sign(&mut self) -> f64 {
        if self.next_u64() & 1 == 0 {
            1.0
        } else {
            -1.0
        }
    }

    /// Bernoulli draw with success probability `p`.
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `0..n` (order randomized).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "cannot sample {k} from {n} without replacement");
        // Partial Fisher-Yates over an index vector; O(n) memory but the
        // experiment sizes (p <= ~100k) make this a non-issue.
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below((n - i) as u64) as usize;
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Sample `k` values from `choices` without replacement.
    pub fn sample_without_replacement<T: Copy>(&mut self, choices: &[T], k: usize) -> Vec<T> {
        self.sample_indices(choices.len(), k)
            .into_iter()
            .map(|i| choices[i])
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_clones() {
        let mut a = Pcg64::new(42);
        let mut b = Pcg64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg64::new(1);
        let mut b = Pcg64::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn uniform_mean_and_range() {
        let mut rng = Pcg64::new(7);
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = rng.next_f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut rng = Pcg64::new(3);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let x = rng.below(7) as usize;
            assert!(x < 7);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg64::new(11);
        let n = 200_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = rng.normal();
            s1 += x;
            s2 += x * x;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn poisson_small_and_large_mean() {
        let mut rng = Pcg64::new(5);
        for &lam in &[0.5, 4.0, 30.0, 120.0] {
            let n = 50_000;
            let mut sum = 0.0;
            let mut sq = 0.0;
            for _ in 0..n {
                let x = rng.poisson(lam) as f64;
                sum += x;
                sq += x * x;
            }
            let mean = sum / n as f64;
            let var = sq / n as f64 - mean * mean;
            assert!((mean - lam).abs() < 0.05 * lam.max(1.0), "lam={lam} mean={mean}");
            assert!((var - lam).abs() < 0.10 * lam.max(1.0), "lam={lam} var={var}");
        }
    }

    #[test]
    fn poisson_zero_rate() {
        let mut rng = Pcg64::new(5);
        assert_eq!(rng.poisson(0.0), 0);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg64::new(9);
        let mut xs: Vec<usize> = (0..50).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(xs, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut rng = Pcg64::new(13);
        let idx = rng.sample_indices(100, 20);
        assert_eq!(idx.len(), 20);
        let mut sorted = idx.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 20);
    }

    #[test]
    fn derive_streams_are_independent() {
        let mut master = Pcg64::new(1);
        let mut a = master.derive(0);
        let mut b = master.derive(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn sign_is_balanced() {
        let mut rng = Pcg64::new(17);
        let n = 10_000;
        let pos = (0..n).filter(|_| rng.sign() > 0.0).count();
        assert!((pos as f64 / n as f64 - 0.5).abs() < 0.03);
    }
}

//! Global counter/gauge registry.
//!
//! Every cell is a `static` [`Counter`] — a named, documented
//! [`AtomicU64`] — listed in the [`ALL`] table. Registration is by
//! static name at compile time: no locks, no lazy maps, no allocation on
//! the update path. An update is one relaxed `fetch_add`/`store`, cheap
//! enough to leave in the innermost kernels unconditionally (the
//! tracing-off overhead gate in `benches/path_speed.rs` holds the line).
//!
//! Counters are monotonic event counts; gauges are levels (queue depth,
//! in-flight jobs) written with [`Counter::set`]. The distinction only
//! matters for exposition: Prometheus renders `# TYPE ... counter` with a
//! `_total` suffix vs `# TYPE ... gauge`.

use std::sync::atomic::{AtomicU64, Ordering};

/// Exposition kind: monotonic counter or level gauge.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kind {
    /// Monotonically increasing event count.
    Counter,
    /// Instantaneous level; written with [`Counter::set`].
    Gauge,
}

/// A named atomic cell in the global registry.
pub struct Counter {
    name: &'static str,
    help: &'static str,
    kind: Kind,
    cell: AtomicU64,
}

impl Counter {
    /// A fresh cell (used by the `static` declarations below).
    pub const fn new(name: &'static str, help: &'static str, kind: Kind) -> Counter {
        Counter { name, help, kind, cell: AtomicU64::new(0) }
    }

    /// Add one.
    #[inline]
    pub fn inc(&self) {
        self.cell.fetch_add(1, Ordering::Relaxed);
    }

    /// Add `n` (no-op for 0, so callers can pass computed work sizes
    /// without branching themselves).
    #[inline]
    pub fn add(&self, n: u64) {
        if n != 0 {
            self.cell.fetch_add(n, Ordering::Relaxed);
        }
    }

    /// Set a gauge level.
    #[inline]
    pub fn set(&self, v: u64) {
        self.cell.store(v, Ordering::Relaxed);
    }

    /// Current value.
    #[inline]
    pub fn get(&self) -> u64 {
        self.cell.load(Ordering::Relaxed)
    }

    /// Registered name (snake_case, un-prefixed; exposition adds the
    /// `slope_` namespace).
    pub fn name(&self) -> &'static str {
        self.name
    }

    /// One-line description (the Prometheus `# HELP` text).
    pub fn help(&self) -> &'static str {
        self.help
    }

    /// Counter or gauge.
    pub fn kind(&self) -> Kind {
        self.kind
    }

    /// Zero the cell. Benchmarks and tests measure deltas instead where
    /// they can — this is process-global state.
    pub fn reset(&self) {
        self.cell.store(0, Ordering::Relaxed);
    }
}

macro_rules! registry {
    ($( $id:ident : $kind:ident, $name:literal, $help:literal; )*) => {
        $(
            #[doc = $help]
            pub static $id: Counter = Counter::new($name, $help, Kind::$kind);
        )*
        /// Every registered cell, in declaration order.
        pub static ALL: &[&Counter] = &[ $( &$id, )* ];
    };
}

registry! {
    // --- linalg kernels (gather engine: Design dispatch) ---
    GEMV_CALLS: Counter, "linalg_gemv_calls", "full X*v kernel invocations";
    GEMV_T_CALLS: Counter, "linalg_gemv_t_calls", "full X^T*v kernel invocations (the full-gradient sweep kernel)";
    GEMV_SUBSET_CALLS: Counter, "linalg_gemv_subset_calls", "column-subset X*v kernel invocations";
    GEMV_T_SUBSET_CALLS: Counter, "linalg_gemv_t_subset_calls", "column-subset X^T*v kernel invocations";
    GATHER_CELLS: Counter, "linalg_gather_cells", "matrix cells touched by gather kernels (rows x cols per call)";
    PACKED_GEMV_CALLS: Counter, "linalg_packed_gemv_calls", "packed-slab X*v kernel invocations";
    PACKED_GEMV_T_CALLS: Counter, "linalg_packed_gemv_t_calls", "packed-slab X^T*v kernel invocations";
    PACKED_CELLS: Counter, "linalg_packed_cells", "matrix cells touched by packed kernels (rows x cols per call)";
    PARALLEL_CALLS: Counter, "linalg_parallel_calls", "kernel calls whose parallel plan split into >1 chunk";
    SERIAL_CALLS: Counter, "linalg_serial_calls", "kernel calls that ran on the serial path";
    // --- pack cache ---
    PACK_CACHE_HITS: Counter, "pack_cache_hits", "screened-set slab reuses from the pack cache";
    PACK_CACHE_MISSES: Counter, "pack_cache_misses", "pack-cache lookups that had to pack fresh";
    PACK_CACHE_STORES: Counter, "pack_cache_stores", "slabs deposited into the pack cache";
    PACK_CACHE_EVICTIONS: Counter, "pack_cache_evictions", "slabs evicted from the pack cache (count or byte bound)";
    // --- serve registry (dataset/model caches) ---
    REGISTRY_MODEL_HITS: Counter, "registry_model_hits", "fit requests answered from the model cache";
    REGISTRY_MODEL_BUILDS: Counter, "registry_model_builds", "fit requests that built a model (cache miss)";
    REGISTRY_COALESCED: Counter, "registry_coalesced_waits", "fit requests coalesced onto an identical in-flight build";
    REGISTRY_DATASET_EVICTIONS: Counter, "registry_dataset_evictions", "interned datasets evicted past the registry cap";
    // --- FISTA solver ---
    FISTA_SOLVES: Counter, "fista_solves", "reduced-problem FISTA solves started";
    FISTA_ITERATIONS: Counter, "fista_iterations", "FISTA iterations across all solves";
    FISTA_PROX_CALLS: Counter, "fista_prox_calls", "sorted-L1 prox evaluations";
    FISTA_BACKTRACKS: Counter, "fista_backtracks", "line-search backtracks (step-size halvings)";
    // --- path driver & screening ---
    PATH_STEPS: Counter, "path_steps", "path steps (sigma grid points) solved";
    GRAD_FULL_SWEEPS: Counter, "grad_full_sweeps", "full p-column gradient sweeps (X^T r over every predictor)";
    GRAD_PARTIAL_SWEEPS: Counter, "grad_partial_sweeps", "partial gradient sweeps over a screened universe";
    GRAD_SWEEP_COLS: Counter, "grad_sweep_cols", "columns swept by full+partial gradient sweeps (p-equivalents = cols/p)";
    SCREEN_RULE_COLS: Counter, "screen_rule_cols", "cumulative strong/previous rule set size across steps";
    SCREEN_SAFE_COLS: Counter, "screen_safe_cols", "cumulative safe-region set size across steps";
    SCREEN_UNIVERSE_COLS: Counter, "screen_universe_cols", "cumulative screening universe size across steps";
    KKT_VIOLATIONS: Counter, "kkt_violations", "screened-out predictors that violated KKT on the check sweep";
    KKT_REFITS: Counter, "kkt_refits", "safeguard refits after KKT violations";
    // --- ingest ---
    INGEST_PASSES: Counter, "ingest_passes", "streaming ingest passes over an input file";
    INGEST_ROWS: Counter, "ingest_rows", "rows parsed by ingest passes";
    // --- serve queue ---
    SERVE_QUEUE_DEPTH: Gauge, "serve_queue_depth", "requests holding admission tickets but not yet admitted";
    SERVE_IN_FLIGHT: Gauge, "serve_in_flight", "admitted (queued-on-pool or running) fit jobs";
    // --- resilience (DESIGN.md §12) ---
    SERVE_WORKER_PANICS: Counter, "serve_worker_panics", "fit jobs that panicked inside a worker (caught and quarantined)";
    SERVE_DEADLINE_EXPIRED: Counter, "serve_deadline_expired", "requests cancelled by their deadline_ms budget";
    SERVE_LOAD_SHED: Counter, "serve_load_shed", "requests rejected with retry_after_ms because the queue was full";
    SERVE_SHUTDOWN_REJECTED: Counter, "serve_shutdown_rejected", "queued requests rejected during graceful drain";
    REGISTRY_QUARANTINED: Counter, "registry_quarantined", "datasets evicted after repeated worker panics (strike-out)";
    PATH_DEGRADED_STEPS: Counter, "path_degraded_steps", "path steps rescued by a more conservative strategy (degradation ladder)";
    FISTA_NONCONVERGED: Counter, "fista_nonconverged", "FISTA solves that exhausted max_iter without certifying convergence";
    FAULT_INJECTIONS: Counter, "fault_injections", "faults injected by an armed fault plan (chaos harness)";
    // --- durable state (DESIGN.md §13) ---
    CKPT_WRITES: Counter, "checkpoint_writes", "path-fit snapshots written atomically to disk";
    CKPT_BYTES: Counter, "checkpoint_bytes", "bytes written across all checkpoint snapshots";
    CKPT_RESUMES: Counter, "checkpoint_resumes", "path fits resumed from a validated snapshot";
    CKPT_CORRUPT_SKIPS: Counter, "checkpoint_corrupt_skips", "snapshots or journal records rejected as corrupt/torn and skipped";
    JOURNAL_RECORDS: Counter, "journal_records", "records appended to the serve registry journal";
    JOURNAL_RESTORED: Counter, "journal_restored", "journal records successfully replayed on registry boot";
    // --- network transport & cross-request batching (DESIGN.md §14) ---
    SERVE_TCP_ACCEPTS: Counter, "serve_tcp_accepts", "TCP connections accepted by the poll-loop transport";
    SERVE_OPEN_CONNS: Gauge, "serve_open_conns", "connections currently open on the poll-loop transport";
    SERVE_CONN_LIMIT_REJECTED: Counter, "serve_conn_limit_rejected", "connections refused at accept because max_conns was reached";
    SERVE_WRITE_BACKPRESSURE: Counter, "serve_write_backpressure", "times a connection's reads were paused because its write buffer was full";
    SERVE_BATCHES: Counter, "serve_batches", "coalesced cross-request batches gathered and executed";
    SERVE_BATCHED_REQUESTS: Counter, "serve_batched_requests", "requests that joined an open batch instead of running alone";
    // --- replication & failover (DESIGN.md §15) ---
    REPL_RECORDS_SHIPPED: Counter, "repl_records_shipped", "journal records queued to replication subscribers (per record per subscriber)";
    REPL_RECORDS_APPLIED: Counter, "repl_records_applied", "replicated journal records applied by a standby";
    REPL_DIGEST_SKIPS: Counter, "repl_digest_skips", "replication stream records rejected by the digest check and skipped";
    REPL_LAG_RECORDS: Gauge, "repl_lag_records", "replication lag in journal records (primary: worst subscriber backlog; standby: records behind the primary)";
    REPL_SUBSCRIBERS: Gauge, "repl_subscribers", "replication subscribers currently attached to this primary";
    REPL_EPOCH: Gauge, "repl_epoch", "failover epoch this server last promoted itself to";
    REPL_HEARTBEATS_MISSED: Counter, "repl_heartbeats_missed", "heartbeat windows a standby waited out without hearing from its primary";
    REPL_PROMOTIONS: Counter, "repl_promotions", "standby-to-primary promotions (explicit op or promote-on-loss)";
    SERVE_FENCED_REJECTS: Counter, "serve_fenced_rejects", "write requests rejected because this server is a standby or a fenced ex-primary";
    SERVE_IDLE_REAPED: Counter, "serve_idle_reaped", "connections closed by the idle-timeout reaper for lack of read/write progress";
    JOURNAL_COMPACTIONS: Counter, "journal_compactions", "registry journal compactions (snapshot rewrite of the live state)";
    JOURNAL_BYTES_RECLAIMED: Counter, "journal_bytes_reclaimed", "journal bytes reclaimed by compaction (old size minus snapshot size)";
}

/// Name/value pairs for every registered cell, in declaration order.
pub fn snapshot() -> Vec<(&'static str, u64)> {
    ALL.iter().map(|c| (c.name(), c.get())).collect()
}

/// Zero every cell. Sequential harnesses (benches) use this between
/// measured sections; concurrent code should difference [`snapshot`]s.
pub fn reset_all() {
    for c in ALL {
        c.reset();
    }
}

/// Prometheus text exposition of the whole registry: `slope_` namespace,
/// `_total` suffix on counters, `# HELP`/`# TYPE` headers.
pub fn render_prometheus(out: &mut String) {
    for c in ALL {
        let (suffix, kind) = match c.kind() {
            Kind::Counter => ("_total", "counter"),
            Kind::Gauge => ("", "gauge"),
        };
        let name = format!("slope_{}{}", c.name(), suffix);
        out.push_str("# HELP ");
        out.push_str(&name);
        out.push(' ');
        out.push_str(c.help());
        out.push('\n');
        out.push_str("# TYPE ");
        out.push_str(&name);
        out.push(' ');
        out.push_str(kind);
        out.push('\n');
        out.push_str(&name);
        out.push(' ');
        out.push_str(&c.get().to_string());
        out.push('\n');
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_are_unique_and_snake_case() {
        let mut seen = std::collections::BTreeSet::new();
        for c in ALL {
            assert!(seen.insert(c.name()), "duplicate counter name {}", c.name());
            assert!(
                c.name().chars().all(|ch| ch.is_ascii_lowercase() || ch.is_ascii_digit() || ch == '_'),
                "non-snake-case counter name {}",
                c.name()
            );
            assert!(!c.help().is_empty());
        }
    }

    #[test]
    fn inc_add_set_are_visible_in_snapshot() {
        // Deltas, not absolutes: other tests in this process bump the
        // same global cells concurrently.
        let before = FISTA_SOLVES.get();
        FISTA_SOLVES.inc();
        FISTA_SOLVES.add(4);
        FISTA_SOLVES.add(0);
        assert!(FISTA_SOLVES.get() >= before + 5);
        SERVE_QUEUE_DEPTH.set(17);
        let snap = snapshot();
        assert_eq!(snap.len(), ALL.len());
        assert!(snap.iter().any(|&(n, _)| n == "fista_solves"));
    }

    #[test]
    fn prometheus_rendering_has_help_type_and_value() {
        let mut text = String::new();
        render_prometheus(&mut text);
        assert!(text.contains("# HELP slope_fista_iterations_total"));
        assert!(text.contains("# TYPE slope_fista_iterations_total counter"));
        assert!(text.contains("# TYPE slope_serve_queue_depth gauge"));
        // every cell appears with a numeric value line
        for c in ALL {
            let suffix = if c.kind() == Kind::Counter { "_total " } else { " " };
            assert!(
                text.contains(&format!("slope_{}{}", c.name(), suffix)),
                "missing exposition for {}",
                c.name()
            );
        }
    }
}

//! Structured span/event tracer with a JSONL sink.
//!
//! Off by default: [`disabled`] is a single relaxed atomic load, and an
//! inert [`Span`] is a no-op on field writes and drop, so trace points
//! can live permanently in the path driver, ingest, and serve without
//! costing the solver anything (the differential test in
//! `tests/integration_obs.rs` proves fits are bitwise unaffected).
//!
//! When enabled (`--trace out.jsonl`), records are formatted with
//! [`crate::jsonio`] and buffered in a per-thread `String`, drained to
//! the process-global sink when the buffer passes a threshold, on
//! [`flush`], on thread exit, and on [`disable`]. One record per line:
//!
//! ```text
//! {"ev":"meta","clock":"monotonic_us","version":1}
//! {"ev":"span","name":"path_step","tid":0,"t_us":412,"dur_us":1890,"sigma":0.73,...}
//! {"ev":"event","name":"gap_check","tid":0,"t_us":911,"gap":1.3e-4,...}
//! {"ev":"counters","counters":{"fista_iterations":5123,...}}
//! ```
//!
//! Spans are emitted as single *completed* records (start `t_us` +
//! `dur_us`) when the RAII guard drops — begin/end pairs carry the same
//! information in twice the lines. `tid` is a small per-thread ordinal
//! (assignment order, not the OS id), which is what the profile
//! aggregator nests self-time within.

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::fs::File;
use std::io::{self, BufWriter, Write};
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

use crate::jsonio::Json;

/// Drain a thread's buffer to the sink once it holds this many bytes.
const FLUSH_BYTES: usize = 8 * 1024;

static ENABLED: AtomicBool = AtomicBool::new(false);
static SINK: Mutex<Option<BufWriter<File>>> = Mutex::new(None);
static NEXT_TID: AtomicU64 = AtomicU64::new(0);

/// The process trace epoch: all `t_us` timestamps are micros since this
/// instant (first touched when tracing is first enabled).
fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

struct TlsBuf {
    tid: u64,
    buf: String,
}

impl Drop for TlsBuf {
    fn drop(&mut self) {
        if !self.buf.is_empty() {
            write_to_sink(&std::mem::take(&mut self.buf));
        }
    }
}

thread_local! {
    static TLS: RefCell<TlsBuf> = RefCell::new(TlsBuf {
        tid: NEXT_TID.fetch_add(1, Ordering::Relaxed),
        buf: String::new(),
    });
}

fn lock_sink() -> std::sync::MutexGuard<'static, Option<BufWriter<File>>> {
    // A panic while holding the sink poisons the lock; tracing must keep
    // working (or at worst drop records), never cascade the panic.
    SINK.lock().unwrap_or_else(|e| e.into_inner())
}

fn write_to_sink(chunk: &str) {
    if chunk.is_empty() {
        return;
    }
    let mut guard = lock_sink();
    if let Some(w) = guard.as_mut() {
        let _ = w.write_all(chunk.as_bytes());
    }
}

/// Fast-path check: `true` when tracing is off (the steady state). One
/// relaxed load — callers branch on this before doing any span work.
#[inline]
pub fn disabled() -> bool {
    !ENABLED.load(Ordering::Relaxed)
}

/// Open `path` (created/truncated) as the JSONL sink and enable tracing.
/// Writes the `meta` header record. Re-enabling replaces the sink.
pub fn enable_file(path: &Path) -> io::Result<()> {
    let file = File::create(path)?;
    let mut writer = BufWriter::new(file);
    epoch(); // pin the timebase before any span can observe it
    let meta = Json::obj(vec![
        ("ev", Json::Str("meta".to_string())),
        ("version", Json::Num(1.0)),
        ("clock", Json::Str("monotonic_us".to_string())),
    ]);
    writer.write_all(meta.to_string().as_bytes())?;
    writer.write_all(b"\n")?;
    *lock_sink() = Some(writer);
    ENABLED.store(true, Ordering::Relaxed);
    Ok(())
}

/// Disable tracing, append a final `counters` record (the registry
/// snapshot), flush, and close the sink. Buffers still held by *other*
/// live threads are dropped — job boundaries call [`flush`] so this only
/// loses records from threads killed mid-span.
pub fn disable() {
    ENABLED.store(false, Ordering::Relaxed);
    let _ = TLS.try_with(|tls| {
        let chunk = std::mem::take(&mut tls.borrow_mut().buf);
        write_to_sink(&chunk);
    });
    let mut guard = lock_sink();
    if let Some(mut w) = guard.take() {
        let mut counters = BTreeMap::new();
        for (name, value) in super::registry::snapshot() {
            counters.insert(name.to_string(), Json::Num(value as f64));
        }
        let record = Json::obj(vec![
            ("ev", Json::Str("counters".to_string())),
            ("counters", Json::Obj(counters)),
        ]);
        let _ = w.write_all(record.to_string().as_bytes());
        let _ = w.write_all(b"\n");
        let _ = w.flush();
    }
}

/// Drain the calling thread's buffer and flush the sink. Call at job
/// boundaries (end of a serve request, end of a pool fit job) so
/// long-lived worker threads don't sit on trace tails.
pub fn flush() {
    let _ = TLS.try_with(|tls| {
        let chunk = std::mem::take(&mut tls.borrow_mut().buf);
        write_to_sink(&chunk);
    });
    let mut guard = lock_sink();
    if let Some(w) = guard.as_mut() {
        let _ = w.flush();
    }
}

fn emit(mut obj: BTreeMap<String, Json>) {
    let wrote = TLS.try_with(|tls| {
        let mut tls = tls.borrow_mut();
        obj.insert("tid".to_string(), Json::Num(tls.tid as f64));
        let line = Json::Obj(std::mem::take(&mut obj)).to_string();
        tls.buf.push_str(&line);
        tls.buf.push('\n');
        if tls.buf.len() >= FLUSH_BYTES {
            let chunk = std::mem::take(&mut tls.buf);
            write_to_sink(&chunk);
        }
    });
    if wrote.is_err() {
        // TLS already destroyed (thread teardown): write the record
        // directly rather than losing it.
        let mut line = Json::Obj(obj).to_string();
        line.push('\n');
        write_to_sink(&line);
    }
}

fn record_base(ev: &str, name: &str, t_us: u64) -> BTreeMap<String, Json> {
    let mut obj = BTreeMap::new();
    obj.insert("ev".to_string(), Json::Str(ev.to_string()));
    obj.insert("name".to_string(), Json::Str(name.to_string()));
    obj.insert("t_us".to_string(), Json::Num(t_us as f64));
    obj
}

fn now_us() -> u64 {
    Instant::now().saturating_duration_since(epoch()).as_micros() as u64
}

/// RAII span: records its start on construction and emits one completed
/// record (start + duration + fields) when dropped. Inert (all methods
/// no-ops) when tracing is disabled at construction time.
pub struct Span {
    name: &'static str,
    start: Option<Instant>,
    fields: Vec<(&'static str, Json)>,
}

impl Span {
    /// Whether this span will emit a record (tracing was enabled when it
    /// was opened). Callers can skip expensive field computation when
    /// `false`.
    #[inline]
    pub fn active(&self) -> bool {
        self.start.is_some()
    }

    /// Attach a float field.
    #[inline]
    pub fn f(&mut self, key: &'static str, value: f64) {
        if self.active() {
            self.fields.push((key, Json::Num(value)));
        }
    }

    /// Attach an integer field.
    #[inline]
    pub fn u(&mut self, key: &'static str, value: u64) {
        if self.active() {
            self.fields.push((key, Json::Num(value as f64)));
        }
    }

    /// Attach a string field.
    #[inline]
    pub fn s(&mut self, key: &'static str, value: &str) {
        if self.active() {
            self.fields.push((key, Json::Str(value.to_string())));
        }
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        let Some(start) = self.start else { return };
        let t_us = start.saturating_duration_since(epoch()).as_micros() as u64;
        let dur_us = start.elapsed().as_micros() as u64;
        let mut obj = record_base("span", self.name, t_us);
        obj.insert("dur_us".to_string(), Json::Num(dur_us as f64));
        for (k, v) in self.fields.drain(..) {
            obj.insert(k.to_string(), v);
        }
        emit(obj);
    }
}

/// Open a span. `name` should be a stable, low-cardinality identifier
/// (`"path_step"`, `"serve_request"`) — per-instance data goes in
/// fields, not the name.
#[inline]
pub fn span(name: &'static str) -> Span {
    if disabled() {
        return Span { name, start: None, fields: Vec::new() };
    }
    Span { name, start: Some(Instant::now()), fields: Vec::new() }
}

/// Emit a point event with fields. No-op when tracing is disabled.
pub fn event(name: &'static str, fields: Vec<(&'static str, Json)>) {
    if disabled() {
        return;
    }
    let mut obj = record_base("event", name, now_us());
    for (k, v) in fields {
        obj.insert(k.to_string(), v);
    }
    emit(obj);
}

/// Serializes tests (and anything else) that toggle the process-global
/// tracer, so concurrent tests in one test binary can't interleave
/// enable/disable.
pub fn test_guard() -> std::sync::MutexGuard<'static, ()> {
    static GUARD: Mutex<()> = Mutex::new(());
    GUARD.lock().unwrap_or_else(|e| e.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_path(tag: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("slope_trace_{}_{}.jsonl", std::process::id(), tag))
    }

    #[test]
    fn disabled_spans_and_events_are_inert() {
        let _g = test_guard();
        assert!(disabled());
        let mut sp = span("never");
        assert!(!sp.active());
        sp.f("x", 1.0);
        sp.u("y", 2);
        sp.s("z", "three");
        drop(sp);
        event("never_either", vec![("k", Json::Num(1.0))]);
        // nothing to assert beyond "did not panic, wrote nothing":
        // there is no sink, so any write would have been dropped anyway.
    }

    #[test]
    fn round_trip_spans_events_and_counters() {
        let _g = test_guard();
        let path = tmp_path("roundtrip");
        enable_file(&path).expect("enable");
        {
            let mut outer = span("outer");
            outer.f("sigma", 0.5);
            outer.s("label", "a\"b"); // must survive JSON escaping
            {
                let mut inner = span("inner");
                inner.u("count", 3);
            }
            event("tick", vec![("gap", Json::Num(1e-4))]);
        }
        disable();
        let text = std::fs::read_to_string(&path).expect("read trace");
        let _ = std::fs::remove_file(&path);
        let mut names = Vec::new();
        let mut saw_meta = false;
        let mut saw_counters = false;
        for line in text.lines().filter(|l| !l.trim().is_empty()) {
            let j = Json::parse(line).expect("each line parses");
            match j.field("ev").and_then(|e| e.as_str()) {
                Some("meta") => saw_meta = true,
                Some("counters") => {
                    saw_counters = true;
                    let c = j.field("counters").expect("counters object");
                    assert!(c.field("fista_iterations").is_some());
                }
                Some("span") => {
                    names.push(j.field("name").unwrap().as_str().unwrap().to_string());
                    assert!(j.field("t_us").unwrap().as_f64().is_some());
                    assert!(j.field("dur_us").unwrap().as_f64().is_some());
                    assert!(j.field("tid").unwrap().as_f64().is_some());
                }
                Some("event") => {
                    assert_eq!(j.field("name").unwrap().as_str(), Some("tick"));
                    assert_eq!(j.field("gap").unwrap().as_f64(), Some(1e-4));
                }
                other => panic!("unexpected ev {other:?}"),
            }
        }
        assert!(saw_meta && saw_counters);
        // inner drops before outer, so it is emitted first
        assert_eq!(names, vec!["inner".to_string(), "outer".to_string()]);
        let outer_line = text.lines().find(|l| l.contains("\"outer\"")).unwrap();
        let outer_json = Json::parse(outer_line).unwrap();
        assert_eq!(outer_json.field("sigma").unwrap().as_f64(), Some(0.5));
        assert_eq!(outer_json.field("label").unwrap().as_str(), Some("a\"b"));
    }

    #[test]
    fn threads_get_distinct_tids() {
        let _g = test_guard();
        let path = tmp_path("tids");
        enable_file(&path).expect("enable");
        std::thread::scope(|scope| {
            for _ in 0..2 {
                scope.spawn(|| {
                    let _sp = span("worker");
                    // TLS drop at thread exit drains the buffer
                });
            }
        });
        {
            let _sp = span("main");
        }
        disable();
        let text = std::fs::read_to_string(&path).expect("read trace");
        let _ = std::fs::remove_file(&path);
        let mut tids = std::collections::BTreeSet::new();
        for line in text.lines().filter(|l| !l.trim().is_empty()) {
            let j = Json::parse(line).unwrap();
            if j.field("ev").and_then(|e| e.as_str()) == Some("span") {
                tids.insert(j.field("tid").unwrap().as_f64().unwrap() as u64);
            }
        }
        assert!(tids.len() >= 3, "expected 3 distinct tids, got {tids:?}");
    }
}

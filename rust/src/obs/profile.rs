//! Trace-profile aggregation: read a JSONL trace back and summarize
//! per-span self-time, event counts, and the final counter snapshot.
//!
//! Self-time nests spans per thread: spans on one `tid` whose intervals
//! are contained in another's are children, and a parent's self-time is
//! its duration minus the time spent in its children. RAII spans nest
//! properly by construction, so a simple interval-stack sweep over the
//! start-sorted spans recovers the tree without parent ids in the
//! records.

use std::collections::BTreeMap;
use std::path::Path;

use crate::jsonio::Json;

/// Aggregate over every completed span with one name.
#[derive(Clone, Debug)]
pub struct SpanStat {
    /// Span name.
    pub name: String,
    /// Completed spans observed.
    pub count: u64,
    /// Total duration (sum of `dur_us`), microseconds.
    pub total_us: u64,
    /// Self-time: total minus time inside nested child spans.
    pub self_us: u64,
    /// Longest single span.
    pub max_us: u64,
}

/// Aggregated trace profile.
#[derive(Clone, Debug, Default)]
pub struct Profile {
    /// Per-name span aggregates, sorted by self-time (descending).
    pub spans: Vec<SpanStat>,
    /// Per-name point-event counts, sorted by name.
    pub events: Vec<(String, u64)>,
    /// The final registry snapshot, if the trace carries a `counters`
    /// record (written by `obs::trace::disable`).
    pub counters: Vec<(String, f64)>,
    /// Records parsed (all kinds).
    pub records: usize,
}

struct RawSpan {
    name: String,
    t_us: u64,
    dur_us: u64,
}

/// Parse and aggregate a trace file.
pub fn profile_file(path: &Path) -> Result<Profile, String> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| format!("cannot read trace {}: {e}", path.display()))?;
    profile_str(&text)
}

/// Parse and aggregate trace JSONL text.
pub fn profile_str(text: &str) -> Result<Profile, String> {
    let mut by_tid: BTreeMap<u64, Vec<RawSpan>> = BTreeMap::new();
    let mut events: BTreeMap<String, u64> = BTreeMap::new();
    let mut counters: Vec<(String, f64)> = Vec::new();
    let mut records = 0usize;
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let j = Json::parse(line).map_err(|e| format!("trace line {}: {e}", lineno + 1))?;
        records += 1;
        let ev = j.field("ev").and_then(|e| e.as_str()).unwrap_or("");
        match ev {
            "span" => {
                let name = j
                    .field("name")
                    .and_then(|n| n.as_str())
                    .ok_or_else(|| format!("trace line {}: span without name", lineno + 1))?
                    .to_string();
                let t_us = j.field("t_us").and_then(|v| v.as_f64()).unwrap_or(0.0) as u64;
                let dur_us = j.field("dur_us").and_then(|v| v.as_f64()).unwrap_or(0.0) as u64;
                let tid = j.field("tid").and_then(|v| v.as_f64()).unwrap_or(0.0) as u64;
                by_tid.entry(tid).or_default().push(RawSpan { name, t_us, dur_us });
            }
            "event" => {
                let name = j.field("name").and_then(|n| n.as_str()).unwrap_or("?").to_string();
                *events.entry(name).or_insert(0) += 1;
            }
            "counters" => {
                if let Some(Json::Obj(map)) = j.field("counters") {
                    counters = map
                        .iter()
                        .filter_map(|(k, v)| v.as_f64().map(|x| (k.clone(), x)))
                        .collect();
                }
            }
            // meta and unknown kinds are counted but not aggregated, so
            // newer trace writers stay readable by older profilers.
            _ => {}
        }
    }

    let mut agg: BTreeMap<String, SpanStat> = BTreeMap::new();
    for spans in by_tid.values_mut() {
        // Start-ordered; at equal starts the longer span is the parent.
        spans.sort_by(|a, b| a.t_us.cmp(&b.t_us).then(b.dur_us.cmp(&a.dur_us)));
        // Interval stack: (end_us, index into `spans`); child durations
        // accumulate against the innermost enclosing span.
        let mut child_us: Vec<u64> = vec![0; spans.len()];
        let mut stack: Vec<(u64, usize)> = Vec::new();
        for i in 0..spans.len() {
            let (start, end) = (spans[i].t_us, spans[i].t_us + spans[i].dur_us);
            while let Some(&(stack_end, _)) = stack.last() {
                if start < stack_end {
                    break;
                }
                stack.pop();
            }
            if let Some(&(_, parent)) = stack.last() {
                child_us[parent] += spans[i].dur_us;
            }
            stack.push((end, i));
        }
        for (i, s) in spans.iter().enumerate() {
            let stat = agg.entry(s.name.clone()).or_insert_with(|| SpanStat {
                name: s.name.clone(),
                count: 0,
                total_us: 0,
                self_us: 0,
                max_us: 0,
            });
            stat.count += 1;
            stat.total_us += s.dur_us;
            stat.self_us += s.dur_us.saturating_sub(child_us[i]);
            stat.max_us = stat.max_us.max(s.dur_us);
        }
    }
    let mut spans: Vec<SpanStat> = agg.into_values().collect();
    spans.sort_by(|a, b| b.self_us.cmp(&a.self_us).then(a.name.cmp(&b.name)));
    Ok(Profile {
        spans,
        events: events.into_iter().collect(),
        counters,
        records,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nested_spans_attribute_self_time_to_the_parent() {
        let text = r#"
{"ev":"meta","version":1}
{"ev":"span","name":"inner","tid":0,"t_us":100,"dur_us":30}
{"ev":"span","name":"inner","tid":0,"t_us":150,"dur_us":20}
{"ev":"span","name":"outer","tid":0,"t_us":90,"dur_us":200}
{"ev":"span","name":"outer","tid":1,"t_us":0,"dur_us":50}
{"ev":"event","name":"tick","tid":0,"t_us":120}
{"ev":"event","name":"tick","tid":0,"t_us":121}
{"ev":"counters","counters":{"fista_iterations":7}}
"#;
        let p = profile_str(text).expect("profile");
        assert_eq!(p.records, 8);
        let outer = p.spans.iter().find(|s| s.name == "outer").unwrap();
        let inner = p.spans.iter().find(|s| s.name == "inner").unwrap();
        assert_eq!(outer.count, 2);
        assert_eq!(outer.total_us, 250);
        // tid 0 outer: 200 - (30 + 20) children; tid 1 outer: all self
        assert_eq!(outer.self_us, 150 + 50);
        assert_eq!(outer.max_us, 200);
        assert_eq!(inner.count, 2);
        assert_eq!(inner.total_us, 50);
        assert_eq!(inner.self_us, 50);
        assert_eq!(p.events, vec![("tick".to_string(), 2)]);
        assert_eq!(p.counters, vec![("fista_iterations".to_string(), 7.0)]);
    }

    #[test]
    fn spans_on_different_tids_do_not_nest() {
        let text = concat!(
            r#"{"ev":"span","name":"a","tid":0,"t_us":0,"dur_us":100}"#,
            "\n",
            r#"{"ev":"span","name":"b","tid":1,"t_us":10,"dur_us":50}"#,
            "\n",
        );
        let p = profile_str(text).expect("profile");
        let a = p.spans.iter().find(|s| s.name == "a").unwrap();
        assert_eq!(a.self_us, 100, "a cross-thread span must not steal self-time");
    }

    #[test]
    fn bad_json_is_an_error_blank_lines_are_not() {
        assert!(profile_str("{not json}").is_err());
        let p = profile_str("\n\n").expect("blank trace");
        assert_eq!(p.records, 0);
        assert!(p.spans.is_empty());
    }
}

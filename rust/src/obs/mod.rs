//! Observability substrate: a global counter/gauge registry, a structured
//! span/event tracer, and trace-profile aggregation (DESIGN.md §11).
//!
//! Three pillars, all zero-dependency:
//!
//! * [`registry`] — process-global monotonic counters and level gauges on
//!   lock-free [`std::sync::atomic::AtomicU64`] cells, registered by
//!   static name. The hot seams (linalg kernels, pack cache, FISTA,
//!   gradient sweeps, screening sets, serve queue) bump these
//!   unconditionally: one relaxed `fetch_add` per event is cheaper than
//!   any branch worth protecting it with. Snapshots render as JSON (the
//!   serve `metrics` op) and Prometheus text exposition.
//! * [`trace`] — an opt-in structured tracer: thread-aware spans (start
//!   time + duration) and point events with typed key/value fields,
//!   buffered per-thread and drained as JSONL through a process-global
//!   sink. Off by default; [`trace::disabled`] is a single relaxed atomic
//!   load, so instrumentation left in the hot path costs a branch and
//!   nothing else. `--trace out.jsonl` on `fit`/`cv`/`serve` enables it.
//! * [`profile`] — reads a trace JSONL back and aggregates per-span
//!   self-time (total minus time attributed to nested spans on the same
//!   thread), the data behind the `profile` CLI subcommand.
//!
//! The overhead contract is testable, not aspirational: counters are
//! always compiled in and never branch; spans compile to a load+branch
//! when disabled; and `tests/integration_obs.rs` asserts that fits with
//! tracing enabled are *bitwise identical* to uninstrumented ones across
//! thread budgets — instrumentation must observe the solver, never
//! perturb it.

pub mod profile;
pub mod registry;
pub mod trace;

pub use registry::{snapshot, Counter, Kind};
pub use trace::{disabled, event, span, Span};

//! Deterministic fault injection for the resilience test harness.
//!
//! A [`FaultPlan`] describes a small set of misbehaviors — panic at the
//! n-th FISTA solve, sleep inside every solve, corrupt a gradient with
//! NaN, drop a serve connection mid-stream — and a process-global
//! registry arms it. Production code calls the `on_*` hooks at the
//! matching sites; every hook opens with a single relaxed atomic load of
//! the `ACTIVE` flag, so a disabled registry costs one predictable branch
//! and touches no solver state (the chaos suite asserts fits are bitwise
//! identical with the registry disarmed).
//!
//! The plan is seeded: the slow-solve jitter draws from a xorshift stream
//! keyed by `seed`, so a chaos run replays identically. Counters reset on
//! [`install`], so scenario ordering inside one test process is explicit
//! rather than accidental.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;

use crate::jsonio::Json;
use crate::obs::registry as obsreg;

/// What to break, and when. All triggers are optional; an empty plan is
/// legal and injects nothing.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultPlan {
    /// Panic (with a recognizable payload) when the n-th FISTA solve of
    /// the process starts, 1-based.
    pub panic_at_solve: Option<u64>,
    /// Sleep this many milliseconds (± seeded jitter) at the start of
    /// every FISTA solve — the lever for deadline-expiry scenarios.
    pub slow_solve_ms: u64,
    /// Overwrite the first gradient entry with NaN on the n-th solve,
    /// 1-based — exercises the non-finite bail + degradation ladder.
    pub nan_grad_at_solve: Option<u64>,
    /// Sever a serve connection after this many request lines.
    pub drop_after_lines: Option<u64>,
    /// Kill the process-side path loop by panicking right after the n-th
    /// σ-step completes (1-based, counting from the first non-trivial
    /// step) — the kill-and-resume chaos lever. The panic fires *after*
    /// the step's checkpoint write, so a checkpointed fit always leaves a
    /// resumable snapshot behind.
    pub kill_after_step: Option<u64>,
    /// Truncate the freshly written checkpoint file to half its length
    /// after the n-th checkpoint write, 1-based — a torn write that must
    /// be caught by the digest and recovered via the previous snapshot.
    pub truncate_checkpoint: Option<u64>,
    /// Flip one digest bit in the n-th journal record shipped to
    /// replication subscribers, 1-based. The on-disk journal keeps the
    /// good frame; only the wire copy is corrupted — the standby must
    /// skip it by digest and never apply it.
    pub repl_flip_digest_at: Option<u64>,
    /// Seed for the jitter stream.
    pub seed: u64,
}

impl FaultPlan {
    /// Parse from the JSON schema documented in DESIGN.md §12:
    /// `{"panic_at_solve": 3, "slow_solve_ms": 50, "nan_grad_at_solve": 1,
    ///   "drop_after_lines": 2, "seed": 7}` — every field optional.
    pub fn parse(json: &Json) -> Result<FaultPlan, String> {
        let mut plan = FaultPlan::default();
        if let Json::Obj(map) = json {
            for key in map.keys() {
                match key.as_str() {
                    "panic_at_solve" | "slow_solve_ms" | "nan_grad_at_solve"
                    | "drop_after_lines" | "kill_after_step" | "truncate_checkpoint"
                    | "repl_flip_digest_at" | "seed" => {}
                    other => return Err(format!("fault plan: unknown field `{other}`")),
                }
            }
        } else {
            return Err("fault plan: expected a JSON object".to_string());
        }
        let u64_field = |name: &str| -> Result<Option<u64>, String> {
            match json.field(name) {
                None | Some(Json::Null) => Ok(None),
                Some(v) => {
                    let n = v
                        .as_f64()
                        .ok_or_else(|| format!("fault plan: `{name}` must be a number"))?;
                    if n < 0.0 || n.fract() != 0.0 {
                        return Err(format!("fault plan: `{name}` must be a non-negative integer"));
                    }
                    Ok(Some(n as u64))
                }
            }
        };
        plan.panic_at_solve = u64_field("panic_at_solve")?;
        plan.slow_solve_ms = u64_field("slow_solve_ms")?.unwrap_or(0);
        plan.nan_grad_at_solve = u64_field("nan_grad_at_solve")?;
        plan.drop_after_lines = u64_field("drop_after_lines")?;
        plan.kill_after_step = u64_field("kill_after_step")?;
        plan.truncate_checkpoint = u64_field("truncate_checkpoint")?;
        plan.repl_flip_digest_at = u64_field("repl_flip_digest_at")?;
        plan.seed = u64_field("seed")?.unwrap_or(0x5EED);
        Ok(plan)
    }

    /// Parse from a JSON source string (file contents or an inline CLI
    /// argument).
    pub fn parse_str(src: &str) -> Result<FaultPlan, String> {
        let json = Json::parse(src).map_err(|e| format!("fault plan: {e}"))?;
        FaultPlan::parse(&json)
    }
}

/// One relaxed load on every hook; everything else lives behind it.
static ACTIVE: AtomicBool = AtomicBool::new(false);
static SOLVE_COUNT: AtomicU64 = AtomicU64::new(0);
static CKPT_WRITE_COUNT: AtomicU64 = AtomicU64::new(0);
static REPL_SHIP_COUNT: AtomicU64 = AtomicU64::new(0);
static JITTER_STATE: AtomicU64 = AtomicU64::new(0);
static PLAN: Mutex<Option<FaultPlan>> = Mutex::new(None);

/// Is a fault plan armed? A single relaxed atomic load — the only cost
/// production code pays when chaos is off.
#[inline]
pub fn enabled() -> bool {
    ACTIVE.load(Ordering::Relaxed)
}

/// Arm `plan`. Resets the solve counter and re-seeds the jitter stream so
/// scenarios replay deterministically.
pub fn install(plan: FaultPlan) {
    SOLVE_COUNT.store(0, Ordering::Relaxed);
    CKPT_WRITE_COUNT.store(0, Ordering::Relaxed);
    REPL_SHIP_COUNT.store(0, Ordering::Relaxed);
    JITTER_STATE.store(plan.seed | 1, Ordering::Relaxed);
    *PLAN.lock().unwrap() = Some(plan);
    ACTIVE.store(true, Ordering::Relaxed);
}

/// Disarm. Hooks become the single disabled-branch again.
pub fn clear() {
    ACTIVE.store(false, Ordering::Relaxed);
    *PLAN.lock().unwrap() = None;
    SOLVE_COUNT.store(0, Ordering::Relaxed);
    CKPT_WRITE_COUNT.store(0, Ordering::Relaxed);
    REPL_SHIP_COUNT.store(0, Ordering::Relaxed);
}

/// A snapshot of the armed plan, if any.
pub fn current() -> Option<FaultPlan> {
    if !enabled() {
        return None;
    }
    PLAN.lock().unwrap().clone()
}

fn next_jitter_ms(bound: u64) -> u64 {
    if bound == 0 {
        return 0;
    }
    // xorshift64 over a shared atomic: deterministic for the serialized
    // chaos tests, and only ever touched while a plan is armed.
    let mut x = JITTER_STATE.load(Ordering::Relaxed);
    x ^= x << 13;
    x ^= x >> 7;
    x ^= x << 17;
    JITTER_STATE.store(x, Ordering::Relaxed);
    x % bound
}

/// Per-solve faults resolved by [`on_solve`].
#[derive(Clone, Copy, Debug, Default)]
pub struct SolveFaults {
    /// Poison the first gradient entry of this solve with NaN.
    pub corrupt_grad: bool,
}

/// Called at the top of every FISTA solve. May sleep (slow-solve plans)
/// or panic (panic-at-nth-solve plans); otherwise reports which in-solve
/// faults apply.
#[inline]
pub fn on_solve() -> SolveFaults {
    if !enabled() {
        return SolveFaults::default();
    }
    on_solve_armed()
}

#[cold]
fn on_solve_armed() -> SolveFaults {
    let Some(plan) = current() else { return SolveFaults::default() };
    let nth = SOLVE_COUNT.fetch_add(1, Ordering::Relaxed) + 1;
    if plan.slow_solve_ms > 0 {
        obsreg::FAULT_INJECTIONS.inc();
        let jitter = next_jitter_ms(plan.slow_solve_ms / 4 + 1);
        std::thread::sleep(std::time::Duration::from_millis(plan.slow_solve_ms + jitter));
    }
    if plan.panic_at_solve == Some(nth) {
        obsreg::FAULT_INJECTIONS.inc();
        panic!("fault injection: planned panic at solve {nth}");
    }
    let corrupt_grad = plan.nan_grad_at_solve == Some(nth);
    if corrupt_grad {
        obsreg::FAULT_INJECTIONS.inc();
    }
    SolveFaults { corrupt_grad }
}

/// Connection-drop trigger for the serve loop: `Some(n)` means the
/// handler should sever the stream after the n-th request line.
#[inline]
pub fn drop_after_lines() -> Option<u64> {
    if !enabled() {
        return None;
    }
    current().and_then(|p| p.drop_after_lines)
}

/// Called by the path driver after σ-step `step` (1-based) completes —
/// and, in a checkpointed fit, after that step's snapshot is on disk.
/// Panics when an armed plan says to kill here: the unwind crosses
/// `main`, so the CLI process dies non-zero, while in-process chaos tests
/// catch it with `catch_unwind`.
#[inline]
pub fn on_path_step(step: u64) {
    if !enabled() {
        return;
    }
    on_path_step_armed(step);
}

#[cold]
fn on_path_step_armed(step: u64) {
    let Some(plan) = current() else { return };
    if plan.kill_after_step == Some(step) {
        obsreg::FAULT_INJECTIONS.inc();
        panic!("fault injection: planned kill after path step {step}");
    }
}

/// Called by the checkpoint writer after each successful atomic write.
/// On the n-th write of an armed `truncate_checkpoint` plan, truncates
/// the fresh snapshot to half its length — simulating a torn write the
/// loader must reject by digest and recover from via `<path>.prev`.
#[inline]
pub fn on_checkpoint_write(path: &std::path::Path) {
    if !enabled() {
        return;
    }
    on_checkpoint_write_armed(path);
}

#[cold]
fn on_checkpoint_write_armed(path: &std::path::Path) {
    let Some(plan) = current() else { return };
    let Some(nth) = plan.truncate_checkpoint else { return };
    let count = CKPT_WRITE_COUNT.fetch_add(1, Ordering::Relaxed) + 1;
    if count == nth {
        obsreg::FAULT_INJECTIONS.inc();
        if let Ok(meta) = std::fs::metadata(path) {
            if let Ok(f) = std::fs::OpenOptions::new().write(true).open(path) {
                let _ = f.set_len(meta.len() / 2);
            }
        }
    }
}

/// Called by the registry once per journal record shipped to replication
/// subscribers. Returns `true` when an armed `repl_flip_digest_at` plan
/// says this shipment's digest should be corrupted on the wire.
#[inline]
pub fn on_repl_ship() -> bool {
    if !enabled() {
        return false;
    }
    on_repl_ship_armed()
}

#[cold]
fn on_repl_ship_armed() -> bool {
    let Some(plan) = current() else { return false };
    let Some(nth) = plan.repl_flip_digest_at else { return false };
    let count = REPL_SHIP_COUNT.fetch_add(1, Ordering::Relaxed) + 1;
    if count == nth {
        obsreg::FAULT_INJECTIONS.inc();
        return true;
    }
    false
}

#[cfg(test)]
mod tests {
    use super::*;

    // The registry is process-global; tests that arm it must serialize.
    // The chaos integration suite holds its own lock — these unit tests
    // share one too.
    static LOCK: Mutex<()> = Mutex::new(());

    #[test]
    fn disabled_hooks_are_inert() {
        let _g = LOCK.lock().unwrap();
        clear();
        assert!(!enabled());
        assert!(!on_solve().corrupt_grad);
        assert_eq!(drop_after_lines(), None);
    }

    #[test]
    fn parse_accepts_partial_plans_and_rejects_junk() {
        let plan = FaultPlan::parse_str(r#"{"panic_at_solve": 2, "seed": 9}"#).unwrap();
        assert_eq!(plan.panic_at_solve, Some(2));
        assert_eq!(plan.slow_solve_ms, 0);
        assert_eq!(plan.seed, 9);
        assert!(FaultPlan::parse_str(r#"{"panic_at_solve": -1}"#).is_err());
        assert!(FaultPlan::parse_str(r#"{"explode": true}"#).is_err());
        assert!(FaultPlan::parse_str("[1,2]").is_err());
    }

    #[test]
    fn kill_after_step_panics_at_the_named_step_only() {
        let _g = LOCK.lock().unwrap();
        install(FaultPlan { kill_after_step: Some(3), ..FaultPlan::default() });
        on_path_step(1);
        on_path_step(2);
        let hit = std::panic::catch_unwind(|| on_path_step(3));
        clear();
        let err = hit.expect_err("step 3 must kill");
        let msg = err.downcast_ref::<String>().cloned().unwrap_or_default();
        assert!(msg.contains("planned kill after path step 3"), "got: {msg}");
    }

    #[test]
    fn truncate_checkpoint_halves_the_nth_write() {
        let _g = LOCK.lock().unwrap();
        let dir = std::env::temp_dir().join(format!("slope-fault-{}-trunc", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("snap.bin");
        install(FaultPlan { truncate_checkpoint: Some(2), ..FaultPlan::default() });
        std::fs::write(&path, vec![7u8; 64]).unwrap();
        on_checkpoint_write(&path);
        assert_eq!(std::fs::metadata(&path).unwrap().len(), 64, "write 1 untouched");
        std::fs::write(&path, vec![7u8; 64]).unwrap();
        on_checkpoint_write(&path);
        assert_eq!(std::fs::metadata(&path).unwrap().len(), 32, "write 2 truncated");
        std::fs::write(&path, vec![7u8; 64]).unwrap();
        on_checkpoint_write(&path);
        assert_eq!(std::fs::metadata(&path).unwrap().len(), 64, "write 3 untouched");
        clear();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn parse_accepts_checkpoint_fault_fields() {
        let plan =
            FaultPlan::parse_str(r#"{"kill_after_step": 3, "truncate_checkpoint": 1}"#).unwrap();
        assert_eq!(plan.kill_after_step, Some(3));
        assert_eq!(plan.truncate_checkpoint, Some(1));
    }

    #[test]
    fn repl_ship_flips_the_nth_shipment_only() {
        let _g = LOCK.lock().unwrap();
        install(FaultPlan { repl_flip_digest_at: Some(2), ..FaultPlan::default() });
        assert!(!on_repl_ship(), "shipment 1 clean");
        assert!(on_repl_ship(), "shipment 2 corrupted");
        assert!(!on_repl_ship(), "shipment 3 clean again");
        clear();
        assert!(!on_repl_ship(), "disarmed registry is inert");
    }

    #[test]
    fn nth_solve_triggers_fire_once_in_order() {
        let _g = LOCK.lock().unwrap();
        install(FaultPlan { nan_grad_at_solve: Some(2), ..FaultPlan::default() });
        assert!(!on_solve().corrupt_grad, "solve 1 clean");
        assert!(on_solve().corrupt_grad, "solve 2 poisoned");
        assert!(!on_solve().corrupt_grad, "solve 3 clean again");
        // Re-install resets the counter.
        install(FaultPlan { nan_grad_at_solve: Some(1), ..FaultPlan::default() });
        assert!(on_solve().corrupt_grad);
        clear();
    }
}

//! Minimal JSON substrate (no `serde` offline).
//!
//! A small value model ([`Json`]), a recursive-descent parser, and a
//! writer. Used for the artifact `manifest.json` handshake between
//! `python/compile/aot.py` and the Rust runtime, and for experiment result
//! files under `results/`.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[allow(clippy::inherent_to_string)] // no Display on purpose: to_string is the one serializer
impl Json {
    /// Parse a JSON document.
    pub fn parse(src: &str) -> Result<Json, String> {
        let mut p = Parser { src: src.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.src.len() {
            return Err(format!("trailing garbage at byte {}", p.pos));
        }
        Ok(v)
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if !x.is_finite() {
                    // JSON has no NaN/Infinity tokens; `null` keeps the
                    // emitted line parseable (protocol responses must
                    // never poison an NDJSON stream).
                    out.push_str("null");
                } else if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(xs) => {
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    /// Object field accessor.
    pub fn field(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Array items.
    pub fn items(&self) -> &[Json] {
        match self {
            Json::Arr(xs) => xs,
            _ => &[],
        }
    }

    /// Numeric accessor.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// Usize accessor.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    /// String accessor.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Build an object from pairs (builder convenience).
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Build an array of numbers.
    pub fn nums(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    src: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.src.len() && self.src[self.pos].is_ascii_whitespace() {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.src.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", b as char, self.pos))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected byte at {}", self.pos)),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.src[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.pos))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.pos += 1;
            } else {
                break;
            }
        }
        let text = std::str::from_utf8(&self.src[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|e| format!("bad number '{text}': {e}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.src.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex =
                                std::str::from_utf8(&self.src[self.pos + 1..self.pos + 5])
                                    .map_err(|_| "bad \\u escape")?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| "bad \\u escape")?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err("bad escape".into()),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.src[self.pos..])
                        .map_err(|_| "invalid utf-8")?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut xs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(xs));
        }
        loop {
            xs.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(xs));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.pos)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.pos)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn roundtrip_nested() {
        let src = r#"{"a": [1, 2, {"b": "x", "c": null}], "d": true}"#;
        let v = Json::parse(src).unwrap();
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn accessors() {
        let v = Json::parse(r#"{"n": 42, "name": "golub", "xs": [1.5, 2.5]}"#).unwrap();
        assert_eq!(v.field("n").unwrap().as_usize(), Some(42));
        assert_eq!(v.field("name").unwrap().as_str(), Some("golub"));
        assert_eq!(v.field("xs").unwrap().items().len(), 2);
        assert!(v.field("missing").is_none());
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(Json::parse("\"\\u0041\"").unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn integers_serialize_without_fraction() {
        assert_eq!(Json::Num(5.0).to_string(), "5");
        assert_eq!(Json::Num(5.25).to_string(), "5.25");
    }

    #[test]
    fn builder_helpers() {
        let v = Json::obj(vec![("k", Json::nums(&[1.0, 2.0]))]);
        assert_eq!(v.to_string(), r#"{"k":[1,2]}"#);
    }

    // --- serve-protocol round-trip guarantees ---------------------------
    // The serve layer frames every request/response as one JSON line, so
    // parse → to_string → parse must be the identity on everything the
    // protocol can carry.

    #[test]
    fn roundtrip_escapes_and_unicode() {
        let v = Json::obj(vec![(
            "text",
            Json::Str("line1\nline2\ttab \"quoted\" back\\slash \r bell\u{7} é λ ↓".into()),
        )]);
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(re, v);
        // and a parse-first direction with \u escapes in the source
        let src = r#"{"a": "x é \n \\ \" / y", "b": "AZ"}"#;
        let v1 = Json::parse(src).unwrap();
        let v2 = Json::parse(&v1.to_string()).unwrap();
        assert_eq!(v1, v2);
        assert_eq!(v1.field("b").unwrap().as_str(), Some("AZ"));
    }

    #[test]
    fn roundtrip_numeric_precision() {
        // Shortest-roundtrip float formatting must reparse to identical
        // bits; integers must survive the integer fast path.
        let vals = [
            0.1,
            2.0 / 3.0,
            1e-300,
            -1.5e300,
            123_456_789.123_456_79,
            f64::MIN_POSITIVE,
            -0.0,
            0.0,
            1.0,
            -42.0,
            999_999_999_999_999.0,   // just below the 1e15 integer cutoff
            9_007_199_254_740_992.0, // 2^53, above the cutoff
            f64::EPSILON,
        ];
        for &x in &vals {
            let v = Json::Num(x);
            let re = Json::parse(&v.to_string()).unwrap();
            match re {
                Json::Num(y) => assert_eq!(
                    y, x,
                    "value {x:?} reparsed as {y:?} (serialized {})",
                    v.to_string()
                ),
                other => panic!("non-numeric reparse: {other:?}"),
            }
        }
    }

    #[test]
    fn non_finite_serializes_as_null() {
        for x in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            let s = Json::obj(vec![("v", Json::Num(x))]).to_string();
            assert_eq!(s, r#"{"v":null}"#);
            // the emitted line stays valid JSON
            assert!(Json::parse(&s).is_ok());
        }
    }

    #[test]
    fn roundtrip_deeply_nested() {
        let mut v = Json::Num(1.0);
        for i in 0..40 {
            v = Json::obj(vec![
                ("level", Json::Num(i as f64)),
                ("child", Json::Arr(vec![v, Json::Null, Json::Bool(i % 2 == 0)])),
                ("empty_obj", Json::Obj(std::collections::BTreeMap::new())),
                ("empty_arr", Json::Arr(Vec::new())),
            ]);
        }
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(re, v);
    }

    #[test]
    fn roundtrip_fixed_protocol_document() {
        let src = r#"{"id": 3, "op": "fit_path", "dataset": {"kind": "inline", "x": [[1.5, -2.25], [0.0, 3.0]], "y": [1, 0]}, "q": 0.05, "nested": [{"deep": [true, false, null, "s\ttr"]}]}"#;
        let v1 = Json::parse(src).unwrap();
        let s = v1.to_string();
        let v2 = Json::parse(&s).unwrap();
        assert_eq!(v1, v2);
        // second serialization is a fixed point
        assert_eq!(s, v2.to_string());
    }
}

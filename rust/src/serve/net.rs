//! Event-driven TCP transport: one non-blocking `poll(2)` loop owns the
//! listener and every connection (DESIGN.md §14).
//!
//! The Unix-socket transport spawns a thread per connection, which is
//! the right trade for a handful of local clients but collapses under
//! fan-in: a thousand mostly-idle TCP peers would pin a thousand stacks
//! just to park in `read()`. Here connection state is data, not threads:
//!
//! * **the poll loop** — accepts, reads readiness-driven bytes into
//!   per-connection buffers, splits NDJSON lines (through the same byte
//!   cap and typed `oversized_line` error as the blocking framing), and
//!   writes queued responses back under `POLLOUT`;
//! * **a bounded dispatcher pool** — runs [`Server::handle_line`] for
//!   complete request lines. At most one request per connection is in
//!   flight at a time, so per-connection ordering is exactly the
//!   blocking transports'; responses come back through a completion
//!   queue and a self-pipe wakes the poll loop;
//! * **write backpressure** — a peer that stops reading accumulates
//!   response bytes; past a high-water mark the connection's reads are
//!   paused (`POLLIN` dropped, counted by `serve_write_backpressure`)
//!   until the kernel drains the buffer. A slow reader throttles itself
//!   — it stops feeding new requests into the admission queue, which is
//!   precisely the signal the load-shedding path keys off — instead of
//!   growing an unbounded response queue server-side.
//!
//! Connections past [`ServerConfig::max_conns`](super::ServerConfig) are
//! refused at accept with a typed `overload` close, the same policy as
//! the Unix transport. Shutdown drains deterministically: in-flight
//! requests finish, every response buffer is flushed (bounded), then
//! connections are closed and the dispatchers joined.

use std::collections::{HashMap, VecDeque};
use std::io::{ErrorKind, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::os::unix::io::AsRawFd;
use std::os::unix::net::UnixStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::jsonio::Json;
use crate::obs::registry as obsreg;

use super::error::ServeError;
use super::protocol;
use super::registry::{self, ReplSubscriber};
use super::server::Server;

/// Poll timeout: bounds how stale the shutdown/drain check can get when
/// no fd is ready.
const POLL_TICK_MS: i32 = 50;
/// Heartbeat cadence on replication connections: often enough that a
/// standby's loss detector (multiples of its own timeout) reacts within
/// a couple of seconds, rare enough to be free.
const HEARTBEAT_MS: u64 = 500;
/// Read chunk size per `read()` call.
const READ_CHUNK: usize = 64 << 10;
/// Reads per connection per tick — bounds how long one flooding peer
/// can hold the loop (level-triggered poll re-reports the remainder).
const READS_PER_TICK: usize = 4;
/// Response backlog (bytes) past which a connection's reads are paused.
const HIGH_WATER: usize = 256 << 10;
/// Bound on the drain phase at shutdown: a peer that never reads its
/// last response cannot hold the server open forever.
const DRAIN_LIMIT: Duration = Duration::from_secs(30);

// Hand-rolled poll(2) binding: the repo links no external crates, and
// the four constants below are identical across the Unix ABIs we build
// on (Linux, the BSDs, macOS).
#[repr(C)]
struct PollFd {
    fd: i32,
    events: i16,
    revents: i16,
}

const POLLIN: i16 = 0x001;
const POLLOUT: i16 = 0x004;
const POLLERR: i16 = 0x008;
const POLLHUP: i16 = 0x010;

#[cfg(target_os = "linux")]
type NFds = std::os::raw::c_ulong;
#[cfg(not(target_os = "linux"))]
type NFds = std::os::raw::c_uint;

extern "C" {
    fn poll(fds: *mut PollFd, nfds: NFds, timeout: std::os::raw::c_int) -> std::os::raw::c_int;
}

fn poll_fds(fds: &mut [PollFd], timeout_ms: i32) -> std::io::Result<usize> {
    loop {
        let rc = unsafe { poll(fds.as_mut_ptr(), fds.len() as NFds, timeout_ms) };
        if rc >= 0 {
            return Ok(rc as usize);
        }
        let err = std::io::Error::last_os_error();
        if err.kind() != ErrorKind::Interrupted {
            return Err(err);
        }
    }
}

/// One parsed unit from a connection's read buffer, queued in arrival
/// order so responses keep the blocking transports' sequencing.
enum Item {
    /// A complete request line (trimmed, non-empty).
    Line(String),
    /// An over-cap line was drained; carries the bytes seen.
    Oversized(usize),
}

/// Per-connection state: plain data owned by the poll loop.
struct Conn {
    stream: TcpStream,
    /// Bytes read but not yet split into complete lines.
    inbuf: Vec<u8>,
    /// Response bytes the kernel has not yet accepted (`outpos` marks
    /// the written prefix; compacted when it grows).
    outbuf: Vec<u8>,
    outpos: usize,
    /// Parsed items waiting their turn.
    pending: VecDeque<Item>,
    /// A dispatcher is running this connection's current request.
    inflight: bool,
    /// Reads paused: response backlog crossed [`HIGH_WATER`].
    paused: bool,
    /// Mid-drain of an over-cap line; counts bytes discarded so far.
    oversized: Option<usize>,
    /// Peer half-closed its write side (we may still owe responses).
    read_closed: bool,
    /// Unrecoverable I/O error or injected drop: remove ASAP.
    dead: bool,
    /// Request lines dispatched (for the `drop_after_lines` fault).
    lines_handled: u64,
    /// Fault plan captured at accept, mirroring the blocking transport
    /// reading it once per connection.
    drop_after: Option<u64>,
    /// A `repl_subscribe` handshake succeeded: this connection carries
    /// raw journal frames from this queue instead of NDJSON responses.
    replica: Option<Arc<ReplSubscriber>>,
    /// Last heartbeat frame queued (replica connections only).
    last_hb: Instant,
    /// Last observed traffic in either direction — the idle reaper's
    /// clock.
    last_activity: Instant,
}

impl Conn {
    fn new(stream: TcpStream) -> Conn {
        Conn {
            stream,
            inbuf: Vec::new(),
            outbuf: Vec::new(),
            outpos: 0,
            pending: VecDeque::new(),
            inflight: false,
            paused: false,
            oversized: None,
            read_closed: false,
            dead: false,
            lines_handled: 0,
            drop_after: crate::fault::drop_after_lines(),
            replica: None,
            last_hb: Instant::now(),
            last_activity: Instant::now(),
        }
    }

    fn out_len(&self) -> usize {
        self.outbuf.len() - self.outpos
    }

    fn push_response(&mut self, line: &str) {
        self.outbuf.extend_from_slice(line.as_bytes());
        self.outbuf.push(b'\n');
        self.last_activity = Instant::now();
    }

    /// Drain readable bytes (bounded per tick) and split complete items.
    fn read_some(&mut self, max_line: usize) {
        let mut chunk = [0u8; READ_CHUNK];
        for _ in 0..READS_PER_TICK {
            match self.stream.read(&mut chunk) {
                Ok(0) => {
                    self.read_closed = true;
                    return;
                }
                Ok(n) => {
                    self.last_activity = Instant::now();
                    self.ingest(&chunk[..n], max_line);
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => return,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(_) => {
                    self.dead = true;
                    return;
                }
            }
        }
    }

    /// Append bytes and split out complete lines, enforcing the same
    /// byte cap as `read_line_capped`: an over-cap line is discarded as
    /// it streams (never buffered whole) and queued as an `Oversized`
    /// marker carrying its observed length.
    fn ingest(&mut self, bytes: &[u8], max_line: usize) {
        self.inbuf.extend_from_slice(bytes);
        loop {
            if let Some(skip) = self.oversized {
                match self.inbuf.iter().position(|&b| b == b'\n') {
                    Some(pos) => {
                        self.oversized = None;
                        self.pending.push_back(Item::Oversized(skip + pos));
                        self.inbuf.drain(..=pos);
                    }
                    None => {
                        self.oversized = Some(skip + self.inbuf.len());
                        self.inbuf.clear();
                        return;
                    }
                }
                continue;
            }
            match self.inbuf.iter().position(|&b| b == b'\n') {
                Some(pos) if pos > max_line => {
                    self.pending.push_back(Item::Oversized(pos));
                    self.inbuf.drain(..=pos);
                }
                Some(pos) => {
                    let text = String::from_utf8_lossy(&self.inbuf[..pos]).trim().to_string();
                    self.inbuf.drain(..=pos);
                    if !text.is_empty() {
                        self.pending.push_back(Item::Line(text));
                    }
                }
                None => {
                    if self.inbuf.len() > max_line {
                        self.oversized = Some(self.inbuf.len());
                        self.inbuf.clear();
                    }
                    return;
                }
            }
        }
    }

    /// Push buffered response bytes to the kernel until it pushes back.
    fn try_write(&mut self) {
        while self.outpos < self.outbuf.len() {
            match self.stream.write(&self.outbuf[self.outpos..]) {
                Ok(0) => {
                    self.dead = true;
                    return;
                }
                Ok(n) => self.outpos += n,
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(_) => {
                    self.dead = true;
                    return;
                }
            }
        }
        if self.outpos == self.outbuf.len() {
            self.outbuf.clear();
            self.outpos = 0;
        } else if self.outpos > READ_CHUNK {
            self.outbuf.drain(..self.outpos);
            self.outpos = 0;
        }
    }

    /// Pause reads past the high-water mark; resume once the kernel has
    /// drained the backlog to half of it (hysteresis, so a peer on the
    /// boundary does not flap the counter).
    fn update_backpressure(&mut self) {
        let backlog = self.out_len();
        if !self.paused && backlog > HIGH_WATER {
            self.paused = true;
            obsreg::SERVE_WRITE_BACKPRESSURE.inc();
        } else if self.paused && backlog <= HIGH_WATER / 2 {
            self.paused = false;
        }
    }
}

/// State shared between the poll loop and the dispatcher pool.
struct Shared {
    /// Complete request lines waiting for a dispatcher.
    requests: Mutex<VecDeque<(u64, String)>>,
    cv: Condvar,
    /// Finished responses waiting for the poll loop.
    responses: Mutex<Vec<(u64, String)>>,
    /// Self-pipe write end: dispatchers nudge the poll loop out of its
    /// timeout when a response lands.
    wake: Mutex<UnixStream>,
    stop: AtomicBool,
}

impl Shared {
    fn wake(&self) {
        // Non-blocking: a full pipe already guarantees a pending wakeup.
        let _ = self.wake.lock().unwrap().write(&[1]);
    }
}

fn dispatcher(server: Arc<Server>, shared: Arc<Shared>) {
    loop {
        let job = {
            let mut q = shared.requests.lock().unwrap();
            loop {
                if let Some(job) = q.pop_front() {
                    break Some(job);
                }
                if shared.stop.load(Ordering::SeqCst) {
                    break None;
                }
                q = shared.cv.wait(q).unwrap();
            }
        };
        let Some((conn_id, line)) = job else { return };
        let response = server.handle_line(&line);
        shared.responses.lock().unwrap().push((conn_id, response));
        shared.wake();
    }
}

/// Dispatcher pool width. Each in-flight request (including a batch
/// joiner parked on its gate) occupies one dispatcher, so this also
/// bounds how many requests can gather into one batch from the TCP
/// transport.
fn dispatcher_count() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).clamp(2, 16)
}

/// Serve NDJSON over TCP at `addr` (e.g. `127.0.0.1:7878`) until a
/// `shutdown` request arrives.
pub fn serve_tcp(server: &Arc<Server>, addr: &str) -> std::io::Result<()> {
    serve_tcp_listener(server, TcpListener::bind(addr)?)
}

/// [`serve_tcp`] over an already-bound listener — the CLI binds first so
/// it can announce the resolved address (`:0` picks an ephemeral port),
/// and tests bind on port 0.
pub fn serve_tcp_listener(server: &Arc<Server>, listener: TcpListener) -> std::io::Result<()> {
    serve_tcp_listener_abortable(server, listener, &Arc::new(AtomicBool::new(false)))
}

/// [`serve_tcp_listener`] with a hard-abort flag: when `abort` flips,
/// the poll loop returns immediately — no drain, no response flush, no
/// graceful anything. In-process chaos tests use it to emulate a
/// `kill -9` of the primary without forking; production entry points go
/// through [`serve_tcp`], whose flag never flips.
pub fn serve_tcp_listener_abortable(
    server: &Arc<Server>,
    listener: TcpListener,
    abort: &Arc<AtomicBool>,
) -> std::io::Result<()> {
    listener.set_nonblocking(true)?;
    let (wake_tx, wake_rx) = UnixStream::pair()?;
    wake_tx.set_nonblocking(true)?;
    wake_rx.set_nonblocking(true)?;
    let shared = Arc::new(Shared {
        requests: Mutex::new(VecDeque::new()),
        cv: Condvar::new(),
        responses: Mutex::new(Vec::new()),
        wake: Mutex::new(wake_tx),
        stop: AtomicBool::new(false),
    });
    // Journal appends happen on dispatcher threads; the wake hook gets
    // a shipped record onto the wire this tick instead of parking it
    // until the next 50 ms poll boundary.
    {
        let sh = Arc::clone(&shared);
        server.registry().set_repl_wake(Box::new(move || sh.wake()));
    }
    let mut workers = Vec::new();
    for _ in 0..dispatcher_count() {
        let srv = Arc::clone(server);
        let sh = Arc::clone(&shared);
        workers.push(std::thread::spawn(move || dispatcher(srv, sh)));
    }
    let result = poll_loop(server, &listener, &wake_rx, &shared, abort);
    shared.stop.store(true, Ordering::SeqCst);
    shared.cv.notify_all();
    for w in workers {
        let _ = w.join();
    }
    result
}

/// If `line` is a `repl_subscribe` handshake, answer it inline on the
/// poll loop: the ok response must hit the wire *before* any journal
/// frame from the subscriber queue, and the dispatcher pool cannot
/// guarantee that ordering. `None` means "not a subscribe — dispatch
/// normally".
fn try_repl_subscribe(
    server: &Server,
    line: &str,
) -> Option<Result<(String, Arc<ReplSubscriber>), String>> {
    // Cheap reject before paying for a parse on every request line.
    if !line.contains("repl_subscribe") {
        return None;
    }
    let j = Json::parse(line).ok()?;
    if j.field("op").and_then(Json::as_str) != Some("repl_subscribe") {
        return None;
    }
    let id = j.field("id").and_then(Json::as_usize).unwrap_or(0) as u64;
    let epoch = j.field("epoch").and_then(Json::as_usize).unwrap_or(0) as u64;
    Some(server.accept_replica(id, epoch))
}

/// Feed ready-to-run items into the dispatcher queue, keeping at most
/// one request per connection in flight. Oversized markers are answered
/// inline (they never ran a handler on the blocking transports either)
/// but still in arrival order relative to real requests.
fn pump_pending(c: &mut Conn, id: u64, server: &Server, shared: &Shared) {
    if c.replica.is_some() {
        // Past the handshake the peer sends nothing meaningful; any
        // stray bytes are discarded rather than parsed as NDJSON.
        c.pending.clear();
        return;
    }
    while !c.inflight && !c.dead {
        match c.pending.pop_front() {
            Some(Item::Oversized(bytes)) => {
                let response = server.oversized_response(bytes);
                c.push_response(&response);
            }
            Some(Item::Line(line)) => {
                match try_repl_subscribe(server, &line) {
                    Some(Ok((response, sub))) => {
                        // Handshake accepted: ok line first, then the
                        // connection leaves NDJSON mode for good —
                        // anything pipelined behind it is void.
                        c.push_response(&response);
                        c.replica = Some(sub);
                        c.pending.clear();
                        return;
                    }
                    Some(Err(response)) => {
                        // Refused (fenced / not primary / no journal):
                        // the connection stays a normal NDJSON client.
                        c.push_response(&response);
                        continue;
                    }
                    None => {}
                }
                if let Some(limit) = c.drop_after {
                    if c.lines_handled >= limit {
                        // Injected connection drop: sever without a
                        // response, exactly like the blocking framing.
                        obsreg::FAULT_INJECTIONS.inc();
                        c.dead = true;
                        return;
                    }
                }
                c.lines_handled += 1;
                c.inflight = true;
                shared.requests.lock().unwrap().push_back((id, line));
                shared.cv.notify_one();
            }
            None => return,
        }
    }
}

fn poll_loop(
    server: &Arc<Server>,
    listener: &TcpListener,
    wake_rx: &UnixStream,
    shared: &Shared,
    abort: &Arc<AtomicBool>,
) -> std::io::Result<()> {
    let mut conns: HashMap<u64, Conn> = HashMap::new();
    let mut next_id: u64 = 0;
    let mut draining = false;
    let mut drain_deadline = Instant::now();
    loop {
        if abort.load(Ordering::SeqCst) {
            // Emulated kill -9: drop everything on the floor. Every
            // subscriber is marked gone so the registry stops queueing
            // for connections that no longer exist.
            for c in conns.values() {
                if let Some(sub) = &c.replica {
                    sub.mark_gone();
                }
            }
            obsreg::SERVE_OPEN_CONNS.set(0);
            return Ok(());
        }
        if !draining && server.is_shutdown() {
            draining = true;
            drain_deadline = Instant::now() + DRAIN_LIMIT;
            // Lines read but not yet begun will never run — the blocking
            // transports drop exactly the same requests when they sever
            // idle connections after their drain.
            for c in conns.values_mut() {
                c.pending.clear();
            }
        }
        if draining {
            let busy = conns.values().any(|c| c.inflight || c.out_len() > 0);
            if !busy || Instant::now() >= drain_deadline {
                break;
            }
        }
        let mut fds = Vec::with_capacity(2 + conns.len());
        fds.push(PollFd { fd: wake_rx.as_raw_fd(), events: POLLIN, revents: 0 });
        fds.push(PollFd {
            fd: listener.as_raw_fd(),
            events: if draining { 0 } else { POLLIN },
            revents: 0,
        });
        let mut order = Vec::with_capacity(conns.len());
        for (&id, c) in conns.iter() {
            let mut events = 0i16;
            if !draining && !c.read_closed && !c.paused && !c.dead {
                events |= POLLIN;
            }
            if c.out_len() > 0 {
                events |= POLLOUT;
            }
            fds.push(PollFd { fd: c.stream.as_raw_fd(), events, revents: 0 });
            order.push(id);
        }
        poll_fds(&mut fds, POLL_TICK_MS)?;
        if fds[0].revents != 0 {
            let mut sink = [0u8; 256];
            while matches!((&*wake_rx).read(&mut sink), Ok(n) if n > 0) {}
        }
        // Deliver finished responses before doing I/O so a completed
        // request's bytes go out on this very tick.
        let done: Vec<(u64, String)> = std::mem::take(&mut *shared.responses.lock().unwrap());
        for (id, response) in done {
            if let Some(c) = conns.get_mut(&id) {
                c.inflight = false;
                c.push_response(&response);
                c.try_write();
                c.update_backpressure();
            }
        }
        // Replication fan-out: drain each subscriber's queue into its
        // connection buffer (bounded by the write high-water mark — a
        // standby that stops reading parks its records in the queue,
        // whose own byte cap eventually marks it gone), plus a
        // heartbeat frame on a fixed cadence so an idle primary still
        // proves liveness and publishes its epoch.
        if !draining {
            for c in conns.values_mut() {
                let Some(sub) = &c.replica else { continue };
                if sub.is_gone() {
                    c.dead = true;
                    continue;
                }
                while c.out_len() < HIGH_WATER {
                    match sub.pop() {
                        Some(chunk) => c.outbuf.extend_from_slice(&chunk),
                        None => break,
                    }
                }
                if c.last_hb.elapsed() >= Duration::from_millis(HEARTBEAT_MS) {
                    c.last_hb = Instant::now();
                    let frame = registry::heartbeat_frame(
                        server.epoch(),
                        server.registry().journal_records_total(),
                    );
                    c.outbuf.extend_from_slice(&frame);
                }
                if c.out_len() > 0 {
                    c.try_write();
                }
            }
        }
        if !draining && fds[1].revents != 0 {
            loop {
                match listener.accept() {
                    Ok((stream, _addr)) => {
                        if conns.len() >= server.max_conns() {
                            // Accept-time admission control, shared with
                            // the Unix transport: a typed `overload`
                            // close the client backoff understands.
                            obsreg::SERVE_CONN_LIMIT_REJECTED.inc();
                            let mut stream = stream;
                            let err = ServeError::Overload { retry_after_ms: 1000 };
                            let _ =
                                stream.write_all(protocol::error_response(0, &err).as_bytes());
                            let _ = stream.write_all(b"\n");
                            continue;
                        }
                        let _ = stream.set_nodelay(true);
                        if stream.set_nonblocking(true).is_err() {
                            continue;
                        }
                        obsreg::SERVE_TCP_ACCEPTS.inc();
                        let id = next_id;
                        next_id += 1;
                        conns.insert(id, Conn::new(stream));
                    }
                    Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                    // Transient accept failures (ECONNABORTED, fd
                    // pressure): try again next tick.
                    Err(_) => break,
                }
            }
        }
        for (i, &id) in order.iter().enumerate() {
            let revents = fds[2 + i].revents;
            if revents == 0 {
                continue;
            }
            let Some(c) = conns.get_mut(&id) else { continue };
            if revents & (POLLERR | POLLHUP) != 0 && revents & POLLIN == 0 {
                c.dead = true;
                continue;
            }
            if revents & POLLIN != 0 {
                c.read_some(server.max_line_bytes());
            }
            if revents & POLLOUT != 0 {
                c.try_write();
            }
            c.update_backpressure();
        }
        let idle_ms = server.idle_timeout_ms();
        let mut gone: Vec<u64> = Vec::new();
        for (&id, c) in conns.iter_mut() {
            if !draining {
                pump_pending(c, id, server, shared);
            }
            if c.dead && !c.inflight {
                gone.push(id);
            } else if c.read_closed && !c.inflight && c.pending.is_empty() && c.out_len() == 0 {
                gone.push(id);
            } else if idle_ms > 0
                && !draining
                && !c.inflight
                && c.replica.is_none()
                && c.pending.is_empty()
                && c.out_len() == 0
                && c.last_activity.elapsed() >= Duration::from_millis(idle_ms)
            {
                // Idle reaper: a connection with nothing read, queued,
                // or owed for the whole window is closed so abandoned
                // peers cannot accumulate fds. Requests in flight are
                // exempt (a slow fit is not idleness) and replication
                // connections keep themselves warm via heartbeats.
                obsreg::SERVE_IDLE_REAPED.inc();
                gone.push(id);
            }
        }
        for id in gone {
            if let Some(c) = conns.remove(&id) {
                if let Some(sub) = c.replica {
                    sub.mark_gone();
                }
            }
        }
        obsreg::SERVE_OPEN_CONNS.set(conns.len() as u64);
    }
    // Every handler has delivered (the drain loop above waited on
    // inflight and flush); make the scheduler's drain barrier explicit
    // anyway so the transports share one contract.
    server.await_jobs_idle();
    obsreg::SERVE_OPEN_CONNS.set(0);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::jsonio::Json;
    use crate::serve::{Server, ServerConfig};
    use std::io::{BufRead, BufReader};

    fn spawn_server(
        cfg: ServerConfig,
    ) -> (Arc<Server>, std::net::SocketAddr, std::thread::JoinHandle<std::io::Result<()>>) {
        let srv = Arc::new(Server::new(cfg));
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let srv2 = Arc::clone(&srv);
        let handle = std::thread::spawn(move || serve_tcp_listener(&srv2, listener));
        (srv, addr, handle)
    }

    fn fit_path_line(id: u64, seed: u64) -> String {
        protocol::request_line(
            id,
            "fit_path",
            vec![
                ("dataset", protocol::synth_dataset_json(30, 60, 4, 0.2, "gaussian", seed)),
                ("q", Json::Num(0.1)),
                ("path_length", Json::Num(8.0)),
            ],
        )
    }

    #[test]
    fn tcp_round_trip_pipelined_in_order_with_graceful_shutdown() {
        let (_srv, addr, handle) = spawn_server(ServerConfig {
            threads: 2,
            queue: 8,
            cache: true,
            ..Default::default()
        });
        let stream = TcpStream::connect(addr).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut writer = stream;
        // Two pipelined requests on one connection: answered in order
        // even though the fit is slow and stats is instant.
        writer
            .write_all(
                format!("{}\n{}\n", fit_path_line(1, 31), r#"{"id": 2, "op": "stats"}"#)
                    .as_bytes(),
            )
            .unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let first = Json::parse(line.trim()).unwrap();
        assert_eq!(first.field("id").unwrap().as_usize(), Some(1));
        assert_eq!(first.field("ok"), Some(&Json::Bool(true)));
        line.clear();
        reader.read_line(&mut line).unwrap();
        let second = Json::parse(line.trim()).unwrap();
        assert_eq!(second.field("id").unwrap().as_usize(), Some(2));
        assert_eq!(second.field("ok"), Some(&Json::Bool(true)));
        // Shutdown: the response is flushed before the server closes,
        // then the connection sees a clean EOF and the loop exits.
        writer.write_all(b"{\"id\": 3, \"op\": \"shutdown\"}\n").unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        assert_eq!(Json::parse(line.trim()).unwrap().field("ok"), Some(&Json::Bool(true)));
        handle.join().unwrap().unwrap();
        line.clear();
        assert_eq!(reader.read_line(&mut line).unwrap(), 0, "expected EOF after drain");
    }

    #[test]
    fn tcp_oversized_line_gets_typed_error_and_connection_survives() {
        let (_srv, addr, handle) = spawn_server(ServerConfig {
            threads: 2,
            queue: 8,
            cache: true,
            max_line_bytes: 4096,
            ..Default::default()
        });
        let stream = TcpStream::connect(addr).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut writer = stream;
        let big =
            format!("{{\"id\": 1, \"op\": \"stats\", \"pad\": \"{}\"}}", "x".repeat(10_000));
        writer
            .write_all(format!("{big}\n{}\n", r#"{"id": 2, "op": "stats"}"#).as_bytes())
            .unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let first = Json::parse(line.trim()).unwrap();
        assert_eq!(first.field("ok"), Some(&Json::Bool(false)));
        assert_eq!(first.field("error_kind").unwrap().as_str(), Some("oversized_line"));
        // The over-cap line was discarded as it streamed; the next
        // request on the same connection is served normally.
        line.clear();
        reader.read_line(&mut line).unwrap();
        let second = Json::parse(line.trim()).unwrap();
        assert_eq!(second.field("ok"), Some(&Json::Bool(true)));
        assert_eq!(second.field("id").unwrap().as_usize(), Some(2));
        writer.write_all(b"{\"id\": 3, \"op\": \"shutdown\"}\n").unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        handle.join().unwrap().unwrap();
    }

    #[test]
    fn tcp_conn_limit_rejects_with_typed_overload_close() {
        let (_srv, addr, handle) = spawn_server(ServerConfig {
            threads: 2,
            queue: 8,
            cache: true,
            max_conns: 1,
            ..Default::default()
        });
        let first = TcpStream::connect(addr).unwrap();
        let mut first_reader = BufReader::new(first.try_clone().unwrap());
        let mut first_writer = first;
        // A full round trip proves the first connection is accepted and
        // counted before the second one races in.
        first_writer.write_all(b"{\"id\": 1, \"op\": \"stats\"}\n").unwrap();
        let mut line = String::new();
        first_reader.read_line(&mut line).unwrap();
        assert_eq!(Json::parse(line.trim()).unwrap().field("ok"), Some(&Json::Bool(true)));
        let second = TcpStream::connect(addr).unwrap();
        let mut rejected = BufReader::new(second);
        let mut rej = String::new();
        rejected.read_line(&mut rej).unwrap();
        let j = Json::parse(rej.trim()).unwrap();
        assert_eq!(j.field("ok"), Some(&Json::Bool(false)));
        assert_eq!(j.field("error_kind").unwrap().as_str(), Some("overload"));
        rej.clear();
        assert_eq!(rejected.read_line(&mut rej).unwrap(), 0, "rejected connection is closed");
        first_writer.write_all(b"{\"id\": 2, \"op\": \"shutdown\"}\n").unwrap();
        line.clear();
        first_reader.read_line(&mut line).unwrap();
        handle.join().unwrap().unwrap();
    }

    #[test]
    fn idle_connections_are_reaped() {
        let (_srv, addr, handle) = spawn_server(ServerConfig {
            threads: 2,
            queue: 8,
            cache: true,
            idle_timeout_ms: 150,
            ..Default::default()
        });
        let reaped_before = obsreg::SERVE_IDLE_REAPED.get();
        let idle = TcpStream::connect(addr).unwrap();
        let mut idle_reader = BufReader::new(idle.try_clone().unwrap());
        let mut idle_writer = idle;
        // One served request proves the connection is live, then it goes
        // quiet past the timeout.
        idle_writer.write_all(b"{\"id\": 1, \"op\": \"stats\"}\n").unwrap();
        let mut line = String::new();
        idle_reader.read_line(&mut line).unwrap();
        assert_eq!(Json::parse(line.trim()).unwrap().field("ok"), Some(&Json::Bool(true)));
        // The reaper closes it: the next read is a clean EOF (or a reset
        // if the close raced our probe), never a hang.
        line.clear();
        let got = idle_reader.read_line(&mut line);
        assert!(matches!(got, Ok(0) | Err(_)), "expected reaped connection, got {line:?}");
        assert!(
            obsreg::SERVE_IDLE_REAPED.get() > reaped_before,
            "reap must be counted"
        );
        // A fresh connection still gets served — reaping is per-idle-
        // connection, not a server state.
        let fresh = TcpStream::connect(addr).unwrap();
        let mut fresh_reader = BufReader::new(fresh.try_clone().unwrap());
        let mut fresh_writer = fresh;
        fresh_writer.write_all(b"{\"id\": 2, \"op\": \"shutdown\"}\n").unwrap();
        line.clear();
        fresh_reader.read_line(&mut line).unwrap();
        assert_eq!(Json::parse(line.trim()).unwrap().field("ok"), Some(&Json::Bool(true)));
        handle.join().unwrap().unwrap();
    }
}

//! Typed serve errors (DESIGN.md §12).
//!
//! Every failure the serve stack can produce is one of these variants,
//! so clients can branch on a stable `error_kind` string instead of
//! parsing prose: deadlines carry partial progress, overload carries a
//! `retry_after_ms` hint, worker panics carry the caught payload. The
//! protocol layer renders them through
//! [`crate::serve::protocol::error_response`].

use std::fmt;

/// A typed serve-layer failure.
#[derive(Clone, Debug, PartialEq)]
pub enum ServeError {
    /// Malformed or semantically invalid request.
    Invalid(String),
    /// The request's `deadline_ms` budget expired before the fit
    /// certified. Carries partial progress: path steps completed before
    /// cancellation and the last certified duality gap, if any.
    Deadline {
        /// The budget that expired.
        deadline_ms: u64,
        /// Path steps completed before cancellation (0 for `fit_point`).
        steps_done: usize,
        /// Last certified duality gap, when a gap-driven solve got far
        /// enough to evaluate one.
        gap: Option<f64>,
    },
    /// The server is draining: the request was rejected before running.
    Shutdown,
    /// The admission queue is full; retry after the hinted delay.
    Overload {
        /// Client backoff hint, derived from the queue depth.
        retry_after_ms: u64,
    },
    /// The fit job panicked inside a worker; the payload was caught and
    /// the job quarantined.
    Panic {
        /// Panic payload, downcast from `catch_unwind`.
        message: String,
    },
    /// This server is not the primary (a standby, or an ex-primary that
    /// observed a higher failover epoch): write requests are fenced.
    /// Clients with more than one endpoint should rotate and retry.
    Fenced {
        /// The role that rejected the write (`standby` or `fenced`).
        role: String,
        /// The failover epoch this server last observed.
        epoch: u64,
    },
    /// An NDJSON request line exceeded the configured byte cap.
    OversizedLine {
        /// Bytes received before the line was abandoned.
        bytes: usize,
        /// The configured cap.
        limit: usize,
    },
    /// Any other failure (build errors, coalesced-build failures, I/O).
    Failed(String),
}

impl ServeError {
    /// Stable machine-readable discriminator, surfaced as `error_kind`
    /// in error responses.
    pub fn kind(&self) -> &'static str {
        match self {
            ServeError::Invalid(_) => "invalid",
            ServeError::Deadline { .. } => "deadline",
            ServeError::Shutdown => "shutdown",
            ServeError::Overload { .. } => "overload",
            ServeError::Panic { .. } => "panic",
            ServeError::Fenced { .. } => "fenced",
            ServeError::OversizedLine { .. } => "oversized_line",
            ServeError::Failed(_) => "failed",
        }
    }

    /// Human-readable message for the `error` field.
    pub fn message(&self) -> String {
        match self {
            ServeError::Invalid(m) => m.clone(),
            ServeError::Deadline { deadline_ms, steps_done, .. } => format!(
                "deadline of {deadline_ms} ms expired after {steps_done} completed path steps"
            ),
            ServeError::Shutdown => "server is shutting down".to_string(),
            ServeError::Overload { retry_after_ms } => {
                format!("queue full; retry after {retry_after_ms} ms")
            }
            ServeError::Panic { message } => format!("fit job panicked: {message}"),
            ServeError::Fenced { role, epoch } => {
                format!("server is {role} at epoch {epoch}: writes are fenced (not the primary)")
            }
            ServeError::OversizedLine { bytes, limit } => {
                format!("request line exceeds {limit} bytes (got at least {bytes})")
            }
            ServeError::Failed(m) => m.clone(),
        }
    }

    /// The backoff hint, when this error carries one.
    pub fn retry_after_ms(&self) -> Option<u64> {
        match self {
            ServeError::Overload { retry_after_ms } => Some(*retry_after_ms),
            _ => None,
        }
    }

    /// Is it safe for a client to retry the *same* request? Deadline
    /// expiries are excluded: the same budget would expire again.
    pub fn retryable(&self) -> bool {
        matches!(self, ServeError::Overload { .. })
    }
}

impl fmt::Display for ServeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.message())
    }
}

// The pre-resilience serve layer reported `String` errors; these keep
// internal call sites and tests terse while everything converges on the
// typed enum.
impl From<String> for ServeError {
    fn from(m: String) -> Self {
        ServeError::Failed(m)
    }
}

impl From<&str> for ServeError {
    fn from(m: &str) -> Self {
        ServeError::Failed(m.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kinds_and_hints_are_stable() {
        assert_eq!(ServeError::Shutdown.kind(), "shutdown");
        assert_eq!(ServeError::Invalid("x".into()).kind(), "invalid");
        let over = ServeError::Overload { retry_after_ms: 120 };
        assert_eq!(over.kind(), "overload");
        assert_eq!(over.retry_after_ms(), Some(120));
        assert!(over.retryable());
        let dl = ServeError::Deadline { deadline_ms: 5, steps_done: 3, gap: Some(0.5) };
        assert_eq!(dl.kind(), "deadline");
        assert!(!dl.retryable());
        assert!(dl.message().contains("5 ms"));
        assert!(dl.message().contains("3 completed"));
        let p = ServeError::Panic { message: "kaboom".into() };
        assert!(p.message().contains("kaboom"));
        let fenced = ServeError::Fenced { role: "standby".into(), epoch: 4 };
        assert_eq!(fenced.kind(), "fenced");
        assert!(!fenced.retryable(), "rotation, not same-connection retry");
        assert!(fenced.message().contains("epoch 4"));
        assert_eq!(ServeError::from("nope").kind(), "failed");
    }
}

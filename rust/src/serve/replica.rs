//! The warm-standby replication loop (DESIGN.md §15).
//!
//! A standby server (`serve --standby <primary>`) runs this loop next
//! to its own transport: connect to the primary, send one
//! `repl_subscribe` NDJSON handshake, then read raw journal frames for
//! the rest of the connection — first the catch-up snapshot (the
//! primary's journal as of the handshake, taken under its journal lock
//! so nothing is lost or reordered), then live appends in exact journal
//! order. Every record is digest-checked and applied through the same
//! [`Registry::apply_replicated`](super::registry::Registry::apply_replicated)
//! machinery boot-time replay uses — datasets intern, seeds go hot,
//! strikes carry, epochs max-merge — and re-journaled locally, so the
//! standby's own state dir is a valid journal at every instant and a
//! promotion needs no catch-up work at all.
//!
//! Heartbeat frames carry the primary's epoch and journal record count:
//! the standby folds the epoch (a primary that somehow fell behind a
//! newer epoch fences itself via
//! [`Server::observe_remote_epoch`](super::server::Server::observe_remote_epoch)),
//! publishes the lag, and uses the heartbeats' *absence* as the loss
//! detector — after [`StandbyConfig::promote_after_misses`] consecutive
//! read timeouts or failed reconnects, the standby promotes itself when
//! `--promote-on-loss` armed it. The default leaves self-promotion off:
//! an operator (or orchestrator) issues the `promote` op explicitly.

use std::io::{ErrorKind, Read, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Duration;

use crate::ingest::{fnv1a, FNV_BASIS};
use crate::jsonio::Json;
use crate::obs::registry as obsreg;

use super::client::Backoff;
use super::server::{Role, Server};

/// Cap on one replication frame — matches the primary's per-subscriber
/// queue bound, so any larger claimed length is stream corruption, not
/// a real record.
const MAX_FRAME_BYTES: usize = 64 << 20;

/// Standby loop configuration (the `serve` CLI fills it from flags).
#[derive(Clone, Debug)]
pub struct StandbyConfig {
    /// Primary endpoints (`host:port`), tried in rotation.
    pub primaries: Vec<String>,
    /// Read timeout while waiting for a frame; one elapsed timeout with
    /// no bytes is one missed heartbeat. Should be a small multiple of
    /// the primary's ~500 ms heartbeat cadence.
    pub heartbeat_timeout_ms: u64,
    /// Missed heartbeats (or failed connects) before self-promotion.
    /// 0 (the default) disables promotion on loss — a network partition
    /// between the pair must not mint a second primary unless the
    /// operator opted into that trade.
    pub promote_after_misses: u64,
    /// Reconnect backoff base in milliseconds.
    pub reconnect_base_ms: u64,
    /// Backoff jitter seed (deterministic schedules in tests).
    pub seed: u64,
}

impl Default for StandbyConfig {
    fn default() -> StandbyConfig {
        StandbyConfig {
            primaries: Vec::new(),
            heartbeat_timeout_ms: 2_000,
            promote_after_misses: 0,
            reconnect_base_ms: 100,
            seed: 0x5eed,
        }
    }
}

/// Why one replication session ended.
enum SessionEnd {
    /// The server is shutting down or left the standby role.
    Stop,
    /// The primary refused the handshake (fenced us, or is itself a
    /// standby): rotate and back off.
    Refused,
    /// Connection failed, timed out past tolerance, or the stream
    /// corrupted beyond resync.
    Lost,
}

/// Spawn the standby loop on its own thread. It exits when the server
/// shuts down, is promoted (by the `promote` op or its own loss
/// detector), or was never configured with a primary.
pub fn spawn_standby(server: Arc<Server>, cfg: StandbyConfig) -> std::thread::JoinHandle<()> {
    std::thread::spawn(move || run_standby(&server, &cfg))
}

fn run_standby(server: &Arc<Server>, cfg: &StandbyConfig) {
    if cfg.primaries.is_empty() {
        return;
    }
    let mut backoff = Backoff::new(cfg.reconnect_base_ms.max(1), 5_000, cfg.seed);
    let mut misses: u64 = 0;
    let mut which = 0usize;
    loop {
        if server.is_shutdown() || server.role() != Role::Standby {
            return;
        }
        let addr = &cfg.primaries[which % cfg.primaries.len()];
        which += 1;
        match run_once(server, cfg, addr, &mut misses, &mut backoff) {
            SessionEnd::Stop => return,
            SessionEnd::Refused | SessionEnd::Lost => {
                if maybe_promote(server, cfg, misses) {
                    return;
                }
                let delay = backoff.next_delay_ms(None);
                std::thread::sleep(Duration::from_millis(delay));
            }
        }
    }
}

/// Promote when the loss detector is armed and tripped. Returns whether
/// this call promoted.
fn maybe_promote(server: &Server, cfg: &StandbyConfig, misses: u64) -> bool {
    if cfg.promote_after_misses == 0 || misses < cfg.promote_after_misses {
        return false;
    }
    eprintln!("serve: standby lost the primary ({misses} missed heartbeats): promoting");
    server.promote();
    true
}

/// One replication session: handshake, then stream frames until the
/// connection dies or the server leaves the standby role.
fn run_once(
    server: &Arc<Server>,
    cfg: &StandbyConfig,
    addr: &str,
    misses: &mut u64,
    backoff: &mut Backoff,
) -> SessionEnd {
    let mut stream = match TcpStream::connect(addr) {
        Ok(s) => s,
        Err(_) => {
            *misses += 1;
            obsreg::REPL_HEARTBEATS_MISSED.inc();
            return SessionEnd::Lost;
        }
    };
    let _ = stream.set_nodelay(true);
    let _ = stream
        .set_read_timeout(Some(Duration::from_millis(cfg.heartbeat_timeout_ms.max(1))));
    let hello =
        format!("{{\"id\": 0, \"op\": \"repl_subscribe\", \"epoch\": {}}}\n", server.epoch());
    if stream.write_all(hello.as_bytes()).is_err() {
        *misses += 1;
        return SessionEnd::Lost;
    }
    // Read the handshake line byte-by-byte: a buffered reader would
    // swallow the head of the framed stream that follows the newline.
    let mut line: Vec<u8> = Vec::new();
    loop {
        let mut b = [0u8; 1];
        match stream.read(&mut b) {
            Ok(0) => {
                *misses += 1;
                return SessionEnd::Lost;
            }
            Ok(_) if b[0] == b'\n' => break,
            Ok(_) => {
                line.push(b[0]);
                if line.len() > 1 << 20 {
                    return SessionEnd::Refused;
                }
            }
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(_) => {
                *misses += 1;
                obsreg::REPL_HEARTBEATS_MISSED.inc();
                return SessionEnd::Lost;
            }
        }
    }
    let Ok(resp) = Json::parse(&String::from_utf8_lossy(&line)) else {
        return SessionEnd::Refused;
    };
    if resp.field("ok") != Some(&Json::Bool(true)) {
        eprintln!(
            "serve: primary {addr} refused replication: {}",
            resp.field("error").and_then(Json::as_str).unwrap_or("unparseable handshake")
        );
        return SessionEnd::Refused;
    }
    let remote_epoch = resp
        .field("result")
        .and_then(|r| r.field("epoch"))
        .and_then(Json::as_usize)
        .unwrap_or(0) as u64;
    // The primary vouched for an epoch at least ours (it fences anything
    // newer than itself); adopt it before applying its records.
    server.observe_remote_epoch(remote_epoch);
    eprintln!("serve: replicating from {addr} (epoch {remote_epoch})");
    // Subscribed: the connection is live, so the loss counter and the
    // reconnect backoff both restart from zero.
    *misses = 0;
    *backoff = Backoff::new(cfg.reconnect_base_ms.max(1), 5_000, cfg.seed);
    let mut buf: Vec<u8> = Vec::new();
    let mut chunk = vec![0u8; 64 << 10];
    // Journal frames consumed this session (heartbeats excluded, bad
    // digests included — the primary's record count includes those too).
    let mut seen: u64 = 0;
    loop {
        if server.is_shutdown() || server.role() != Role::Standby {
            return SessionEnd::Stop;
        }
        match stream.read(&mut chunk) {
            Ok(0) => {
                *misses += 1;
                obsreg::REPL_HEARTBEATS_MISSED.inc();
                return SessionEnd::Lost;
            }
            Ok(n) => {
                *misses = 0;
                buf.extend_from_slice(&chunk[..n]);
                loop {
                    match take_frame(&mut buf) {
                        FrameOutcome::Need => break,
                        FrameOutcome::Corrupt => {
                            // Frame boundaries are gone; only a fresh
                            // handshake (and snapshot) can resync.
                            eprintln!("serve: replication stream corrupted; resubscribing");
                            return SessionEnd::Lost;
                        }
                        FrameOutcome::BadDigest => {
                            // Damaged in flight: skip exactly this
                            // record, never apply it. The journal's
                            // last-record-wins semantics make the next
                            // clean record for the same key heal it.
                            seen += 1;
                            obsreg::REPL_DIGEST_SKIPS.inc();
                        }
                        FrameOutcome::Record(rec) => {
                            if rec.field("kind").and_then(Json::as_str) == Some("heartbeat") {
                                let epoch = rec
                                    .field("epoch")
                                    .and_then(Json::as_usize)
                                    .unwrap_or(0)
                                    as u64;
                                server.observe_remote_epoch(epoch);
                                let records = rec
                                    .field("records")
                                    .and_then(Json::as_usize)
                                    .unwrap_or(0)
                                    as u64;
                                server.set_repl_lag(records.saturating_sub(seen));
                            } else {
                                seen += 1;
                                if server.registry().apply_replicated(&rec) {
                                    obsreg::REPL_RECORDS_APPLIED.inc();
                                }
                            }
                        }
                    }
                }
            }
            // A read timeout is one missed heartbeat: the primary
            // proves liveness every ~500 ms even when idle.
            Err(e)
                if e.kind() == ErrorKind::WouldBlock || e.kind() == ErrorKind::TimedOut =>
            {
                *misses += 1;
                obsreg::REPL_HEARTBEATS_MISSED.inc();
                if maybe_promote(server, cfg, *misses) {
                    return SessionEnd::Stop;
                }
            }
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(_) => {
                *misses += 1;
                obsreg::REPL_HEARTBEATS_MISSED.inc();
                return SessionEnd::Lost;
            }
        }
    }
}

/// Outcome of one attempt to take a frame off the stream buffer.
enum FrameOutcome {
    /// Not enough buffered bytes for a complete frame yet.
    Need,
    /// A complete frame whose digest and JSON both checked out.
    Record(Json),
    /// A complete frame whose payload did not match its digest (or
    /// didn't parse) — the boundary was sound, the stream continues.
    BadDigest,
    /// An implausible frame length: boundaries are unrecoverable.
    Corrupt,
}

/// Take one `[u32 len][u64 fnv1a][payload]` frame off the front of
/// `buf`, partial-read-safe (the caller accumulates whatever sizes the
/// kernel hands it).
fn take_frame(buf: &mut Vec<u8>) -> FrameOutcome {
    if buf.len() < 12 {
        return FrameOutcome::Need;
    }
    let len = u32::from_le_bytes(buf[0..4].try_into().unwrap()) as usize;
    if len > MAX_FRAME_BYTES {
        return FrameOutcome::Corrupt;
    }
    if buf.len() < 12 + len {
        return FrameOutcome::Need;
    }
    let digest = u64::from_le_bytes(buf[4..12].try_into().unwrap());
    let payload: Vec<u8> = buf[12..12 + len].to_vec();
    buf.drain(..12 + len);
    if fnv1a(FNV_BASIS, &payload) != digest {
        return FrameOutcome::BadDigest;
    }
    match std::str::from_utf8(&payload).ok().and_then(|s| Json::parse(s).ok()) {
        Some(rec) => FrameOutcome::Record(rec),
        None => FrameOutcome::BadDigest,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::registry::frame_record;

    #[test]
    fn take_frame_parses_skips_flipped_digests_and_rejects_garbage() {
        let rec = Json::obj(vec![
            ("kind", Json::Str("strikes".to_string())),
            ("fp", Json::Str("00000000000000aa".to_string())),
            ("count", Json::Num(2.0)),
        ]);
        let mut stream = frame_record(&rec);
        let mut flipped = frame_record(&rec);
        flipped[4] ^= 0x01; // the digest flip the wire fault injects
        stream.extend_from_slice(&flipped);
        stream.extend_from_slice(&frame_record(&rec));
        stream.extend_from_slice(&[7, 0, 0]); // torn tail
        let first = take_frame(&mut stream);
        match first {
            FrameOutcome::Record(j) => {
                assert_eq!(j.field("kind").and_then(Json::as_str), Some("strikes"));
            }
            _ => panic!("expected a clean record first"),
        }
        assert!(matches!(take_frame(&mut stream), FrameOutcome::BadDigest));
        assert!(matches!(take_frame(&mut stream), FrameOutcome::Record(_)));
        assert!(matches!(take_frame(&mut stream), FrameOutcome::Need));
        assert_eq!(stream.len(), 3, "torn tail stays buffered for the next read");
        // an implausible length can never resync
        let mut garbage = vec![0xffu8; 16];
        assert!(matches!(take_frame(&mut garbage), FrameOutcome::Corrupt));
    }
}

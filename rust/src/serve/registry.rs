//! Dataset/model registry with a fingerprinted warm-start cache.
//!
//! Datasets are interned by the 64-bit fingerprint of their
//! [`DatasetSpec`]; each entry holds the materialized [`Problem`] plus two
//! caches keyed by model spec:
//!
//! * fitted paths ([`CachedModel`]) with the final-point
//!   [`PathSeed`] — repeated requests are cache hits, refined requests on
//!   the same dataset warm-start from a sibling model's seed;
//! * single-point states ([`PointState`]) — a `fit_point` stream reuses
//!   the previous point's coefficients, gradient and screened support via
//!   the previous-set strategy, which is where screening pays off across
//!   requests;
//! * packed screened-column slabs ([`PackCache`], keyed by screened set)
//!   — warm requests whose supports repeat a previous fit's adopt the
//!   existing slab instead of re-materializing it (DESIGN.md §5).
//!
//! Concurrent requests for the same (dataset, model) are **coalesced**:
//! the first one fits, the rest block on a [`BuildGate`] and share the
//! result — the serving analogue of fitting each path point once.
//!
//! **Panic quarantine** (DESIGN.md §12): fits that panic charge a strike
//! against their dataset entry via [`Registry::record_panic`]; at
//! [`QUARANTINE_STRIKES`] the entry — problem, cached models, point
//! states and packed slabs — is evicted wholesale, so a poisoned
//! materialization (or cache state that keeps re-triggering the same
//! crash) cannot take the server down request after request. The next
//! request re-materializes from the spec.
//!
//! **Durable state** (DESIGN.md §13): with [`Registry::with_state_dir`]
//! the registry journals interned dataset specs, warm-start seeds of
//! built models, and strike counts to `<dir>/registry.journal` —
//! length-prefixed, FNV-digested records, appended and fsynced. On boot
//! the journal replays: datasets re-materialize from their specs, seeds
//! prime [`DatasetEntry::any_ready_seed`] (a restarted server warm-starts
//! instead of refitting cold), and the strike ledger survives — a
//! crash-looping dataset cannot launder its quarantine strikes by
//! restarting the server. Corrupt or torn records are detected, logged
//! and skipped — never trusted.
//!
//! **Replication** (DESIGN.md §15): the same journal doubles as a
//! replication log. Standbys attach a [`ReplSubscriber`]; every
//! [`Registry::append_record`] fans the framed record out to them under
//! the journal lock (so subscribers observe journal order exactly), and
//! the standby applies records via [`Registry::apply_replicated`]. A
//! [`Registry::snapshot_records`] rewrite compacts the append-only
//! journal in place once it outgrows its threshold, and a u64 failover
//! epoch — journaled like any other record — fences stale primaries.

use std::collections::{HashMap, VecDeque};
use std::io::Write as _;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use crate::ingest::{fnv1a, FNV_BASIS};
use crate::jsonio::Json;
use crate::linalg::packed::PackCache;
use crate::obs::registry as obsreg;
use crate::serve::error::ServeError;
use crate::slope::family::Problem;
use crate::slope::path::{PathFit, PathSeed};

use super::protocol::{ColumnTransform, DatasetSpec};

/// Worker panics charged to one dataset entry before it is quarantined.
pub const QUARANTINE_STRIKES: u64 = 3;

/// Journal size (bytes) past which an append triggers a compaction
/// rewrite. Tests lower it via [`Registry::set_compact_bytes`].
const JOURNAL_COMPACT_BYTES: u64 = 8 << 20;

/// Byte cap on one replication subscriber's unsent queue. A standby that
/// falls this far behind is dropped — it reconnects and re-snapshots —
/// instead of growing the primary's memory without bound.
const REPL_MAX_QUEUE_BYTES: u64 = 64 << 20;

/// A fitted path cached with its warm-start state.
pub struct CachedModel {
    /// The fitted path.
    pub fit: PathFit,
    /// Warm-start state at the final path point.
    pub seed: PathSeed,
    /// Strategy the fit actually used.
    pub strategy: &'static str,
    /// Wall time of the original fit (seconds).
    pub wall_time: f64,
    /// Times this cache entry was served.
    pub hits: AtomicU64,
}

/// Warm-start state for a `fit_point` stream.
pub struct PointState {
    /// State at the most recently solved point.
    pub seed: PathSeed,
    /// σ_max of this (dataset, λ) pair, for resolving relative σ requests.
    pub sigma_max: f64,
}

/// One-shot completion gate for coalesced builds.
pub struct BuildGate {
    slot: Mutex<(bool, Option<Arc<CachedModel>>)>,
    ready: Condvar,
}

impl BuildGate {
    fn new() -> BuildGate {
        BuildGate { slot: Mutex::new((false, None)), ready: Condvar::new() }
    }

    fn complete(&self, model: Option<Arc<CachedModel>>) {
        let mut slot = self.slot.lock().unwrap();
        slot.0 = true;
        slot.1 = model;
        self.ready.notify_all();
    }

    fn wait(&self) -> Option<Arc<CachedModel>> {
        let mut slot = self.slot.lock().unwrap();
        while !slot.0 {
            slot = self.ready.wait(slot).unwrap();
        }
        slot.1.clone()
    }
}

enum ModelSlot {
    Building(Arc<BuildGate>),
    Ready(Arc<CachedModel>),
}

/// Cap on interned datasets; the oldest is evicted beyond this (inline
/// client matrices can be large, and a seed-sweeping client would
/// otherwise grow the server without bound). In-flight requests keep
/// their `Arc` alive, so eviction never invalidates running work.
const MAX_DATASETS: usize = 64;

/// Cap on cached models (and point states) per dataset.
const MAX_MODELS_PER_DATASET: usize = 32;

/// Cap on cached packed screened sets per dataset. Sized above the
/// default path length (50 σ-steps deposit one set each) so a warm
/// re-fit of a full path hits on every step; the byte budget below is
/// the real bound on memory (eviction in the cache is FIFO, so the
/// oldest path steps retire first).
const MAX_PACKS_PER_DATASET: usize = 64;

/// Slab byte budget per dataset's pack cache (64 datasets × 32 MB caps
/// the server-wide pack footprint at 2 GB in the worst case; typical
/// screened slabs are tens to hundreds of KB, so real usage is far
/// lower).
const MAX_PACK_BYTES_PER_DATASET: usize = 32 << 20;

/// An interned dataset with its model caches.
pub struct DatasetEntry {
    /// Spec fingerprint (the intern key).
    pub fingerprint: u64,
    /// Human label from the spec.
    pub label: String,
    /// The materialized problem (shared with fit jobs).
    pub problem: Arc<Problem>,
    /// Raw-row → model-row transform for predictions (inline data that
    /// was standardized server-side).
    pub transform: Option<ColumnTransform>,
    /// Offset added back to predicted scores (gaussian y-centering).
    pub intercept: f64,
    /// Packed screened-column slabs keyed by screened set (DESIGN.md §5):
    /// every fit on this dataset shares one cache, so warm requests with
    /// stable supports adopt an existing slab instead of re-packing.
    packs: Arc<PackCache>,
    /// Column norms `‖x_j‖` of the design, computed once on first use —
    /// the gap-driven screens' sphere tests need them (fit-invariant, so
    /// per-request `fit_point` streams must not re-pay the O(n·p) pass).
    col_norms: Mutex<Option<Arc<Vec<f64>>>>,
    /// Worker panics charged to this entry (quarantined at
    /// [`QUARANTINE_STRIKES`]).
    strikes: AtomicU64,
    /// Warm-start seed restored from the journal of a previous process
    /// (or shipped by replication, or deposited by the latest local
    /// build on a durable server); consulted by
    /// [`DatasetEntry::any_ready_seed`] when no model has been built
    /// *this* process yet, and by `fit_point` streams with no prior
    /// point state.
    restored_seed: Mutex<Option<PathSeed>>,
    /// The journal form of this entry's spec (`None` for inline specs,
    /// which are deliberately not durable) — what a compaction snapshot
    /// re-emits.
    spec_json: Option<Json>,
    models: Mutex<HashMap<String, ModelSlot>>,
    points: Mutex<HashMap<String, Arc<PointState>>>,
}

impl DatasetEntry {
    /// The dataset's shared packed-design cache (hand to
    /// [`crate::slope::path::PathOptions::with_pack_cache`]).
    pub fn pack_cache(&self) -> Arc<PackCache> {
        Arc::clone(&self.packs)
    }

    /// Column norms of this dataset's design, computed lazily on first
    /// use and shared by every later gap-driven fit (hand to
    /// [`crate::slope::path::PathOptions::with_col_norms`]).
    pub fn col_norms(&self, par: crate::linalg::ParConfig) -> Arc<Vec<f64>> {
        let mut slot = self.col_norms.lock().unwrap();
        if let Some(norms) = &*slot {
            return Arc::clone(norms);
        }
        let norms: Arc<Vec<f64>> = Arc::new(self.problem.x.col_norms_with(par));
        *slot = Some(Arc::clone(&norms));
        norms
    }

    /// Cached point state for a model key, if any.
    pub fn point_state(&self, key: &str) -> Option<Arc<PointState>> {
        self.points.lock().unwrap().get(key).cloned()
    }

    /// Replace the point state for a model key (bounded: an arbitrary
    /// older entry is evicted past the per-dataset cap).
    pub fn store_point_state(&self, key: &str, state: PointState) {
        let mut points = self.points.lock().unwrap();
        if !points.contains_key(key) && points.len() >= MAX_MODELS_PER_DATASET {
            if let Some(evict) = points.keys().next().cloned() {
                points.remove(&evict);
            }
        }
        points.insert(key.to_string(), Arc::new(state));
    }

    /// A warm-start seed from any already-fitted model on this dataset
    /// (used to prime a fit under a *different* model spec — the
    /// "refined request" case).
    pub fn any_ready_seed(&self) -> Option<PathSeed> {
        {
            let models = self.models.lock().unwrap();
            for slot in models.values() {
                if let ModelSlot::Ready(m) = slot {
                    return Some(m.seed.clone());
                }
            }
        }
        // Nothing built this process: fall back to a seed journaled by a
        // previous one, so a restarted server warm-starts its first fit.
        self.restored_seed.lock().unwrap().clone()
    }

    /// The journal-restored (or replication-shipped) warm-start seed, if
    /// any — what a `fit_point` with no prior point state warms from on
    /// a durable or failed-over server (DESIGN.md §15).
    pub fn restored_seed(&self) -> Option<PathSeed> {
        self.restored_seed.lock().unwrap().clone()
    }

    /// Number of fully-built cached models.
    pub fn ready_models(&self) -> usize {
        self.models
            .lock()
            .unwrap()
            .values()
            .filter(|s| matches!(s, ModelSlot::Ready(_)))
            .count()
    }
}

/// How a model was obtained from [`Registry::model`].
pub enum Fetched {
    /// Served straight from cache.
    Hit(Arc<CachedModel>),
    /// Another request was building it; this one waited and shared.
    Coalesced(Arc<CachedModel>),
    /// Built by this caller (and now cached).
    Built(Arc<CachedModel>),
}

impl Fetched {
    /// The model, regardless of provenance.
    pub fn model(&self) -> &Arc<CachedModel> {
        match self {
            Fetched::Hit(m) | Fetched::Coalesced(m) | Fetched::Built(m) => m,
        }
    }

    /// Provenance label for responses/metrics.
    pub fn source(&self) -> &'static str {
        match self {
            Fetched::Hit(_) => "cache",
            Fetched::Coalesced(_) => "coalesced",
            Fetched::Built(_) => "fit",
        }
    }
}

/// Interned datasets plus insertion order for eviction.
#[derive(Default)]
struct DatasetMap {
    by_fp: HashMap<u64, Arc<DatasetEntry>>,
    order: VecDeque<u64>,
}

/// One attached replication subscriber: framed journal records queued by
/// [`Registry::append_record`], drained into the standby's connection by
/// the owning transport (the net.rs poll loop). Queue depth is the
/// primary-side backpressure signal — `REPL_LAG_RECORDS` reports the
/// worst queue — and a subscriber more than [`REPL_MAX_QUEUE_BYTES`]
/// behind is dropped (it reconnects and re-snapshots).
pub struct ReplSubscriber {
    chunks: Mutex<VecDeque<(Vec<u8>, u64)>>,
    queued_records: AtomicU64,
    queued_bytes: AtomicU64,
    gone: AtomicBool,
}

impl ReplSubscriber {
    /// A fresh, not-yet-attached subscriber.
    pub fn new() -> ReplSubscriber {
        ReplSubscriber {
            chunks: Mutex::new(VecDeque::new()),
            queued_records: AtomicU64::new(0),
            queued_bytes: AtomicU64::new(0),
            gone: AtomicBool::new(false),
        }
    }

    /// Queue `records` journal records serialized as `bytes`.
    fn push(&self, bytes: Vec<u8>, records: u64) {
        if self.is_gone() {
            return;
        }
        self.queued_records.fetch_add(records, Ordering::SeqCst);
        let total =
            self.queued_bytes.fetch_add(bytes.len() as u64, Ordering::SeqCst) + bytes.len() as u64;
        self.chunks.lock().unwrap().push_back((bytes, records));
        if total > REPL_MAX_QUEUE_BYTES {
            eprintln!(
                "registry: replication subscriber {total} bytes behind; dropping it \
                 (it will reconnect and re-snapshot)"
            );
            self.mark_gone();
        }
    }

    /// Pop the next queued chunk for the wire, or `None` when drained.
    pub fn pop(&self) -> Option<Vec<u8>> {
        let (bytes, records) = self.chunks.lock().unwrap().pop_front()?;
        self.queued_records.fetch_sub(records, Ordering::SeqCst);
        self.queued_bytes.fetch_sub(bytes.len() as u64, Ordering::SeqCst);
        Some(bytes)
    }

    /// Records queued but not yet handed to the transport.
    pub fn lag_records(&self) -> u64 {
        self.queued_records.load(Ordering::SeqCst)
    }

    /// Detach: the registry stops queueing and drops this subscriber on
    /// its next ship; the transport closes the connection.
    pub fn mark_gone(&self) {
        self.gone.store(true, Ordering::Release);
    }

    /// Has this subscriber been detached (dead connection, hopeless lag)?
    pub fn is_gone(&self) -> bool {
        self.gone.load(Ordering::Acquire)
    }
}

impl Default for ReplSubscriber {
    fn default() -> Self {
        ReplSubscriber::new()
    }
}

/// The server-wide registry.
pub struct Registry {
    datasets: Mutex<DatasetMap>,
    cache_enabled: bool,
    /// Append handle to `<state-dir>/registry.journal`; `None` when the
    /// server runs without durable state (and during boot replay, which
    /// is what keeps replay from re-journaling what it restores).
    journal: Option<Mutex<std::fs::File>>,
    /// The journal file's path — compaction's atomic rewrite and the
    /// subscribe-time snapshot read need it.
    journal_path: Option<PathBuf>,
    /// Bytes currently in the journal file (appends add, compaction
    /// resets) — the compaction trigger.
    journal_bytes: AtomicU64,
    /// Intact framed records in the journal (replayed + appended) — the
    /// primary side of replication-lag accounting.
    journal_records: AtomicU64,
    /// Compaction threshold; tests lower it to force rewrites.
    compact_bytes: AtomicU64,
    /// Failover epoch: the highest promotion epoch this registry has
    /// journaled or observed (DESIGN.md §15). Journaled on every raise,
    /// so fencing survives a restart.
    epoch: AtomicU64,
    /// Live replication subscribers fed by `append_record`.
    repl_subs: Mutex<Vec<Arc<ReplSubscriber>>>,
    /// Fast-path flag: with no subscribers, `append_record` pays one
    /// relaxed load and nothing else.
    repl_active: AtomicBool,
    /// Transport nudge, called after frames are queued so the poll loop
    /// drains them now instead of on its next 50 ms tick.
    repl_wake: Mutex<Option<Box<dyn Fn() + Send + Sync>>>,
    /// Strike counts by dataset fingerprint. Outlives the entry itself
    /// (FIFO eviction, restart) so a crash-looping dataset cannot reset
    /// its quarantine count by cycling through the cache or rebooting
    /// the server. Quarantine clears the ledger: the post-quarantine
    /// re-intern is a deliberate fresh start.
    strike_ledger: Mutex<HashMap<u64, u64>>,
    /// Warm-start seeds `(model key, seed)` restored from the journal or
    /// deposited by the latest local build, adopted by the entry when
    /// its dataset is (re-)interned. Mirrors replay's last-record-wins
    /// semantics, so a compaction snapshot of this map is equivalent to
    /// the journal it replaces.
    restored_seeds: Mutex<HashMap<u64, (String, PathSeed)>>,
}

impl Registry {
    /// New registry; `cache_enabled = false` turns every lookup into a
    /// rebuild (the cold baseline the throughput bench compares against).
    pub fn new(cache_enabled: bool) -> Registry {
        Registry::with_state_dir(cache_enabled, None)
    }

    /// New registry with opt-in durable state: when `state_dir` is set,
    /// `<dir>/registry.journal` is replayed (datasets re-interned from
    /// their specs, seeds and strike counts restored) and then opened
    /// for append, so everything registered from here on survives a
    /// restart. Journal IO failures degrade to an in-memory registry
    /// with a log line — serving never blocks on durability.
    pub fn with_state_dir(cache_enabled: bool, state_dir: Option<&Path>) -> Registry {
        let mut reg = Registry {
            datasets: Mutex::new(DatasetMap::default()),
            cache_enabled,
            journal: None,
            journal_path: None,
            journal_bytes: AtomicU64::new(0),
            journal_records: AtomicU64::new(0),
            compact_bytes: AtomicU64::new(JOURNAL_COMPACT_BYTES),
            epoch: AtomicU64::new(0),
            repl_subs: Mutex::new(Vec::new()),
            repl_active: AtomicBool::new(false),
            repl_wake: Mutex::new(None),
            strike_ledger: Mutex::new(HashMap::new()),
            restored_seeds: Mutex::new(HashMap::new()),
        };
        let Some(dir) = state_dir else { return reg };
        if let Err(e) = std::fs::create_dir_all(dir) {
            eprintln!("registry: cannot create state dir {}: {e}; running in-memory", dir.display());
            return reg;
        }
        let path = dir.join("registry.journal");
        // A crash between compaction's two renames leaves no journal but
        // a complete `.prev` (the pre-compaction log, which replays to
        // the same state): restore it rather than booting empty.
        if !path.exists() {
            let prev = sibling(&path, ".prev");
            if prev.exists() {
                if let Err(e) = std::fs::rename(&prev, &path) {
                    eprintln!(
                        "registry: cannot restore {} from its .prev: {e}",
                        path.display()
                    );
                }
            }
        }
        // Replay while `journal` is still None: restoring a dataset goes
        // through `dataset()`, and a live journal there would append a
        // duplicate record for every record replayed.
        let valid = reg.replay_journal(&path);
        // A torn tail must be cut before appending: a new record written
        // after the tear would be unreachable by every future replay
        // (which stops at the first broken frame).
        match std::fs::metadata(&path) {
            Ok(meta) if meta.len() > valid => {
                if let Err(e) = std::fs::OpenOptions::new()
                    .write(true)
                    .open(&path)
                    .and_then(|f| f.set_len(valid))
                {
                    eprintln!(
                        "registry: cannot truncate torn journal {}: {e}; running in-memory",
                        path.display()
                    );
                    return reg;
                }
            }
            _ => {}
        }
        match std::fs::OpenOptions::new().create(true).append(true).open(&path) {
            Ok(f) => {
                reg.journal = Some(Mutex::new(f));
                reg.journal_path = Some(path);
                reg.journal_bytes.store(valid, Ordering::SeqCst);
            }
            Err(e) => {
                eprintln!("registry: cannot open journal {}: {e}; running in-memory", path.display())
            }
        }
        reg
    }

    /// Whether result caching is on.
    pub fn cache_enabled(&self) -> bool {
        self.cache_enabled
    }

    /// Intern a dataset: materialize it on first sight, reuse afterwards.
    /// Past [`MAX_DATASETS`], the oldest interned dataset is evicted.
    pub fn dataset(&self, spec: &DatasetSpec) -> Result<Arc<DatasetEntry>, ServeError> {
        let fp = spec.fingerprint();
        if let Some(entry) = self.datasets.lock().unwrap().by_fp.get(&fp) {
            return Ok(Arc::clone(entry));
        }
        // Materialize outside the lock — generation can be slow, and two
        // racing materializations of the same spec are identical anyway.
        let materialized = spec.materialize().map_err(ServeError::Invalid)?;
        // File-backed specs are fingerprinted by *content*, and the file
        // is re-read by materialize: if it changed in between, the entry
        // would be permanently cached under the wrong key and serve fits
        // of the wrong data. Re-fingerprint after materializing and
        // refuse the intern on a mismatch (synthetic/real/inline specs
        // are deterministic, so this recheck is only ever observable for
        // files — and costs one extra streamed read on a cold intern).
        if spec.fingerprint() != fp {
            return Err(ServeError::Failed(format!(
                "dataset `{}` changed while being registered; retry",
                spec.label()
            )));
        }
        let problem = Arc::new(materialized.problem);
        // Strikes carry over from the ledger (never from the evicted
        // entry's Arc): eviction or a restart must not launder them.
        let carried_strikes =
            self.strike_ledger.lock().unwrap().get(&fp).copied().unwrap_or(0);
        // A journaled seed only fits if its dimensions still match the
        // re-materialized problem; anything else is stale and dropped.
        let restored = self.restored_seeds.lock().unwrap().get(&fp).and_then(|(_, s)| {
            (s.beta.len() == problem.p_total() && s.grad.len() == problem.p_total())
                .then(|| s.clone())
        });
        let entry = Arc::new(DatasetEntry {
            fingerprint: fp,
            label: spec.label(),
            problem,
            transform: materialized.transform,
            intercept: materialized.intercept,
            packs: Arc::new(
                PackCache::new(MAX_PACKS_PER_DATASET).with_max_bytes(MAX_PACK_BYTES_PER_DATASET),
            ),
            col_norms: Mutex::new(None),
            strikes: AtomicU64::new(carried_strikes),
            restored_seed: Mutex::new(restored),
            spec_json: spec_to_json(spec),
            models: Mutex::new(HashMap::new()),
            points: Mutex::new(HashMap::new()),
        });
        let mut newly_interned = false;
        let entry = {
            let mut map = self.datasets.lock().unwrap();
            if !map.by_fp.contains_key(&fp) {
                map.by_fp.insert(fp, entry);
                map.order.push_back(fp);
                newly_interned = true;
                while map.by_fp.len() > MAX_DATASETS {
                    if let Some(oldest) = map.order.pop_front() {
                        map.by_fp.remove(&oldest);
                        obsreg::REGISTRY_DATASET_EVICTIONS.inc();
                    } else {
                        break;
                    }
                }
            }
            Arc::clone(map.by_fp.get(&fp).expect("just interned"))
        };
        if newly_interned {
            self.journal_dataset(spec);
        }
        Ok(entry)
    }

    /// Look up a fitted model, building (at most once, concurrently) via
    /// `build` on a miss. `build` runs on the calling thread; concurrent
    /// callers for the same key wait on the gate and share the result.
    pub fn model(
        &self,
        entry: &DatasetEntry,
        key: &str,
        build: impl FnOnce() -> Result<CachedModel, ServeError>,
    ) -> Result<Fetched, ServeError> {
        if !self.cache_enabled {
            obsreg::REGISTRY_MODEL_BUILDS.inc();
            return build().map(|m| Fetched::Built(Arc::new(m)));
        }
        let gate = {
            let mut models = entry.models.lock().unwrap();
            match models.get(key) {
                Some(ModelSlot::Ready(m)) => {
                    m.hits.fetch_add(1, Ordering::Relaxed);
                    obsreg::REGISTRY_MODEL_HITS.inc();
                    return Ok(Fetched::Hit(Arc::clone(m)));
                }
                Some(ModelSlot::Building(g)) => {
                    let g = Arc::clone(g);
                    drop(models);
                    obsreg::REGISTRY_COALESCED.inc();
                    return match g.wait() {
                        Some(m) => Ok(Fetched::Coalesced(m)),
                        None => Err(ServeError::Failed("coalesced fit failed; retry".to_string())),
                    };
                }
                None => {
                    let g = Arc::new(BuildGate::new());
                    models.insert(key.to_string(), ModelSlot::Building(Arc::clone(&g)));
                    g
                }
            }
        };
        obsreg::REGISTRY_MODEL_BUILDS.inc();
        match build() {
            Ok(model) => {
                let model = Arc::new(model);
                {
                    let mut models = entry.models.lock().unwrap();
                    // Bounded cache: past the cap, evict an arbitrary *ready*
                    // sibling (never this key, never an in-flight Building
                    // slot — peers are parked on its gate). Our own slot is
                    // already in the map as Building, so count it.
                    if models.len() > MAX_MODELS_PER_DATASET {
                        let evict = models
                            .iter()
                            .find(|(k, slot)| {
                                k.as_str() != key && matches!(slot, ModelSlot::Ready(_))
                            })
                            .map(|(k, _)| k.clone());
                        if let Some(evict) = evict {
                            models.remove(&evict);
                        }
                    }
                    models.insert(key.to_string(), ModelSlot::Ready(Arc::clone(&model)));
                }
                gate.complete(Some(Arc::clone(&model)));
                self.journal_seed(entry.fingerprint, key, &model.seed);
                Ok(Fetched::Built(model))
            }
            Err(e) => {
                entry.models.lock().unwrap().remove(key);
                gate.complete(None);
                Err(e)
            }
        }
    }

    /// `(datasets, ready models)` across the registry.
    pub fn counts(&self) -> (usize, usize) {
        let datasets = self.datasets.lock().unwrap();
        let models = datasets.by_fp.values().map(|e| e.ready_models()).sum();
        (datasets.by_fp.len(), models)
    }

    /// Charge a worker panic to `entry`. At [`QUARANTINE_STRIKES`] the
    /// entry is quarantined: evicted from the registry (so the next
    /// request re-materializes from the spec) and its model/point caches
    /// cleared for any in-flight holders. Returns `true` when this call
    /// quarantined the entry. In-flight `Arc`s stay valid — quarantine
    /// never invalidates running work.
    pub fn record_panic(&self, entry: &DatasetEntry) -> bool {
        let strikes = entry.strikes.fetch_add(1, Ordering::SeqCst) + 1;
        if strikes < QUARANTINE_STRIKES {
            self.strike_ledger.lock().unwrap().insert(entry.fingerprint, strikes);
            self.journal_strikes(entry.fingerprint, strikes);
            return false;
        }
        {
            let mut map = self.datasets.lock().unwrap();
            if map.by_fp.remove(&entry.fingerprint).is_some() {
                map.order.retain(|&fp| fp != entry.fingerprint);
            } else {
                // Already quarantined by a racing striker; don't double-count.
                return false;
            }
        }
        entry.models.lock().unwrap().clear();
        entry.points.lock().unwrap().clear();
        // Quarantine clears the ledger — journaled as an explicit zero so
        // a restart replays the clean slate, not the pre-quarantine count.
        self.strike_ledger.lock().unwrap().remove(&entry.fingerprint);
        self.journal_strikes(entry.fingerprint, 0);
        obsreg::REGISTRY_QUARANTINED.inc();
        true
    }

    /// [`Registry::record_panic`], `count` times — the batched-solve
    /// path, where one worker panic fails every member of the batch and
    /// sequential handling would have charged one strike per request.
    /// Keeping strike parity means coalescing can't slow (or hasten) a
    /// poisoned dataset's quarantine. Returns `true` if any strike
    /// quarantined the entry (later strikes on an evicted entry are
    /// no-ops by `record_panic`'s own race guard).
    pub fn record_panics(&self, entry: &DatasetEntry, count: usize) -> bool {
        let mut quarantined = false;
        for _ in 0..count {
            quarantined |= self.record_panic(entry);
        }
        quarantined
    }

    // --- durable-state journal (DESIGN.md §13) ---------------------------

    /// Append one JSON record, framed `[u32 len][u64 fnv1a(payload)][payload]`
    /// and fsynced, then fan it out to replication subscribers (still
    /// under the journal lock, so subscribers see exact journal order)
    /// and compact if the file outgrew its threshold. No-op without a
    /// journal; IO errors log and drop the record rather than failing
    /// the serving path that triggered it.
    fn append_record(&self, record: &Json) {
        let Some(journal) = &self.journal else { return };
        let frame = frame_record(record);
        let mut f = journal.lock().unwrap();
        match f.write_all(&frame).and_then(|_| f.sync_data()) {
            Ok(()) => {
                obsreg::JOURNAL_RECORDS.inc();
                self.journal_records.fetch_add(1, Ordering::SeqCst);
                let bytes = self.journal_bytes.fetch_add(frame.len() as u64, Ordering::SeqCst)
                    + frame.len() as u64;
                self.ship_frame(frame);
                if bytes >= self.compact_bytes.load(Ordering::Relaxed) {
                    self.compact_locked(&mut f);
                }
            }
            Err(e) => eprintln!("registry: journal append failed: {e}"),
        }
    }

    // --- replication (DESIGN.md §15) -------------------------------------

    /// Fan one framed record out to every live subscriber. Called with
    /// the journal lock held. Zero subscribers cost one relaxed load —
    /// the replication-disabled fast path.
    fn ship_frame(&self, frame: Vec<u8>) {
        if !self.repl_active.load(Ordering::Acquire) {
            return;
        }
        let mut frame = frame;
        if crate::fault::on_repl_ship() {
            // Corrupt the wire copy's digest only: the on-disk journal
            // already holds the good frame.
            frame[4] ^= 0x01;
        }
        let mut max_lag = 0u64;
        let live = {
            let mut subs = self.repl_subs.lock().unwrap();
            subs.retain(|s| !s.is_gone());
            for sub in subs.iter() {
                sub.push(frame.clone(), 1);
                obsreg::REPL_RECORDS_SHIPPED.inc();
                max_lag = max_lag.max(sub.lag_records());
            }
            if subs.is_empty() {
                self.repl_active.store(false, Ordering::Release);
            }
            subs.len()
        };
        obsreg::REPL_SUBSCRIBERS.set(live as u64);
        obsreg::REPL_LAG_RECORDS.set(max_lag);
        if live > 0 {
            if let Some(wake) = &*self.repl_wake.lock().unwrap() {
                wake();
            }
        }
    }

    /// Attach a replication subscriber: under the journal lock (so no
    /// append can interleave), queue the entire on-disk journal as the
    /// catch-up snapshot, then register for every future
    /// `append_record` fan-out — no record is lost or reordered between
    /// snapshot and stream. Returns the number of intact records in the
    /// snapshot, for the standby's lag accounting.
    pub fn attach_subscriber(&self, sub: Arc<ReplSubscriber>) -> Result<u64, String> {
        let Some(journal) = &self.journal else {
            return Err("replication requires --state-dir (no journal to ship)".to_string());
        };
        let path = self.journal_path.as_ref().expect("journal implies a path");
        let _append_guard = journal.lock().unwrap();
        let snapshot = std::fs::read(path)
            .map_err(|e| format!("cannot read journal for replication snapshot: {e}"))?;
        let records = self.journal_records.load(Ordering::SeqCst);
        if !snapshot.is_empty() {
            sub.push(snapshot, records);
        }
        let mut subs = self.repl_subs.lock().unwrap();
        subs.retain(|s| !s.is_gone());
        subs.push(sub);
        obsreg::REPL_SUBSCRIBERS.set(subs.len() as u64);
        self.repl_active.store(true, Ordering::Release);
        Ok(records)
    }

    /// Install the transport nudge called whenever replication frames
    /// are queued (the TCP poll loop's self-pipe).
    pub fn set_repl_wake(&self, wake: Box<dyn Fn() + Send + Sync>) {
        *self.repl_wake.lock().unwrap() = Some(wake);
    }

    /// `(live subscribers, worst queued-record lag)` for `health`.
    pub fn subscriber_stats(&self) -> (usize, u64) {
        let subs = self.repl_subs.lock().unwrap();
        let live: Vec<_> = subs.iter().filter(|s| !s.is_gone()).collect();
        let lag = live.iter().map(|s| s.lag_records()).max().unwrap_or(0);
        (live.len(), lag)
    }

    /// Intact records in this registry's journal (heartbeats carry it so
    /// standbys can account lag against the primary).
    pub fn journal_records_total(&self) -> u64 {
        self.journal_records.load(Ordering::SeqCst)
    }

    /// The failover epoch this registry last journaled or observed.
    pub fn epoch(&self) -> u64 {
        self.epoch.load(Ordering::SeqCst)
    }

    /// Record a remotely-observed epoch, keeping the max; the raise is
    /// journaled so fencing survives a restart. Returns `true` when the
    /// epoch actually rose.
    pub fn bump_epoch_to(&self, epoch: u64) -> bool {
        let prev = self.epoch.fetch_max(epoch, Ordering::SeqCst);
        if epoch > prev {
            self.append_record(&epoch_record(epoch));
            return true;
        }
        false
    }

    /// Bump the epoch for a promotion and journal it; returns the new
    /// epoch. An ex-primary fenced at epoch N promotes to N+1 — above
    /// everything it has observed.
    pub fn advance_epoch(&self) -> u64 {
        let epoch = self.epoch.fetch_add(1, Ordering::SeqCst) + 1;
        self.append_record(&epoch_record(epoch));
        epoch
    }

    /// Apply one replicated journal record on a standby, making it
    /// durable in the standby's own journal. Dataset records journal
    /// themselves inside [`Registry::dataset`] on a fresh intern; every
    /// other kind is re-appended explicitly after a successful apply.
    /// Returns `false` for records that were skipped.
    pub fn apply_replicated(&self, rec: &Json) -> bool {
        let is_dataset = rec.field("kind").and_then(Json::as_str) == Some("dataset");
        let applied = self.apply_journal_record(rec);
        if applied && !is_dataset {
            self.append_record(rec);
        }
        applied
    }

    // --- journal compaction (DESIGN.md §15) ------------------------------

    /// Force a compaction rewrite now (tests; production compacts
    /// automatically past the size threshold). No-op without a journal.
    pub fn compact_journal(&self) {
        let Some(journal) = &self.journal else { return };
        let mut f = journal.lock().unwrap();
        self.compact_locked(&mut f);
    }

    /// Lower (or raise) the automatic compaction threshold in bytes.
    pub fn set_compact_bytes(&self, bytes: u64) {
        self.compact_bytes.store(bytes.max(1), Ordering::Relaxed);
    }

    /// The live registry state as a minimal record stream: replaying it
    /// into a fresh registry reproduces exactly what replaying the full
    /// journal would (last-record-wins seeds, the strike ledger, the
    /// epoch, every durable dataset spec). It is both the compaction
    /// payload and the state-equality witness in tests. Deterministic:
    /// the epoch leads, then datasets, strikes and seeds sorted by
    /// fingerprint.
    pub fn snapshot_records(&self) -> Vec<Json> {
        let mut recs = Vec::new();
        let epoch = self.epoch();
        if epoch > 0 {
            recs.push(epoch_record(epoch));
        }
        let mut specs: Vec<(u64, Json)> = {
            let map = self.datasets.lock().unwrap();
            map.by_fp
                .iter()
                .filter_map(|(fp, e)| e.spec_json.clone().map(|sj| (*fp, sj)))
                .collect()
        };
        specs.sort_by_key(|(fp, _)| *fp);
        for (_, sj) in specs {
            recs.push(Json::obj(vec![("kind", Json::Str("dataset".to_string())), ("spec", sj)]));
        }
        let mut strikes: Vec<(u64, u64)> =
            self.strike_ledger.lock().unwrap().iter().map(|(&fp, &c)| (fp, c)).collect();
        strikes.sort_unstable();
        for (fp, count) in strikes {
            recs.push(Json::obj(vec![
                ("kind", Json::Str("strikes".to_string())),
                ("fp", Json::Str(fp_hex(fp))),
                ("count", Json::Num(count as f64)),
            ]));
        }
        let mut seeds: Vec<(u64, String, PathSeed)> = self
            .restored_seeds
            .lock()
            .unwrap()
            .iter()
            .map(|(&fp, (key, seed))| (fp, key.clone(), seed.clone()))
            .collect();
        seeds.sort_by(|a, b| a.0.cmp(&b.0));
        for (fp, key, seed) in seeds {
            recs.push(seed_record(fp, &key, &seed));
        }
        recs
    }

    /// Rewrite the journal as a snapshot of the live state, following
    /// checkpoint.rs's atomic-write discipline: tmp + fsync, rotate the
    /// old journal to `.prev`, rename the snapshot into place, fsync the
    /// directory, reopen for append. Called with the journal lock held;
    /// on any IO error the old handle and file stay authoritative.
    fn compact_locked(&self, f: &mut std::fs::File) {
        let Some(path) = &self.journal_path else { return };
        let recs = self.snapshot_records();
        let mut payload = Vec::new();
        for rec in &recs {
            payload.extend_from_slice(&frame_record(rec));
        }
        let tmp = sibling(path, ".tmp");
        let prev = sibling(path, ".prev");
        let rewrite = || -> std::io::Result<()> {
            let mut out = std::fs::File::create(&tmp)?;
            out.write_all(&payload)?;
            out.sync_all()?;
            std::fs::rename(path, &prev)?;
            std::fs::rename(&tmp, path)?;
            if let Some(dir) = path.parent() {
                if let Ok(d) = std::fs::File::open(dir) {
                    let _ = d.sync_all();
                }
            }
            Ok(())
        };
        if let Err(e) = rewrite() {
            eprintln!("registry: journal compaction failed: {e}; keeping the append-only journal");
            return;
        }
        match std::fs::OpenOptions::new().append(true).open(path) {
            Ok(fresh) => *f = fresh,
            Err(e) => {
                // The snapshot is in place but can't be appended to; keep
                // the old handle (now `.prev`). New records land there and
                // a restart replays only the snapshot — degraded
                // durability, never corruption. The next compaction
                // attempt re-squares it.
                eprintln!("registry: cannot reopen compacted journal: {e}");
                return;
            }
        }
        let old = self.journal_bytes.swap(payload.len() as u64, Ordering::SeqCst);
        self.journal_records.store(recs.len() as u64, Ordering::SeqCst);
        obsreg::JOURNAL_COMPACTIONS.inc();
        obsreg::JOURNAL_BYTES_RECLAIMED.add(old.saturating_sub(payload.len() as u64));
    }

    fn journal_dataset(&self, spec: &DatasetSpec) {
        if self.journal.is_none() {
            return;
        }
        match spec_to_json(spec) {
            Some(sj) => self.append_record(&Json::obj(vec![
                ("kind", Json::Str("dataset".to_string())),
                ("spec", sj),
            ])),
            // Inline payloads can be arbitrarily large and the client
            // re-sends them anyway; registration is intentionally not
            // durable for them.
            None => eprintln!(
                "registry: inline dataset `{}` not journaled (re-register after restart)",
                spec.label()
            ),
        }
    }

    fn journal_seed(&self, fp: u64, key: &str, seed: &PathSeed) {
        if self.journal.is_none() {
            return;
        }
        self.append_record(&seed_record(fp, key, seed));
        // Mirror the append into the restored-seed state: replay's
        // last-record-wins rule says this build is now the seed a
        // restart (or a compaction snapshot, or a standby applying this
        // very record) would restore — keeping the live registry and
        // its journal equivalent.
        if let Some(entry) = self.datasets.lock().unwrap().by_fp.get(&fp) {
            if seed.beta.len() == entry.problem.p_total() {
                *entry.restored_seed.lock().unwrap() = Some(seed.clone());
            }
        }
        self.restored_seeds.lock().unwrap().insert(fp, (key.to_string(), seed.clone()));
    }

    fn journal_strikes(&self, fp: u64, count: u64) {
        if self.journal.is_none() {
            return;
        }
        self.append_record(&Json::obj(vec![
            ("kind", Json::Str("strikes".to_string())),
            ("fp", Json::Str(fp_hex(fp))),
            ("count", Json::Num(count as f64)),
        ]));
    }

    /// Replay `<state-dir>/registry.journal` into this (pre-journal)
    /// registry. Torn tails stop the replay (everything before them is
    /// kept); records with a bad digest or shape are skipped and counted
    /// — a corrupt journal degrades to a partial restore, never a panic
    /// and never trusted bytes. Returns the byte length of the valid
    /// frame prefix, so the caller can cut a torn tail before appending.
    fn replay_journal(&self, path: &Path) -> u64 {
        let buf = match std::fs::read(path) {
            Ok(buf) => buf,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return 0,
            Err(e) => {
                eprintln!("registry: cannot read journal {}: {e}", path.display());
                return 0;
            }
        };
        let mut off = 0usize;
        while off < buf.len() {
            if buf.len() - off < 12 {
                eprintln!("registry: journal has a torn tail at byte {off}; ignoring it");
                obsreg::CKPT_CORRUPT_SKIPS.inc();
                return off as u64;
            }
            let len = u32::from_le_bytes(buf[off..off + 4].try_into().unwrap()) as usize;
            let digest = u64::from_le_bytes(buf[off + 4..off + 12].try_into().unwrap());
            let start = off + 12;
            let Some(end) = start.checked_add(len).filter(|&e| e <= buf.len()) else {
                // A record that claims to extend past EOF is a torn final
                // append (the only kind a crash can produce mid-frame).
                eprintln!("registry: journal has a torn record at byte {off}; ignoring it");
                obsreg::CKPT_CORRUPT_SKIPS.inc();
                return off as u64;
            };
            let payload = &buf[start..end];
            off = end;
            // Every complete frame counts toward the record total — a
            // replication snapshot ships them all, digest-bad included
            // (the standby skips those itself), so lag accounting must
            // agree on what "a record" is.
            self.journal_records.fetch_add(1, Ordering::SeqCst);
            if fnv1a(FNV_BASIS, payload) != digest {
                // Damaged in place but the frame is intact: skip just it.
                eprintln!("registry: journal record with bad digest skipped");
                obsreg::CKPT_CORRUPT_SKIPS.inc();
                continue;
            }
            let parsed = std::str::from_utf8(payload)
                .ok()
                .and_then(|s| Json::parse(s).ok());
            let Some(rec) = parsed else {
                eprintln!("registry: unparseable journal record skipped");
                obsreg::CKPT_CORRUPT_SKIPS.inc();
                continue;
            };
            if self.apply_journal_record(&rec) {
                obsreg::JOURNAL_RESTORED.inc();
            } else {
                obsreg::CKPT_CORRUPT_SKIPS.inc();
            }
        }
        buf.len() as u64
    }

    /// Apply one verified journal record; `false` means the record was
    /// well-framed but semantically unusable (unknown kind, missing
    /// fields, failed re-materialization) and was skipped.
    fn apply_journal_record(&self, rec: &Json) -> bool {
        match rec.field("kind").and_then(Json::as_str) {
            Some("dataset") => {
                let Some(sj) = rec.field("spec") else { return false };
                let spec = match DatasetSpec::parse(sj) {
                    Ok(spec) => spec,
                    Err(e) => {
                        eprintln!("registry: journaled dataset spec rejected: {e}");
                        return false;
                    }
                };
                match self.dataset(&spec) {
                    Ok(_) => true,
                    Err(e) => {
                        // e.g. a file-backed dataset whose file changed or
                        // vanished since it was journaled.
                        eprintln!("registry: journaled dataset `{}` not restored: {e}", spec.label());
                        false
                    }
                }
            }
            Some("strikes") => {
                let Some(fp) = rec.field("fp").and_then(Json::as_str).and_then(parse_fp_hex)
                else {
                    return false;
                };
                let Some(count) = rec.field("count").and_then(Json::as_usize) else {
                    return false;
                };
                let count = count as u64;
                if count == 0 {
                    self.strike_ledger.lock().unwrap().remove(&fp);
                } else {
                    self.strike_ledger.lock().unwrap().insert(fp, count);
                }
                // The dataset record replays before its strikes; patch an
                // already-interned entry so the live count matches too.
                if let Some(entry) = self.datasets.lock().unwrap().by_fp.get(&fp) {
                    entry.strikes.store(count, Ordering::SeqCst);
                }
                true
            }
            Some("model") => {
                let Some(fp) = rec.field("fp").and_then(Json::as_str).and_then(parse_fp_hex)
                else {
                    return false;
                };
                let Some(sigma) = rec.field("sigma").and_then(Json::as_f64) else { return false };
                let (Some(beta), Some(grad)) =
                    (rec.field("beta").and_then(json_f64s), rec.field("grad").and_then(json_f64s))
                else {
                    return false;
                };
                if beta.is_empty() || beta.len() != grad.len() {
                    return false;
                }
                let key = rec.field("key").and_then(Json::as_str).unwrap_or("").to_string();
                let seed = PathSeed { sigma, beta, grad };
                if let Some(entry) = self.datasets.lock().unwrap().by_fp.get(&fp) {
                    if seed.beta.len() == entry.problem.p_total() {
                        *entry.restored_seed.lock().unwrap() = Some(seed.clone());
                    }
                }
                // Keep it keyed too, for an entry interned after replay
                // (or re-interned post-eviction). Last record wins: it is
                // the most recent successful build.
                self.restored_seeds.lock().unwrap().insert(fp, (key, seed));
                true
            }
            Some("epoch") => {
                let Some(epoch) = rec.field("epoch").and_then(Json::as_usize) else {
                    return false;
                };
                // Max-merge: replaying an old journal (or a duplicated
                // replication stream) can never lower the fence.
                self.epoch.fetch_max(epoch as u64, Ordering::SeqCst);
                true
            }
            _ => {
                eprintln!("registry: journal record with unknown kind skipped");
                false
            }
        }
    }
}

/// Frame one journal record for disk or wire:
/// `[u32 len (LE)][u64 fnv1a(payload) (LE)][JSON payload]`.
pub fn frame_record(record: &Json) -> Vec<u8> {
    let payload = record.to_string();
    let bytes = payload.as_bytes();
    let mut frame = Vec::with_capacity(bytes.len() + 12);
    frame.extend_from_slice(&(bytes.len() as u32).to_le_bytes());
    frame.extend_from_slice(&fnv1a(FNV_BASIS, bytes).to_le_bytes());
    frame.extend_from_slice(bytes);
    frame
}

/// A replication heartbeat frame: the primary's epoch and record count,
/// so a standby can account lag and detect a silent primary. Framed like
/// a journal record but never journaled by either side.
pub fn heartbeat_frame(epoch: u64, records: u64) -> Vec<u8> {
    frame_record(&Json::obj(vec![
        ("kind", Json::Str("heartbeat".to_string())),
        ("epoch", Json::Num(epoch as f64)),
        ("records", Json::Num(records as f64)),
    ]))
}

/// The journal form of an epoch raise. Epochs are small promotion
/// counters — nowhere near 2^53 — so a plain JSON number is exact.
fn epoch_record(epoch: u64) -> Json {
    Json::obj(vec![("kind", Json::Str("epoch".to_string())), ("epoch", Json::Num(epoch as f64))])
}

/// The journal form of a built model's warm-start seed.
fn seed_record(fp: u64, key: &str, seed: &PathSeed) -> Json {
    Json::obj(vec![
        ("kind", Json::Str("model".to_string())),
        ("fp", Json::Str(fp_hex(fp))),
        ("key", Json::Str(key.to_string())),
        ("sigma", Json::Num(seed.sigma)),
        ("beta", Json::nums(&seed.beta)),
        ("grad", Json::nums(&seed.grad)),
    ])
}

/// `<path><suffix>` as a sibling file (`registry.journal` →
/// `registry.journal.prev`); `Path::with_extension` would eat the
/// `.journal` part.
fn sibling(path: &Path, suffix: &str) -> PathBuf {
    let mut s = path.as_os_str().to_owned();
    s.push(suffix);
    PathBuf::from(s)
}

/// Fingerprints are 64-bit and routinely exceed 2^53, so they journal as
/// hex strings — `Json::Num(f64)` would silently round them.
fn fp_hex(fp: u64) -> String {
    format!("{fp:016x}")
}

fn parse_fp_hex(s: &str) -> Option<u64> {
    u64::from_str_radix(s, 16).ok()
}

fn json_f64s(j: &Json) -> Option<Vec<f64>> {
    let items = j.items();
    let vals: Vec<f64> = items.iter().filter_map(Json::as_f64).collect();
    (vals.len() == items.len()).then_some(vals)
}

/// Serialize a spec for the journal in the exact shape
/// [`DatasetSpec::parse`] reads back. Inline specs return `None`: their
/// payload is client-owned and unbounded, so they are deliberately not
/// durable.
fn spec_to_json(spec: &DatasetSpec) -> Option<Json> {
    match spec {
        DatasetSpec::Synth { n, p, k, rho, design, family, classes, seed } => {
            Some(Json::obj(vec![
                ("kind", Json::Str("synth".to_string())),
                ("n", Json::Num(*n as f64)),
                ("p", Json::Num(*p as f64)),
                ("k", Json::Num(*k as f64)),
                ("rho", Json::Num(*rho)),
                ("design", Json::Str(design.clone())),
                ("family", Json::Str(family.clone())),
                ("classes", Json::Num(*classes as f64)),
                ("seed", Json::Num(*seed as f64)),
            ]))
        }
        DatasetSpec::Real { name } => Some(Json::obj(vec![
            ("kind", Json::Str("real".to_string())),
            ("name", Json::Str(name.clone())),
        ])),
        DatasetSpec::File { path, family, classes, standardize } => Some(Json::obj(vec![
            ("kind", Json::Str("file".to_string())),
            ("path", Json::Str(path.clone())),
            ("family", Json::Str(family.clone())),
            ("classes", Json::Num(*classes as f64)),
            ("standardize", Json::Bool(*standardize)),
        ])),
        DatasetSpec::Inline { .. } => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::slope::path::{fit_path, NativeGradient, PathOptions};
    use crate::slope::lambda::{LambdaKind, PathConfig};
    use std::sync::atomic::AtomicUsize;

    fn spec(seed: u64) -> DatasetSpec {
        DatasetSpec::Synth {
            n: 25,
            p: 40,
            k: 3,
            rho: 0.1,
            design: "compound".to_string(),
            family: "gaussian".to_string(),
            classes: 3,
            seed,
        }
    }

    fn build_model(entry: &DatasetEntry) -> CachedModel {
        let mut cfg = PathConfig::new(LambdaKind::Bh { q: 0.1 });
        cfg.length = 6;
        let opts = PathOptions::new(cfg).with_pack_cache(entry.pack_cache());
        let prob = entry.problem.as_ref();
        let fit = fit_path(prob, &opts, &NativeGradient(prob));
        let seed = fit.seed();
        let wall = fit.wall_time;
        CachedModel { fit, seed, strategy: "strong", wall_time: wall, hits: AtomicU64::new(0) }
    }

    #[test]
    fn datasets_intern_by_fingerprint() {
        let reg = Registry::new(true);
        let a = reg.dataset(&spec(1)).unwrap();
        let b = reg.dataset(&spec(1)).unwrap();
        let c = reg.dataset(&spec(2)).unwrap();
        assert!(Arc::ptr_eq(&a, &b));
        assert!(!Arc::ptr_eq(&a, &c));
        assert_eq!(reg.counts().0, 2);
    }

    #[test]
    fn model_cache_hit_and_miss() {
        let reg = Registry::new(true);
        let entry = reg.dataset(&spec(3)).unwrap();
        let built = reg.model(&entry, "k1", || Ok(build_model(&entry))).unwrap();
        assert_eq!(built.source(), "fit");
        let hit = reg.model(&entry, "k1", || panic!("must not rebuild")).unwrap();
        assert_eq!(hit.source(), "cache");
        assert_eq!(hit.model().hits.load(Ordering::Relaxed), 1);
        assert_eq!(reg.counts(), (1, 1));
    }

    #[test]
    fn cache_disabled_always_rebuilds() {
        let reg = Registry::new(false);
        let entry = reg.dataset(&spec(4)).unwrap();
        let n_builds = AtomicUsize::new(0);
        for _ in 0..3 {
            let f = reg
                .model(&entry, "k", || {
                    n_builds.fetch_add(1, Ordering::SeqCst);
                    Ok(build_model(&entry))
                })
                .unwrap();
            assert_eq!(f.source(), "fit");
        }
        assert_eq!(n_builds.load(Ordering::SeqCst), 3);
    }

    #[test]
    fn failed_build_clears_slot() {
        let reg = Registry::new(true);
        let entry = reg.dataset(&spec(5)).unwrap();
        assert!(reg.model(&entry, "k", || Err(ServeError::from("nope"))).is_err());
        // a later request can build successfully
        let ok = reg.model(&entry, "k", || Ok(build_model(&entry))).unwrap();
        assert_eq!(ok.source(), "fit");
    }

    #[test]
    fn repeated_panics_quarantine_the_dataset() {
        let reg = Registry::new(true);
        let entry = reg.dataset(&spec(21)).unwrap();
        reg.model(&entry, "k", || Ok(build_model(&entry))).unwrap();
        assert_eq!(reg.counts(), (1, 1));
        let before = obsreg::REGISTRY_QUARANTINED.get();
        // two strikes: still serving
        assert!(!reg.record_panic(&entry));
        assert!(!reg.record_panic(&entry));
        assert_eq!(reg.counts().0, 1);
        // third strike: evicted, caches cleared, counter bumped
        assert!(reg.record_panic(&entry));
        assert_eq!(reg.counts(), (0, 0));
        assert_eq!(entry.ready_models(), 0);
        assert!(obsreg::REGISTRY_QUARANTINED.get() > before);
        // a later striker on the stale Arc cannot double-quarantine
        assert!(!reg.record_panic(&entry));
        // the same spec re-interns fresh (zero strikes)
        let fresh = reg.dataset(&spec(21)).unwrap();
        assert!(!Arc::ptr_eq(&entry, &fresh));
        assert_eq!(fresh.strikes.load(Ordering::SeqCst), 0);
    }

    #[test]
    fn concurrent_requests_coalesce() {
        let reg = Arc::new(Registry::new(true));
        let entry = reg.dataset(&spec(6)).unwrap();
        let builds = Arc::new(AtomicUsize::new(0));
        std::thread::scope(|scope| {
            for _ in 0..6 {
                let reg = Arc::clone(&reg);
                let entry = Arc::clone(&entry);
                let builds = Arc::clone(&builds);
                scope.spawn(move || {
                    let f = reg
                        .model(&entry, "shared", || {
                            builds.fetch_add(1, Ordering::SeqCst);
                            // widen the race window so peers land on the gate
                            std::thread::sleep(std::time::Duration::from_millis(30));
                            Ok(build_model(&entry))
                        })
                        .unwrap();
                    assert!(f.model().fit.steps.len() > 1);
                });
            }
        });
        assert_eq!(builds.load(Ordering::SeqCst), 1, "exactly one build must run");
    }

    #[test]
    fn dataset_cache_is_bounded() {
        let reg = Registry::new(true);
        let last = MAX_DATASETS as u64 + 4;
        for seed in 0..=last {
            reg.dataset(&spec(seed)).unwrap();
        }
        let (datasets, _) = reg.counts();
        assert!(datasets <= MAX_DATASETS, "unbounded registry: {datasets}");
        // the newest spec is still interned under its fingerprint
        let again = reg.dataset(&spec(last)).unwrap();
        assert_eq!(again.fingerprint, spec(last).fingerprint());
    }

    #[test]
    fn point_state_round_trip() {
        let reg = Registry::new(true);
        let entry = reg.dataset(&spec(7)).unwrap();
        assert!(entry.point_state("m").is_none());
        let model = build_model(&entry);
        entry.store_point_state("m", PointState { seed: model.seed.clone(), sigma_max: 1.5 });
        let st = entry.point_state("m").unwrap();
        assert_eq!(st.sigma_max, 1.5);
        assert_eq!(st.seed.beta.len(), entry.problem.p_total());
    }

    #[test]
    fn col_norms_are_computed_once_per_dataset() {
        let reg = Registry::new(true);
        let entry = reg.dataset(&spec(31)).unwrap();
        let a = entry.col_norms(crate::linalg::ParConfig::serial());
        let b = entry.col_norms(crate::linalg::ParConfig::serial());
        assert!(Arc::ptr_eq(&a, &b), "second call must reuse the cached vector");
        assert_eq!(a.len(), entry.problem.p());
        assert!(a.iter().all(|&n| n.is_finite() && n >= 0.0));
    }

    #[test]
    fn pack_cache_is_shared_across_fits_on_a_dataset() {
        let reg = Registry::new(false); // model cache off: every fit runs
        let entry = reg.dataset(&spec(9)).unwrap();
        assert!(entry.pack_cache().is_empty());
        reg.model(&entry, "a", || Ok(build_model(&entry))).unwrap();
        assert!(!entry.pack_cache().is_empty(), "a fit must deposit packs");
        let (hits_before, _) = entry.pack_cache().stats();
        // an identical re-fit repeats the same screened sets -> pack hits
        reg.model(&entry, "a", || Ok(build_model(&entry))).unwrap();
        let (hits_after, _) = entry.pack_cache().stats();
        assert!(
            hits_after > hits_before,
            "re-fit must adopt cached packs ({hits_before} -> {hits_after})"
        );
        // a different dataset has its own, empty cache
        let other = reg.dataset(&spec(10)).unwrap();
        assert!(other.pack_cache().is_empty());
    }

    #[test]
    fn sibling_seed_available_after_first_fit() {
        let reg = Registry::new(true);
        let entry = reg.dataset(&spec(8)).unwrap();
        assert!(entry.any_ready_seed().is_none());
        reg.model(&entry, "a", || Ok(build_model(&entry))).unwrap();
        let seed = entry.any_ready_seed().unwrap();
        assert_eq!(seed.beta.len(), entry.problem.p_total());
    }

    /// Fresh per-test state dir (process id + tag keeps parallel test
    /// binaries and parallel tests apart).
    fn state_dir(tag: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("slope-registry-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn journal_restores_datasets_and_seeds_across_restart() {
        let dir = state_dir("restore");
        let expected_p = {
            let reg = Registry::with_state_dir(true, Some(&dir));
            let entry = reg.dataset(&spec(101)).unwrap();
            reg.model(&entry, "k1", || Ok(build_model(&entry))).unwrap();
            entry.problem.p_total()
        }; // "server" exits; only the journal survives
        let reg2 = Registry::with_state_dir(true, Some(&dir));
        assert_eq!(reg2.counts().0, 1, "dataset must be interned from the journal on boot");
        let entry = reg2.dataset(&spec(101)).unwrap();
        let seed = entry.any_ready_seed().expect("journaled seed must warm-start the restart");
        assert_eq!(seed.beta.len(), expected_p);
        assert!(seed.beta.iter().chain(&seed.grad).all(|v| v.is_finite()));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn journaled_seed_round_trips_bitwise() {
        let dir = state_dir("bitwise");
        let original = {
            let reg = Registry::with_state_dir(true, Some(&dir));
            let entry = reg.dataset(&spec(102)).unwrap();
            let built = reg.model(&entry, "k", || Ok(build_model(&entry))).unwrap();
            built.model().seed.clone()
        };
        let reg2 = Registry::with_state_dir(true, Some(&dir));
        let entry = reg2.dataset(&spec(102)).unwrap();
        let restored = entry.any_ready_seed().unwrap();
        let bits = |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(restored.sigma.to_bits(), original.sigma.to_bits());
        assert_eq!(bits(&restored.beta), bits(&original.beta));
        assert_eq!(bits(&restored.grad), bits(&original.grad));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn strikes_survive_restart_but_quarantine_clears_them() {
        let dir = state_dir("strikes");
        {
            let reg = Registry::with_state_dir(true, Some(&dir));
            let entry = reg.dataset(&spec(103)).unwrap();
            assert!(!reg.record_panic(&entry));
            assert!(!reg.record_panic(&entry));
        }
        // Restart: the two strikes must still be charged — a crash-looping
        // dataset cannot launder its count by bouncing the server.
        let reg2 = Registry::with_state_dir(true, Some(&dir));
        let entry = reg2.dataset(&spec(103)).unwrap();
        assert_eq!(entry.strikes.load(Ordering::SeqCst), 2);
        // One more panic quarantines...
        assert!(reg2.record_panic(&entry));
        drop(reg2);
        // ...and the *next* restart replays the explicit zero: the spec
        // re-interns as a deliberate fresh start.
        let reg3 = Registry::with_state_dir(true, Some(&dir));
        let fresh = reg3.dataset(&spec(103)).unwrap();
        assert_eq!(fresh.strikes.load(Ordering::SeqCst), 0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn eviction_does_not_launder_strikes() {
        // In-memory ledger alone must carry strikes across FIFO eviction.
        let reg = Registry::new(true);
        let victim = reg.dataset(&spec(200)).unwrap();
        assert!(!reg.record_panic(&victim));
        for seed in 201..(201 + MAX_DATASETS as u64) {
            reg.dataset(&spec(seed)).unwrap(); // push the victim out
        }
        let again = reg.dataset(&spec(200)).unwrap();
        assert!(!Arc::ptr_eq(&victim, &again), "victim must have been evicted");
        assert_eq!(again.strikes.load(Ordering::SeqCst), 1, "strike must survive eviction");
    }

    #[test]
    fn corrupt_journal_records_are_skipped_never_trusted() {
        let dir = state_dir("corrupt");
        {
            let reg = Registry::with_state_dir(true, Some(&dir));
            reg.dataset(&spec(104)).unwrap();
            reg.dataset(&spec(105)).unwrap();
        }
        let path = dir.join("registry.journal");
        let mut buf = std::fs::read(&path).unwrap();
        // Flip one payload bit inside the first record: its digest check
        // must fail and only that record be dropped.
        buf[14] ^= 0x01;
        // Torn tail: half a frame header from an append cut off mid-crash.
        buf.extend_from_slice(&[0xAA; 5]);
        std::fs::write(&path, &buf).unwrap();
        let skips_before = obsreg::CKPT_CORRUPT_SKIPS.get();
        let reg2 = Registry::with_state_dir(true, Some(&dir));
        assert_eq!(reg2.counts().0, 1, "the intact record must restore, the corrupt one skip");
        assert!(
            obsreg::CKPT_CORRUPT_SKIPS.get() >= skips_before + 2,
            "bit flip and torn tail must both be counted"
        );
        // The surviving journal handle still appends: new interns after a
        // partially-corrupt replay remain durable.
        reg2.dataset(&spec(106)).unwrap();
        drop(reg2);
        let reg3 = Registry::with_state_dir(true, Some(&dir));
        assert_eq!(reg3.counts().0, 2);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn inline_specs_are_not_journaled() {
        let dir = state_dir("inline");
        {
            let reg = Registry::with_state_dir(true, Some(&dir));
            let inline = DatasetSpec::Inline {
                x: vec![vec![1.0, 0.0], vec![0.0, 1.0], vec![1.0, 1.0]],
                y: vec![1.0, 2.0, 3.0],
                family: "gaussian".to_string(),
                classes: 3,
                standardize: false,
            };
            reg.dataset(&inline).unwrap();
            reg.dataset(&spec(107)).unwrap();
            assert_eq!(reg.counts().0, 2);
        }
        let reg2 = Registry::with_state_dir(true, Some(&dir));
        // Only the synth spec survives: inline data is client-owned.
        assert_eq!(reg2.counts().0, 1);
        assert_eq!(reg2.dataset(&spec(107)).unwrap().fingerprint, spec(107).fingerprint());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn no_state_dir_means_no_journal_files() {
        let reg = Registry::new(true);
        assert!(reg.journal.is_none());
        reg.dataset(&spec(108)).unwrap(); // must not touch the filesystem
    }

    fn render(recs: &[Json]) -> String {
        recs.iter().map(Json::to_string).collect::<Vec<_>>().join("\n")
    }

    #[test]
    fn compacted_journal_replays_to_identical_registry() {
        let dir = state_dir("compact");
        let path = dir.join("registry.journal");
        let snap = {
            let reg = Registry::with_state_dir(true, Some(&dir));
            let entry = reg.dataset(&spec(110)).unwrap();
            reg.model(&entry, "k", || Ok(build_model(&entry))).unwrap();
            let other = reg.dataset(&spec(111)).unwrap();
            assert!(!reg.record_panic(&other));
            // Redundant appends a snapshot folds into one record each.
            assert!(reg.bump_epoch_to(2));
            assert!(reg.bump_epoch_to(3));
            assert!(reg.bump_epoch_to(4));
            let snap = render(&reg.snapshot_records());
            let old_len = std::fs::metadata(&path).unwrap().len();
            let compactions = obsreg::JOURNAL_COMPACTIONS.get();
            let reclaimed = obsreg::JOURNAL_BYTES_RECLAIMED.get();
            reg.compact_journal();
            assert!(obsreg::JOURNAL_COMPACTIONS.get() > compactions);
            assert!(obsreg::JOURNAL_BYTES_RECLAIMED.get() > reclaimed);
            let new_len = std::fs::metadata(&path).unwrap().len();
            assert!(new_len < old_len, "snapshot must shrink the journal: {old_len} -> {new_len}");
            assert!(dir.join("registry.journal.prev").exists(), "old journal rotates to .prev");
            assert_eq!(render(&reg.snapshot_records()), snap, "compaction must not change state");
            // The reopened handle still appends durably.
            assert!(reg.bump_epoch_to(6));
            snap.replace("\"epoch\":4", "\"epoch\":6")
        };
        let reg2 = Registry::with_state_dir(true, Some(&dir));
        assert_eq!(render(&reg2.snapshot_records()), snap, "replay of compacted journal");
        assert_eq!(reg2.counts().0, 2);
        assert_eq!(reg2.epoch(), 6);
        let entry = reg2.dataset(&spec(110)).unwrap();
        assert!(entry.restored_seed().is_some(), "seed survives compaction + restart");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn size_triggered_compaction_fires_on_append() {
        let dir = state_dir("autocompact");
        let reg = Registry::with_state_dir(true, Some(&dir));
        reg.dataset(&spec(112)).unwrap();
        let before = obsreg::JOURNAL_COMPACTIONS.get();
        reg.set_compact_bytes(1);
        assert!(reg.bump_epoch_to(1)); // any append past the threshold compacts
        assert!(obsreg::JOURNAL_COMPACTIONS.get() > before);
        drop(reg);
        let reg2 = Registry::with_state_dir(true, Some(&dir));
        assert_eq!(reg2.counts().0, 1);
        assert_eq!(reg2.epoch(), 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn epoch_raises_are_journaled_and_never_lower() {
        let dir = state_dir("epoch");
        {
            let reg = Registry::with_state_dir(true, Some(&dir));
            assert_eq!(reg.epoch(), 0);
            assert_eq!(reg.advance_epoch(), 1);
            assert!(reg.bump_epoch_to(5));
            assert!(!reg.bump_epoch_to(3), "a stale epoch must not lower the fence");
            assert_eq!(reg.epoch(), 5);
        }
        let reg2 = Registry::with_state_dir(true, Some(&dir));
        assert_eq!(reg2.epoch(), 5, "fencing must survive a restart");
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Split a drained subscriber byte stream back into JSON records,
    /// checking each digest — the standby-side framing in miniature.
    fn parse_frames(buf: &[u8]) -> Vec<Json> {
        let mut recs = Vec::new();
        let mut off = 0;
        while off + 12 <= buf.len() {
            let len = u32::from_le_bytes(buf[off..off + 4].try_into().unwrap()) as usize;
            let digest = u64::from_le_bytes(buf[off + 4..off + 12].try_into().unwrap());
            let payload = &buf[off + 12..off + 12 + len];
            assert_eq!(fnv1a(FNV_BASIS, payload), digest, "frame digest");
            recs.push(Json::parse(std::str::from_utf8(payload).unwrap()).unwrap());
            off += 12 + len;
        }
        assert_eq!(off, buf.len(), "no partial frame");
        recs
    }

    #[test]
    fn subscribers_get_snapshot_then_live_appends_in_order() {
        let dir = state_dir("repl");
        let reg = Registry::with_state_dir(true, Some(&dir));
        reg.dataset(&spec(120)).unwrap();
        let sub = Arc::new(ReplSubscriber::new());
        let records = reg.attach_subscriber(Arc::clone(&sub)).unwrap();
        assert_eq!(records, 1, "snapshot carries the pre-subscribe intern");
        assert_eq!(sub.lag_records(), 1);
        reg.dataset(&spec(121)).unwrap();
        assert!(reg.bump_epoch_to(1));
        let mut buf = Vec::new();
        while let Some(chunk) = sub.pop() {
            buf.extend_from_slice(&chunk);
        }
        assert_eq!(sub.lag_records(), 0, "drained queue means zero lag");
        let recs = parse_frames(&buf);
        assert_eq!(recs.len(), 3);
        // A fresh registry applying the stream converges to the same state.
        let replica = Registry::new(true);
        for rec in &recs {
            assert!(replica.apply_replicated(rec), "{rec}");
        }
        assert_eq!(replica.counts().0, 2);
        assert_eq!(replica.epoch(), 1);
        // A detached subscriber stops receiving and drops from stats.
        sub.mark_gone();
        reg.dataset(&spec(122)).unwrap();
        assert_eq!(reg.subscriber_stats().0, 0);
        assert!(sub.pop().is_none());
        let _ = std::fs::remove_dir_all(&dir);
    }
}

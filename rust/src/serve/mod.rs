//! `serve` — a screening-aware SLOPE fit server.
//!
//! The paper's point is that the strong screening rule makes full SLOPE
//! paths cheap in the p ≫ n regime. This layer turns that into a *service*
//! property: a long-running, multi-threaded server that answers
//! `fit_path` / `fit_point` / `predict` / `dataset_from_file` / `stats` /
//! `shutdown` requests over newline-delimited JSON, amortizing gradients,
//! warm starts and screened working sets **across requests**, not just
//! across path steps. Datasets may be synthetic specs, the paper's
//! stand-ins, inline client matrices, or server-side files ingested
//! through [`crate::ingest`] (content-fingerprinted, so renamed copies
//! share one cache entry).
//!
//! Components:
//!
//! * [`protocol`] — the request/response codec built on [`crate::jsonio`]
//!   (no serde offline), including dataset specs (synthetic, simulated-real
//!   or inline client data) and model specs (λ shape + path config).
//! * [`registry`] — the dataset/model registry: datasets are interned by a
//!   64-bit FNV-1a fingerprint of their spec (or raw bytes, for inline
//!   data); fitted models are cached under `(fingerprint, model key)`
//!   together with a [`crate::slope::path::PathSeed`] warm-start state.
//!   Concurrent identical requests are *coalesced*: one fit runs, everyone
//!   shares the result.
//! * [`scheduler`] — dispatches fit jobs onto the [`crate::pool`] worker
//!   pool behind a bounded admission queue (backpressure: submitters block
//!   when the queue is full), and picks the screening strategy per job —
//!   [`crate::slope::path::Strategy::StrongSet`] for cold fits,
//!   [`crate::slope::path::Strategy::PreviousSet`] when a cached seed makes
//!   the previous-set guess (Algorithm 4) cheap and accurate.
//! * [`metrics`] — request counters and latency quantiles (reusing
//!   [`crate::benchkit::Timing`]), exposed through the `stats` request.
//! * [`server`] — the request core plus the blocking transports:
//!   newline-delimited JSON over stdin/stdout or a Unix-domain socket
//!   (thread per connection, drain-latch shutdown handshake). Zero
//!   external crates. With a gather window configured, concurrent
//!   `fit_point`/`predict` requests against the same dataset
//!   fingerprint and option regime coalesce into one packed solve / one
//!   stacked-row gemv, bitwise-identical to sequential handling
//!   (DESIGN.md §14).
//! * [`net`] — the event-driven TCP transport: a non-blocking `poll(2)`
//!   loop owns every connection (readiness-driven read/write buffers,
//!   accept-time connection limits, per-connection write backpressure),
//!   with a bounded dispatcher pool running the handlers.
//! * [`replica`] — the warm-standby replication loop: a standby dials
//!   the primary, handshakes with `repl_subscribe`, and applies the
//!   journal-shipped record stream (digest-checked, epoch-fenced) so a
//!   promotion serves warm from the first request (DESIGN.md §15).
//! * [`client`] — a small blocking client for the socket transports
//!   (Unix or TCP; the `client` CLI subcommand and the serving example
//!   use it), with jittered exponential backoff for retryable
//!   rejections.
//! * [`error`] — the typed [`error::ServeError`] every layer reports:
//!   deadlines with partial progress, overload with `retry_after_ms`,
//!   caught panic payloads, drain rejections (DESIGN.md §12).
//!
//! See `DESIGN.md` §Serve for the protocol reference.

pub mod client;
pub mod error;
pub mod metrics;
#[cfg(unix)]
pub mod net;
pub mod protocol;
pub mod registry;
pub mod replica;
pub mod scheduler;
pub mod server;

pub use server::{Role, Server, ServerConfig};

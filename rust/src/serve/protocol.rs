//! Request/response codec for the serve layer: newline-delimited JSON over
//! [`crate::jsonio`] (no serde offline).
//!
//! A request line is an object `{"id": <u64>, "op": <str>, ...}`. The
//! response line echoes the id: `{"id": ..., "ok": true, "result": {...}}`
//! or `{"id": ..., "ok": false, "error": "..."}`.
//!
//! Ops: `fit_path`, `fit_point`, `predict`, `dataset_from_file`, `stats`,
//! `metrics`, `shutdown`. Fit ops carry a `dataset` spec (`synth`,
//! `real`, `inline` or `file`) and model fields (`lambda`, `q`,
//! `path_length`, `screen`); `fit_point` adds `sigma_ratio`; `predict`
//! adds `x` (rows) and optionally `step`; `dataset_from_file` registers a
//! server-side data file (content-fingerprinted) ahead of any fit;
//! `metrics` takes a `format` (`json` or `prometheus`) and returns the
//! full observability exposition.

use crate::data::real::RealDataset;
use crate::data::synth::{BetaSpec, DesignKind, SyntheticSpec};
use crate::jsonio::Json;
use crate::serve::error::ServeError;
use crate::linalg::{Design, Mat};
use crate::rng::Pcg64;
use crate::slope::family::{Family, Problem};
use crate::slope::lambda::{LambdaKind, PathConfig};
use crate::slope::path::PathOptions;

/// How a request describes the data to fit on.
#[derive(Clone, Debug, PartialEq)]
pub enum DatasetSpec {
    /// Synthetic design generated server-side from a seed (§3.2 setups).
    Synth {
        /// Observations.
        n: usize,
        /// Predictors.
        p: usize,
        /// True support size.
        k: usize,
        /// Correlation parameter.
        rho: f64,
        /// `compound|chain|iid`.
        design: String,
        /// `gaussian|binomial|poisson|multinomial`.
        family: String,
        /// Classes (multinomial only).
        classes: usize,
        /// Generator seed — part of the fingerprint, so two clients asking
        /// for the same spec share one interned dataset.
        seed: u64,
    },
    /// One of the paper's simulated real-dataset stand-ins (§3.3).
    Real {
        /// Dataset name (`golub`, `arcene`, ...).
        name: String,
    },
    /// Client-supplied data inlined in the request.
    Inline {
        /// Design rows (each of length p).
        x: Vec<Vec<f64>>,
        /// Response (length n).
        y: Vec<f64>,
        /// Response family.
        family: String,
        /// Classes (multinomial only).
        classes: usize,
        /// Center+scale columns server-side.
        standardize: bool,
    },
    /// A server-side data file ingested through [`crate::ingest`]
    /// (`.csv` dense, `.svm`/`.svmlight`/`.libsvm` sparse). Fingerprinted
    /// by file *content*, so re-registrations and renamed copies intern
    /// to the same entry and the warm-start/pack caches keep working.
    File {
        /// Server-side path.
        path: String,
        /// Response family.
        family: String,
        /// Classes (multinomial only).
        classes: usize,
        /// Standardize at ingest (off when the file is already in model
        /// coordinates, e.g. our own exports).
        standardize: bool,
    },
}

// The canonical FNV-1a lives in the ingest layer (file fingerprints use
// it too); re-exported here so existing callers keep their import path.
pub use crate::ingest::{fnv1a, FNV_BASIS};

fn parse_family(family: &str, classes: usize) -> Result<Family, String> {
    if family.is_empty() {
        return Ok(Family::Gaussian);
    }
    Family::parse(family, classes)
}

impl DatasetSpec {
    /// Parse the `dataset` field of a request.
    pub fn parse(j: &Json) -> Result<DatasetSpec, String> {
        let kind = str_field(j, "kind", "synth")?;
        match kind.as_str() {
            "synth" => Ok(DatasetSpec::Synth {
                n: usize_field(j, "n", 100)?,
                p: usize_field(j, "p", 500)?,
                k: usize_field(j, "k", 10)?,
                rho: f64_field(j, "rho", 0.0)?,
                design: str_field(j, "design", "compound")?,
                family: str_field(j, "family", "gaussian")?,
                classes: usize_field(j, "classes", 3)?,
                seed: usize_field(j, "seed", 42)? as u64,
            }),
            "real" => Ok(DatasetSpec::Real { name: str_field(j, "name", "")? }),
            "inline" => {
                let x_json = req_field(j, "x")?;
                let mut x = Vec::new();
                for row in x_json.items() {
                    let mut r = Vec::new();
                    for v in row.items() {
                        r.push(v.as_f64().ok_or("inline x must be numeric rows")?);
                    }
                    x.push(r);
                }
                let y: Vec<f64> = req_field(j, "y")?
                    .items()
                    .iter()
                    .map(|v| v.as_f64().ok_or("inline y must be numeric"))
                    .collect::<Result<_, _>>()?;
                Ok(DatasetSpec::Inline {
                    x,
                    y,
                    family: str_field(j, "family", "gaussian")?,
                    classes: usize_field(j, "classes", 3)?,
                    standardize: bool_field(j, "standardize", true)?,
                })
            }
            "file" => {
                let path = req_field(j, "path")?
                    .as_str()
                    .ok_or("field `path` must be a string")?
                    .to_string();
                if path.is_empty() {
                    return Err("field `path` must not be empty".to_string());
                }
                Ok(DatasetSpec::File {
                    path,
                    family: str_field(j, "family", "gaussian")?,
                    classes: usize_field(j, "classes", 3)?,
                    standardize: bool_field(j, "standardize", true)?,
                })
            }
            other => {
                Err(format!("unknown dataset kind `{other}` (expected synth|real|inline|file)"))
            }
        }
    }

    /// Content fingerprint: equal specs (including generator seeds and, for
    /// inline data, the raw bytes) intern to the same registry entry.
    pub fn fingerprint(&self) -> u64 {
        match self {
            DatasetSpec::Synth { n, p, k, rho, design, family, classes, seed } => {
                let canon = format!(
                    "synth:n={n}:p={p}:k={k}:rho={rho}:design={design}:family={family}:classes={classes}:seed={seed}"
                );
                fnv1a(FNV_BASIS, canon.as_bytes())
            }
            DatasetSpec::Real { name } => fnv1a(FNV_BASIS, format!("real:{name}").as_bytes()),
            DatasetSpec::Inline { x, y, family, classes, standardize } => {
                let mut h = fnv1a(
                    FNV_BASIS,
                    format!("inline:family={family}:classes={classes}:std={standardize}").as_bytes(),
                );
                for row in x {
                    h = fnv1a(h, &(row.len() as u64).to_le_bytes());
                    for v in row {
                        h = fnv1a(h, &v.to_bits().to_le_bytes());
                    }
                }
                for v in y {
                    h = fnv1a(h, &v.to_bits().to_le_bytes());
                }
                h
            }
            DatasetSpec::File { path, family, classes, standardize } => {
                let h = fnv1a(
                    FNV_BASIS,
                    format!("file:family={family}:classes={classes}:std={standardize}:")
                        .as_bytes(),
                );
                // Content fingerprint: identical bytes at any path intern
                // to one entry (warm-start/pack caches survive renames).
                // An unreadable file falls back to hashing the path; its
                // materialize then reports the real I/O error.
                crate::ingest::hash_file(h, std::path::Path::new(path))
                    .unwrap_or_else(|_| fnv1a(h, path.as_bytes()))
            }
        }
    }

    /// Short human label for logs and responses.
    pub fn label(&self) -> String {
        match self {
            DatasetSpec::Synth { n, p, family, .. } => format!("synth[{family} n={n} p={p}]"),
            DatasetSpec::Real { name } => format!("real[{name}]"),
            DatasetSpec::Inline { x, y, family, .. } => {
                format!("inline[{family} n={} p={}]", y.len(), x.first().map_or(0, Vec::len))
            }
            DatasetSpec::File { path, family, .. } => {
                let name = std::path::Path::new(path)
                    .file_name()
                    .and_then(|n| n.to_str())
                    .unwrap_or(path.as_str());
                format!("file[{family} {name}]")
            }
        }
    }

    /// Materialize the problem instance. Validates everything that would
    /// otherwise panic inside `Problem::new` or the path driver, so a bad
    /// request yields an error response rather than a dead worker.
    ///
    /// For inline data with `standardize = true`, the returned transform
    /// records the column means/scales so `predict` can map raw client
    /// rows into the model's coordinates. Synthetic/real datasets are
    /// generated server-side directly in model coordinates (`transform:
    /// None`) — clients never observe a raw coordinate system for them.
    pub fn materialize(&self) -> Result<Materialized, String> {
        match self {
            DatasetSpec::Synth { n, p, k, rho, design, family, classes, seed } => {
                if *n == 0 || *p == 0 {
                    return Err("synth dataset needs n > 0 and p > 0".to_string());
                }
                if !(0.0..1.0).contains(rho) {
                    return Err(format!("rho must be in [0,1), got {rho}"));
                }
                let fam = parse_family(family, *classes)?;
                let design = match design.as_str() {
                    "compound" => DesignKind::Compound,
                    "chain" => DesignKind::Chain,
                    "iid" => DesignKind::Iid,
                    other => return Err(format!("unknown design `{other}`")),
                };
                let spec = SyntheticSpec {
                    n: *n,
                    p: *p,
                    rho: *rho,
                    design,
                    beta: match fam {
                        Family::Poisson => BetaSpec::Ladder { k: *k, step: 1.0 / 40.0 },
                        _ => BetaSpec::PlusMinus { k: *k, scale: 2.0 },
                    },
                    family: fam,
                    noise_sd: 1.0,
                    standardize: true,
                };
                Ok(Materialized {
                    problem: spec.generate(&mut Pcg64::new(*seed)),
                    transform: None,
                    intercept: 0.0,
                })
            }
            DatasetSpec::Real { name } => RealDataset::all()
                .into_iter()
                .find(|d| d.name() == name)
                .map(|d| Materialized { problem: d.load(), transform: None, intercept: 0.0 })
                .ok_or_else(|| format!("unknown real dataset `{name}`")),
            DatasetSpec::Inline { x, y, family, classes, standardize } => {
                let n = x.len();
                if n == 0 {
                    return Err("inline dataset has no rows".to_string());
                }
                let p = x[0].len();
                if p == 0 {
                    return Err("inline dataset has no columns".to_string());
                }
                for (i, row) in x.iter().enumerate() {
                    if row.len() != p {
                        return Err(format!("inline row {i} has {} values, expected {p}", row.len()));
                    }
                }
                if y.len() != n {
                    return Err(format!("inline y has {} values, expected {n}", y.len()));
                }
                if let Some(i) = y.iter().position(|v| !v.is_finite()) {
                    return Err(format!("inline y[{i}] is not finite"));
                }
                let fam = parse_family(family, *classes)?;
                match fam {
                    Family::Binomial => {
                        if !y.iter().all(|&v| v == 0.0 || v == 1.0) {
                            return Err("binomial response must be 0/1".to_string());
                        }
                    }
                    Family::Poisson => {
                        if !y.iter().all(|&v| v >= 0.0) {
                            return Err("poisson response must be non-negative".to_string());
                        }
                    }
                    Family::Multinomial { classes } => {
                        if !y
                            .iter()
                            .all(|&v| v >= 0.0 && v < classes as f64 && v.fract() == 0.0)
                        {
                            return Err("multinomial response must be class indices".to_string());
                        }
                    }
                    Family::Gaussian => {}
                }
                let mut mat = Mat::zeros(n, p);
                for (i, row) in x.iter().enumerate() {
                    for (j, &v) in row.iter().enumerate() {
                        if !v.is_finite() {
                            return Err(format!("inline x[{i}][{j}] is not finite"));
                        }
                        mat.set(i, j, v);
                    }
                }
                let transform = if *standardize {
                    let n_f = n as f64;
                    let mut means = Vec::with_capacity(p);
                    let mut inv_norms = Vec::with_capacity(p);
                    for j in 0..p {
                        let col = mat.col(j);
                        let mean = col.iter().sum::<f64>() / n_f;
                        let norm = col
                            .iter()
                            .map(|v| (v - mean) * (v - mean))
                            .sum::<f64>()
                            .sqrt();
                        means.push(mean);
                        inv_norms.push(if norm > 0.0 { 1.0 / norm } else { 0.0 });
                    }
                    mat.standardize_with(true, true, crate::linalg::ParConfig::default());
                    Some(ColumnTransform { means, inv_norms })
                } else {
                    None
                };
                // With a centered design the intercept-free model cannot
                // absorb mean(y); center gaussian responses and keep the
                // offset so predictions return to the client's scale.
                let mut y_fit = y.clone();
                let mut intercept = 0.0;
                if *standardize && fam == Family::Gaussian {
                    intercept = crate::linalg::ops::mean(&y_fit);
                    for v in y_fit.iter_mut() {
                        *v -= intercept;
                    }
                }
                // Entry values are finite, but standardization can still
                // overflow (huge columns: mean = ∞ ⇒ NaN after scaling).
                // The ingest-layer guard keeps such data out of the
                // solver — an error response, never a NaN-poisoned fit.
                let design = Design::Dense(mat);
                crate::ingest::check_finite(&design, &y_fit)
                    .map_err(|e| format!("inline dataset: {e}"))?;
                Ok(Materialized {
                    problem: Problem::new(design, y_fit, fam),
                    transform,
                    intercept,
                })
            }
            DatasetSpec::File { path, family, classes, standardize } => {
                let fam = parse_family(family, *classes)?;
                let opts = crate::ingest::IngestOptions::default()
                    .with_family(fam)
                    .with_standardize(*standardize);
                let ing = crate::ingest::load_path(std::path::Path::new(path), &opts)
                    .map_err(|e| format!("ingest `{path}`: {e}"))?;
                let transform = ing
                    .stats
                    .map(|s| ColumnTransform { means: s.means, inv_norms: s.inv_norms });
                Ok(Materialized { problem: ing.problem, transform, intercept: ing.intercept })
            }
        }
    }
}

/// Column standardization applied to a design before fitting; kept so
/// `predict` can map raw client rows into the model's coordinates.
#[derive(Clone, Debug, PartialEq)]
pub struct ColumnTransform {
    /// Per-column mean subtracted before scaling.
    pub means: Vec<f64>,
    /// Per-column reciprocal of the centered ℓ2 norm (0 for constant
    /// columns, matching [`Mat::standardize`]).
    pub inv_norms: Vec<f64>,
}

impl ColumnTransform {
    /// Map one raw feature row into model coordinates.
    pub fn apply(&self, row: &[f64]) -> Vec<f64> {
        row.iter()
            .zip(self.means.iter().zip(&self.inv_norms))
            .map(|(&v, (&mean, &inv))| (v - mean) * inv)
            .collect()
    }
}

/// A materialized dataset: the fit-ready problem plus the raw-row →
/// model-row transform (when one was applied server-side).
pub struct Materialized {
    /// The problem the solver fits.
    pub problem: Problem,
    /// Transform for mapping prediction rows (None = rows are already in
    /// model coordinates).
    pub transform: Option<ColumnTransform>,
    /// Offset added back to predicted scores (mean of y removed before a
    /// gaussian fit on a centered design; 0 otherwise).
    pub intercept: f64,
}

/// Model-side request fields: penalty shape and path/screen configuration.
#[derive(Clone, Debug, PartialEq)]
pub struct ModelSpec {
    /// `bh|oscar|lasso|gaussian-seq`.
    pub lambda: String,
    /// BH/OSCAR/Gaussian parameter.
    pub q: f64,
    /// Path length for `fit_path`.
    pub path_length: usize,
    /// `auto|none|strong|previous|safe|hybrid` — `auto` lets the
    /// scheduler choose from cache state.
    pub screen: String,
    /// Kernel thread budget for this request's fit (0 = the scheduler's
    /// per-job split of the machine). Like `screen`, a performance knob
    /// that never changes the solution — deliberately not part of the
    /// cache identity.
    pub threads: usize,
    /// Relative duality-gap tolerance for the gap-driven screens
    /// (`safe`/`hybrid`); 0 defers to the server-wide default. Bounded to
    /// the tolerance regime `(0, 1e-4]` so it stays a solver knob, and —
    /// like `screen`/`threads` — excluded from the cache identity.
    pub gap_tol: f64,
    /// Per-request deadline in milliseconds (0 = the server-wide default,
    /// which itself defaults to no deadline). A budget, not a model
    /// parameter: like `screen`/`threads`/`gap_tol` it is excluded from
    /// the cache identity — an expired request never caches a partial
    /// fit, and a completed one is the same fit at any budget.
    pub deadline_ms: u64,
}

impl ModelSpec {
    /// Parse model fields (with serving defaults) from a request object.
    pub fn parse(j: &Json) -> Result<ModelSpec, String> {
        let spec = ModelSpec {
            lambda: str_field(j, "lambda", "bh")?,
            q: f64_field(j, "q", 0.1)?,
            path_length: usize_field(j, "path_length", 50)?,
            screen: str_field(j, "screen", "auto")?,
            threads: usize_field(j, "threads", 0)?,
            gap_tol: f64_field(j, "gap_tol", 0.0)?,
            deadline_ms: usize_field(j, "deadline_ms", 0)? as u64,
        };
        if spec.path_length == 0 {
            return Err("path_length must be >= 1".to_string());
        }
        if spec.threads > 256 {
            return Err(format!("threads must be <= 256, got {}", spec.threads));
        }
        // 0 = server default; explicit values must stay in the tolerance
        // regime (a large "tolerance" would change solutions, which the
        // cache identity assumes it cannot). `!(..)` also rejects NaN.
        if spec.gap_tol != 0.0 && !(spec.gap_tol > 0.0 && spec.gap_tol <= 1e-4) {
            return Err(format!("gap_tol must be in (0, 1e-4], got {}", spec.gap_tol));
        }
        match spec.lambda.as_str() {
            "bh" | "gaussian-seq" => {
                if !(spec.q > 0.0 && spec.q < 1.0) {
                    return Err(format!("lambda `{}` needs q in (0,1), got {}", spec.lambda, spec.q));
                }
            }
            "oscar" => {
                if spec.q < 0.0 {
                    return Err(format!("oscar needs q >= 0, got {}", spec.q));
                }
            }
            "lasso" => {}
            other => {
                return Err(format!(
                    "unknown lambda `{other}` (expected bh|oscar|lasso|gaussian-seq)"
                ))
            }
        }
        Ok(spec)
    }

    /// Cache key within a dataset entry. `screen`, `threads` and
    /// `gap_tol` are deliberately *not* part of the identity: all three
    /// are per-job performance strategies that never change the solution
    /// beyond solver tolerance (the KKT safeguard guarantees it for the
    /// heuristic screens, the duality-gap certificate for the gap-driven
    /// ones — `gap_tol` is bounded to the tolerance regime at parse
    /// time; the parallel dense kernels are bitwise-deterministic, and
    /// the one reduction-based sparse kernel agrees to rounding — far
    /// inside the fit tolerance), so requests differing only in them
    /// share one fitted model.
    pub fn key(&self) -> String {
        format!("{}:q={}:len={}", self.lambda, self.q, self.path_length)
    }

    /// Cache key for `fit_point` warm-start state: `path_length` only
    /// shapes `fit_path` grids, so point streams share their state
    /// across it (only the penalty identity matters).
    pub fn point_key(&self) -> String {
        format!("{}:q={}", self.lambda, self.q)
    }

    /// Coalescing key for cross-request batching (DESIGN.md §14).
    /// Requests may share one batched solve only when *everything* that
    /// could alter their handling matches: the dataset (its
    /// fingerprint), the penalty identity (`op_key` — [`ModelSpec::point_key`]
    /// for `fit_point`, [`ModelSpec::key`] plus the step for `predict`),
    /// and the full tolerance/performance regime. Note the asymmetry
    /// with the cache keys: `screen`/`threads`/`gap_tol`/`deadline_ms`
    /// are excluded from cache identity (any regime produces the same
    /// solution) but **included** here, because a batch runs its members
    /// under one shared option set — members must agree on it so each is
    /// handled exactly as it would have been alone.
    pub fn batch_key(&self, fingerprint: u64, op_key: &str) -> u64 {
        let canon = format!(
            "{fingerprint:016x}:{op_key}:screen={}:threads={}:gap_tol={:016x}:deadline={}",
            self.screen,
            self.threads,
            self.gap_tol.to_bits(),
            self.deadline_ms
        );
        fnv1a(FNV_BASIS, canon.as_bytes())
    }

    /// Build the path options (strategy is chosen later, per job).
    pub fn path_options(&self, prob: &Problem) -> Result<PathOptions, String> {
        let kind = match self.lambda.as_str() {
            "bh" => LambdaKind::Bh { q: self.q },
            "oscar" => LambdaKind::Oscar { q: self.q },
            "lasso" => LambdaKind::Lasso,
            "gaussian-seq" => LambdaKind::Gaussian { q: self.q, n: prob.n() },
            other => return Err(format!("unknown lambda `{other}`")),
        };
        let mut cfg = PathConfig::new(kind);
        cfg.length = self.path_length;
        let mut opts = PathOptions::new(cfg);
        if self.gap_tol > 0.0 {
            opts = opts.with_gap_tol(self.gap_tol);
        }
        Ok(opts)
    }
}

/// A parsed request body.
#[derive(Clone, Debug)]
pub enum Request {
    /// Fit (or serve from cache) a full path.
    FitPath {
        /// Data to fit on.
        dataset: DatasetSpec,
        /// Penalty/path configuration.
        model: ModelSpec,
    },
    /// Fit a single path point at `sigma = sigma_ratio · σ_max`.
    FitPoint {
        /// Data to fit on.
        dataset: DatasetSpec,
        /// Penalty configuration.
        model: ModelSpec,
        /// Relative penalty scale in (0, 1].
        sigma_ratio: f64,
    },
    /// Predict linear scores for new rows from a fitted path.
    Predict {
        /// Data the model was fitted on.
        dataset: DatasetSpec,
        /// Penalty/path configuration identifying the model.
        model: ModelSpec,
        /// Rows to score.
        x: Vec<Vec<f64>>,
        /// Path step to use (default: last).
        step: Option<usize>,
    },
    /// Register (intern) a server-side data file without fitting: the
    /// file is ingested, fingerprinted by content and cached, so later
    /// fit requests for it skip materialization entirely.
    RegisterDataset {
        /// The file-backed dataset to intern.
        dataset: DatasetSpec,
    },
    /// Server/cache/latency statistics.
    Stats,
    /// Full metrics exposition: serve counters, per-op latency quantiles
    /// and the global observability registry, as JSON or Prometheus text.
    Metrics {
        /// `json` (default) or `prometheus`.
        format: String,
    },
    /// Role, epoch, replication lag and readiness — the probe op
    /// (DESIGN.md §15). Always served, whatever the role.
    Health,
    /// Promote this server to primary: bump the failover epoch and start
    /// accepting writes. Idempotent on a server that is already primary.
    Promote,
    /// Subscribe to the primary's journal stream (replication). Parsed
    /// here for a total grammar, but only the TCP transport serves it —
    /// after the handshake response the connection stops speaking
    /// NDJSON and carries raw journal frames.
    ReplSubscribe {
        /// The subscriber's own epoch; a primary with a lower epoch must
        /// fence itself instead of streaming.
        epoch: u64,
    },
    /// Stop the server after responding.
    Shutdown,
}

/// A request with its client-chosen id.
#[derive(Clone, Debug)]
pub struct Envelope {
    /// Echoed back in the response.
    pub id: u64,
    /// The operation.
    pub request: Request,
}

impl Envelope {
    /// Parse one request line. Errors carry the request id when the line
    /// was at least valid JSON (0 otherwise), so error responses still
    /// correlate with their requests.
    pub fn parse_line(line: &str) -> Result<Envelope, (u64, String)> {
        let j = Json::parse(line).map_err(|e| (0, format!("bad request JSON: {e}")))?;
        let id = usize_field(&j, "id", 0).map_err(|e| (0, e))? as u64;
        match parse_request(&j) {
            Ok(request) => Ok(Envelope { id, request }),
            Err(e) => Err((id, e)),
        }
    }
}

fn parse_request(j: &Json) -> Result<Request, String> {
    let op = str_field(j, "op", "")?;
    let request = match op.as_str() {
        "fit_path" => Request::FitPath {
            dataset: DatasetSpec::parse(req_field(j, "dataset")?)?,
            model: ModelSpec::parse(j)?,
        },
        "fit_point" => {
            let ratio = f64_field(j, "sigma_ratio", 0.5)?;
            if !(ratio > 0.0 && ratio <= 1.0) {
                return Err(format!("sigma_ratio must be in (0,1], got {ratio}"));
            }
            Request::FitPoint {
                dataset: DatasetSpec::parse(req_field(j, "dataset")?)?,
                model: ModelSpec::parse(j)?,
                sigma_ratio: ratio,
            }
        }
        "predict" => {
            let x_json = req_field(j, "x")?;
            let mut x = Vec::new();
            for row in x_json.items() {
                let mut r = Vec::new();
                for v in row.items() {
                    r.push(v.as_f64().ok_or("predict x must be numeric rows")?);
                }
                x.push(r);
            }
            Request::Predict {
                dataset: DatasetSpec::parse(req_field(j, "dataset")?)?,
                model: ModelSpec::parse(j)?,
                x,
                step: j.field("step").and_then(Json::as_usize),
            }
        }
        "dataset_from_file" | "dataset-from-file" => {
            let dataset = DatasetSpec::parse(req_field(j, "dataset")?)?;
            if !matches!(dataset, DatasetSpec::File { .. }) {
                return Err("dataset_from_file requires a dataset of kind `file`".to_string());
            }
            Request::RegisterDataset { dataset }
        }
        "stats" => Request::Stats,
        "metrics" => {
            let format = str_field(j, "format", "json")?;
            if format != "json" && format != "prometheus" {
                return Err(format!(
                    "unknown metrics format `{format}` (expected json|prometheus)"
                ));
            }
            Request::Metrics { format }
        }
        "health" => Request::Health,
        "promote" => Request::Promote,
        "repl_subscribe" => {
            Request::ReplSubscribe { epoch: usize_field(j, "epoch", 0)? as u64 }
        }
        "shutdown" => Request::Shutdown,
        "" => return Err("request missing `op`".to_string()),
        other => {
            return Err(format!(
                "unknown op `{other}` (expected fit_path|fit_point|predict|dataset_from_file|stats|metrics|health|promote|repl_subscribe|shutdown)"
            ))
        }
    };
    Ok(request)
}

/// Success response line (no trailing newline).
pub fn ok_response(id: u64, result: Json) -> String {
    Json::obj(vec![
        ("id", Json::Num(id as f64)),
        ("ok", Json::Bool(true)),
        ("result", result),
    ])
    .to_string()
}

/// Error response line (no trailing newline). Untyped legacy shape —
/// everything serve-side now goes through [`error_response`]; this
/// remains for parse-stage failures, which are always `invalid`.
pub fn err_response(id: u64, message: &str) -> String {
    error_response(id, &ServeError::Invalid(message.to_string()))
}

/// Typed error response line (no trailing newline).
///
/// Always `{"id", "ok": false, "error", "error_kind"}`; overload adds
/// `retry_after_ms`, deadline expiry adds `partial` with `steps_done`
/// and (when a gap-driven solve certified one) the last duality `gap`.
pub fn error_response(id: u64, err: &ServeError) -> String {
    let mut fields = vec![
        ("id", Json::Num(id as f64)),
        ("ok", Json::Bool(false)),
        ("error", Json::Str(err.message())),
        ("error_kind", Json::Str(err.kind().to_string())),
    ];
    if let Some(ms) = err.retry_after_ms() {
        fields.push(("retry_after_ms", Json::Num(ms as f64)));
    }
    if let ServeError::Deadline { steps_done, gap, .. } = err {
        let mut partial = vec![("steps_done", Json::Num(*steps_done as f64))];
        if let Some(g) = gap {
            partial.push(("gap", Json::Num(*g)));
        }
        fields.push(("partial", Json::obj(partial)));
    }
    Json::obj(fields).to_string()
}

/// Build a request line (client-side convenience).
pub fn request_line(id: u64, op: &str, mut fields: Vec<(&str, Json)>) -> String {
    let mut all = vec![("id", Json::Num(id as f64)), ("op", Json::Str(op.to_string()))];
    all.append(&mut fields);
    Json::obj(all).to_string()
}

/// JSON for a synthetic-dataset spec (client-side convenience).
pub fn synth_dataset_json(n: usize, p: usize, k: usize, rho: f64, family: &str, seed: u64) -> Json {
    Json::obj(vec![
        ("kind", Json::Str("synth".to_string())),
        ("n", Json::Num(n as f64)),
        ("p", Json::Num(p as f64)),
        ("k", Json::Num(k as f64)),
        ("rho", Json::Num(rho)),
        ("family", Json::Str(family.to_string())),
        ("seed", Json::Num(seed as f64)),
    ])
}

// --- field helpers -------------------------------------------------------
// Absent fields take their documented defaults; *present* fields of the
// wrong type are errors — a client sending `"q": "0.02"` must get a parse
// error, not a silent fit of the default model.

fn req_field<'a>(j: &'a Json, key: &str) -> Result<&'a Json, String> {
    j.field(key).ok_or_else(|| format!("request missing `{key}`"))
}

fn str_field(j: &Json, key: &str, default: &str) -> Result<String, String> {
    match j.field(key) {
        None => Ok(default.to_string()),
        Some(v) => v
            .as_str()
            .map(str::to_string)
            .ok_or_else(|| format!("field `{key}` must be a string")),
    }
}

fn f64_field(j: &Json, key: &str, default: f64) -> Result<f64, String> {
    match j.field(key) {
        None => Ok(default),
        Some(v) => v
            .as_f64()
            .ok_or_else(|| format!("field `{key}` must be a number")),
    }
}

fn usize_field(j: &Json, key: &str, default: usize) -> Result<usize, String> {
    match j.field(key) {
        None => Ok(default),
        Some(v) => {
            let x = v
                .as_f64()
                .ok_or_else(|| format!("field `{key}` must be a number"))?;
            if x < 0.0 || x.fract() != 0.0 {
                return Err(format!("field `{key}` must be a non-negative integer, got {x}"));
            }
            Ok(x as usize)
        }
    }
}

fn bool_field(j: &Json, key: &str, default: bool) -> Result<bool, String> {
    match j.field(key) {
        None => Ok(default),
        Some(Json::Bool(b)) => Ok(*b),
        Some(_) => Err(format!("field `{key}` must be a boolean")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_fit_path_request() {
        let line = r#"{"id": 7, "op": "fit_path", "dataset": {"kind": "synth", "n": 40, "p": 80, "seed": 1}, "lambda": "bh", "q": 0.05, "path_length": 12}"#;
        let env = Envelope::parse_line(line).unwrap();
        assert_eq!(env.id, 7);
        match env.request {
            Request::FitPath { dataset, model } => {
                assert_eq!(model.q, 0.05);
                assert_eq!(model.path_length, 12);
                assert_eq!(model.screen, "auto");
                match dataset {
                    DatasetSpec::Synth { n, p, .. } => {
                        assert_eq!((n, p), (40, 80));
                    }
                    other => panic!("wrong dataset: {other:?}"),
                }
            }
            other => panic!("wrong request: {other:?}"),
        }
    }

    #[test]
    fn rejects_bad_requests() {
        assert!(Envelope::parse_line("not json").is_err());
        assert!(Envelope::parse_line(r#"{"id": 1}"#).is_err());
        assert!(Envelope::parse_line(r#"{"id": 1, "op": "dance"}"#).is_err());
        assert!(Envelope::parse_line(
            r#"{"id": 1, "op": "fit_point", "dataset": {"kind": "synth"}, "sigma_ratio": 2.0}"#
        )
        .is_err());
        assert!(Envelope::parse_line(
            r#"{"id": 1, "op": "fit_path", "dataset": {"kind": "synth"}, "q": 7.0}"#
        )
        .is_err());
    }

    #[test]
    fn fingerprints_distinguish_specs() {
        let a = DatasetSpec::parse(
            &Json::parse(r#"{"kind": "synth", "n": 50, "p": 100, "seed": 1}"#).unwrap(),
        )
        .unwrap();
        let b = DatasetSpec::parse(
            &Json::parse(r#"{"kind": "synth", "n": 50, "p": 100, "seed": 2}"#).unwrap(),
        )
        .unwrap();
        let a2 = DatasetSpec::parse(
            &Json::parse(r#"{"kind": "synth", "n": 50, "p": 100, "seed": 1}"#).unwrap(),
        )
        .unwrap();
        assert_eq!(a.fingerprint(), a2.fingerprint());
        assert_ne!(a.fingerprint(), b.fingerprint());
    }

    #[test]
    fn batch_key_separates_regimes_but_not_sigmas() {
        let base = ModelSpec {
            lambda: "bh".to_string(),
            q: 0.1,
            path_length: 50,
            screen: "auto".to_string(),
            threads: 0,
            gap_tol: 0.0,
            deadline_ms: 0,
        };
        let fp = 0xdead_beef_u64;
        let k = base.batch_key(fp, &base.point_key());
        // Same spec, same key — sigma_ratio is NOT in the key (batching
        // across σ is the whole point).
        assert_eq!(k, base.batch_key(fp, &base.point_key()));
        // Different dataset, penalty, or any regime knob splits the batch.
        assert_ne!(k, base.batch_key(fp + 1, &base.point_key()));
        let q2 = ModelSpec { q: 0.2, ..base.clone() };
        assert_ne!(k, q2.batch_key(fp, &q2.point_key()));
        let strong = ModelSpec { screen: "strong".to_string(), ..base.clone() };
        assert_ne!(k, strong.batch_key(fp, &strong.point_key()));
        let threads = ModelSpec { threads: 2, ..base.clone() };
        assert_ne!(k, threads.batch_key(fp, &threads.point_key()));
        let tol = ModelSpec { gap_tol: 1e-6, ..base.clone() };
        assert_ne!(k, tol.batch_key(fp, &tol.point_key()));
        let dl = ModelSpec { deadline_ms: 100, ..base.clone() };
        assert_ne!(k, dl.batch_key(fp, &dl.point_key()));
        // predict keys (model key + step) stay apart from fit_point keys.
        assert_ne!(
            base.batch_key(fp, &format!("predict:{}:step=3", base.key())),
            base.batch_key(fp, &base.point_key())
        );
    }

    #[test]
    fn inline_fingerprint_tracks_data() {
        let mk = |v: f64| DatasetSpec::Inline {
            x: vec![vec![1.0, v], vec![0.5, 1.0]],
            y: vec![1.0, 0.0],
            family: "gaussian".to_string(),
            classes: 3,
            standardize: true,
        };
        assert_eq!(mk(2.0).fingerprint(), mk(2.0).fingerprint());
        assert_ne!(mk(2.0).fingerprint(), mk(2.000001).fingerprint());
    }

    #[test]
    fn inline_materialize_validates() {
        let ragged = DatasetSpec::Inline {
            x: vec![vec![1.0, 2.0], vec![3.0]],
            y: vec![0.0, 1.0],
            family: "gaussian".to_string(),
            classes: 3,
            standardize: false,
        };
        assert!(ragged.materialize().is_err());
        let bad_labels = DatasetSpec::Inline {
            x: vec![vec![1.0], vec![2.0]],
            y: vec![0.0, 2.0],
            family: "binomial".to_string(),
            classes: 3,
            standardize: false,
        };
        assert!(bad_labels.materialize().is_err());
        let good = DatasetSpec::Inline {
            x: vec![vec![1.0, 0.0], vec![0.0, 1.0], vec![1.0, 1.0]],
            y: vec![0.0, 1.0, 1.0],
            family: "binomial".to_string(),
            classes: 3,
            standardize: false,
        };
        let m = good.materialize().unwrap();
        assert_eq!((m.problem.n(), m.problem.p()), (3, 2));
        assert!(m.transform.is_none());
    }

    #[test]
    fn inline_transform_maps_raw_rows_to_model_coordinates() {
        let rows = [vec![1.0, 10.0], vec![2.0, 20.0], vec![3.0, 60.0]];
        let spec = DatasetSpec::Inline {
            x: rows.to_vec(),
            y: vec![0.1, 0.2, 0.3],
            family: "gaussian".to_string(),
            classes: 3,
            standardize: true,
        };
        let m = spec.materialize().unwrap();
        let transform = m.transform.expect("standardized inline data records a transform");
        let x_model = m.problem.x.as_dense().unwrap();
        // transforming the original raw rows reproduces the fitted design
        for (i, row) in rows.iter().enumerate() {
            let got = transform.apply(row);
            for j in 0..2 {
                assert!(
                    (got[j] - x_model.get(i, j)).abs() < 1e-12,
                    "row {i} col {j}: {} vs {}",
                    got[j],
                    x_model.get(i, j)
                );
            }
        }
    }

    #[test]
    fn wrong_typed_fields_are_errors_not_defaults() {
        // q as a string must not silently fit the default model
        assert!(Envelope::parse_line(
            r#"{"id": 1, "op": "fit_path", "dataset": {"kind": "synth"}, "q": "0.02"}"#
        )
        .is_err());
        // negative sizes must not saturate to a default
        assert!(Envelope::parse_line(
            r#"{"id": 1, "op": "fit_path", "dataset": {"kind": "synth", "n": -5}}"#
        )
        .is_err());
        assert!(Envelope::parse_line(
            r#"{"id": 1, "op": "fit_path", "dataset": {"kind": "synth"}, "path_length": "100"}"#
        )
        .is_err());
    }

    #[test]
    fn inline_gaussian_centers_y_and_records_intercept() {
        let spec = DatasetSpec::Inline {
            x: vec![vec![1.0], vec![2.0], vec![3.0]],
            y: vec![101.0, 102.0, 103.0],
            family: "gaussian".to_string(),
            classes: 3,
            standardize: true,
        };
        let m = spec.materialize().unwrap();
        assert!((m.intercept - 102.0).abs() < 1e-12);
        assert!(crate::linalg::ops::mean(&m.problem.y).abs() < 1e-12);
        // non-gaussian responses are never shifted
        let spec2 = DatasetSpec::Inline {
            x: vec![vec![1.0], vec![2.0]],
            y: vec![0.0, 1.0],
            family: "binomial".to_string(),
            classes: 3,
            standardize: true,
        };
        let m2 = spec2.materialize().unwrap();
        assert_eq!(m2.intercept, 0.0);
        assert_eq!(m2.problem.y, vec![0.0, 1.0]);
    }

    #[test]
    fn inline_overflow_during_standardization_is_rejected() {
        // Every raw entry is finite, but the column mean overflows to ∞,
        // centering yields -∞ and the zero inverse-norm scale yields NaN
        // — the ingest-layer guard must turn this into an error response
        // instead of handing the solver a NaN design (regression: before
        // the guard, this materialized successfully).
        let spec = DatasetSpec::Inline {
            x: vec![vec![1e308], vec![1e308], vec![-1e308]],
            y: vec![0.0, 1.0, 2.0],
            family: "gaussian".to_string(),
            classes: 3,
            standardize: true,
        };
        let err = spec.materialize().err().expect("overflowing inline data must be rejected");
        assert!(err.contains("not finite"), "unexpected error: {err}");
        // the same data without standardization is finite and accepted
        let raw = DatasetSpec::Inline {
            x: vec![vec![1e308], vec![1e308], vec![-1e308]],
            y: vec![0.0, 1.0, 2.0],
            family: "gaussian".to_string(),
            classes: 3,
            standardize: false,
        };
        assert!(raw.materialize().is_ok());
    }

    fn tmp_file(name: &str, contents: &str) -> std::path::PathBuf {
        let path = std::env::temp_dir()
            .join(format!("slope-protocol-{}-{name}", std::process::id()));
        std::fs::write(&path, contents).unwrap();
        path
    }

    #[test]
    fn file_spec_fingerprints_by_content_not_path() {
        let a = tmp_file("fp-a.csv", "x1,y\n1,0\n2,1\n");
        let b = tmp_file("fp-b.csv", "x1,y\n1,0\n2,1\n");
        let c = tmp_file("fp-c.csv", "x1,y\n1,0\n2,2\n");
        let spec = |p: &std::path::Path| DatasetSpec::File {
            path: p.to_str().unwrap().to_string(),
            family: "gaussian".to_string(),
            classes: 3,
            standardize: true,
        };
        assert_eq!(spec(&a).fingerprint(), spec(&b).fingerprint());
        assert_ne!(spec(&a).fingerprint(), spec(&c).fingerprint());
        // the spec prefix is part of the identity: same bytes, other family
        let other_family = DatasetSpec::File {
            path: a.to_str().unwrap().to_string(),
            family: "binomial".to_string(),
            classes: 3,
            standardize: true,
        };
        assert_ne!(spec(&a).fingerprint(), other_family.fingerprint());
        for p in [a, b, c] {
            let _ = std::fs::remove_file(p);
        }
    }

    #[test]
    fn file_spec_materializes_and_missing_files_error() {
        let path = tmp_file("mat.csv", "x1,x2,y\n1,0,0.5\n0,1,-0.5\n2,2,0\n");
        let spec = DatasetSpec::File {
            path: path.to_str().unwrap().to_string(),
            family: "gaussian".to_string(),
            classes: 3,
            standardize: false,
        };
        let m = spec.materialize().unwrap();
        assert_eq!((m.problem.n(), m.problem.p()), (3, 2));
        assert!(m.transform.is_none());
        assert_eq!(m.problem.y, vec![0.5, -0.5, 0.0]);
        let _ = std::fs::remove_file(&path);
        let err = spec.materialize().err().expect("missing file must error");
        assert!(err.contains("ingest"), "unexpected error: {err}");
    }

    #[test]
    fn dataset_from_file_op_parses_and_requires_file_kind() {
        let line = r#"{"id": 3, "op": "dataset_from_file", "dataset": {"kind": "file", "path": "/tmp/x.csv", "family": "binomial"}}"#;
        let env = Envelope::parse_line(line).unwrap();
        assert!(matches!(
            env.request,
            Request::RegisterDataset { dataset: DatasetSpec::File { .. } }
        ));
        // hyphenated spelling accepted too
        let line = r#"{"id": 3, "op": "dataset-from-file", "dataset": {"kind": "file", "path": "/tmp/x.csv"}}"#;
        assert!(Envelope::parse_line(line).is_ok());
        // non-file specs are rejected for this op
        let line = r#"{"id": 3, "op": "dataset_from_file", "dataset": {"kind": "synth"}}"#;
        assert!(Envelope::parse_line(line).is_err());
        // empty paths are rejected at parse time
        let line = r#"{"id": 3, "op": "fit_path", "dataset": {"kind": "file", "path": ""}}"#;
        assert!(Envelope::parse_line(line).is_err());
    }

    #[test]
    fn threads_is_a_perf_knob_not_an_identity() {
        let a = ModelSpec::parse(&Json::parse(r#"{"lambda": "bh", "q": 0.05}"#).unwrap()).unwrap();
        let b = ModelSpec::parse(
            &Json::parse(r#"{"lambda": "bh", "q": 0.05, "threads": 4}"#).unwrap(),
        )
        .unwrap();
        assert_eq!(a.threads, 0);
        assert_eq!(b.threads, 4);
        assert_eq!(a.key(), b.key());
        assert_eq!(a.point_key(), b.point_key());
        // absurd budgets are rejected, not obeyed
        assert!(ModelSpec::parse(
            &Json::parse(r#"{"lambda": "bh", "q": 0.05, "threads": 100000}"#).unwrap()
        )
        .is_err());
    }

    #[test]
    fn gap_tol_is_a_perf_knob_not_an_identity() {
        let a = ModelSpec::parse(&Json::parse(r#"{"lambda": "bh", "q": 0.05}"#).unwrap()).unwrap();
        let b = ModelSpec::parse(
            &Json::parse(r#"{"lambda": "bh", "q": 0.05, "gap_tol": 1e-9, "screen": "hybrid"}"#)
                .unwrap(),
        )
        .unwrap();
        assert_eq!(a.gap_tol, 0.0);
        assert_eq!(b.gap_tol, 1e-9);
        assert_eq!(b.screen, "hybrid");
        assert_eq!(a.key(), b.key());
        assert_eq!(a.point_key(), b.point_key());
        // out-of-regime "tolerances" are rejected, not cached
        for bad in [r#""gap_tol": 0.5"#, r#""gap_tol": -1e-9"#, r#""gap_tol": 1e-3"#] {
            let line = format!(r#"{{"lambda": "bh", "q": 0.05, {bad}}}"#);
            assert!(ModelSpec::parse(&Json::parse(&line).unwrap()).is_err(), "{bad}");
        }
        // a valid gap_tol flows into the path options
        let prob = crate::data::synth::SyntheticSpec {
            n: 10,
            p: 4,
            rho: 0.0,
            design: crate::data::synth::DesignKind::Iid,
            beta: crate::data::synth::BetaSpec::PlusMinus { k: 1, scale: 1.0 },
            family: crate::slope::family::Family::Gaussian,
            noise_sd: 1.0,
            standardize: true,
        }
        .generate(&mut crate::rng::Pcg64::new(5));
        let opts = b.path_options(&prob).unwrap();
        assert_eq!(opts.gap_tol, 1e-9);
        let default_opts = a.path_options(&prob).unwrap();
        assert!(default_opts.gap_tol > 0.0, "library default stays in place");
    }

    #[test]
    fn point_key_ignores_path_length() {
        let j = Json::parse(r#"{"lambda": "bh", "q": 0.05, "path_length": 20}"#).unwrap();
        let a = ModelSpec::parse(&j).unwrap();
        let j = Json::parse(r#"{"lambda": "bh", "q": 0.05, "path_length": 80}"#).unwrap();
        let b = ModelSpec::parse(&j).unwrap();
        assert_ne!(a.key(), b.key());
        assert_eq!(a.point_key(), b.point_key());
    }

    #[test]
    fn metrics_op_parses_with_format_validation() {
        let env = Envelope::parse_line(r#"{"id": 4, "op": "metrics"}"#).unwrap();
        match env.request {
            Request::Metrics { format } => assert_eq!(format, "json"),
            other => panic!("wrong request: {other:?}"),
        }
        let env =
            Envelope::parse_line(r#"{"id": 4, "op": "metrics", "format": "prometheus"}"#).unwrap();
        assert!(matches!(env.request, Request::Metrics { format } if format == "prometheus"));
        let (_, msg) =
            Envelope::parse_line(r#"{"id": 4, "op": "metrics", "format": "xml"}"#).unwrap_err();
        assert!(msg.contains("unknown metrics format"), "{msg}");
    }

    #[test]
    fn failover_ops_parse() {
        let env = Envelope::parse_line(r#"{"id": 1, "op": "health"}"#).unwrap();
        assert!(matches!(env.request, Request::Health));
        let env = Envelope::parse_line(r#"{"id": 2, "op": "promote"}"#).unwrap();
        assert!(matches!(env.request, Request::Promote));
        let env = Envelope::parse_line(r#"{"id": 3, "op": "repl_subscribe", "epoch": 7}"#).unwrap();
        assert!(matches!(env.request, Request::ReplSubscribe { epoch: 7 }));
        // epoch defaults to 0 for a never-promoted standby
        let env = Envelope::parse_line(r#"{"id": 4, "op": "repl_subscribe"}"#).unwrap();
        assert!(matches!(env.request, Request::ReplSubscribe { epoch: 0 }));
    }

    #[test]
    fn parse_errors_keep_request_id() {
        let (id, msg) = Envelope::parse_line(r#"{"id": 9, "op": "dance"}"#).unwrap_err();
        assert_eq!(id, 9);
        assert!(msg.contains("unknown op"));
        let (id0, _) = Envelope::parse_line("garbage").unwrap_err();
        assert_eq!(id0, 0);
    }

    #[test]
    fn synth_materialize_matches_spec_dimensions() {
        let spec = DatasetSpec::Synth {
            n: 30,
            p: 50,
            k: 5,
            rho: 0.2,
            design: "compound".to_string(),
            family: "gaussian".to_string(),
            classes: 3,
            seed: 9,
        };
        let prob = spec.materialize().unwrap().problem;
        assert_eq!((prob.n(), prob.p()), (30, 50));
        // deterministic: same spec, same data
        let again = spec.materialize().unwrap().problem;
        assert_eq!(prob.y, again.y);
    }

    #[test]
    fn responses_echo_id_and_shape() {
        let ok = ok_response(12, Json::obj(vec![("x", Json::Num(1.0))]));
        let j = Json::parse(&ok).unwrap();
        assert_eq!(j.field("id").unwrap().as_usize(), Some(12));
        assert_eq!(j.field("ok"), Some(&Json::Bool(true)));
        let err = err_response(3, "boom");
        let j = Json::parse(&err).unwrap();
        assert_eq!(j.field("ok"), Some(&Json::Bool(false)));
        assert_eq!(j.field("error").unwrap().as_str(), Some("boom"));
        // the legacy helper is now typed under the hood
        assert_eq!(j.field("error_kind").unwrap().as_str(), Some("invalid"));
    }

    #[test]
    fn deadline_ms_is_a_perf_knob_not_an_identity() {
        let a = ModelSpec::parse(&Json::parse(r#"{"lambda": "bh", "q": 0.05}"#).unwrap()).unwrap();
        let b = ModelSpec::parse(
            &Json::parse(r#"{"lambda": "bh", "q": 0.05, "deadline_ms": 250}"#).unwrap(),
        )
        .unwrap();
        assert_eq!(a.deadline_ms, 0);
        assert_eq!(b.deadline_ms, 250);
        assert_eq!(a.key(), b.key());
        assert_eq!(a.point_key(), b.point_key());
        // non-integer budgets are rejected, not truncated
        assert!(ModelSpec::parse(
            &Json::parse(r#"{"lambda": "bh", "q": 0.05, "deadline_ms": 1.5}"#).unwrap()
        )
        .is_err());
    }

    #[test]
    fn typed_error_responses_carry_kind_hint_and_partial() {
        let line = error_response(7, &ServeError::Overload { retry_after_ms: 150 });
        let j = Json::parse(&line).unwrap();
        assert_eq!(j.field("id").unwrap().as_usize(), Some(7));
        assert_eq!(j.field("ok"), Some(&Json::Bool(false)));
        assert_eq!(j.field("error_kind").unwrap().as_str(), Some("overload"));
        assert_eq!(j.field("retry_after_ms").unwrap().as_usize(), Some(150));

        let line = error_response(
            8,
            &ServeError::Deadline { deadline_ms: 5, steps_done: 3, gap: Some(0.25) },
        );
        let j = Json::parse(&line).unwrap();
        assert_eq!(j.field("error_kind").unwrap().as_str(), Some("deadline"));
        let partial = j.field("partial").unwrap();
        assert_eq!(partial.field("steps_done").unwrap().as_usize(), Some(3));
        assert_eq!(partial.field("gap").unwrap().as_f64(), Some(0.25));
        // no hint on non-retryable errors
        assert!(j.field("retry_after_ms").is_none());

        let line = error_response(9, &ServeError::Shutdown);
        let j = Json::parse(&line).unwrap();
        assert_eq!(j.field("error_kind").unwrap().as_str(), Some("shutdown"));
        assert!(j.field("partial").is_none());
    }

    #[test]
    fn request_line_round_trips() {
        let line = request_line(
            5,
            "fit_path",
            vec![("dataset", synth_dataset_json(20, 30, 3, 0.1, "gaussian", 1))],
        );
        let env = Envelope::parse_line(&line).unwrap();
        assert_eq!(env.id, 5);
        assert!(matches!(env.request, Request::FitPath { .. }));
    }
}

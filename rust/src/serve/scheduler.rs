//! Job dispatch for the serve layer: a bounded admission queue with
//! backpressure in front of the [`crate::pool::WorkerPool`], plus the
//! per-job screening-strategy policy.
//!
//! Request threads (one per connection) call [`Scheduler::run_job`] and
//! block for their result; at most `capacity` jobs are admitted at once,
//! so a burst of heavy fits queues here instead of oversubscribing the
//! pool. Failures are typed ([`ServeError`], DESIGN.md §12):
//!
//! * Panics inside jobs are caught and surfaced as
//!   [`ServeError::Panic`] carrying the payload — a malformed problem
//!   must produce an error response, not a dead worker.
//! * A job whose [`CancelToken`] fires while *parked in the queue*
//!   abandons its ticket and returns [`ServeError::Deadline`] with zero
//!   steps done (deadline waiters park on a 10 ms `wait_timeout` so
//!   expiry is noticed promptly; tokenless waiters block indefinitely,
//!   exactly as before).
//! * After [`Scheduler::begin_drain`], queued and new jobs are rejected
//!   with [`ServeError::Shutdown`]; admitted jobs run to completion.
//! * With an opt-in shed limit (off for a raw scheduler; the server
//!   configures it), jobs arriving to a deep queue are rejected with
//!   [`ServeError::Overload`] and a `retry_after_ms` hint instead of
//!   parking — the default remains blocking backpressure.
//!
//! The module also hosts the cross-request [`Batcher`] (DESIGN.md §14):
//! a keyed gather queue that coalesces requests sharing a batch key
//! (dataset fingerprint + tolerance regime) arriving within a small
//! window into one leader-executed batch, per-request results handed
//! back through [`BatchGate`]s.

use std::collections::{HashMap, HashSet};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::obs::registry as obsreg;
use crate::pool::WorkerPool;
use crate::serve::error::ServeError;
use crate::slope::cancel::CancelToken;
use crate::slope::path::Strategy;

/// How often a deadline-carrying waiter re-checks its token while parked.
const DEADLINE_POLL: Duration = Duration::from_millis(10);

/// Admission-gate state: a ticket queue makes waiting strictly FIFO —
/// under sustained load the longest-parked request is always admitted
/// next (bare condvar wakeups carry no ordering guarantee). Tickets whose
/// holders gave up (deadline expiry, drain) land in `abandoned` so the
/// serving counter can skip over them.
#[derive(Default)]
struct GateState {
    admitted: usize,
    next_ticket: u64,
    now_serving: u64,
    draining: bool,
    abandoned: HashSet<u64>,
}

impl GateState {
    /// Requests parked on tickets (abandoned ones excluded).
    fn waiting(&self) -> u64 {
        (self.next_ticket - self.now_serving).saturating_sub(self.abandoned.len() as u64)
    }

    /// Skip over abandoned tickets so the queue keeps moving after a
    /// waiter gives up. Call whenever `now_serving` advances or a ticket
    /// at the front is abandoned.
    fn advance(&mut self) {
        while self.abandoned.remove(&self.now_serving) {
            self.now_serving += 1;
        }
    }

    /// Publish the gate's levels as registry gauges (called under the
    /// gate lock at every transition, so the published pair is always a
    /// consistent snapshot). `admitted` is queued-on-pool+running.
    fn publish(&self) {
        obsreg::SERVE_QUEUE_DEPTH.set(self.waiting());
        obsreg::SERVE_IN_FLIGHT.set(self.admitted as u64);
    }
}

/// Per-job dispatch options.
#[derive(Clone, Debug, Default)]
pub struct JobOptions {
    /// Deadline/cancellation token: checked while parked in the queue
    /// (the job body is expected to poll it too, via
    /// [`crate::slope::path::PathOptions::cancel`]).
    pub cancel: Option<CancelToken>,
    /// May this job be load-shed when the queue is deep? The server sets
    /// this for fit jobs; cheap jobs (stats, metrics) bypass the
    /// scheduler entirely.
    pub shed: bool,
}

/// Bounded-queue dispatcher over a worker pool.
pub struct Scheduler {
    pool: WorkerPool,
    gate: Arc<(Mutex<GateState>, Condvar)>,
    capacity: usize,
    fit_threads: usize,
    shed_limit: Option<usize>,
}

impl Scheduler {
    /// `threads = 0` sizes the pool to the machine; `capacity` bounds the
    /// number of admitted (queued + running) jobs. The per-job kernel
    /// thread budget defaults to the machine budget split across the
    /// pool's workers (override with [`Scheduler::set_fit_threads`]).
    pub fn new(threads: usize, capacity: usize) -> Scheduler {
        let pool = if threads == 0 {
            WorkerPool::with_default_size()
        } else {
            WorkerPool::new(threads)
        };
        let fit_threads = crate::pool::fit_thread_budget(pool.size());
        Scheduler {
            pool,
            gate: Arc::new((Mutex::new(GateState::default()), Condvar::new())),
            capacity: capacity.max(1),
            fit_threads,
            shed_limit: None,
        }
    }

    /// Worker threads in the pool.
    pub fn threads(&self) -> usize {
        self.pool.size()
    }

    /// Admission capacity.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Kernel threads each fit job may use (the `linalg::par` budget
    /// handed to [`crate::slope::path::PathOptions::threads`]): with
    /// `pool.size()` fits running at once, each gets its share of the
    /// machine so concurrent fits don't oversubscribe it.
    pub fn fit_threads(&self) -> usize {
        self.fit_threads
    }

    /// Override the per-job kernel thread budget (serve's
    /// `--fit-threads` / `fit_threads` config; 0 restores the automatic
    /// split).
    pub fn set_fit_threads(&mut self, fit_threads: usize) {
        self.fit_threads = if fit_threads == 0 {
            crate::pool::fit_thread_budget(self.pool.size())
        } else {
            fit_threads
        };
    }

    /// Currently admitted jobs.
    pub fn in_flight(&self) -> usize {
        self.gate.0.lock().unwrap().admitted
    }

    /// Requests parked in the admission queue right now (abandoned
    /// tickets excluded) — the `health` op's queue-depth figure.
    pub fn queue_depth(&self) -> usize {
        self.gate.0.lock().unwrap().waiting() as usize
    }

    /// Opt into load-shedding: jobs submitted with `shed: true` while
    /// `limit` or more requests are parked are rejected with
    /// [`ServeError::Overload`] instead of blocking. `None` (the
    /// default) keeps pure blocking backpressure.
    pub fn set_shed_limit(&mut self, limit: Option<usize>) {
        self.shed_limit = limit;
    }

    /// Begin a graceful drain: every parked and future submission is
    /// rejected with [`ServeError::Shutdown`]; jobs already admitted run
    /// to completion (await them with [`Scheduler::await_idle`]).
    pub fn begin_drain(&self) {
        let mut state = self.gate.0.lock().unwrap();
        state.draining = true;
        self.gate.1.notify_all();
    }

    /// Has a drain begun?
    pub fn draining(&self) -> bool {
        self.gate.0.lock().unwrap().draining
    }

    /// Block until no jobs are admitted (queued-on-pool or running).
    pub fn await_idle(&self) {
        let mut state = self.gate.0.lock().unwrap();
        while state.admitted > 0 {
            state = self.gate.1.wait(state).unwrap();
        }
    }

    /// [`Scheduler::run_job`] with default options (no token, no shed).
    pub fn run<T, F>(&self, f: F) -> Result<T, ServeError>
    where
        T: Send + 'static,
        F: FnOnce() -> T + Send + 'static,
    {
        self.run_job(JobOptions::default(), f)
    }

    /// Run `f` on the pool and block for its result. Applies backpressure
    /// (blocks while `capacity` jobs are admitted; admission is FIFO by
    /// arrival); converts panics, queue-time deadline expiry, drain and
    /// overload into typed errors.
    pub fn run_job<T, F>(&self, opts: JobOptions, f: F) -> Result<T, ServeError>
    where
        T: Send + 'static,
        F: FnOnce() -> T + Send + 'static,
    {
        {
            let mut state = self.gate.0.lock().unwrap();
            if state.draining {
                obsreg::SERVE_SHUTDOWN_REJECTED.inc();
                return Err(ServeError::Shutdown);
            }
            if opts.shed {
                if let Some(limit) = self.shed_limit {
                    let waiting = state.waiting() as usize;
                    if waiting >= limit {
                        obsreg::SERVE_LOAD_SHED.inc();
                        let retry_after_ms = (waiting as u64 * 50).clamp(50, 5000);
                        return Err(ServeError::Overload { retry_after_ms });
                    }
                }
            }
            let ticket = state.next_ticket;
            state.next_ticket += 1;
            state.publish();
            loop {
                if state.draining {
                    state.abandoned.insert(ticket);
                    state.advance();
                    state.publish();
                    self.gate.1.notify_all();
                    obsreg::SERVE_SHUTDOWN_REJECTED.inc();
                    return Err(ServeError::Shutdown);
                }
                if let Some(tok) = opts.cancel.as_ref() {
                    if tok.is_cancelled() {
                        state.abandoned.insert(ticket);
                        state.advance();
                        state.publish();
                        self.gate.1.notify_all();
                        obsreg::SERVE_DEADLINE_EXPIRED.inc();
                        return Err(ServeError::Deadline {
                            deadline_ms: tok.deadline_ms().unwrap_or(0),
                            steps_done: 0,
                            gap: None,
                        });
                    }
                }
                if state.now_serving == ticket && state.admitted < self.capacity {
                    break;
                }
                state = if opts.cancel.is_some() {
                    self.gate.1.wait_timeout(state, DEADLINE_POLL).unwrap().0
                } else {
                    self.gate.1.wait(state).unwrap()
                };
            }
            state.admitted += 1;
            state.now_serving += 1;
            state.advance();
            state.publish();
            // Wake the next ticket holder (it may be admissible already).
            self.gate.1.notify_all();
        }
        let (tx, rx) = mpsc::channel();
        let gate = Arc::clone(&self.gate);
        self.pool.submit(move || {
            let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(f));
            let _ = tx.send(outcome);
            let mut state = gate.0.lock().unwrap();
            state.admitted -= 1;
            state.publish();
            gate.1.notify_all();
        });
        match rx.recv() {
            Ok(Ok(value)) => Ok(value),
            Ok(Err(panic)) => {
                obsreg::SERVE_WORKER_PANICS.inc();
                let message = if let Some(s) = panic.downcast_ref::<&str>() {
                    (*s).to_string()
                } else if let Some(s) = panic.downcast_ref::<String>() {
                    s.clone()
                } else {
                    "unknown panic".to_string()
                };
                Err(ServeError::Panic { message })
            }
            Err(_) => Err(ServeError::Failed("worker dropped the job result".to_string())),
        }
    }
}

/// One request's seat in a coalesced batch: the leader executes the
/// batch and delivers every member's result here; the member blocks in
/// [`BatchGate::wait`]. One-shot — a second deliver replaces an untaken
/// result, which no correct leader does.
pub struct BatchGate<R> {
    slot: Mutex<Option<Result<R, ServeError>>>,
    cv: Condvar,
}

impl<R> BatchGate<R> {
    fn new() -> Arc<BatchGate<R>> {
        Arc::new(BatchGate { slot: Mutex::new(None), cv: Condvar::new() })
    }

    /// Hand this member its result and wake it.
    pub fn deliver(&self, result: Result<R, ServeError>) {
        *self.slot.lock().unwrap() = Some(result);
        self.cv.notify_all();
    }

    /// Block until the leader delivers this member's result.
    pub fn wait(&self) -> Result<R, ServeError> {
        let mut slot = self.slot.lock().unwrap();
        loop {
            if let Some(result) = slot.take() {
                return result;
            }
            slot = self.cv.wait(slot).unwrap();
        }
    }
}

/// What [`Batcher::submit`] made of a request: the first arrival under a
/// key becomes the **leader** (it must call [`Batcher::gather`] and
/// execute the batch); later arrivals within the window are **joiners**
/// that park on their gate until the leader delivers.
pub enum Submitted<R> {
    /// Execute the batch: gather with the returned `(key, gen)`, run the
    /// items, deliver every gate. The leader's own item is the first one
    /// gathered (arrival order is preserved).
    Leader { key: u64, gen: u64 },
    /// Wait on the gate; some leader owns this request now.
    Joiner(Arc<BatchGate<R>>),
}

/// An open batch: members in arrival order. `closed` flips when the
/// batch fills to `max_batch` (the leader's gather returns immediately)
/// or when the leader's window expires.
struct OpenBatch<I, R> {
    closed: bool,
    items: Vec<(I, Arc<BatchGate<R>>)>,
}

struct BatchMap<I, R> {
    /// All un-gathered batches, keyed by `(batch key, generation)` — the
    /// generation distinguishes successive batches under one key.
    batches: HashMap<(u64, u64), OpenBatch<I, R>>,
    /// The currently joinable generation per key. A key absent here means
    /// the next arrival starts a fresh batch (and leads it).
    current: HashMap<u64, u64>,
    next_gen: u64,
}

/// Keyed gather queue for cross-request coalescing. `submit` is
/// non-blocking and lock-scoped; the leader alone pays the gather-window
/// wait. Correctness does not depend on timing: a batch is just the set
/// of requests the leader happened to collect, and the executor runs
/// them in arrival order — any gather outcome is a valid sequential
/// serialization (DESIGN.md §14).
pub struct Batcher<I, R> {
    inner: Mutex<BatchMap<I, R>>,
    cv: Condvar,
    window: Duration,
    max_batch: usize,
}

impl<I, R> Batcher<I, R> {
    /// A batcher gathering for `window_ms` with at most `max_batch`
    /// members per batch (a full batch closes early).
    pub fn new(window_ms: u64, max_batch: usize) -> Batcher<I, R> {
        Batcher {
            inner: Mutex::new(BatchMap {
                batches: HashMap::new(),
                current: HashMap::new(),
                next_gen: 0,
            }),
            cv: Condvar::new(),
            window: Duration::from_millis(window_ms),
            max_batch: max_batch.max(1),
        }
    }

    /// Enqueue one request under its batch key. First arrival leads;
    /// later arrivals join until the batch closes (gathered or full).
    pub fn submit(&self, key: u64, item: I) -> Submitted<R> {
        let gate = BatchGate::new();
        let mut map = self.inner.lock().unwrap();
        if let Some(&gen) = map.current.get(&key) {
            let batch = map
                .batches
                .get_mut(&(key, gen))
                .expect("current generation must have an open batch");
            batch.items.push((item, Arc::clone(&gate)));
            obsreg::SERVE_BATCHED_REQUESTS.inc();
            if batch.items.len() >= self.max_batch {
                batch.closed = true;
                map.current.remove(&key);
                self.cv.notify_all();
            }
            return Submitted::Joiner(gate);
        }
        let gen = map.next_gen;
        map.next_gen += 1;
        map.batches
            .insert((key, gen), OpenBatch { closed: false, items: vec![(item, gate)] });
        map.current.insert(key, gen);
        Submitted::Leader { key, gen }
    }

    /// Leader side: park for the gather window (or until the batch
    /// fills), then take the batch. Returns the members in arrival order
    /// — the leader's own item first.
    pub fn gather(&self, key: u64, gen: u64) -> Vec<(I, Arc<BatchGate<R>>)> {
        let deadline = Instant::now() + self.window;
        let mut map = self.inner.lock().unwrap();
        loop {
            let closed = map
                .batches
                .get(&(key, gen))
                .expect("leader's batch cannot disappear before gather")
                .closed;
            if closed {
                break;
            }
            let now = Instant::now();
            if now >= deadline {
                // Window over: close the batch ourselves so no further
                // joiner slips in after we release the lock.
                if map.current.get(&key) == Some(&gen) {
                    map.current.remove(&key);
                }
                break;
            }
            map = self.cv.wait_timeout(map, deadline - now).unwrap().0;
        }
        let batch = map.batches.remove(&(key, gen)).expect("gather takes the batch once");
        obsreg::SERVE_BATCHES.inc();
        batch.items
    }
}

/// Screening-strategy policy: explicit request wins; `auto` uses the
/// previous-set algorithm (Algorithm 4) when a cached warm-start seed
/// exists — the previous support is then a good guess and the strong set
/// only serves as the first KKT check — and the strong-set algorithm
/// (Algorithm 3) on cold fits.
pub fn choose_strategy(requested: &str, warm: bool) -> Result<Strategy, String> {
    Ok(match requested {
        "none" => Strategy::NoScreening,
        "strong" => Strategy::StrongSet,
        "previous" => Strategy::PreviousSet,
        "safe" => Strategy::SafeOnly,
        "hybrid" => Strategy::GapHybrid,
        "auto" | "" => {
            if warm {
                Strategy::PreviousSet
            } else {
                Strategy::StrongSet
            }
        }
        other => {
            return Err(format!(
                "unknown screening strategy `{other}` (expected auto|none|strong|previous|safe|hybrid)"
            ))
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn runs_jobs_and_returns_results() {
        let sched = Scheduler::new(2, 4);
        assert_eq!(sched.run(|| 2 + 3).unwrap(), 5);
        assert_eq!(sched.in_flight(), 0);
    }

    #[test]
    fn catches_panics() {
        let sched = Scheduler::new(1, 2);
        let before = obsreg::SERVE_WORKER_PANICS.get();
        let err = sched.run(|| -> usize { panic!("kaboom {}", 7) }).unwrap_err();
        // typed, with the payload preserved and the counter bumped
        assert_eq!(err.kind(), "panic");
        assert!(err.message().contains("kaboom 7"), "{err}");
        assert!(obsreg::SERVE_WORKER_PANICS.get() > before);
        // the pool survives the panic
        assert_eq!(sched.run(|| 1usize).unwrap(), 1);
    }

    #[test]
    fn expired_token_abandons_its_queue_ticket() {
        let sched = Scheduler::new(1, 1);
        // Occupy the single admission slot with a slow job...
        let slow = std::thread::scope(|scope| {
            let sched = &sched;
            let occupier = scope.spawn(move || {
                sched.run(|| std::thread::sleep(std::time::Duration::from_millis(120)))
            });
            // ...give it time to be admitted, then park a pre-expired job.
            std::thread::sleep(std::time::Duration::from_millis(30));
            let tok = CancelToken::with_deadline_ms(1);
            std::thread::sleep(std::time::Duration::from_millis(5));
            let opts = JobOptions { cancel: Some(tok), shed: false };
            let err = sched.run_job(opts, || 1usize).unwrap_err();
            assert_eq!(err.kind(), "deadline");
            if let ServeError::Deadline { steps_done, deadline_ms, .. } = err {
                assert_eq!(steps_done, 0);
                assert_eq!(deadline_ms, 1);
            }
            occupier.join().unwrap()
        });
        slow.unwrap();
        // the abandoned ticket does not wedge the queue
        assert_eq!(sched.run(|| 3usize).unwrap(), 3);
    }

    #[test]
    fn drain_rejects_new_jobs_but_finishes_admitted_ones() {
        let sched = Scheduler::new(2, 4);
        let result = std::thread::scope(|scope| {
            let sched = &sched;
            let inflight = scope.spawn(move || {
                sched.run(|| {
                    std::thread::sleep(std::time::Duration::from_millis(60));
                    41usize + 1
                })
            });
            std::thread::sleep(std::time::Duration::from_millis(20));
            sched.begin_drain();
            assert!(sched.draining());
            // post-drain submissions get the typed rejection
            let err = sched.run(|| 0usize).unwrap_err();
            assert_eq!(err, ServeError::Shutdown);
            inflight.join().unwrap()
        });
        // the admitted job ran to completion
        assert_eq!(result.unwrap(), 42);
        sched.await_idle();
        assert_eq!(sched.in_flight(), 0);
    }

    #[test]
    fn shed_limit_rejects_instead_of_parking() {
        let mut sched = Scheduler::new(1, 1);
        sched.set_shed_limit(Some(0)); // shed anything that would park
        let err = sched
            .run_job(JobOptions { cancel: None, shed: true }, || 1usize)
            .unwrap_err();
        assert_eq!(err.kind(), "overload");
        let hint = err.retry_after_ms().unwrap();
        assert!((50..=5000).contains(&hint), "hint {hint} out of range");
        // shed-exempt jobs still run
        assert_eq!(
            sched.run_job(JobOptions { cancel: None, shed: false }, || 2usize).unwrap(),
            2
        );
    }

    #[test]
    fn backpressure_bounds_admission() {
        let sched = Arc::new(Scheduler::new(2, 2));
        let peak = Arc::new(AtomicUsize::new(0));
        let live = Arc::new(AtomicUsize::new(0));
        std::thread::scope(|scope| {
            for _ in 0..8 {
                let sched = Arc::clone(&sched);
                let peak = Arc::clone(&peak);
                let live = Arc::clone(&live);
                scope.spawn(move || {
                    sched
                        .run(move || {
                            let now = live.fetch_add(1, Ordering::SeqCst) + 1;
                            peak.fetch_max(now, Ordering::SeqCst);
                            std::thread::sleep(std::time::Duration::from_millis(10));
                            live.fetch_sub(1, Ordering::SeqCst);
                        })
                        .unwrap();
                });
            }
        });
        assert!(peak.load(Ordering::SeqCst) <= 2, "admission cap exceeded");
    }

    #[test]
    fn fit_thread_budget_splits_the_machine() {
        let mut sched = Scheduler::new(4, 8);
        // auto budget: total/workers, at least 1
        assert!(sched.fit_threads() >= 1);
        assert!(sched.fit_threads() <= crate::linalg::par::MAX_AUTO_THREADS);
        // explicit override wins; 0 restores the automatic split
        sched.set_fit_threads(3);
        assert_eq!(sched.fit_threads(), 3);
        sched.set_fit_threads(0);
        // (compared loosely: another test may race the global setting)
        assert!(sched.fit_threads() >= 1);
    }

    #[test]
    fn batcher_coalesces_and_demuxes_in_arrival_order() {
        let batcher: Arc<Batcher<u32, u32>> = Arc::new(Batcher::new(2000, 8));
        let lead = match batcher.submit(7, 100) {
            Submitted::Leader { key, gen } => (key, gen),
            Submitted::Joiner(_) => panic!("first arrival must lead"),
        };
        let joiners: Vec<_> = std::thread::scope(|scope| {
            let handles: Vec<_> = (0..3u32)
                .map(|i| {
                    let batcher = Arc::clone(&batcher);
                    scope.spawn(move || match batcher.submit(7, 101 + i) {
                        Submitted::Joiner(gate) => gate.wait(),
                        Submitted::Leader { .. } => panic!("open batch must absorb arrivals"),
                    })
                })
                .collect();
            // Let the joiners enqueue, then gather and execute: result =
            // item · 2, delivered per member.
            std::thread::sleep(Duration::from_millis(100));
            let items = batcher.gather(lead.0, lead.1);
            assert_eq!(items.len(), 4);
            assert_eq!(items[0].0, 100, "leader's item comes first");
            for (item, gate) in &items {
                gate.deliver(Ok(item * 2));
            }
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        let mut got: Vec<u32> = joiners.into_iter().map(|r| r.unwrap()).collect();
        got.sort_unstable();
        assert_eq!(got, vec![202, 204, 206]);
    }

    #[test]
    fn full_batch_closes_before_the_window() {
        let batcher: Arc<Batcher<u32, u32>> = Arc::new(Batcher::new(60_000, 2));
        let lead = match batcher.submit(1, 0) {
            Submitted::Leader { key, gen } => (key, gen),
            Submitted::Joiner(_) => panic!("first arrival must lead"),
        };
        let gate = match batcher.submit(1, 1) {
            Submitted::Joiner(gate) => gate,
            Submitted::Leader { .. } => panic!("second arrival must join"),
        };
        // max_batch reached: gather returns far inside the 60 s window...
        let t0 = Instant::now();
        let items = batcher.gather(lead.0, lead.1);
        assert!(t0.elapsed() < Duration::from_secs(10), "gather must not wait the window out");
        assert_eq!(items.len(), 2);
        // ...and the key is free again — the next arrival leads a new batch.
        assert!(matches!(batcher.submit(1, 2), Submitted::Leader { .. }));
        for (item, g) in &items {
            g.deliver(Ok(*item));
        }
        assert_eq!(gate.wait().unwrap(), 1);
    }

    #[test]
    fn distinct_keys_do_not_coalesce_and_errors_fan_out() {
        let batcher: Batcher<u32, u32> = Batcher::new(0, 8);
        let a = batcher.submit(10, 0);
        let b = batcher.submit(11, 1);
        assert!(matches!(a, Submitted::Leader { .. }));
        assert!(matches!(b, Submitted::Leader { .. }));
        // A zero window gathers immediately: a batch of one, and a typed
        // error delivered through the gate round-trips.
        if let Submitted::Leader { key, gen } = a {
            let items = batcher.gather(key, gen);
            assert_eq!(items.len(), 1);
            items[0].1.deliver(Err(ServeError::Panic { message: "boom".into() }));
            assert_eq!(items[0].1.wait().unwrap_err().kind(), "panic");
        }
    }

    #[test]
    fn strategy_policy() {
        assert_eq!(choose_strategy("auto", false).unwrap(), Strategy::StrongSet);
        assert_eq!(choose_strategy("auto", true).unwrap(), Strategy::PreviousSet);
        assert_eq!(choose_strategy("none", true).unwrap(), Strategy::NoScreening);
        assert_eq!(choose_strategy("strong", true).unwrap(), Strategy::StrongSet);
        assert_eq!(choose_strategy("previous", false).unwrap(), Strategy::PreviousSet);
        assert_eq!(choose_strategy("safe", false).unwrap(), Strategy::SafeOnly);
        assert_eq!(choose_strategy("hybrid", true).unwrap(), Strategy::GapHybrid);
        assert!(choose_strategy("gap", false).is_err());
        assert!(choose_strategy("sideways", false).is_err());
    }
}

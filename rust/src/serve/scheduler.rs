//! Job dispatch for the serve layer: a bounded admission queue with
//! backpressure in front of the [`crate::pool::WorkerPool`], plus the
//! per-job screening-strategy policy.
//!
//! Request threads (one per connection) call [`Scheduler::run`] and block
//! for their result; at most `capacity` jobs are admitted at once, so a
//! burst of heavy fits queues here instead of oversubscribing the pool.
//! Panics inside jobs are caught and surfaced as errors — a malformed
//! problem must produce an error response, not a dead worker.

use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};

use crate::obs::registry as obsreg;
use crate::pool::WorkerPool;
use crate::slope::path::Strategy;

/// Admission-gate state: a ticket queue makes waiting strictly FIFO —
/// under sustained load the longest-parked request is always admitted
/// next (bare condvar wakeups carry no ordering guarantee).
#[derive(Default)]
struct GateState {
    admitted: usize,
    next_ticket: u64,
    now_serving: u64,
}

impl GateState {
    /// Publish the gate's levels as registry gauges (called under the
    /// gate lock at every transition, so the published pair is always a
    /// consistent snapshot). `next_ticket - now_serving` is the number of
    /// requests parked on tickets; `admitted` is queued-on-pool+running.
    fn publish(&self) {
        obsreg::SERVE_QUEUE_DEPTH.set(self.next_ticket - self.now_serving);
        obsreg::SERVE_IN_FLIGHT.set(self.admitted as u64);
    }
}

/// Bounded-queue dispatcher over a worker pool.
pub struct Scheduler {
    pool: WorkerPool,
    gate: Arc<(Mutex<GateState>, Condvar)>,
    capacity: usize,
    fit_threads: usize,
}

impl Scheduler {
    /// `threads = 0` sizes the pool to the machine; `capacity` bounds the
    /// number of admitted (queued + running) jobs. The per-job kernel
    /// thread budget defaults to the machine budget split across the
    /// pool's workers (override with [`Scheduler::set_fit_threads`]).
    pub fn new(threads: usize, capacity: usize) -> Scheduler {
        let pool = if threads == 0 {
            WorkerPool::with_default_size()
        } else {
            WorkerPool::new(threads)
        };
        let fit_threads = crate::pool::fit_thread_budget(pool.size());
        Scheduler {
            pool,
            gate: Arc::new((Mutex::new(GateState::default()), Condvar::new())),
            capacity: capacity.max(1),
            fit_threads,
        }
    }

    /// Worker threads in the pool.
    pub fn threads(&self) -> usize {
        self.pool.size()
    }

    /// Admission capacity.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Kernel threads each fit job may use (the `linalg::par` budget
    /// handed to [`crate::slope::path::PathOptions::threads`]): with
    /// `pool.size()` fits running at once, each gets its share of the
    /// machine so concurrent fits don't oversubscribe it.
    pub fn fit_threads(&self) -> usize {
        self.fit_threads
    }

    /// Override the per-job kernel thread budget (serve's
    /// `--fit-threads` / `fit_threads` config; 0 restores the automatic
    /// split).
    pub fn set_fit_threads(&mut self, fit_threads: usize) {
        self.fit_threads = if fit_threads == 0 {
            crate::pool::fit_thread_budget(self.pool.size())
        } else {
            fit_threads
        };
    }

    /// Currently admitted jobs.
    pub fn in_flight(&self) -> usize {
        self.gate.0.lock().unwrap().admitted
    }

    /// Run `f` on the pool and block for its result. Applies backpressure
    /// (blocks while `capacity` jobs are admitted; admission is FIFO by
    /// arrival) and converts panics into `Err`.
    pub fn run<T, F>(&self, f: F) -> Result<T, String>
    where
        T: Send + 'static,
        F: FnOnce() -> T + Send + 'static,
    {
        {
            let mut state = self.gate.0.lock().unwrap();
            let ticket = state.next_ticket;
            state.next_ticket += 1;
            state.publish();
            while state.now_serving != ticket || state.admitted >= self.capacity {
                state = self.gate.1.wait(state).unwrap();
            }
            state.admitted += 1;
            state.now_serving += 1;
            state.publish();
            // Wake the next ticket holder (it may be admissible already).
            self.gate.1.notify_all();
        }
        let (tx, rx) = mpsc::channel();
        let gate = Arc::clone(&self.gate);
        self.pool.submit(move || {
            let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(f));
            let _ = tx.send(outcome);
            let mut state = gate.0.lock().unwrap();
            state.admitted -= 1;
            state.publish();
            gate.1.notify_all();
        });
        match rx.recv() {
            Ok(Ok(value)) => Ok(value),
            Ok(Err(panic)) => {
                let msg = if let Some(s) = panic.downcast_ref::<&str>() {
                    (*s).to_string()
                } else if let Some(s) = panic.downcast_ref::<String>() {
                    s.clone()
                } else {
                    "unknown panic".to_string()
                };
                Err(format!("job panicked: {msg}"))
            }
            Err(_) => Err("worker dropped the job result".to_string()),
        }
    }
}

/// Screening-strategy policy: explicit request wins; `auto` uses the
/// previous-set algorithm (Algorithm 4) when a cached warm-start seed
/// exists — the previous support is then a good guess and the strong set
/// only serves as the first KKT check — and the strong-set algorithm
/// (Algorithm 3) on cold fits.
pub fn choose_strategy(requested: &str, warm: bool) -> Result<Strategy, String> {
    Ok(match requested {
        "none" => Strategy::NoScreening,
        "strong" => Strategy::StrongSet,
        "previous" => Strategy::PreviousSet,
        "safe" => Strategy::SafeOnly,
        "hybrid" => Strategy::GapHybrid,
        "auto" | "" => {
            if warm {
                Strategy::PreviousSet
            } else {
                Strategy::StrongSet
            }
        }
        other => {
            return Err(format!(
                "unknown screening strategy `{other}` (expected auto|none|strong|previous|safe|hybrid)"
            ))
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn runs_jobs_and_returns_results() {
        let sched = Scheduler::new(2, 4);
        assert_eq!(sched.run(|| 2 + 3).unwrap(), 5);
        assert_eq!(sched.in_flight(), 0);
    }

    #[test]
    fn catches_panics() {
        let sched = Scheduler::new(1, 2);
        let err = sched.run(|| -> usize { panic!("kaboom {}", 7) }).unwrap_err();
        assert!(err.contains("kaboom"), "{err}");
        // the pool survives the panic
        assert_eq!(sched.run(|| 1usize).unwrap(), 1);
    }

    #[test]
    fn backpressure_bounds_admission() {
        let sched = Arc::new(Scheduler::new(2, 2));
        let peak = Arc::new(AtomicUsize::new(0));
        let live = Arc::new(AtomicUsize::new(0));
        std::thread::scope(|scope| {
            for _ in 0..8 {
                let sched = Arc::clone(&sched);
                let peak = Arc::clone(&peak);
                let live = Arc::clone(&live);
                scope.spawn(move || {
                    sched
                        .run(move || {
                            let now = live.fetch_add(1, Ordering::SeqCst) + 1;
                            peak.fetch_max(now, Ordering::SeqCst);
                            std::thread::sleep(std::time::Duration::from_millis(10));
                            live.fetch_sub(1, Ordering::SeqCst);
                        })
                        .unwrap();
                });
            }
        });
        assert!(peak.load(Ordering::SeqCst) <= 2, "admission cap exceeded");
    }

    #[test]
    fn fit_thread_budget_splits_the_machine() {
        let mut sched = Scheduler::new(4, 8);
        // auto budget: total/workers, at least 1
        assert!(sched.fit_threads() >= 1);
        assert!(sched.fit_threads() <= crate::linalg::par::MAX_AUTO_THREADS);
        // explicit override wins; 0 restores the automatic split
        sched.set_fit_threads(3);
        assert_eq!(sched.fit_threads(), 3);
        sched.set_fit_threads(0);
        // (compared loosely: another test may race the global setting)
        assert!(sched.fit_threads() >= 1);
    }

    #[test]
    fn strategy_policy() {
        assert_eq!(choose_strategy("auto", false).unwrap(), Strategy::StrongSet);
        assert_eq!(choose_strategy("auto", true).unwrap(), Strategy::PreviousSet);
        assert_eq!(choose_strategy("none", true).unwrap(), Strategy::NoScreening);
        assert_eq!(choose_strategy("strong", true).unwrap(), Strategy::StrongSet);
        assert_eq!(choose_strategy("previous", false).unwrap(), Strategy::PreviousSet);
        assert_eq!(choose_strategy("safe", false).unwrap(), Strategy::SafeOnly);
        assert_eq!(choose_strategy("hybrid", true).unwrap(), Strategy::GapHybrid);
        assert!(choose_strategy("gap", false).is_err());
        assert!(choose_strategy("sideways", false).is_err());
    }
}

//! The SLOPE fit server: request handling over newline-delimited JSON,
//! with stdin/stdout and Unix-domain-socket transports.
//!
//! Request handling is synchronous per connection; heavy work (path and
//! point fits) is dispatched through the [`Scheduler`] onto the worker
//! pool, so concurrent connections share the machine under backpressure
//! while the registry coalesces duplicate fits and serves cache hits
//! without touching the pool at all.

use std::io::{BufRead, BufReader, Write};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicU8, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::jsonio::Json;
use crate::linalg::packed::score_rows;
use crate::obs::registry as obsreg;
use crate::slope::cancel::CancelToken;
use crate::slope::family::{sigmoid, Family};
use crate::slope::path::{
    fit_path_seeded, fit_point_batch, zero_seed, NativeGradient, PathSeed, PointFit, Strategy,
};

use super::error::ServeError;
use super::metrics::Metrics;
use super::protocol::{self, DatasetSpec, Envelope, ModelSpec, Request};
use super::registry::{CachedModel, DatasetEntry, Fetched, PointState, Registry};
use super::scheduler::{choose_strategy, Batcher, JobOptions, Scheduler, Submitted};

/// Server construction parameters.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Worker threads (0 = machine default).
    pub threads: usize,
    /// Admission-queue capacity (backpressure bound).
    pub queue: usize,
    /// Enable the warm-start/model cache (off = cold baseline).
    pub cache: bool,
    /// Kernel threads per fit job for the `linalg::par` backend (0 = the
    /// machine budget split across the worker pool, so concurrent fits
    /// never oversubscribe; a per-request `threads` field overrides it).
    pub fit_threads: usize,
    /// Server-wide relative duality-gap tolerance for the gap-driven
    /// screens (`safe`/`hybrid`); 0 keeps the library default. A
    /// per-request `gap_tol` field overrides it. Like `fit_threads`, a
    /// performance knob outside every cache identity — which is exactly
    /// why [`Server::new`] enforces the same `(0, 1e-4]` bound the
    /// per-request parser does: a loose "tolerance" would change cached
    /// solutions.
    pub gap_tol: f64,
    /// Byte cap on one NDJSON request line; oversized lines are drained
    /// and answered with a typed `oversized_line` error instead of
    /// buffering without bound. Default 16 MiB (roomy for inline
    /// datasets, far below a memory-exhaustion payload).
    pub max_line_bytes: usize,
    /// Server-wide deadline for fit jobs in milliseconds (0 = none). A
    /// per-request `deadline_ms` field overrides it. Expired fits return
    /// a typed `deadline` error carrying partial progress and are never
    /// cached.
    pub deadline_ms: u64,
    /// Load-shedding threshold: with this many requests parked in the
    /// admission queue, further fit jobs are rejected with a typed
    /// `overload` error and a `retry_after_ms` hint. 0 (the default)
    /// keeps pure blocking backpressure.
    pub shed_queue: usize,
    /// Opt-in durable state (DESIGN.md §13): when set, the registry
    /// journals dataset registrations, warm-start seeds and quarantine
    /// strikes to `<dir>/registry.journal` and restores them on boot.
    /// `None` (the default) keeps the registry purely in-memory.
    pub state_dir: Option<std::path::PathBuf>,
    /// Open-connection cap shared by the socket transports (Unix and
    /// TCP): connections past the cap are refused at accept with a typed
    /// `overload` close instead of spawning state the load-shedder never
    /// sees. 0 falls back to the default (1024).
    pub max_conns: usize,
    /// Cross-request batching gather window in milliseconds (DESIGN.md
    /// §14): `fit_point`/`predict` requests sharing a dataset
    /// fingerprint and tolerance regime that arrive within this window
    /// of each other coalesce into one solve. 0 (the default) disables
    /// batching — every request runs alone, exactly as before.
    pub gather_window_ms: u64,
    /// Most requests one batch may absorb (a full batch closes its
    /// gather window early). Ignored while batching is disabled.
    pub max_batch: usize,
    /// Boot as a warm standby (DESIGN.md §15): write requests are
    /// fenced with a typed `fenced` error until a `promote` op (or the
    /// standby loop's loss detector) promotes this server. The
    /// replication stream itself is wired by the transport layer
    /// (`serve --standby`).
    pub standby: bool,
    /// Idle-connection reaper threshold for the TCP transport in
    /// milliseconds (0 = off, default 5 minutes). Connections with no
    /// traffic for this long are closed and counted
    /// (`serve_idle_reaped`); connections with a request in flight and
    /// replication subscribers are exempt.
    pub idle_timeout_ms: u64,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            threads: 0,
            queue: 64,
            cache: true,
            fit_threads: 0,
            gap_tol: 0.0,
            max_line_bytes: 16 << 20,
            deadline_ms: 0,
            shed_queue: 0,
            state_dir: None,
            max_conns: 0,
            gather_window_ms: 0,
            max_batch: 32,
            standby: false,
            idle_timeout_ms: 300_000,
        }
    }
}

/// The failover role one server currently holds (DESIGN.md §15). Stored
/// as an `AtomicU8` on [`Server`] so every request checks it without a
/// lock.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Role {
    /// Accepts writes; ships its journal to subscribed standbys.
    Primary = 0,
    /// Replicates a primary's journal; fences writes until promoted.
    Standby = 1,
    /// An ex-primary that observed a higher failover epoch: it fences
    /// writes permanently (restart it as a standby to rejoin).
    Fenced = 2,
}

impl Role {
    fn from_u8(v: u8) -> Role {
        match v {
            1 => Role::Standby,
            2 => Role::Fenced,
            _ => Role::Primary,
        }
    }

    /// Stable lowercase name (`health` responses, fenced errors).
    pub fn name(self) -> &'static str {
        match self {
            Role::Primary => "primary",
            Role::Standby => "standby",
            Role::Fenced => "fenced",
        }
    }
}

/// The cross-request batchers, present only while batching is enabled.
/// `fit_point` members are their `sigma_ratio`s; `predict` members are
/// their raw row blocks. Results are fully built response objects.
struct Batching {
    point: Batcher<f64, Json>,
    predict: Batcher<Vec<Vec<f64>>, Json>,
}

/// A running SLOPE fit server (transport-independent core).
pub struct Server {
    registry: Registry,
    sched: Scheduler,
    /// Request/latency metrics, served by the `stats` op.
    pub metrics: Metrics,
    shutdown: AtomicBool,
    /// Server default for requests that leave `gap_tol` at 0.
    gap_tol: f64,
    /// Byte cap on one NDJSON request line.
    max_line_bytes: usize,
    /// Server default for requests that leave `deadline_ms` at 0.
    deadline_ms: u64,
    /// Open-connection cap for the socket transports.
    max_conns: usize,
    /// Cross-request batching (None = disabled).
    batching: Option<Batching>,
    /// Failover role ([`Role`] as `u8`).
    role: AtomicU8,
    /// Replication lag in records, as last reported by the standby
    /// apply loop; a primary reports its subscriber queues instead.
    repl_lag: AtomicU64,
    /// Idle-connection reaper threshold for the poll-loop transport.
    idle_timeout_ms: u64,
}

impl Server {
    /// Build a server; spawns the worker pool immediately.
    ///
    /// Panics if `cfg.gap_tol` is outside `{0} ∪ (0, 1e-4]` — the model
    /// cache's identity excludes `gap_tol` on the grounds that it stays
    /// in the tolerance regime, so an out-of-regime server default must
    /// be a startup error, not a cache poisoner.
    pub fn new(cfg: ServerConfig) -> Server {
        assert!(
            cfg.gap_tol == 0.0 || (cfg.gap_tol > 0.0 && cfg.gap_tol <= 1e-4),
            "ServerConfig::gap_tol must be 0 (library default) or in (0, 1e-4], got {}",
            cfg.gap_tol
        );
        let mut sched = Scheduler::new(cfg.threads, cfg.queue);
        if cfg.fit_threads > 0 {
            sched.set_fit_threads(cfg.fit_threads);
        }
        if cfg.shed_queue > 0 {
            sched.set_shed_limit(Some(cfg.shed_queue));
        }
        let batching = (cfg.gather_window_ms > 0).then(|| Batching {
            point: Batcher::new(cfg.gather_window_ms, cfg.max_batch),
            predict: Batcher::new(cfg.gather_window_ms, cfg.max_batch),
        });
        Server {
            registry: Registry::with_state_dir(cfg.cache, cfg.state_dir.as_deref()),
            sched,
            metrics: Metrics::new(),
            shutdown: AtomicBool::new(false),
            gap_tol: cfg.gap_tol,
            max_line_bytes: cfg.max_line_bytes.max(1024),
            deadline_ms: cfg.deadline_ms,
            max_conns: if cfg.max_conns == 0 { 1024 } else { cfg.max_conns },
            batching,
            role: AtomicU8::new(if cfg.standby { Role::Standby } else { Role::Primary } as u8),
            repl_lag: AtomicU64::new(0),
            idle_timeout_ms: cfg.idle_timeout_ms,
        }
    }

    /// Direct registry access for the replication layer (the standby
    /// apply loop and the transports' subscriber plumbing).
    pub fn registry(&self) -> &Registry {
        &self.registry
    }

    /// This server's current failover role.
    pub fn role(&self) -> Role {
        Role::from_u8(self.role.load(Ordering::SeqCst))
    }

    /// The failover epoch this server last observed. Journaled on every
    /// raise, so it survives restarts.
    pub fn epoch(&self) -> u64 {
        self.registry.epoch()
    }

    /// Idle-connection reaper threshold for the TCP transport (0 = off).
    pub(crate) fn idle_timeout_ms(&self) -> u64 {
        self.idle_timeout_ms
    }

    /// Record the replication lag the standby loop last computed from a
    /// primary heartbeat; surfaced by the `health` op.
    pub fn set_repl_lag(&self, records: u64) {
        self.repl_lag.store(records, Ordering::Relaxed);
        obsreg::REPL_LAG_RECORDS.set(records);
    }

    /// Promote this server to primary, bumping (and journaling) the
    /// failover epoch so the old primary can be fenced by anything that
    /// later shows it the new epoch. Returns the epoch now in force and
    /// whether a promotion actually happened — a `promote` against a
    /// server that is already primary is a no-op reporting its epoch,
    /// so retried promotions cannot burn epochs.
    pub fn promote(&self) -> (u64, bool) {
        if self.role() == Role::Primary {
            return (self.epoch(), false);
        }
        let epoch = self.registry.advance_epoch();
        self.role.store(Role::Primary as u8, Ordering::SeqCst);
        obsreg::REPL_PROMOTIONS.inc();
        obsreg::REPL_EPOCH.set(epoch);
        eprintln!("serve: promoted to primary at epoch {epoch}");
        (epoch, true)
    }

    /// Adopt an epoch observed on the wire (journaling any raise). An
    /// epoch ahead of ours while we hold the primary role is proof a
    /// newer primary exists — this server lost a failover it didn't
    /// witness — so it fences its own writes rather than split-brain.
    /// Returns `true` when this call fenced.
    pub fn observe_remote_epoch(&self, remote: u64) -> bool {
        let raised = self.registry.bump_epoch_to(remote);
        if !raised {
            return false;
        }
        obsreg::REPL_EPOCH.set(self.epoch());
        if self.role() == Role::Primary {
            self.role.store(Role::Fenced as u8, Ordering::SeqCst);
            eprintln!("serve: observed epoch {remote} ahead of ours: fencing writes");
            return true;
        }
        false
    }

    /// Handle one `repl_subscribe` handshake for the transport layer.
    ///
    /// A subscriber presenting an epoch ahead of ours fences us (see
    /// [`Server::observe_remote_epoch`]) and is refused with a typed
    /// `fenced` error; a non-primary refuses too (the replication chain
    /// is depth one). Otherwise the subscriber is attached under the
    /// journal lock — snapshot first, then live appends, no gap — and
    /// the ok response carries our role, epoch and snapshot record
    /// count. Returns the rendered response either way; `Ok` also hands
    /// the transport the queue to drain into the connection.
    pub(crate) fn accept_replica(
        &self,
        id: u64,
        remote_epoch: u64,
    ) -> Result<(String, Arc<super::registry::ReplSubscriber>), String> {
        if remote_epoch > self.epoch() {
            self.observe_remote_epoch(remote_epoch);
            obsreg::SERVE_FENCED_REJECTS.inc();
            let err =
                ServeError::Fenced { role: self.role().name().to_string(), epoch: self.epoch() };
            return Err(protocol::error_response(id, &err));
        }
        if self.role() != Role::Primary {
            obsreg::SERVE_FENCED_REJECTS.inc();
            let err =
                ServeError::Fenced { role: self.role().name().to_string(), epoch: self.epoch() };
            return Err(protocol::error_response(id, &err));
        }
        let sub = Arc::new(super::registry::ReplSubscriber::new());
        match self.registry.attach_subscriber(Arc::clone(&sub)) {
            Ok(records) => {
                let body = Json::obj(vec![
                    ("role", Json::Str(self.role().name().to_string())),
                    ("epoch", Json::Num(self.epoch() as f64)),
                    ("records", Json::Num(records as f64)),
                ]);
                Ok((protocol::ok_response(id, body), sub))
            }
            Err(e) => Err(protocol::error_response(id, &ServeError::Invalid(e))),
        }
    }

    /// Open-connection cap shared by the socket transports.
    pub(crate) fn max_conns(&self) -> usize {
        self.max_conns
    }

    /// Byte cap on one NDJSON request line (the TCP framing layer
    /// enforces the same bound the BufRead transports do).
    pub(crate) fn max_line_bytes(&self) -> usize {
        self.max_line_bytes
    }

    /// Count and render a typed `oversized_line` error response (shared
    /// by the BufRead and poll-loop framings).
    pub(crate) fn oversized_response(&self, bytes: usize) -> String {
        self.metrics.counters.errors.fetch_add(1, Ordering::Relaxed);
        let err = ServeError::OversizedLine { bytes, limit: self.max_line_bytes };
        protocol::error_response(0, &err)
    }

    /// Block until every admitted fit job has finished (the transports'
    /// graceful-drain step).
    pub(crate) fn await_jobs_idle(&self) {
        self.sched.await_idle();
    }

    /// The deadline one fit job runs under: the request's explicit
    /// `deadline_ms` if given, else the server default; a fresh token per
    /// job (deadlines are relative to admission attempt, not to server
    /// start). `None` when neither sets a budget — the healthy path pays
    /// nothing.
    fn job_token(&self, model: &ModelSpec) -> Option<(CancelToken, u64)> {
        let deadline = if model.deadline_ms > 0 { model.deadline_ms } else { self.deadline_ms };
        if deadline == 0 {
            return None;
        }
        Some((CancelToken::with_deadline_ms(deadline), deadline))
    }

    /// The kernel thread budget one fit job runs under: the request's
    /// explicit `threads` if given, else the scheduler's per-job split.
    ///
    /// The override exists so a client the operator trusts can exceed
    /// the conservative split for a latency-critical fit; it is clamped
    /// to the process-wide budget, which bounds any *single* job to the
    /// machine the operator configured. It does not bound the aggregate:
    /// if every concurrent job requests the full budget the box can be
    /// transiently oversubscribed by up to the pool width — operators
    /// who need a hard aggregate cap should leave per-request `threads`
    /// unset (the default split never oversubscribes).
    fn job_threads(&self, model: &ModelSpec) -> usize {
        if model.threads > 0 {
            model.threads.min(crate::linalg::par::global_threads())
        } else {
            self.sched.fit_threads()
        }
    }

    /// True once a `shutdown` request has been handled.
    pub fn is_shutdown(&self) -> bool {
        self.shutdown.load(Ordering::SeqCst)
    }

    /// Handle one request line; returns the response line (no newline).
    ///
    /// With tracing enabled, the whole lifecycle is one `serve_request`
    /// span (op, id, status); the fit jobs it dispatches add their own
    /// `fit_job` spans carrying the admission-queue wait, and cache
    /// coalescing is a point event — so a trace shows where a slow
    /// request spent its time: parked in the queue, fitting, or waiting
    /// on someone else's identical fit.
    pub fn handle_line(&self, line: &str) -> String {
        self.metrics.counters.requests.fetch_add(1, Ordering::Relaxed);
        let t0 = Instant::now();
        let response = match Envelope::parse_line(line) {
            Err((id, e)) => {
                self.metrics.counters.errors.fetch_add(1, Ordering::Relaxed);
                protocol::err_response(id, &e)
            }
            Ok(env) => {
                let op = op_name(&env.request);
                let mut req_span = crate::obs::trace::span("serve_request");
                if req_span.active() {
                    req_span.s("op", op);
                    req_span.u("id", env.id);
                }
                match self.dispatch(env.request) {
                    Ok(result) => {
                        self.metrics.record(op, t0.elapsed().as_secs_f64());
                        req_span.s("status", "ok");
                        protocol::ok_response(env.id, result)
                    }
                    Err(e) => {
                        self.metrics.counters.errors.fetch_add(1, Ordering::Relaxed);
                        req_span.s("status", "error");
                        req_span.s("error_kind", e.kind());
                        protocol::error_response(env.id, &e)
                    }
                }
            }
        };
        // Connection threads can idle indefinitely between requests:
        // drain this thread's span buffer now so the trace tail is never
        // parked in TLS.
        if !crate::obs::trace::disabled() {
            crate::obs::trace::flush();
        }
        response
    }

    fn dispatch(&self, request: Request) -> Result<Json, ServeError> {
        // Write fencing (DESIGN.md §15): a standby, or an ex-primary
        // that observed a higher failover epoch, rejects anything that
        // mutates fit or registry state — two servers can never both
        // act as the primary within one epoch. Reads (stats, metrics,
        // health) stay available so a fenced server can still be
        // inspected.
        if self.role() != Role::Primary
            && matches!(
                request,
                Request::FitPath { .. }
                    | Request::FitPoint { .. }
                    | Request::Predict { .. }
                    | Request::RegisterDataset { .. }
            )
        {
            obsreg::SERVE_FENCED_REJECTS.inc();
            return Err(ServeError::Fenced {
                role: self.role().name().to_string(),
                epoch: self.epoch(),
            });
        }
        match request {
            Request::FitPath { dataset, model } => self.do_fit_path(&dataset, &model),
            Request::FitPoint { dataset, model, sigma_ratio } => {
                self.do_fit_point(&dataset, &model, sigma_ratio)
            }
            Request::Predict { dataset, model, x, step } => {
                self.do_predict(&dataset, &model, x, step)
            }
            Request::RegisterDataset { dataset } => self.do_register(&dataset),
            Request::Stats => Ok(self.do_stats()),
            Request::Metrics { format } => Ok(self.do_metrics(&format)),
            Request::Health => Ok(self.do_health()),
            Request::Promote => {
                let (epoch, promoted) = self.promote();
                Ok(Json::obj(vec![
                    ("promoted", Json::Bool(promoted)),
                    ("role", Json::Str(self.role().name().to_string())),
                    ("epoch", Json::Num(epoch as f64)),
                ]))
            }
            // The subscribe handshake switches the connection to raw
            // journal frames, which only the poll-loop TCP transport
            // can carry; it intercepts the op before dispatch.
            Request::ReplSubscribe { .. } => Err(ServeError::Invalid(
                "repl_subscribe is only served on the TCP transport".to_string(),
            )),
            Request::Shutdown => {
                // Graceful drain: parked fit jobs are rejected with typed
                // `shutdown` errors; admitted ones run to completion (the
                // transports wait for them before severing connections).
                self.sched.begin_drain();
                self.shutdown.store(true, Ordering::SeqCst);
                Ok(Json::obj(vec![("shutting_down", Json::Bool(true))]))
            }
        }
    }

    /// Fetch the fitted path for (dataset, model): cache hit, coalesced
    /// wait, or a scheduled fit (warm-started from a sibling model's seed
    /// when one exists).
    fn fitted_model(
        &self,
        entry: &Arc<DatasetEntry>,
        model: &ModelSpec,
    ) -> Result<(Arc<CachedModel>, &'static str), ServeError> {
        let key = model.key();
        let fetched = self.registry.model(entry, &key, || {
            let warm_seed = entry.any_ready_seed();
            let warm = warm_seed.is_some();
            let strategy = choose_strategy(&model.screen, warm).map_err(ServeError::Invalid)?;
            let mut opts = model
                .path_options(entry.problem.as_ref())
                .map_err(ServeError::Invalid)?
                .with_strategy(strategy)
                .with_threads(self.job_threads(model))
                .with_pack_cache(entry.pack_cache());
            // `path_options` already folded in a per-request gap_tol; the
            // server default only fills the gap when the request left it
            // unset. Gap-driven fits also share the dataset's cached
            // column norms so the sphere tests never re-pay the O(n·p)
            // norm pass per request.
            if model.gap_tol == 0.0 && self.gap_tol > 0.0 {
                opts = opts.with_gap_tol(self.gap_tol);
            }
            if strategy.is_gap_driven() {
                opts = opts.with_col_norms(entry.col_norms(opts.par()));
            }
            let token = self.job_token(model);
            if let Some((tok, _)) = &token {
                opts = opts.with_cancel(tok.clone());
            }
            let job = JobOptions { cancel: token.as_ref().map(|(t, _)| t.clone()), shed: true };
            let prob = Arc::clone(&entry.problem);
            let t_enqueue = Instant::now();
            let fit = self.sched.run_job(job, move || {
                let fit = {
                    let mut job_span = crate::obs::trace::span("fit_job");
                    if job_span.active() {
                        job_span.s("op", "fit_path");
                        job_span.u("queue_wait_us", t_enqueue.elapsed().as_micros() as u64);
                    }
                    let gradient = NativeGradient(prob.as_ref());
                    fit_path_seeded(prob.as_ref(), &opts, &gradient, warm_seed.as_ref())
                };
                // Pool workers are long-lived: hand the job's trace tail
                // to the sink instead of parking it in worker TLS.
                if !crate::obs::trace::disabled() {
                    crate::obs::trace::flush();
                }
                fit
            })?;
            // An expired deadline is a typed error carrying partial
            // progress; the partial fit is never cached (returning Err
            // clears the build slot for the next attempt).
            if fit.stopped_early == Some("cancelled") {
                obsreg::SERVE_DEADLINE_EXPIRED.inc();
                let deadline_ms = token.map(|(_, d)| d).unwrap_or(0);
                return Err(ServeError::Deadline {
                    deadline_ms,
                    steps_done: fit.steps.len(),
                    gap: fit.steps.last().and_then(|s| s.gap),
                });
            }
            if warm {
                self.metrics.counters.warm_fits.fetch_add(1, Ordering::Relaxed);
            } else {
                self.metrics.counters.cold_fits.fetch_add(1, Ordering::Relaxed);
            }
            let seed = fit.seed();
            let wall_time = fit.wall_time;
            Ok(CachedModel {
                fit,
                seed,
                strategy: strategy.name(),
                wall_time,
                hits: std::sync::atomic::AtomicU64::new(0),
            })
        });
        let fetched = match fetched {
            Ok(f) => f,
            Err(e) => {
                // Worker panics strike the dataset entry: repeated panics
                // quarantine it so a poisoned materialization cannot take
                // the server down request after request.
                if matches!(e, ServeError::Panic { .. }) {
                    self.registry.record_panic(entry);
                }
                return Err(e);
            }
        };
        match &fetched {
            Fetched::Hit(_) => {
                self.metrics.counters.cache_hits.fetch_add(1, Ordering::Relaxed);
            }
            Fetched::Coalesced(_) => {
                self.metrics.counters.coalesced.fetch_add(1, Ordering::Relaxed);
                if !crate::obs::trace::disabled() {
                    crate::obs::trace::event(
                        "coalesced_wait",
                        vec![("model_key", Json::Str(key.clone()))],
                    );
                }
            }
            Fetched::Built(_) => {}
        }
        let source = fetched.source();
        Ok((Arc::clone(fetched.model()), source))
    }

    fn do_fit_path(&self, dataset: &DatasetSpec, model: &ModelSpec) -> Result<Json, ServeError> {
        let entry = self.registry.dataset(dataset)?;
        let (m, source) = self.fitted_model(&entry, model)?;
        let fit = &m.fit;
        let degraded_steps = fit.steps.iter().filter(|s| s.degraded_to.is_some()).count();
        Ok(Json::obj(vec![
            ("dataset", Json::Str(entry.label.clone())),
            ("fingerprint", Json::Str(format!("{:016x}", entry.fingerprint))),
            ("model_key", Json::Str(model.key())),
            ("source", Json::Str(source.to_string())),
            ("strategy", Json::Str(m.strategy.to_string())),
            ("steps", Json::Num(fit.steps.len() as f64)),
            ("sigmas", Json::nums(&fit.sigmas)),
            (
                "n_active",
                Json::Arr(fit.steps.iter().map(|s| Json::Num(s.n_active as f64)).collect()),
            ),
            (
                "n_screened",
                Json::Arr(
                    fit.steps.iter().map(|s| Json::Num(s.n_screened_rule as f64)).collect(),
                ),
            ),
            (
                "dev_ratio",
                Json::nums(&fit.steps.iter().map(|s| s.dev_ratio).collect::<Vec<f64>>()),
            ),
            ("total_violations", Json::Num(fit.total_violations as f64)),
            ("full_grad_sweeps", Json::Num(fit.total_grad_sweeps)),
            (
                "solver_converged",
                Json::Bool(fit.steps.iter().all(|s| s.solver_converged)),
            ),
            ("degraded_steps", Json::Num(degraded_steps as f64)),
            ("fit_wall_s", Json::Num(m.wall_time)),
            (
                "stopped_early",
                match fit.stopped_early {
                    Some(reason) => Json::Str(reason.to_string()),
                    None => Json::Null,
                },
            ),
        ]))
    }

    /// One `fit_point` request: without batching it is a singleton batch;
    /// with batching enabled it joins (or leads) the open batch for its
    /// `(fingerprint, point identity, option regime)` key, and the leader
    /// runs the gathered batch as one scheduler job, demultiplexing the
    /// per-member responses back through each joiner's gate.
    fn do_fit_point(
        &self,
        dataset: &DatasetSpec,
        model: &ModelSpec,
        sigma_ratio: f64,
    ) -> Result<Json, ServeError> {
        let entry = self.registry.dataset(dataset)?;
        let Some(batching) = &self.batching else {
            return self
                .run_point_batch(&entry, model, &[sigma_ratio])
                .pop()
                .expect("singleton batch produces one result");
        };
        // Requests may only coalesce when they would solve the same
        // problem under the same option regime: the batch key covers the
        // dataset fingerprint, the point-cache identity, and every
        // perf/tolerance knob the cache identity deliberately excludes.
        let key = model.batch_key(entry.fingerprint, &model.point_key());
        match batching.point.submit(key, sigma_ratio) {
            Submitted::Joiner(gate) => gate.wait(),
            Submitted::Leader { key, gen } => {
                let members = batching.point.gather(key, gen);
                let (ratios, gates): (Vec<_>, Vec<_>) = members.into_iter().unzip();
                let mut results = self.run_point_batch(&entry, model, &ratios);
                let own = results.remove(0);
                for (gate, result) in gates.into_iter().skip(1).zip(results) {
                    gate.deliver(result);
                }
                own
            }
        }
    }

    /// Run a coalesced batch of point fits as ONE scheduler job.
    ///
    /// Items execute sequentially inside the job in arrival order and are
    /// chained through the warm-start cycle exactly as back-to-back
    /// requests would be (item k stores its seed, item k+1 reads it), so
    /// on the healthy path the batch's responses are bitwise-identical to
    /// the sequential serialization. The batch shares one deadline token;
    /// once it fires, every unconverged member reports `deadline` (n
    /// separate tokens would attribute the expiry per-request, which is
    /// the one place batch error attribution is coarser). A panic fails
    /// every member with a typed `panic` error and charges one quarantine
    /// strike per member — the ledger lands where the sequential replays
    /// would have left it.
    fn run_point_batch(
        &self,
        entry: &Arc<DatasetEntry>,
        model: &ModelSpec,
        ratios: &[f64],
    ) -> Vec<Result<Json, ServeError>> {
        let n = ratios.len();
        let fan = |e: ServeError| -> Vec<Result<Json, ServeError>> {
            (0..n).map(|_| Err(e.clone())).collect()
        };
        let key = model.point_key();
        let prior = entry.point_state(&key);
        // No in-memory point state (a fresh boot, or a standby promoted
        // after journal-shipped replication): fall back to the last
        // journaled seed, so the first failed-over fit warm-starts from
        // the exact coefficients the old primary last stored. On a
        // server without a state dir `restored_seed()` is always None —
        // the non-durable path is bit-for-bit what it was.
        let restored = if prior.is_none() { entry.restored_seed() } else { None };
        let warm = prior.is_some() || restored.is_some();
        // Chaining replicates the store/read cycle, which only exists
        // while the warm-start cache is on; with it off, every item is
        // the same independent cold fit a sequential client would get.
        let chain = self.registry.cache_enabled();
        let strategy_first = match choose_strategy(&model.screen, warm) {
            Ok(s) => s,
            Err(e) => return fan(ServeError::Invalid(e)),
        };
        // Item k>0 chains off item k-1's stored seed, so it is warm no
        // matter how the batch started.
        let strategy_rest = if chain {
            match choose_strategy(&model.screen, true) {
                Ok(s) => s,
                Err(e) => return fan(ServeError::Invalid(e)),
            }
        } else {
            strategy_first
        };
        let base_opts = match model.path_options(entry.problem.as_ref()) {
            Ok(o) => o,
            Err(e) => return fan(ServeError::Invalid(e)),
        };
        let token = self.job_token(model);
        // Same precedence as the path-fit site: per-request gap_tol was
        // applied by `path_options`; the server default fills unset
        // requests, and gap-driven point fits reuse the dataset's cached
        // column norms (the per-request fit_point stream is exactly the
        // case where re-sweeping norms per call would cancel the win).
        let build_opts = |strategy: Strategy| {
            let mut opts = base_opts
                .clone()
                .with_strategy(strategy)
                .with_threads(self.job_threads(model))
                .with_pack_cache(entry.pack_cache());
            if model.gap_tol == 0.0 && self.gap_tol > 0.0 {
                opts = opts.with_gap_tol(self.gap_tol);
            }
            if strategy.is_gap_driven() {
                opts = opts.with_col_norms(entry.col_norms(opts.par()));
            }
            if let Some((tok, _)) = &token {
                opts = opts.with_cancel(tok.clone());
            }
            opts
        };
        let opts_first = build_opts(strategy_first);
        let opts_rest = build_opts(strategy_rest);
        let job = JobOptions { cancel: token.as_ref().map(|(t, _)| t.clone()), shed: true };
        let prob = Arc::clone(&entry.problem);
        let sigma_ratios: Vec<f64> = ratios.to_vec();
        let t_enqueue = Instant::now();
        let result = self.sched.run_job(job, move || {
            let out = {
                let mut job_span = crate::obs::trace::span("fit_job");
                if job_span.active() {
                    job_span.s("op", "fit_point");
                    job_span.u("batch", sigma_ratios.len() as u64);
                    job_span.u("queue_wait_us", t_enqueue.elapsed().as_micros() as u64);
                }
                let gradient = NativeGradient(prob.as_ref());
                let (seed, sigma_max): (PathSeed, f64) = match prior {
                    Some(state) => (state.seed.clone(), state.sigma_max),
                    None => {
                        // σ_max always comes from the zero seed — the
                        // restored seed sits at whatever σ the primary
                        // last fit, which is not the path scale.
                        let zero = zero_seed(prob.as_ref(), &opts_first, &gradient);
                        let smax = zero.sigma;
                        (restored.unwrap_or(zero), smax)
                    }
                };
                let sigmas: Vec<f64> = sigma_ratios.iter().map(|r| sigma_max * r).collect();
                let points = fit_point_batch(
                    prob.as_ref(),
                    &opts_first,
                    &opts_rest,
                    &gradient,
                    &seed,
                    &sigmas,
                    chain,
                );
                (points, sigma_max)
            };
            if !crate::obs::trace::disabled() {
                crate::obs::trace::flush();
            }
            out
        });
        let (points, sigma_max) = match result {
            Ok(v) => v,
            Err(e) => {
                if matches!(e, ServeError::Panic { .. }) {
                    self.registry.record_panics(entry, n);
                }
                return fan(e);
            }
        };
        let mut out = Vec::with_capacity(n);
        let mut last_store: Option<&PointFit> = None;
        for (k, point) in points.iter().enumerate() {
            // A fit the deadline interrupted is an error with partial
            // progress, and its state is never cached as a warm start.
            if !point.solver_converged {
                if let Some((tok, deadline_ms)) = &token {
                    if tok.is_cancelled() {
                        obsreg::SERVE_DEADLINE_EXPIRED.inc();
                        out.push(Err(ServeError::Deadline {
                            deadline_ms: *deadline_ms,
                            steps_done: 0,
                            gap: point.gap,
                        }));
                        continue;
                    }
                }
            }
            let warm_k = warm || (chain && k > 0);
            if warm_k {
                self.metrics.counters.warm_fits.fetch_add(1, Ordering::Relaxed);
            } else {
                self.metrics.counters.cold_fits.fetch_add(1, Ordering::Relaxed);
            }
            let strategy_k = if chain && k > 0 { strategy_rest } else { strategy_first };
            last_store = Some(point);
            out.push(Ok(Self::point_response(entry, point, sigma_max, warm_k, strategy_k)));
        }
        // Sequentially each item would store its seed and the next would
        // read it back; the net registry state is the last stored item's,
        // written once.
        if self.registry.cache_enabled() {
            if let Some(point) = last_store {
                entry.store_point_state(&key, PointState { seed: point.seed(), sigma_max });
            }
        }
        out
    }

    /// The `fit_point` response object (shared by every batch member).
    fn point_response(
        entry: &DatasetEntry,
        point: &PointFit,
        sigma_max: f64,
        warm: bool,
        strategy: Strategy,
    ) -> Json {
        let nonzeros: Vec<Json> = point
            .beta
            .iter()
            .enumerate()
            .filter(|(_, &v)| v != 0.0)
            .take(100)
            .map(|(i, &v)| Json::Arr(vec![Json::Num(i as f64), Json::Num(v)]))
            .collect();
        Json::obj(vec![
            ("dataset", Json::Str(entry.label.clone())),
            ("sigma", Json::Num(point.sigma)),
            ("sigma_max", Json::Num(sigma_max)),
            ("warm", Json::Bool(warm)),
            ("strategy", Json::Str(strategy.name().to_string())),
            ("n_active", Json::Num(point.n_active as f64)),
            ("n_screened", Json::Num(point.n_screened_rule as f64)),
            ("n_fitted", Json::Num(point.n_fitted as f64)),
            ("violations", Json::Num(point.violations as f64)),
            ("solver_iterations", Json::Num(point.solver_iterations as f64)),
            ("solver_converged", Json::Bool(point.solver_converged)),
            (
                "degraded_to",
                match point.degraded_to {
                    Some(s) => Json::Str(s.to_string()),
                    None => Json::Null,
                },
            ),
            ("full_grad_sweeps", Json::Num(point.full_grad_sweeps)),
            (
                "gap",
                match point.gap {
                    Some(g) => Json::Num(g),
                    None => Json::Null,
                },
            ),
            ("deviance", Json::Num(point.deviance)),
            ("dev_ratio", Json::Num(point.dev_ratio)),
            ("wall_s", Json::Num(point.wall_time)),
            ("nonzeros", Json::Arr(nonzeros)),
        ])
    }

    /// One `predict` request: without batching it is a singleton batch;
    /// with batching enabled, requests against the same fitted model and
    /// step within the gather window stack their row blocks into one
    /// blocked gemv per class and the responses are demultiplexed by row
    /// span.
    fn do_predict(
        &self,
        dataset: &DatasetSpec,
        model: &ModelSpec,
        x: Vec<Vec<f64>>,
        step: Option<usize>,
    ) -> Result<Json, ServeError> {
        let entry = self.registry.dataset(dataset)?;
        let Some(batching) = &self.batching else {
            return self
                .run_predict_batch(&entry, model, step, vec![x])
                .pop()
                .expect("singleton batch produces one result");
        };
        // Predict identity is the full fitted path plus the step, so the
        // op key uses `model.key()` (which includes `path_length`), not
        // the point identity.
        let op_key = format!("{}:step={}", model.key(), step.map_or(-1, |s| s as i64));
        let key = model.batch_key(entry.fingerprint, &op_key);
        match batching.predict.submit(key, x) {
            Submitted::Joiner(gate) => gate.wait(),
            Submitted::Leader { key, gen } => {
                let members = batching.predict.gather(key, gen);
                let (blocks, gates): (Vec<_>, Vec<_>) = members.into_iter().unzip();
                let mut results = self.run_predict_batch(&entry, model, step, blocks);
                let own = results.remove(0);
                for (gate, result) in gates.into_iter().skip(1).zip(results) {
                    gate.deliver(result);
                }
                own
            }
        }
    }

    /// Score a coalesced batch of predict requests with one stacked-row
    /// pass per class.
    ///
    /// Every member's rows (transformed into model coordinates where the
    /// design was standardized server-side) are packed into one row slab;
    /// [`score_rows`] streams `beta` once across four rows at a time with
    /// per-row scalar accumulators seeded by the dataset intercept, so
    /// each score is bitwise-identical to the sequential per-row loop it
    /// replaces. A member with a malformed row gets its own typed error
    /// while the rest of the batch proceeds, exactly as sequential
    /// handling would.
    fn run_predict_batch(
        &self,
        entry: &Arc<DatasetEntry>,
        model: &ModelSpec,
        step: Option<usize>,
        blocks: Vec<Vec<Vec<f64>>>,
    ) -> Vec<Result<Json, ServeError>> {
        let nblocks = blocks.len();
        let fan = |e: ServeError| -> Vec<Result<Json, ServeError>> {
            (0..nblocks).map(|_| Err(e.clone())).collect()
        };
        let (m, source) = match self.fitted_model(entry, model) {
            Ok(v) => v,
            Err(e) => return fan(e),
        };
        let prob = entry.problem.as_ref();
        let p = prob.p();
        let classes = prob.family.n_classes();
        let n_steps = m.fit.betas.len();
        let step = step.unwrap_or(n_steps.saturating_sub(1));
        if step >= n_steps {
            return fan(ServeError::Invalid(format!(
                "step {step} out of range (path has {n_steps} steps)"
            )));
        }
        let beta = m.fit.beta_at(step, prob.p_total());
        // Pack each member's rows into the shared slab, recording its
        // `(first_row, n_rows)` span for demultiplexing; malformed
        // members record their error instead and contribute no rows.
        let mut slab: Vec<f64> = Vec::new();
        let mut spans: Vec<Result<(usize, usize), ServeError>> = Vec::with_capacity(nblocks);
        let mut total_rows = 0usize;
        for x in &blocks {
            let bad = x.iter().enumerate().find_map(|(i, row)| {
                (row.len() != p).then(|| {
                    ServeError::Invalid(format!(
                        "prediction row {i} has {} features, expected {p}",
                        row.len()
                    ))
                })
            });
            if let Some(e) = bad {
                spans.push(Err(e));
                continue;
            }
            for row in x {
                // Map raw client rows into the model's coordinates when
                // the design was standardized server-side (inline data).
                match &entry.transform {
                    Some(t) => slab.extend_from_slice(&t.apply(row)),
                    None => slab.extend_from_slice(row),
                }
            }
            spans.push(Ok((total_rows, x.len())));
            total_rows += x.len();
        }
        // One blocked gemv per class over the whole slab. entry.intercept
        // restores the y-centering removed before a gaussian fit (0 for
        // every other dataset kind).
        let mut class_scores: Vec<Vec<f64>> = Vec::with_capacity(classes);
        for l in 0..classes {
            let mut scores = vec![0.0; total_rows];
            score_rows(&slab, p, &beta[l * p..(l + 1) * p], entry.intercept, &mut scores);
            class_scores.push(scores);
        }
        spans
            .into_iter()
            .map(|span| {
                let (first, nrows) = span?;
                let mut eta_rows = Vec::with_capacity(nrows);
                let mut prob_rows = Vec::with_capacity(nrows);
                for r in first..first + nrows {
                    let scores: Vec<f64> = (0..classes).map(|l| class_scores[l][r]).collect();
                    if prob.family == Family::Binomial {
                        prob_rows.push(Json::Num(sigmoid(scores[0])));
                    }
                    eta_rows.push(Json::nums(&scores));
                }
                self.metrics.counters.predictions.fetch_add(nrows as u64, Ordering::Relaxed);
                let mut fields = vec![
                    ("dataset", Json::Str(entry.label.clone())),
                    ("source", Json::Str(source.to_string())),
                    ("step", Json::Num(step as f64)),
                    ("sigma", Json::Num(m.fit.sigmas[step])),
                    ("eta", Json::Arr(eta_rows)),
                ];
                if prob.family == Family::Binomial {
                    fields.push(("prob", Json::Arr(prob_rows)));
                }
                Ok(Json::obj(fields))
            })
            .collect()
    }

    /// Intern a file-backed dataset ahead of any fit: the file is
    /// ingested (streaming, validated) and cached under its content
    /// fingerprint, so subsequent fit/predict requests naming the same
    /// file skip materialization and share the entry's warm-start and
    /// pack caches.
    fn do_register(&self, dataset: &DatasetSpec) -> Result<Json, ServeError> {
        let entry = self.registry.dataset(dataset)?;
        let prob = entry.problem.as_ref();
        let sparse = matches!(prob.x, crate::linalg::Design::Sparse(_));
        Ok(Json::obj(vec![
            ("dataset", Json::Str(entry.label.clone())),
            ("fingerprint", Json::Str(format!("{:016x}", entry.fingerprint))),
            ("n", Json::Num(prob.n() as f64)),
            ("p", Json::Num(prob.p() as f64)),
            ("family", Json::Str(prob.family.name().to_string())),
            ("sparse", Json::Bool(sparse)),
            ("standardized", Json::Bool(entry.transform.is_some())),
        ]))
    }

    fn do_stats(&self) -> Json {
        let (datasets, models) = self.registry.counts();
        Json::obj(vec![
            (
                "server",
                Json::obj(vec![
                    ("threads", Json::Num(self.sched.threads() as f64)),
                    ("fit_threads", Json::Num(self.sched.fit_threads() as f64)),
                    ("queue_capacity", Json::Num(self.sched.capacity() as f64)),
                    ("in_flight", Json::Num(self.sched.in_flight() as f64)),
                    ("cache", Json::Bool(self.registry.cache_enabled())),
                ]),
            ),
            (
                "registry",
                Json::obj(vec![
                    ("datasets", Json::Num(datasets as f64)),
                    ("models", Json::Num(models as f64)),
                ]),
            ),
            ("metrics", self.metrics.snapshot()),
        ])
    }

    /// The `metrics` op: the full exposition (serve counters, per-op
    /// latency quantiles, observability registry). `format: "json"`
    /// returns it structured; `format: "prometheus"` returns the text
    /// exposition in a `text` field so the transport stays
    /// newline-delimited JSON either way.
    fn do_metrics(&self, format: &str) -> Json {
        if format == "prometheus" {
            Json::obj(vec![
                ("format", Json::Str("prometheus".to_string())),
                ("text", Json::Str(self.metrics.prometheus())),
            ])
        } else {
            self.metrics.snapshot()
        }
    }

    /// The `health` op: one cheap summary of this server's failover
    /// state — role, epoch, replication lag, queue depth — plus a
    /// pre-rendered one-line `text` form so a shell probe can `grep`
    /// it without a JSON parser.
    fn do_health(&self) -> Json {
        let role = self.role();
        let epoch = self.epoch();
        let (subs, primary_lag) = self.registry.subscriber_stats();
        // A primary's lag is its slowest subscriber queue; a standby's
        // is what its apply loop last computed from a heartbeat.
        let lag = match role {
            Role::Primary => primary_lag,
            _ => self.repl_lag.load(Ordering::Relaxed),
        };
        let queue = self.sched.queue_depth();
        let state = if self.is_shutdown() {
            "draining"
        } else if role == Role::Fenced {
            "degraded"
        } else {
            "ready"
        };
        let text = format!(
            "role={} epoch={epoch} lag={lag} queue={queue} subscribers={subs} state={state}",
            role.name()
        );
        Json::obj(vec![
            ("role", Json::Str(role.name().to_string())),
            ("epoch", Json::Num(epoch as f64)),
            ("journal_records", Json::Num(self.registry.journal_records_total() as f64)),
            ("replication_lag", Json::Num(lag as f64)),
            ("subscribers", Json::Num(subs as f64)),
            ("queue_depth", Json::Num(queue as f64)),
            ("in_flight", Json::Num(self.sched.in_flight() as f64)),
            ("state", Json::Str(state.to_string())),
            ("text", Json::Str(text)),
        ])
    }

    /// Serve newline-delimited requests from `reader`, writing responses
    /// to `writer` — the stdin/stdout transport, also used per-connection
    /// by the socket transport and directly by tests.
    ///
    /// Lines are read through a byte cap ([`ServerConfig::max_line_bytes`]):
    /// an oversized line is drained (never buffered whole) and answered
    /// with a typed `oversized_line` error, and the connection keeps
    /// serving. With a connection-drop fault armed ([`crate::fault`]),
    /// the stream is severed without a response after the planned number
    /// of requests — the chaos harness' stand-in for a client vanishing
    /// mid-conversation.
    pub fn serve_lines<R: BufRead, W: Write>(&self, reader: R, writer: W) -> std::io::Result<()> {
        self.serve_lines_inner(reader, writer, None)
    }

    /// [`Server::serve_lines`] with an optional drain latch: socket
    /// transports pass one so shutdown can wait for the exact moment
    /// every in-flight response has been flushed instead of sleeping a
    /// guessed interval and hoping the flushes fit inside it.
    pub(crate) fn serve_lines_inner<R: BufRead, W: Write>(
        &self,
        mut reader: R,
        mut writer: W,
        latch: Option<&DrainLatch>,
    ) -> std::io::Result<()> {
        let drop_after = crate::fault::drop_after_lines();
        let mut lines_handled: u64 = 0;
        let mut buf: Vec<u8> = Vec::new();
        loop {
            match read_line_capped(&mut reader, &mut buf, self.max_line_bytes)? {
                LineRead::Eof => break,
                LineRead::Oversized(bytes) => {
                    let _busy = BusyGuard::new(latch);
                    writer.write_all(self.oversized_response(bytes).as_bytes())?;
                    writer.write_all(b"\n")?;
                    writer.flush()?;
                    continue;
                }
                LineRead::Line => {}
            }
            let line = String::from_utf8_lossy(&buf);
            let trimmed = line.trim();
            if trimmed.is_empty() {
                continue;
            }
            if let Some(limit) = drop_after {
                if lines_handled >= limit {
                    obsreg::FAULT_INJECTIONS.inc();
                    return Ok(());
                }
            }
            // Busy from "request read" to "response flushed" — the drain
            // latch's definition of an in-flight request; the RAII guard
            // keeps the count balanced across the early `?` returns.
            let busy = BusyGuard::new(latch);
            let response = self.handle_line(trimmed);
            lines_handled += 1;
            writer.write_all(response.as_bytes())?;
            writer.write_all(b"\n")?;
            writer.flush()?;
            drop(busy);
            if self.is_shutdown() {
                break;
            }
        }
        Ok(())
    }

    /// Serve over a Unix-domain socket, one handler thread per
    /// connection, until a `shutdown` request arrives.
    ///
    /// Binding probes an existing socket file first: if something
    /// answers, a second server is live and the bind is refused
    /// (`AddrInUse`) instead of silently stealing its socket; only a
    /// stale, unanswering file is removed. Connections past the
    /// `max_conns` cap are refused at accept with a typed `overload`
    /// close. Shutdown drains deterministically: admitted jobs finish,
    /// the drain latch waits for every in-flight response to be flushed,
    /// and only then are open connections severed and handlers joined —
    /// idle clients cannot wedge the join, and finished handles are
    /// pruned each loop turn so short-lived connections do not
    /// accumulate fds.
    #[cfg(unix)]
    pub fn serve_unix(self: &Arc<Self>, path: &std::path::Path) -> std::io::Result<()> {
        use std::collections::HashMap;
        use std::os::unix::net::{UnixListener, UnixStream};
        if path.exists() {
            match UnixStream::connect(path) {
                // A live server answered the probe: refuse to steal its
                // socket (the old unconditional remove_file silently
                // orphaned a running instance).
                Ok(_) => {
                    return Err(std::io::Error::new(
                        std::io::ErrorKind::AddrInUse,
                        format!(
                            "socket {} is answering: another server is already live",
                            path.display()
                        ),
                    ));
                }
                Err(_) => {
                    let _ = std::fs::remove_file(path);
                }
            }
        }
        let listener = UnixListener::bind(path)?;
        listener.set_nonblocking(true)?;
        let latch = Arc::new(DrainLatch::new());
        // Live connection registry: each handler removes its own entry on
        // exit (closing the duplicated fd).
        let live: Arc<Mutex<HashMap<u64, UnixStream>>> = Arc::new(Mutex::new(HashMap::new()));
        let mut handlers: Vec<std::thread::JoinHandle<()>> = Vec::new();
        let mut next_id = 0u64;
        while !self.is_shutdown() {
            match listener.accept() {
                Ok((stream, _addr)) => {
                    let _ = stream.set_nonblocking(false);
                    // Accept-time admission control: past the cap, answer
                    // with a typed `overload` close instead of spawning
                    // handler state the load-shedder never sees.
                    if live.lock().unwrap().len() >= self.max_conns {
                        obsreg::SERVE_CONN_LIMIT_REJECTED.inc();
                        let mut stream = stream;
                        let err = ServeError::Overload { retry_after_ms: 1000 };
                        let _ = stream.write_all(protocol::error_response(0, &err).as_bytes());
                        let _ = stream.write_all(b"\n");
                        let _ = stream.flush();
                        continue;
                    }
                    match stream.try_clone() {
                        Ok(tracked) => {
                            let id = next_id;
                            next_id += 1;
                            live.lock().unwrap().insert(id, tracked);
                            let server = Arc::clone(self);
                            let live_for_handler = Arc::clone(&live);
                            let latch_for_handler = Arc::clone(&latch);
                            handlers.push(std::thread::spawn(move || {
                                if let Ok(s) = stream.try_clone() {
                                    let _ = server.serve_lines_inner(
                                        BufReader::new(s),
                                        stream,
                                        Some(&latch_for_handler),
                                    );
                                }
                                live_for_handler.lock().unwrap().remove(&id);
                            }));
                        }
                        // Can't register the connection for shutdown
                        // cleanup (fd pressure): refuse it rather than
                        // spawn a handler the join could wait on forever.
                        Err(_) => drop(stream),
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(std::time::Duration::from_millis(5));
                }
                Err(e) => {
                    let _ = std::fs::remove_file(path);
                    return Err(e);
                }
            }
            handlers.retain(|h| !h.is_finished());
        }
        // Graceful drain: jobs already admitted when the drain began run
        // to completion — their handler threads still hold live
        // connections and write the response. Everything parked in the
        // queue was rejected with a typed `shutdown` error by
        // `begin_drain`, so every accepted request gets exactly one
        // response. The latch then waits for the exact moment every one
        // of those responses has been flushed (bounded, so a wedged peer
        // cannot hold shutdown hostage) before idle connections are
        // severed — severing is what unblocks handlers parked in a read
        // on clients that never hang up.
        self.sched.await_idle();
        let _ = latch.wait_idle(Duration::from_secs(30));
        for stream in live.lock().unwrap().values() {
            let _ = stream.shutdown(std::net::Shutdown::Both);
        }
        for h in handlers {
            let _ = h.join();
        }
        let _ = std::fs::remove_file(path);
        Ok(())
    }
}

/// Deterministic drain handshake for the socket transports: each handler
/// counts itself busy from the moment a request line is read to the
/// moment its response is flushed, and shutdown waits for the count to
/// hit zero instead of sleeping a fixed interval (the old 50 ms pause
/// dropped final responses whenever a flush outlasted it).
pub(crate) struct DrainLatch {
    busy: Mutex<usize>,
    cv: Condvar,
}

impl DrainLatch {
    pub(crate) fn new() -> DrainLatch {
        DrainLatch { busy: Mutex::new(0), cv: Condvar::new() }
    }

    fn enter(&self) {
        *self.busy.lock().unwrap() += 1;
    }

    fn exit(&self) {
        let mut n = self.busy.lock().unwrap();
        *n -= 1;
        if *n == 0 {
            self.cv.notify_all();
        }
    }

    /// Wait until no handler is between reading a request and flushing
    /// its response. Bounded: returns `false` on timeout so a peer that
    /// stops reading its socket cannot hold shutdown hostage.
    pub(crate) fn wait_idle(&self, timeout: Duration) -> bool {
        let deadline = Instant::now() + timeout;
        let mut n = self.busy.lock().unwrap();
        while *n > 0 {
            let now = Instant::now();
            if now >= deadline {
                return false;
            }
            let (guard, _) = self.cv.wait_timeout(n, deadline - now).unwrap();
            n = guard;
        }
        true
    }
}

/// RAII busy marker for [`DrainLatch`]; a `None` latch (the stdio
/// transport, tests) makes it free.
pub(crate) struct BusyGuard<'a>(Option<&'a DrainLatch>);

impl<'a> BusyGuard<'a> {
    pub(crate) fn new(latch: Option<&'a DrainLatch>) -> BusyGuard<'a> {
        if let Some(l) = latch {
            l.enter();
        }
        BusyGuard(latch)
    }
}

impl Drop for BusyGuard<'_> {
    fn drop(&mut self) {
        if let Some(l) = self.0 {
            l.exit();
        }
    }
}

/// Outcome of one capped line read.
enum LineRead {
    /// Stream ended before any byte of a new line.
    Eof,
    /// A complete line is in the buffer (newline excluded).
    Line,
    /// The line exceeded the cap; carries the bytes seen. The excess was
    /// drained (never buffered) up to its terminating newline or EOF,
    /// so the next read starts on a fresh line.
    Oversized(usize),
}

/// Read one newline-terminated line into `buf`, refusing to buffer more
/// than `cap` bytes — the defense against a single unbounded request
/// line exhausting server memory.
fn read_line_capped<R: BufRead>(
    reader: &mut R,
    buf: &mut Vec<u8>,
    cap: usize,
) -> std::io::Result<LineRead> {
    buf.clear();
    let mut line_len = 0usize;
    let mut overflowed = false;
    loop {
        let (used, terminated, eof) = {
            let chunk = reader.fill_buf()?;
            if chunk.is_empty() {
                (0, false, true)
            } else {
                match chunk.iter().position(|&b| b == b'\n') {
                    Some(pos) => {
                        if !overflowed && line_len + pos <= cap {
                            buf.extend_from_slice(&chunk[..pos]);
                        } else {
                            overflowed = true;
                        }
                        line_len += pos;
                        (pos + 1, true, false)
                    }
                    None => {
                        let len = chunk.len();
                        if !overflowed && line_len + len <= cap {
                            buf.extend_from_slice(chunk);
                        } else {
                            overflowed = true;
                        }
                        line_len += len;
                        (len, false, false)
                    }
                }
            }
        };
        reader.consume(used);
        if eof || terminated {
            if eof && line_len == 0 {
                return Ok(LineRead::Eof);
            }
            return Ok(if overflowed {
                buf.clear();
                LineRead::Oversized(line_len)
            } else {
                LineRead::Line
            });
        }
    }
}

fn op_name(request: &Request) -> &'static str {
    match request {
        Request::FitPath { .. } => "fit_path",
        Request::FitPoint { .. } => "fit_point",
        Request::Predict { .. } => "predict",
        Request::RegisterDataset { .. } => "dataset_from_file",
        Request::Stats => "stats",
        Request::Metrics { .. } => "metrics",
        Request::Health => "health",
        Request::Promote => "promote",
        Request::ReplSubscribe { .. } => "repl_subscribe",
        Request::Shutdown => "shutdown",
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn server() -> Server {
        Server::new(ServerConfig { threads: 2, queue: 8, cache: true, ..Default::default() })
    }

    fn parse_ok(response: &str) -> Json {
        let j = Json::parse(response).unwrap();
        assert_eq!(
            j.field("ok"),
            Some(&Json::Bool(true)),
            "expected success, got: {response}"
        );
        j.field("result").unwrap().clone()
    }

    fn fit_path_line(id: u64, seed: u64) -> String {
        protocol::request_line(
            id,
            "fit_path",
            vec![
                ("dataset", protocol::synth_dataset_json(30, 60, 4, 0.2, "gaussian", seed)),
                ("q", Json::Num(0.1)),
                ("path_length", Json::Num(8.0)),
            ],
        )
    }

    #[test]
    fn fit_path_cold_then_cached() {
        let srv = server();
        let first = parse_ok(&srv.handle_line(&fit_path_line(1, 5)));
        assert_eq!(first.field("source").unwrap().as_str(), Some("fit"));
        assert_eq!(first.field("strategy").unwrap().as_str(), Some("strong"));
        assert!(first.field("steps").unwrap().as_usize().unwrap() >= 2);
        let second = parse_ok(&srv.handle_line(&fit_path_line(2, 5)));
        assert_eq!(second.field("source").unwrap().as_str(), Some("cache"));
        assert_eq!(
            first.field("sigmas").unwrap().items(),
            second.field("sigmas").unwrap().items()
        );
        assert_eq!(
            srv.metrics.counters.cache_hits.load(Ordering::Relaxed),
            1
        );
    }

    #[test]
    fn restart_with_state_dir_restores_datasets() {
        let dir =
            std::env::temp_dir().join(format!("slope-server-state-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let cfg = || ServerConfig {
            threads: 2,
            queue: 8,
            cache: true,
            state_dir: Some(dir.clone()),
            ..Default::default()
        };
        {
            let srv = Server::new(cfg());
            parse_ok(&srv.handle_line(&fit_path_line(1, 77)));
        } // "crash": only the journal survives the first server
        let srv2 = Server::new(cfg());
        // The dataset is interned on boot, no re-registration needed...
        let stats = parse_ok(&srv2.handle_line(r#"{"id": 2, "op": "stats"}"#));
        assert_eq!(stats.field("datasets").unwrap().as_usize(), Some(1));
        // ...and a fit against it works immediately (fresh model cache,
        // warm-started from the journaled seed).
        let refit = parse_ok(&srv2.handle_line(&fit_path_line(3, 77)));
        assert_eq!(refit.field("source").unwrap().as_str(), Some("fit"));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn sibling_model_fit_is_warm() {
        let srv = server();
        parse_ok(&srv.handle_line(&fit_path_line(1, 6)));
        // same dataset, different path length => new model key, warm seed
        let refined = protocol::request_line(
            2,
            "fit_path",
            vec![
                ("dataset", protocol::synth_dataset_json(30, 60, 4, 0.2, "gaussian", 6)),
                ("q", Json::Num(0.1)),
                ("path_length", Json::Num(12.0)),
            ],
        );
        let result = parse_ok(&srv.handle_line(&refined));
        assert_eq!(result.field("source").unwrap().as_str(), Some("fit"));
        assert_eq!(result.field("strategy").unwrap().as_str(), Some("previous"));
        assert_eq!(srv.metrics.counters.warm_fits.load(Ordering::Relaxed), 1);
    }

    fn fit_point_line(id: u64, seed: u64, ratio: f64) -> String {
        protocol::request_line(
            id,
            "fit_point",
            vec![
                ("dataset", protocol::synth_dataset_json(30, 80, 4, 0.1, "gaussian", seed)),
                ("q", Json::Num(0.1)),
                ("sigma_ratio", Json::Num(ratio)),
            ],
        )
    }

    #[test]
    fn fit_point_warm_start_cycle() {
        let srv = server();
        let cold = parse_ok(&srv.handle_line(&fit_point_line(1, 7, 0.4)));
        assert_eq!(cold.field("warm"), Some(&Json::Bool(false)));
        assert_eq!(cold.field("strategy").unwrap().as_str(), Some("strong"));
        let cold_iters = cold.field("solver_iterations").unwrap().as_usize().unwrap();
        // repeat at the same σ: warm, previous-set, and an immediate solve
        let warm = parse_ok(&srv.handle_line(&fit_point_line(2, 7, 0.4)));
        assert_eq!(warm.field("warm"), Some(&Json::Bool(true)));
        assert_eq!(warm.field("strategy").unwrap().as_str(), Some("previous"));
        let warm_iters = warm.field("solver_iterations").unwrap().as_usize().unwrap();
        assert!(warm_iters <= cold_iters, "warm {warm_iters} vs cold {cold_iters}");
        assert_eq!(
            cold.field("n_active").unwrap().as_usize(),
            warm.field("n_active").unwrap().as_usize()
        );
        // a refined request (nearby σ) stays warm
        let refined = parse_ok(&srv.handle_line(&fit_point_line(3, 7, 0.35)));
        assert_eq!(refined.field("warm"), Some(&Json::Bool(true)));
    }

    #[test]
    fn predict_scores_rows() {
        let srv = server();
        let p = 40;
        let rows: Vec<Json> = (0..3)
            .map(|i| Json::nums(&(0..p).map(|j| ((i + j) % 5) as f64 * 0.1).collect::<Vec<f64>>()))
            .collect();
        let line = protocol::request_line(
            9,
            "predict",
            vec![
                ("dataset", protocol::synth_dataset_json(25, p, 3, 0.0, "gaussian", 11)),
                ("q", Json::Num(0.1)),
                ("path_length", Json::Num(6.0)),
                ("x", Json::Arr(rows)),
            ],
        );
        let result = parse_ok(&srv.handle_line(&line));
        assert_eq!(result.field("eta").unwrap().items().len(), 3);
        assert_eq!(srv.metrics.counters.predictions.load(Ordering::Relaxed), 3);
        // bad row width is a clean error
        let bad = protocol::request_line(
            10,
            "predict",
            vec![
                ("dataset", protocol::synth_dataset_json(25, p, 3, 0.0, "gaussian", 11)),
                ("q", Json::Num(0.1)),
                ("path_length", Json::Num(6.0)),
                ("x", Json::Arr(vec![Json::nums(&[1.0, 2.0])])),
            ],
        );
        let resp = Json::parse(&srv.handle_line(&bad)).unwrap();
        assert_eq!(resp.field("ok"), Some(&Json::Bool(false)));
    }

    #[test]
    fn predict_on_inline_dataset_uses_model_coordinates() {
        let srv = server();
        // Raw features on wildly different scales: feature 0 ≈ 1000,
        // feature 1 ≈ 0.01 — both perfectly correlated with y.
        let x: Vec<Vec<f64>> = (0..12).map(|i| vec![1000.0 + i as f64, 0.001 * i as f64]).collect();
        let y: Vec<f64> = (0..12).map(|i| 2.0 * i as f64 - 11.0).collect();
        let dataset = Json::obj(vec![
            ("kind", Json::Str("inline".to_string())),
            ("x", Json::Arr(x.iter().map(|r| Json::nums(r)).collect())),
            ("y", Json::nums(&y)),
            ("family", Json::Str("gaussian".to_string())),
        ]);
        let line = protocol::request_line(
            1,
            "predict",
            vec![
                ("dataset", dataset),
                ("lambda", Json::Str("lasso".to_string())),
                ("path_length", Json::Num(10.0)),
                ("x", Json::Arr(vec![Json::nums(&x[0]), Json::nums(&x[11])])),
            ],
        );
        let result = parse_ok(&srv.handle_line(&line));
        let eta = result.field("eta").unwrap().items();
        assert_eq!(eta.len(), 2);
        let e0 = eta[0].items()[0].as_f64().unwrap();
        let e1 = eta[1].items()[0].as_f64().unwrap();
        // Raw feature values are ~1000; without the server-side transform
        // the scores would be on that scale. In model coordinates they
        // must stay on the response scale and preserve the signal order.
        assert!(e0.abs() < 100.0 && e1.abs() < 100.0, "eta not in model coordinates: {e0} {e1}");
        assert!(e1 > e0, "predictions lost the signal direction: {e0} vs {e1}");
    }

    #[test]
    fn inline_gaussian_predictions_return_to_client_scale() {
        let srv = server();
        let x: Vec<Vec<f64>> = (0..10).map(|i| vec![i as f64]).collect();
        let y: Vec<f64> = (0..10).map(|i| 500.0 + 3.0 * i as f64).collect();
        let dataset = Json::obj(vec![
            ("kind", Json::Str("inline".to_string())),
            ("x", Json::Arr(x.iter().map(|r| Json::nums(r)).collect())),
            ("y", Json::nums(&y)),
            ("family", Json::Str("gaussian".to_string())),
        ]);
        let line = protocol::request_line(
            1,
            "predict",
            vec![
                ("dataset", dataset),
                ("lambda", Json::Str("lasso".to_string())),
                ("path_length", Json::Num(12.0)),
                ("x", Json::Arr(vec![Json::nums(&x[0]), Json::nums(&x[9])])),
            ],
        );
        let result = parse_ok(&srv.handle_line(&line));
        let eta = result.field("eta").unwrap().items();
        let e0 = eta[0].items()[0].as_f64().unwrap();
        let e9 = eta[1].items()[0].as_f64().unwrap();
        // Scores sit on the client's response scale (~500..527), not the
        // centered model scale (~±13): the y-centering intercept is
        // restored.
        assert!(e0 > 400.0 && e9 > 400.0, "intercept lost: {e0} {e9}");
        assert!(e9 > e0, "signal direction lost: {e0} vs {e9}");
    }

    #[test]
    fn dataset_from_file_registers_and_fits_from_cache_entry() {
        let srv = server();
        let path = std::env::temp_dir()
            .join(format!("slope-serve-file-{}.csv", std::process::id()));
        std::fs::write(&path, "x1,x2,y\n1,0,0.1\n0,1,0.4\n1,1,0.9\n2,0,0.2\n").unwrap();
        let dataset = Json::obj(vec![
            ("kind", Json::Str("file".to_string())),
            ("path", Json::Str(path.to_str().unwrap().to_string())),
            ("family", Json::Str("gaussian".to_string())),
        ]);
        let reg = protocol::request_line(1, "dataset_from_file", vec![("dataset", dataset.clone())]);
        let result = parse_ok(&srv.handle_line(&reg));
        assert_eq!(result.field("n").unwrap().as_usize(), Some(4));
        assert_eq!(result.field("p").unwrap().as_usize(), Some(2));
        assert_eq!(result.field("sparse"), Some(&Json::Bool(false)));
        assert_eq!(result.field("standardized"), Some(&Json::Bool(true)));
        let fp = result.field("fingerprint").unwrap().as_str().unwrap().to_string();
        // a fit naming the same file reuses the interned entry
        let fit = protocol::request_line(
            2,
            "fit_path",
            vec![
                ("dataset", dataset),
                ("lambda", Json::Str("lasso".to_string())),
                ("path_length", Json::Num(5.0)),
            ],
        );
        let fitted = parse_ok(&srv.handle_line(&fit));
        assert_eq!(fitted.field("fingerprint").unwrap().as_str(), Some(fp.as_str()));
        let _ = std::fs::remove_file(&path);
        // a missing file is an error response that echoes the id
        let gone = protocol::request_line(
            3,
            "dataset_from_file",
            vec![(
                "dataset",
                Json::obj(vec![
                    ("kind", Json::Str("file".to_string())),
                    ("path", Json::Str("/nonexistent/slope.csv".to_string())),
                ]),
            )],
        );
        let resp = Json::parse(&srv.handle_line(&gone)).unwrap();
        assert_eq!(resp.field("ok"), Some(&Json::Bool(false)));
        assert_eq!(resp.field("id").unwrap().as_usize(), Some(3));
    }

    #[test]
    fn inline_overflow_is_an_error_response_not_a_nan_fit() {
        let srv = server();
        let dataset = Json::obj(vec![
            ("kind", Json::Str("inline".to_string())),
            (
                "x",
                Json::Arr(vec![
                    Json::nums(&[1e308]),
                    Json::nums(&[1e308]),
                    Json::nums(&[-1e308]),
                ]),
            ),
            ("y", Json::nums(&[0.0, 1.0, 2.0])),
            ("family", Json::Str("gaussian".to_string())),
        ]);
        let line = protocol::request_line(
            7,
            "fit_path",
            vec![("dataset", dataset), ("q", Json::Num(0.1)), ("path_length", Json::Num(4.0))],
        );
        let resp = Json::parse(&srv.handle_line(&line)).unwrap();
        assert_eq!(resp.field("ok"), Some(&Json::Bool(false)));
        assert!(resp.field("error").unwrap().as_str().unwrap().contains("not finite"));
    }

    #[test]
    fn error_responses_echo_the_request_id() {
        let srv = server();
        let resp = srv.handle_line(r#"{"id": 41, "op": "fit_point", "dataset": {"kind": "synth"}, "sigma_ratio": 5.0}"#);
        let j = Json::parse(&resp).unwrap();
        assert_eq!(j.field("ok"), Some(&Json::Bool(false)));
        assert_eq!(j.field("id").unwrap().as_usize(), Some(41));
    }

    #[test]
    fn fit_threads_budget_is_exposed_and_overridable() {
        let srv = Server::new(ServerConfig {
            threads: 2,
            queue: 8,
            cache: true,
            fit_threads: 3,
            ..Default::default()
        });
        let stats = parse_ok(&srv.handle_line(r#"{"id": 1, "op": "stats"}"#));
        let ft = stats
            .field("server")
            .unwrap()
            .field("fit_threads")
            .unwrap()
            .as_usize()
            .unwrap();
        assert_eq!(ft, 3);
        // a per-request budget is accepted (and does not change the result:
        // the parallel backend is deterministic)
        let line = protocol::request_line(
            2,
            "fit_path",
            vec![
                ("dataset", protocol::synth_dataset_json(20, 30, 3, 0.1, "gaussian", 77)),
                ("q", Json::Num(0.1)),
                ("path_length", Json::Num(5.0)),
                ("threads", Json::Num(2.0)),
            ],
        );
        parse_ok(&srv.handle_line(&line));
    }

    #[test]
    fn hybrid_screen_and_gap_tol_share_the_model_cache() {
        // `screen: hybrid` + `gap_tol` are performance knobs: the fitted
        // model they produce is interchangeable with the strong-rule one,
        // so a follow-up request differing only in them must be a cache
        // hit, not a refit.
        let srv = server();
        let req = |id: u64, extra: Vec<(&'static str, Json)>| {
            let mut fields = vec![
                ("dataset", protocol::synth_dataset_json(25, 40, 3, 0.1, "gaussian", 91)),
                ("q", Json::Num(0.1)),
                ("path_length", Json::Num(6.0)),
            ];
            fields.extend(extra);
            protocol::request_line(id, "fit_path", fields)
        };
        let first = parse_ok(&srv.handle_line(&req(
            1,
            vec![
                ("screen", Json::Str("hybrid".to_string())),
                ("gap_tol", Json::Num(1e-9)),
            ],
        )));
        assert_eq!(
            first.field("strategy").unwrap().as_str(),
            Some("hybrid"),
            "explicit hybrid screen must be honored"
        );
        assert_eq!(first.field("source").unwrap().as_str(), Some("fit"));
        let second =
            parse_ok(&srv.handle_line(&req(2, vec![("screen", Json::Str("strong".to_string()))])));
        assert_eq!(second.field("source").unwrap().as_str(), Some("cache"));
        // same fitted grid either way
        assert_eq!(
            first.field("steps").unwrap().as_usize(),
            second.field("steps").unwrap().as_usize()
        );
        // a safe-only fit also goes through end to end
        let third = parse_ok(&srv.handle_line(&protocol::request_line(
            3,
            "fit_path",
            vec![
                ("dataset", protocol::synth_dataset_json(25, 40, 3, 0.1, "gaussian", 92)),
                ("q", Json::Num(0.1)),
                ("path_length", Json::Num(5.0)),
                ("screen", Json::Str("safe".to_string())),
            ],
        )));
        assert_eq!(third.field("total_violations").unwrap().as_usize(), Some(0));
    }

    #[test]
    fn stats_and_errors_and_shutdown() {
        let srv = server();
        let bad = srv.handle_line("this is not json");
        let j = Json::parse(&bad).unwrap();
        assert_eq!(j.field("ok"), Some(&Json::Bool(false)));
        let stats = parse_ok(&srv.handle_line(r#"{"id": 1, "op": "stats"}"#));
        let requests = stats
            .field("metrics")
            .unwrap()
            .field("counters")
            .unwrap()
            .field("requests")
            .unwrap()
            .as_usize()
            .unwrap();
        assert!(requests >= 2);
        assert!(!srv.is_shutdown());
        parse_ok(&srv.handle_line(r#"{"id": 2, "op": "shutdown"}"#));
        assert!(srv.is_shutdown());
    }

    #[test]
    fn metrics_op_serves_json_and_prometheus() {
        let srv = server();
        parse_ok(&srv.handle_line(&fit_path_line(1, 51)));
        // JSON form: full snapshot with serve counters, latency
        // quantiles, and the observability registry
        let snap = parse_ok(&srv.handle_line(r#"{"id": 2, "op": "metrics"}"#));
        let counters = snap.field("counters").unwrap();
        assert!(counters.field("requests").unwrap().as_usize().unwrap() >= 2);
        let fit_lat = snap.field("latency").unwrap().field("fit_path").unwrap();
        assert_eq!(fit_lat.field("count").unwrap().as_usize(), Some(1));
        let reg = snap.field("registry").unwrap();
        assert!(
            reg.field("registry_model_builds").unwrap().as_usize().unwrap() >= 1,
            "the fit above must be counted as a model build"
        );
        assert!(reg.field("fista_iterations").unwrap().as_usize().unwrap() >= 1);
        // Prometheus form: text exposition wrapped in a JSON field
        let prom =
            parse_ok(&srv.handle_line(r#"{"id": 3, "op": "metrics", "format": "prometheus"}"#));
        assert_eq!(prom.field("format").unwrap().as_str(), Some("prometheus"));
        let text = prom.field("text").unwrap().as_str().unwrap();
        assert!(text.contains("slope_serve_requests_total"));
        assert!(text.contains("# TYPE slope_path_steps_total counter"));
        assert!(text.contains("slope_serve_op_seconds_count{op=\"fit_path\"} 1"));
        // bad format is an error response
        let bad = srv.handle_line(r#"{"id": 4, "op": "metrics", "format": "xml"}"#);
        assert_eq!(Json::parse(&bad).unwrap().field("ok"), Some(&Json::Bool(false)));
    }

    #[test]
    fn oversized_lines_get_a_typed_error_and_the_connection_survives() {
        let srv = Server::new(ServerConfig {
            threads: 2,
            queue: 8,
            cache: true,
            max_line_bytes: 4096,
            ..Default::default()
        });
        let big = format!(
            "{{\"id\": 1, \"op\": \"stats\", \"pad\": \"{}\"}}",
            "x".repeat(10_000)
        );
        let input = format!(
            "{big}\n{}\n{}\n",
            r#"{"id": 2, "op": "stats"}"#,
            r#"{"id": 3, "op": "shutdown"}"#
        );
        let mut out: Vec<u8> = Vec::new();
        srv.serve_lines(std::io::Cursor::new(input.into_bytes()), &mut out)
            .unwrap();
        let text = String::from_utf8(out).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 3, "oversized + stats + shutdown: {text}");
        let first = Json::parse(lines[0]).unwrap();
        assert_eq!(first.field("ok"), Some(&Json::Bool(false)));
        assert_eq!(first.field("error_kind").unwrap().as_str(), Some("oversized_line"));
        assert!(first.field("error").unwrap().as_str().unwrap().contains("4096"));
        // the oversized line was drained, not parsed: the next request
        // on the same connection is served normally
        let second = Json::parse(lines[1]).unwrap();
        assert_eq!(second.field("ok"), Some(&Json::Bool(true)));
        assert_eq!(second.field("id").unwrap().as_usize(), Some(2));
    }

    #[test]
    fn expired_deadline_is_a_typed_error_and_never_cached() {
        let srv = server();
        let fields = |id: u64, deadline: Option<f64>| {
            let mut f = vec![
                ("dataset", protocol::synth_dataset_json(150, 3000, 10, 0.2, "gaussian", 13)),
                ("q", Json::Num(0.1)),
                ("path_length", Json::Num(40.0)),
            ];
            if let Some(ms) = deadline {
                f.push(("deadline_ms", Json::Num(ms)));
            }
            protocol::request_line(id, "fit_path", f)
        };
        let resp = Json::parse(&srv.handle_line(&fields(1, Some(1.0)))).unwrap();
        assert_eq!(resp.field("ok"), Some(&Json::Bool(false)));
        assert_eq!(resp.field("error_kind").unwrap().as_str(), Some("deadline"));
        let partial = resp.field("partial").unwrap();
        assert!(partial.field("steps_done").unwrap().as_usize().is_some());
        // the partial fit was not cached: a later unbounded request on
        // the same (dataset, model) fits fresh and succeeds
        let ok = parse_ok(&srv.handle_line(&fields(2, None)));
        assert_eq!(ok.field("source").unwrap().as_str(), Some("fit"));
        assert!(ok.field("steps").unwrap().as_usize().unwrap() >= 2);
    }

    #[test]
    fn serve_lines_round_trips() {
        let srv = server();
        let input = format!(
            "{}\n\n{}\n",
            fit_path_line(1, 21),
            r#"{"id": 2, "op": "shutdown"}"#
        );
        let mut out: Vec<u8> = Vec::new();
        srv.serve_lines(std::io::Cursor::new(input.into_bytes()), &mut out)
            .unwrap();
        let text = String::from_utf8(out).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        let first = Json::parse(lines[0]).unwrap();
        assert_eq!(first.field("id").unwrap().as_usize(), Some(1));
        assert_eq!(first.field("ok"), Some(&Json::Bool(true)));
        assert!(srv.is_shutdown());
    }

    #[cfg(unix)]
    #[test]
    fn unix_socket_round_trip() {
        use super::super::client;
        let dir = std::env::temp_dir();
        let sock = dir.join(format!("slope-serve-test-{}.sock", std::process::id()));
        let srv = Arc::new(server());
        let srv2 = Arc::clone(&srv);
        let sock2 = sock.clone();
        let handle = std::thread::spawn(move || srv2.serve_unix(&sock2));
        let mut cl = client::connect_with_retry(&sock, 100, 10).expect("connect");
        let resp = cl.round_trip(&fit_path_line(1, 31)).unwrap();
        let j = Json::parse(&resp).unwrap();
        assert_eq!(j.field("ok"), Some(&Json::Bool(true)));
        let resp = cl.round_trip(r#"{"id": 2, "op": "shutdown"}"#).unwrap();
        assert!(Json::parse(&resp).is_ok());
        drop(cl);
        handle.join().unwrap().unwrap();
        assert!(!sock.exists());
    }

    #[test]
    fn batched_fit_point_matches_sequential_responses_bitwise() {
        let batched = Arc::new(Server::new(ServerConfig {
            threads: 2,
            queue: 8,
            cache: true,
            gather_window_ms: 1500,
            max_batch: 3,
            ..Default::default()
        }));
        let sequential = server();
        // Intern the dataset first so the racing requests go straight to
        // the batcher instead of serializing on dataset ingest.
        let register = protocol::request_line(
            0,
            "dataset_from_file",
            vec![("dataset", protocol::synth_dataset_json(30, 80, 4, 0.1, "gaussian", 7))],
        );
        parse_ok(&batched.handle_line(&register));
        let threads: Vec<_> = (1..=3u64)
            .map(|id| {
                let srv = Arc::clone(&batched);
                std::thread::spawn(move || parse_ok(&srv.handle_line(&fit_point_line(id, 7, 0.4))))
            })
            .collect();
        let mut got: Vec<Json> = threads.into_iter().map(|t| t.join().unwrap()).collect();
        // Reference: the same three requests back to back.
        let seq: Vec<Json> = (1..=3u64)
            .map(|id| parse_ok(&sequential.handle_line(&fit_point_line(id, 7, 0.4))))
            .collect();
        // Arrival order inside the batch is whatever the race produced,
        // but exactly one member was cold and the rest chained warm — the
        // same multiset sequential handling yields. Sort the cold
        // response first to line the two sides up.
        got.sort_by_key(|r| r.field("warm") == Some(&Json::Bool(true)));
        assert_eq!(got[0].field("warm"), Some(&Json::Bool(false)));
        for (g, s) in got.iter().zip(&seq) {
            assert_eq!(g.field("warm"), s.field("warm"));
            assert_eq!(g.field("strategy"), s.field("strategy"));
            assert_eq!(g.field("violations"), s.field("violations"));
            assert_eq!(g.field("n_active"), s.field("n_active"));
            assert_eq!(g.field("n_fitted"), s.field("n_fitted"));
            let gb = g.field("nonzeros").unwrap().items();
            let sb = s.field("nonzeros").unwrap().items();
            assert_eq!(gb.len(), sb.len());
            for (a, b) in gb.iter().zip(sb) {
                let (ai, av) = (a.items()[0].as_f64().unwrap(), a.items()[1].as_f64().unwrap());
                let (bi, bv) = (b.items()[0].as_f64().unwrap(), b.items()[1].as_f64().unwrap());
                assert_eq!(ai, bi);
                // coefficient identity is exact, not approximate
                assert_eq!(av.to_bits(), bv.to_bits(), "coefficient {ai} differs");
            }
        }
    }

    #[test]
    fn batched_predict_demuxes_members_bitwise() {
        let batched = Arc::new(Server::new(ServerConfig {
            threads: 2,
            queue: 8,
            cache: true,
            gather_window_ms: 1500,
            max_batch: 2,
            ..Default::default()
        }));
        let plain = server();
        let p = 40;
        let row = |i: usize| (0..p).map(|j| ((i + j) % 5) as f64 * 0.1).collect::<Vec<f64>>();
        let line = |id: u64, rows: &[usize]| {
            protocol::request_line(
                id,
                "predict",
                vec![
                    ("dataset", protocol::synth_dataset_json(25, p, 3, 0.0, "gaussian", 11)),
                    ("q", Json::Num(0.1)),
                    ("path_length", Json::Num(6.0)),
                    ("x", Json::Arr(rows.iter().map(|&i| Json::nums(&row(i))).collect())),
                ],
            )
        };
        // Fit once on each server so the racing predicts hit the model
        // cache and actually coalesce.
        parse_ok(&batched.handle_line(&line(0, &[0])));
        parse_ok(&plain.handle_line(&line(0, &[0])));
        let (srv_a, srv_b) = (Arc::clone(&batched), Arc::clone(&batched));
        let line_a = line(1, &[1, 2]);
        let line_b = line(2, &[3, 4, 5]);
        let ta = std::thread::spawn(move || parse_ok(&srv_a.handle_line(&line_a)));
        let tb = std::thread::spawn(move || parse_ok(&srv_b.handle_line(&line_b)));
        let (ra, rb) = (ta.join().unwrap(), tb.join().unwrap());
        // Each member got exactly its own rows back...
        assert_eq!(ra.field("eta").unwrap().items().len(), 2);
        assert_eq!(rb.field("eta").unwrap().items().len(), 3);
        // ...and each score is bit-identical to unbatched handling.
        for (got, reference) in
            [(&ra, plain.handle_line(&line(1, &[1, 2]))), (&rb, plain.handle_line(&line(2, &[3, 4, 5])))]
        {
            let want = parse_ok(&reference);
            let ge = got.field("eta").unwrap().items();
            let we = want.field("eta").unwrap().items();
            assert_eq!(ge.len(), we.len());
            for (grow, wrow) in ge.iter().zip(we) {
                for (gv, wv) in grow.items().iter().zip(wrow.items()) {
                    assert_eq!(
                        gv.as_f64().unwrap().to_bits(),
                        wv.as_f64().unwrap().to_bits(),
                        "batched prediction diverged from sequential"
                    );
                }
            }
        }
    }

    #[cfg(unix)]
    #[test]
    fn conn_limit_is_enforced_at_accept_with_typed_overload() {
        use super::super::client;
        let sock = std::env::temp_dir()
            .join(format!("slope-serve-connlimit-{}.sock", std::process::id()));
        let srv = Arc::new(Server::new(ServerConfig {
            threads: 2,
            queue: 8,
            cache: true,
            max_conns: 1,
            ..Default::default()
        }));
        let srv2 = Arc::clone(&srv);
        let sock2 = sock.clone();
        let handle = std::thread::spawn(move || srv2.serve_unix(&sock2));
        let mut first = client::connect_with_retry(&sock, 100, 10).expect("connect");
        // Prove the first connection is registered before racing a second.
        let resp = first.round_trip(r#"{"id": 1, "op": "stats"}"#).unwrap();
        assert_eq!(Json::parse(&resp).unwrap().field("ok"), Some(&Json::Bool(true)));
        // The second connection is answered with a typed overload close
        // instead of a silent hang or an untracked handler thread.
        let second = std::os::unix::net::UnixStream::connect(&sock).unwrap();
        let mut line = String::new();
        BufReader::new(second).read_line(&mut line).unwrap();
        let j = Json::parse(line.trim()).unwrap();
        assert_eq!(j.field("ok"), Some(&Json::Bool(false)));
        assert_eq!(j.field("error_kind").unwrap().as_str(), Some("overload"));
        let resp = first.round_trip(r#"{"id": 2, "op": "shutdown"}"#).unwrap();
        assert!(Json::parse(&resp).is_ok());
        drop(first);
        handle.join().unwrap().unwrap();
    }

    #[cfg(unix)]
    #[test]
    fn live_socket_bind_is_refused_and_stale_socket_is_reclaimed() {
        use super::super::client;
        let sock =
            std::env::temp_dir().join(format!("slope-serve-probe-{}.sock", std::process::id()));
        let _ = std::fs::remove_file(&sock);
        let srv = Arc::new(server());
        let srv2 = Arc::clone(&srv);
        let sock2 = sock.clone();
        let handle = std::thread::spawn(move || srv2.serve_unix(&sock2));
        let mut cl = client::connect_with_retry(&sock, 100, 10).expect("connect");
        // A second server probing the same path finds it answering and
        // refuses to steal the socket out from under the live instance.
        let other = Arc::new(server());
        let err = other.serve_unix(&sock).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::AddrInUse);
        // The first server is unharmed by the probe.
        let resp = cl.round_trip(r#"{"id": 1, "op": "stats"}"#).unwrap();
        assert_eq!(Json::parse(&resp).unwrap().field("ok"), Some(&Json::Bool(true)));
        let _ = cl.round_trip(r#"{"id": 2, "op": "shutdown"}"#).unwrap();
        drop(cl);
        handle.join().unwrap().unwrap();
        // A stale socket file (nothing listening behind it) is reclaimed.
        {
            let _stale = std::os::unix::net::UnixListener::bind(&sock).unwrap();
        } // dropped: the file stays on disk, nothing answers
        assert!(sock.exists());
        let srv3 = Arc::new(server());
        let srv4 = Arc::clone(&srv3);
        let sock3 = sock.clone();
        let handle = std::thread::spawn(move || srv4.serve_unix(&sock3));
        let mut cl = client::connect_with_retry(&sock, 100, 10).expect("reclaim stale socket");
        let _ = cl.round_trip(r#"{"id": 3, "op": "shutdown"}"#).unwrap();
        drop(cl);
        handle.join().unwrap().unwrap();
    }

    #[test]
    fn health_reports_role_epoch_and_queue() {
        let srv = server();
        let h = parse_ok(&srv.handle_line(r#"{"id": 1, "op": "health"}"#));
        assert_eq!(h.field("role").unwrap().as_str(), Some("primary"));
        assert_eq!(h.field("epoch").unwrap().as_usize(), Some(0));
        assert_eq!(h.field("state").unwrap().as_str(), Some("ready"));
        assert_eq!(h.field("queue_depth").unwrap().as_usize(), Some(0));
        assert_eq!(h.field("subscribers").unwrap().as_usize(), Some(0));
        let text = h.field("text").unwrap().as_str().unwrap();
        assert!(
            text.contains("role=primary") && text.contains("state=ready"),
            "one-line form must be grep-able: {text}"
        );
    }

    #[test]
    fn standby_fences_writes_until_promoted() {
        let srv = Server::new(ServerConfig {
            threads: 2,
            queue: 8,
            cache: true,
            standby: true,
            ..Default::default()
        });
        let resp = Json::parse(&srv.handle_line(&fit_path_line(1, 5))).unwrap();
        assert_eq!(resp.field("ok"), Some(&Json::Bool(false)));
        assert_eq!(resp.field("error_kind").unwrap().as_str(), Some("fenced"));
        assert!(obsreg::SERVE_FENCED_REJECTS.get() >= 1);
        // reads stay available on a standby
        parse_ok(&srv.handle_line(r#"{"id": 2, "op": "stats"}"#));
        let h = parse_ok(&srv.handle_line(r#"{"id": 3, "op": "health"}"#));
        assert_eq!(h.field("role").unwrap().as_str(), Some("standby"));
        assert_eq!(h.field("state").unwrap().as_str(), Some("ready"));
        // promotion bumps the epoch and opens writes
        let p = parse_ok(&srv.handle_line(r#"{"id": 4, "op": "promote"}"#));
        assert_eq!(p.field("promoted"), Some(&Json::Bool(true)));
        assert_eq!(p.field("role").unwrap().as_str(), Some("primary"));
        assert_eq!(p.field("epoch").unwrap().as_usize(), Some(1));
        parse_ok(&srv.handle_line(&fit_path_line(5, 5)));
        // a retried promote is a no-op at the same epoch
        let p2 = parse_ok(&srv.handle_line(r#"{"id": 6, "op": "promote"}"#));
        assert_eq!(p2.field("promoted"), Some(&Json::Bool(false)));
        assert_eq!(p2.field("epoch").unwrap().as_usize(), Some(1));
    }

    #[test]
    fn observing_a_higher_epoch_fences_a_primary() {
        let srv = server();
        assert_eq!(srv.role(), Role::Primary);
        assert!(srv.observe_remote_epoch(3), "higher epoch must fence");
        assert_eq!(srv.role(), Role::Fenced);
        assert_eq!(srv.epoch(), 3);
        let resp = Json::parse(&srv.handle_line(&fit_path_line(1, 5))).unwrap();
        assert_eq!(resp.field("error_kind").unwrap().as_str(), Some("fenced"));
        assert!(resp.field("error").unwrap().as_str().unwrap().contains("epoch 3"));
        let h = parse_ok(&srv.handle_line(r#"{"id": 2, "op": "health"}"#));
        assert_eq!(h.field("state").unwrap().as_str(), Some("degraded"));
        // an older epoch seen later neither un-fences nor regresses
        assert!(!srv.observe_remote_epoch(2));
        assert_eq!(srv.role(), Role::Fenced);
        assert_eq!(srv.epoch(), 3);
    }
}

//! Serving metrics: request counters, bounded per-op latency reservoirs,
//! and the process-wide [`crate::obs::registry`] snapshot, rendered as
//! JSON (`stats` / `metrics` ops) or Prometheus text exposition
//! (`metrics` with `format: "prometheus"`).
//!
//! Latency storage is a fixed-size uniform reservoir per op (Vitter's
//! Algorithm R with a deterministic xorshift stream): under sustained
//! load memory stays bounded at [`RESERVOIR`] samples while every sample
//! ever recorded remains equally likely to be retained, so quantiles
//! describe the whole run, not just the recent window. Totals (`count`,
//! `sum`, `max`) are exact — only the quantiles are sampled. A snapshot
//! clones at most the reservoir, never an unbounded history.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::benchkit::Timing;
use crate::jsonio::Json;
use crate::obs;

/// Retained latency samples per op (the reservoir size). Totals are
/// exact regardless; this bounds only quantile-estimation memory.
const RESERVOIR: usize = 4096;

/// Monotonic request/cache counters.
#[derive(Default)]
pub struct Counters {
    /// Request lines received.
    pub requests: AtomicU64,
    /// Error responses produced.
    pub errors: AtomicU64,
    /// Fit requests served from the model cache.
    pub cache_hits: AtomicU64,
    /// Fit requests coalesced onto an in-flight identical fit.
    pub coalesced: AtomicU64,
    /// Cold (unseeded) fits executed.
    pub cold_fits: AtomicU64,
    /// Warm (seeded) fits executed.
    pub warm_fits: AtomicU64,
    /// Rows scored by `predict`.
    pub predictions: AtomicU64,
}

impl Counters {
    fn pairs(&self) -> Vec<(&'static str, u64)> {
        vec![
            ("requests", self.requests.load(Ordering::Relaxed)),
            ("errors", self.errors.load(Ordering::Relaxed)),
            ("cache_hits", self.cache_hits.load(Ordering::Relaxed)),
            ("coalesced", self.coalesced.load(Ordering::Relaxed)),
            ("cold_fits", self.cold_fits.load(Ordering::Relaxed)),
            ("warm_fits", self.warm_fits.load(Ordering::Relaxed)),
            ("predictions", self.predictions.load(Ordering::Relaxed)),
        ]
    }
}

/// Exact totals plus a bounded uniform sample of one op's latencies.
struct OpStats {
    count: u64,
    sum: f64,
    max: f64,
    reservoir: Vec<f64>,
    /// xorshift64 state for Algorithm R's replacement index — cheap,
    /// lock-held, and deterministic given the record sequence.
    rng: u64,
}

impl OpStats {
    fn new(seed: u64) -> OpStats {
        OpStats { count: 0, sum: 0.0, max: 0.0, reservoir: Vec::new(), rng: seed | 1 }
    }

    fn record(&mut self, seconds: f64) {
        self.count += 1;
        self.sum += seconds;
        if seconds > self.max {
            self.max = seconds;
        }
        if self.reservoir.len() < RESERVOIR {
            self.reservoir.push(seconds);
            return;
        }
        // Algorithm R: keep the new sample with probability R/count, at a
        // uniform position — every sample so far survives with equal
        // probability R/count.
        self.rng ^= self.rng << 13;
        self.rng ^= self.rng >> 7;
        self.rng ^= self.rng << 17;
        let j = self.rng % self.count;
        if (j as usize) < RESERVOIR {
            self.reservoir[j as usize] = seconds;
        }
    }
}

/// Server metrics: counters plus per-op latency reservoirs.
pub struct Metrics {
    started: Instant,
    /// The counters (bumped directly by the server).
    pub counters: Counters,
    latencies: Mutex<BTreeMap<String, OpStats>>,
}

impl Metrics {
    /// Fresh metrics with the uptime clock started.
    pub fn new() -> Metrics {
        Metrics {
            started: Instant::now(),
            counters: Counters::default(),
            latencies: Mutex::new(BTreeMap::new()),
        }
    }

    /// Record one op latency in seconds.
    pub fn record(&self, op: &str, seconds: f64) {
        let mut map = self.latencies.lock().unwrap();
        let seed = 0x9e37_79b9_7f4a_7c15u64.wrapping_add(map.len() as u64);
        map.entry(op.to_string()).or_insert_with(|| OpStats::new(seed)).record(seconds);
    }

    /// Per-op latency summaries: exact `count`/`mean`/`max`, quantiles
    /// from the bounded reservoir. The lock is held only to clone each
    /// op's reservoir (≤ [`RESERVOIR`] values), never a full history.
    fn latency_json(&self) -> Json {
        let mut ops = BTreeMap::new();
        let sampled: Vec<(String, u64, f64, f64, Vec<f64>)> = {
            let map = self.latencies.lock().unwrap();
            map.iter()
                .filter(|(_, s)| s.count > 0)
                .map(|(op, s)| (op.clone(), s.count, s.sum, s.max, s.reservoir.clone()))
                .collect()
        };
        for (op, count, sum, max, reservoir) in sampled {
            let t = Timing::from_samples(reservoir);
            ops.insert(
                op,
                Json::obj(vec![
                    ("count", Json::Num(count as f64)),
                    ("median_s", Json::Num(t.median())),
                    ("mean_s", Json::Num(sum / count as f64)),
                    ("p95_s", Json::Num(t.quantile(0.95))),
                    ("max_s", Json::Num(max)),
                ]),
            );
        }
        Json::Obj(ops)
    }

    /// JSON snapshot: uptime, serve counters, per-op latency quantiles,
    /// and the global observability registry (kernel/cache/solver
    /// counters, queue gauges).
    pub fn snapshot(&self) -> Json {
        let counters = Json::obj(
            self.counters.pairs().into_iter().map(|(k, v)| (k, Json::Num(v as f64))).collect(),
        );
        let registry = Json::Obj(
            obs::snapshot()
                .into_iter()
                .map(|(k, v)| (k.to_string(), Json::Num(v as f64)))
                .collect(),
        );
        Json::obj(vec![
            ("uptime_s", Json::Num(self.started.elapsed().as_secs_f64())),
            ("counters", counters),
            ("latency", self.latency_json()),
            ("registry", registry),
        ])
    }

    /// Prometheus text exposition: serve counters and per-op latency
    /// summaries under `slope_serve_*`, then the whole observability
    /// registry under `slope_*`.
    pub fn prometheus(&self) -> String {
        let mut out = String::new();
        out.push_str("# HELP slope_serve_uptime_seconds server uptime\n");
        out.push_str("# TYPE slope_serve_uptime_seconds gauge\n");
        out.push_str(&format!(
            "slope_serve_uptime_seconds {}\n",
            self.started.elapsed().as_secs_f64()
        ));
        for (name, value) in self.counters.pairs() {
            let metric = format!("slope_serve_{name}_total");
            out.push_str(&format!("# TYPE {metric} counter\n{metric} {value}\n"));
        }
        let totals: Vec<(String, u64, f64)> = {
            let map = self.latencies.lock().unwrap();
            map.iter().map(|(op, s)| (op.clone(), s.count, s.sum)).collect()
        };
        out.push_str("# HELP slope_serve_op_seconds per-op latency totals\n");
        out.push_str("# TYPE slope_serve_op_seconds summary\n");
        for (op, count, sum) in totals {
            out.push_str(&format!("slope_serve_op_seconds_count{{op=\"{op}\"}} {count}\n"));
            out.push_str(&format!("slope_serve_op_seconds_sum{{op=\"{op}\"}} {sum}\n"));
        }
        obs::registry::render_prometheus(&mut out);
        out
    }

    #[cfg(test)]
    fn reservoir_len(&self, op: &str) -> usize {
        self.latencies.lock().unwrap().get(op).map_or(0, |s| s.reservoir.len())
    }
}

impl Default for Metrics {
    fn default() -> Self {
        Metrics::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_reports_counters_and_quantiles() {
        let m = Metrics::new();
        m.counters.requests.fetch_add(3, Ordering::Relaxed);
        m.record("fit_path", 0.5);
        m.record("fit_path", 1.5);
        m.record("stats", 0.001);
        let snap = m.snapshot();
        let counters = snap.field("counters").unwrap();
        assert_eq!(counters.field("requests").unwrap().as_f64(), Some(3.0));
        let lat = snap.field("latency").unwrap();
        let fp = lat.field("fit_path").unwrap();
        assert_eq!(fp.field("count").unwrap().as_f64(), Some(2.0));
        assert_eq!(fp.field("median_s").unwrap().as_f64(), Some(1.0));
        assert_eq!(fp.field("mean_s").unwrap().as_f64(), Some(1.0));
        assert_eq!(fp.field("max_s").unwrap().as_f64(), Some(1.5));
        assert!(snap.field("uptime_s").unwrap().as_f64().unwrap() >= 0.0);
        // the global registry rides along
        let reg = snap.field("registry").unwrap();
        assert!(reg.field("fista_iterations").unwrap().as_f64().is_some());
        assert!(reg.field("serve_queue_depth").unwrap().as_f64().is_some());
    }

    #[test]
    fn reservoir_is_bounded_but_totals_are_exact() {
        let m = Metrics::new();
        let total = RESERVOIR + 1000;
        for i in 0..total {
            m.record("op", i as f64);
        }
        assert_eq!(m.reservoir_len("op"), RESERVOIR);
        let snap = m.snapshot();
        let op = snap.field("latency").unwrap().field("op").unwrap();
        // count is the true total, not the retained-sample count
        assert_eq!(op.field("count").unwrap().as_f64(), Some(total as f64));
        // max is exact even if the max sample left the reservoir
        assert_eq!(op.field("max_s").unwrap().as_f64(), Some((total - 1) as f64));
        // exact mean of 0..total-1
        let mean = op.field("mean_s").unwrap().as_f64().unwrap();
        assert!((mean - (total - 1) as f64 / 2.0).abs() < 1e-9);
        // the sampled median must land in the data range
        let med = op.field("median_s").unwrap().as_f64().unwrap();
        assert!(med >= 0.0 && med <= (total - 1) as f64);
    }

    #[test]
    fn prometheus_exposition_includes_serve_and_registry_metrics() {
        let m = Metrics::new();
        m.counters.requests.fetch_add(2, Ordering::Relaxed);
        m.record("fit_path", 0.25);
        let text = m.prometheus();
        assert!(text.contains("slope_serve_requests_total 2"));
        assert!(text.contains("slope_serve_op_seconds_count{op=\"fit_path\"} 1"));
        assert!(text.contains("# TYPE slope_serve_uptime_seconds gauge"));
        assert!(text.contains("# TYPE slope_fista_iterations_total counter"));
        assert!(text.contains("# TYPE slope_serve_queue_depth gauge"));
    }
}

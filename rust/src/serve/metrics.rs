//! Serving metrics: request counters and per-op latency quantiles,
//! reusing [`crate::benchkit::Timing`] for the summary statistics and
//! rendered as JSON for the `stats` request.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::benchkit::Timing;
use crate::jsonio::Json;

/// Cap on retained latency samples per op (oldest half dropped on
/// overflow — the quantiles track recent behavior).
const MAX_SAMPLES: usize = 4096;

/// Monotonic request/cache counters.
#[derive(Default)]
pub struct Counters {
    /// Request lines received.
    pub requests: AtomicU64,
    /// Error responses produced.
    pub errors: AtomicU64,
    /// Fit requests served from the model cache.
    pub cache_hits: AtomicU64,
    /// Fit requests coalesced onto an in-flight identical fit.
    pub coalesced: AtomicU64,
    /// Cold (unseeded) fits executed.
    pub cold_fits: AtomicU64,
    /// Warm (seeded) fits executed.
    pub warm_fits: AtomicU64,
    /// Rows scored by `predict`.
    pub predictions: AtomicU64,
}

/// Server metrics: counters plus per-op latency histograms.
pub struct Metrics {
    started: Instant,
    /// The counters (bumped directly by the server).
    pub counters: Counters,
    latencies: Mutex<BTreeMap<String, Vec<f64>>>,
}

impl Metrics {
    /// Fresh metrics with the uptime clock started.
    pub fn new() -> Metrics {
        Metrics {
            started: Instant::now(),
            counters: Counters::default(),
            latencies: Mutex::new(BTreeMap::new()),
        }
    }

    /// Record one op latency in seconds.
    pub fn record(&self, op: &str, seconds: f64) {
        let mut map = self.latencies.lock().unwrap();
        let samples = map.entry(op.to_string()).or_default();
        if samples.len() >= MAX_SAMPLES {
            samples.drain(..MAX_SAMPLES / 2);
        }
        samples.push(seconds);
    }

    /// JSON snapshot: uptime, counters, and per-op latency quantiles.
    pub fn snapshot(&self) -> Json {
        let c = &self.counters;
        let counters = Json::obj(vec![
            ("requests", Json::Num(c.requests.load(Ordering::Relaxed) as f64)),
            ("errors", Json::Num(c.errors.load(Ordering::Relaxed) as f64)),
            ("cache_hits", Json::Num(c.cache_hits.load(Ordering::Relaxed) as f64)),
            ("coalesced", Json::Num(c.coalesced.load(Ordering::Relaxed) as f64)),
            ("cold_fits", Json::Num(c.cold_fits.load(Ordering::Relaxed) as f64)),
            ("warm_fits", Json::Num(c.warm_fits.load(Ordering::Relaxed) as f64)),
            ("predictions", Json::Num(c.predictions.load(Ordering::Relaxed) as f64)),
        ]);
        let mut ops = BTreeMap::new();
        for (op, samples) in self.latencies.lock().unwrap().iter() {
            if samples.is_empty() {
                continue;
            }
            let t = Timing::from_samples(samples.clone());
            ops.insert(
                op.clone(),
                Json::obj(vec![
                    ("count", Json::Num(samples.len() as f64)),
                    ("median_s", Json::Num(t.median())),
                    ("mean_s", Json::Num(t.mean())),
                    ("p95_s", Json::Num(t.quantile(0.95))),
                    ("max_s", Json::Num(t.quantile(1.0))),
                ]),
            );
        }
        Json::obj(vec![
            ("uptime_s", Json::Num(self.started.elapsed().as_secs_f64())),
            ("counters", counters),
            ("latency", Json::Obj(ops)),
        ])
    }
}

impl Default for Metrics {
    fn default() -> Self {
        Metrics::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_reports_counters_and_quantiles() {
        let m = Metrics::new();
        m.counters.requests.fetch_add(3, Ordering::Relaxed);
        m.record("fit_path", 0.5);
        m.record("fit_path", 1.5);
        m.record("stats", 0.001);
        let snap = m.snapshot();
        let counters = snap.field("counters").unwrap();
        assert_eq!(counters.field("requests").unwrap().as_f64(), Some(3.0));
        let lat = snap.field("latency").unwrap();
        let fp = lat.field("fit_path").unwrap();
        assert_eq!(fp.field("count").unwrap().as_f64(), Some(2.0));
        assert_eq!(fp.field("median_s").unwrap().as_f64(), Some(1.0));
        assert!(snap.field("uptime_s").unwrap().as_f64().unwrap() >= 0.0);
    }

    #[test]
    fn sample_buffer_is_bounded() {
        let m = Metrics::new();
        for i in 0..(MAX_SAMPLES + 100) {
            m.record("op", i as f64);
        }
        let snap = m.snapshot();
        let count = snap
            .field("latency")
            .unwrap()
            .field("op")
            .unwrap()
            .field("count")
            .unwrap()
            .as_usize()
            .unwrap();
        assert!(count <= MAX_SAMPLES);
    }
}

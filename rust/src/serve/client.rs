//! Blocking newline-delimited JSON client for the serve socket transport
//! (the `client` CLI subcommand and `examples/serving.rs` use it).

#[cfg(unix)]
pub use unix_impl::{connect_with_retry, Client};

#[cfg(unix)]
mod unix_impl {
    use std::io::{BufRead, BufReader, Write};
    use std::os::unix::net::UnixStream;
    use std::path::Path;
    use std::time::Duration;

    /// One connection to a serve socket.
    pub struct Client {
        reader: BufReader<UnixStream>,
        writer: UnixStream,
    }

    impl Client {
        /// Connect to a serve socket.
        pub fn connect(path: &Path) -> std::io::Result<Client> {
            let stream = UnixStream::connect(path)?;
            let reader = BufReader::new(stream.try_clone()?);
            Ok(Client { reader, writer: stream })
        }

        /// Send one request line and read the matching response line.
        pub fn round_trip(&mut self, request: &str) -> std::io::Result<String> {
            self.writer.write_all(request.trim().as_bytes())?;
            self.writer.write_all(b"\n")?;
            self.writer.flush()?;
            let mut line = String::new();
            let n = self.reader.read_line(&mut line)?;
            if n == 0 {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::UnexpectedEof,
                    "server closed the connection",
                ));
            }
            Ok(line.trim_end().to_string())
        }
    }

    /// Connect with retries — for clients racing a just-spawned server.
    pub fn connect_with_retry(
        path: &Path,
        attempts: usize,
        delay_ms: u64,
    ) -> std::io::Result<Client> {
        let mut last_err = None;
        for _ in 0..attempts.max(1) {
            match Client::connect(path) {
                Ok(client) => return Ok(client),
                Err(e) => {
                    last_err = Some(e);
                    std::thread::sleep(Duration::from_millis(delay_ms));
                }
            }
        }
        Err(last_err.unwrap_or_else(|| {
            std::io::Error::new(std::io::ErrorKind::NotFound, "serve socket never appeared")
        }))
    }
}

//! Blocking newline-delimited JSON client for the serve socket
//! transports — Unix domain or TCP (the `client` CLI subcommand and
//! `examples/serving.rs` use it) — plus [`Backoff`] — seeded, jittered
//! exponential retry for the typed rejections the resilient server can
//! answer with (DESIGN.md §12).

/// Jittered exponential backoff policy for retryable serve rejections.
///
/// Deterministic: the jitter draws from a xorshift stream keyed by the
/// seed, so a retry schedule replays in tests. The server's
/// `retry_after_ms` hint, when present, takes precedence over the
/// exponential base — the server knows its own queue depth.
#[derive(Clone, Debug)]
pub struct Backoff {
    base_ms: u64,
    cap_ms: u64,
    attempt: u32,
    state: u64,
}

impl Backoff {
    /// Policy starting at `base_ms`, doubling per attempt, capped at
    /// `cap_ms`, jittered from `seed`.
    pub fn new(base_ms: u64, cap_ms: u64, seed: u64) -> Backoff {
        Backoff { base_ms: base_ms.max(1), cap_ms: cap_ms.max(1), attempt: 0, state: seed | 1 }
    }

    /// Retries taken so far.
    pub fn attempts(&self) -> u32 {
        self.attempt
    }

    fn next_jitter(&mut self, bound: u64) -> u64 {
        if bound == 0 {
            return 0;
        }
        let mut x = self.state;
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        self.state = x;
        x % bound
    }

    /// The next delay in milliseconds: `hint` (the server's
    /// `retry_after_ms`, if it sent one) or the exponential base, plus
    /// up to 25% jitter so a herd of rejected clients does not return in
    /// lockstep.
    pub fn next_delay_ms(&mut self, hint: Option<u64>) -> u64 {
        let base = match hint {
            Some(ms) => ms.max(1),
            None => {
                let exp = self.base_ms.saturating_mul(1u64 << self.attempt.min(16));
                exp.min(self.cap_ms)
            }
        };
        self.attempt += 1;
        let capped = base.min(self.cap_ms);
        capped + self.next_jitter(capped / 4 + 1)
    }
}

/// Is `op` safe to retry after an overload rejection or a dropped
/// connection? Everything the serve protocol offers is idempotent —
/// fits are pure functions of (dataset, model) and registrations intern
/// by fingerprint — except `shutdown`, where a retry could kill a
/// freshly restarted server.
pub fn idempotent_op(op: &str) -> bool {
    op != "shutdown"
}

pub use imp::{connect_tcp_with_retry, Client};
#[cfg(unix)]
pub use imp::connect_with_retry;

mod imp {
    use std::io::{BufRead, BufReader, Read, Write};
    use std::net::TcpStream;
    #[cfg(unix)]
    use std::os::unix::net::UnixStream;
    #[cfg(unix)]
    use std::path::{Path, PathBuf};
    use std::time::Duration;

    use super::{idempotent_op, Backoff};
    use crate::jsonio::Json;

    /// Where a client dials — kept for reconnects after a dropped
    /// connection. The TCP form holds *every* endpoint the caller gave
    /// (`--tcp primary:port,standby:port`): `current` remembers which
    /// one answered last, and a reconnect rotates past it, so a client
    /// parked on a dead primary fails over to the standby instead of
    /// redialing a corpse.
    #[derive(Clone)]
    enum Target {
        #[cfg(unix)]
        Unix(PathBuf),
        Tcp { endpoints: Vec<String>, current: usize },
    }

    /// A connected stream on either transport; the client logic above it
    /// is transport-blind.
    enum StreamKind {
        #[cfg(unix)]
        Unix(UnixStream),
        Tcp(TcpStream),
    }

    impl StreamKind {
        fn try_clone(&self) -> std::io::Result<StreamKind> {
            Ok(match self {
                #[cfg(unix)]
                StreamKind::Unix(s) => StreamKind::Unix(s.try_clone()?),
                StreamKind::Tcp(s) => StreamKind::Tcp(s.try_clone()?),
            })
        }
    }

    impl Read for StreamKind {
        fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
            match self {
                #[cfg(unix)]
                StreamKind::Unix(s) => s.read(buf),
                StreamKind::Tcp(s) => s.read(buf),
            }
        }
    }

    impl Write for StreamKind {
        fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
            match self {
                #[cfg(unix)]
                StreamKind::Unix(s) => s.write(buf),
                StreamKind::Tcp(s) => s.write(buf),
            }
        }

        fn flush(&mut self) -> std::io::Result<()> {
            match self {
                #[cfg(unix)]
                StreamKind::Unix(s) => s.flush(),
                StreamKind::Tcp(s) => s.flush(),
            }
        }
    }

    /// One connection to a serve endpoint (Unix socket or TCP).
    pub struct Client {
        reader: BufReader<StreamKind>,
        writer: StreamKind,
        target: Target,
    }

    impl Client {
        fn dial_tcp(addr: &str) -> std::io::Result<StreamKind> {
            let stream = TcpStream::connect(addr)?;
            // Request lines are small; Nagle only adds latency.
            let _ = stream.set_nodelay(true);
            Ok(StreamKind::Tcp(stream))
        }

        /// Dial the target; the TCP form tries endpoints in rotation
        /// starting at `current` and records the one that answered.
        fn from_target(mut target: Target) -> std::io::Result<Client> {
            let stream = match &mut target {
                #[cfg(unix)]
                Target::Unix(path) => StreamKind::Unix(UnixStream::connect(path)?),
                Target::Tcp { endpoints, current } => {
                    let mut dialed = None;
                    let mut last_err = None;
                    for k in 0..endpoints.len() {
                        let idx = (*current + k) % endpoints.len();
                        match Client::dial_tcp(&endpoints[idx]) {
                            Ok(s) => {
                                *current = idx;
                                dialed = Some(s);
                                break;
                            }
                            Err(e) => last_err = Some(e),
                        }
                    }
                    match dialed {
                        Some(s) => s,
                        None => {
                            return Err(last_err.unwrap_or_else(|| {
                                std::io::Error::new(
                                    std::io::ErrorKind::InvalidInput,
                                    "no TCP endpoint given",
                                )
                            }))
                        }
                    }
                }
            };
            let reader = BufReader::new(stream.try_clone()?);
            Ok(Client { reader, writer: stream, target })
        }

        /// Connect to a serve Unix socket.
        #[cfg(unix)]
        pub fn connect(path: &Path) -> std::io::Result<Client> {
            Client::from_target(Target::Unix(path.to_path_buf()))
        }

        /// Connect to one or more serve TCP endpoints — a comma-separated
        /// `host:port` list. The first reachable endpoint answers;
        /// later reconnects rotate through the rest (failover).
        pub fn connect_tcp(addr: &str) -> std::io::Result<Client> {
            let endpoints: Vec<String> =
                addr.split(',').map(str::trim).filter(|s| !s.is_empty()).map(String::from).collect();
            if endpoints.is_empty() {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::InvalidInput,
                    "no TCP endpoint given",
                ));
            }
            Client::from_target(Target::Tcp { endpoints, current: 0 })
        }

        /// Drop the current connection and dial again. With multiple TCP
        /// endpoints the rotation starts at the *next* one — the old
        /// connection just died, so its endpoint goes to the back of the
        /// line (it is still retried last if the others are down too).
        pub fn reconnect(&mut self) -> std::io::Result<()> {
            let mut target = self.target.clone();
            if let Target::Tcp { endpoints, current } = &mut target {
                if endpoints.len() > 1 {
                    *current = (*current + 1) % endpoints.len();
                }
            }
            let fresh = Client::from_target(target)?;
            *self = fresh;
            Ok(())
        }

        /// Send one request line and read the matching response line.
        pub fn round_trip(&mut self, request: &str) -> std::io::Result<String> {
            self.writer.write_all(request.trim().as_bytes())?;
            self.writer.write_all(b"\n")?;
            self.writer.flush()?;
            let mut line = String::new();
            let n = self.reader.read_line(&mut line)?;
            if n == 0 {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::UnexpectedEof,
                    "server closed the connection",
                ));
            }
            Ok(line.trim_end().to_string())
        }

        /// [`Client::round_trip`] with resilience: overload rejections
        /// back off (honoring the server's `retry_after_ms` hint) and
        /// retry; dropped connections reconnect and retry. Only
        /// idempotent ops are ever retried — a non-idempotent request
        /// (`shutdown`) takes exactly one attempt. Non-retryable error
        /// responses (deadline, panic, invalid, ...) are returned as-is:
        /// they are answers, not transport failures.
        pub fn round_trip_with_retry(
            &mut self,
            request: &str,
            retries: u32,
            backoff: &mut Backoff,
        ) -> std::io::Result<String> {
            let op = Json::parse(request.trim())
                .ok()
                .and_then(|j| j.field("op").and_then(|v| v.as_str().map(str::to_string)))
                .unwrap_or_default();
            let retryable_op = idempotent_op(&op);
            let mut attempts_left = if retryable_op { retries } else { 0 };
            loop {
                match self.round_trip(request) {
                    Ok(response) => {
                        let parsed = Json::parse(&response).ok();
                        // A fenced answer means this endpoint is (now) a
                        // standby or a deposed primary: rotate to the
                        // next endpoint and retry there. Honored before
                        // the overload hint — waiting out a fence on the
                        // same endpoint gets us nowhere.
                        let fenced = parsed
                            .as_ref()
                            .and_then(|j| j.field("error_kind").and_then(Json::as_str))
                            == Some("fenced");
                        if fenced && retryable_op && attempts_left > 0 {
                            attempts_left -= 1;
                            let delay = backoff.next_delay_ms(None);
                            std::thread::sleep(Duration::from_millis(delay));
                            let _ = self.reconnect();
                            continue;
                        }
                        let hint = parsed
                            .as_ref()
                            .and_then(|j| j.field("retry_after_ms").and_then(Json::as_usize));
                        match hint {
                            Some(ms) if attempts_left > 0 => {
                                attempts_left -= 1;
                                let delay = backoff.next_delay_ms(Some(ms as u64));
                                std::thread::sleep(Duration::from_millis(delay));
                            }
                            _ => return Ok(response),
                        }
                    }
                    Err(e) if retryable_op && attempts_left > 0 => {
                        attempts_left -= 1;
                        let delay = backoff.next_delay_ms(None);
                        std::thread::sleep(Duration::from_millis(delay));
                        // A dead connection stays dead; redial before the
                        // next attempt. If the server is still down the
                        // reconnect error surfaces on the last attempt.
                        if self.reconnect().is_err() && attempts_left == 0 {
                            return Err(e);
                        }
                    }
                    Err(e) => return Err(e),
                }
            }
        }
    }

    /// Connect to a Unix socket with retries — for clients racing a
    /// just-spawned server.
    #[cfg(unix)]
    pub fn connect_with_retry(
        path: &Path,
        attempts: usize,
        delay_ms: u64,
    ) -> std::io::Result<Client> {
        retry(attempts, delay_ms, || Client::connect(path))
    }

    /// [`connect_with_retry`] for the TCP transport.
    pub fn connect_tcp_with_retry(
        addr: &str,
        attempts: usize,
        delay_ms: u64,
    ) -> std::io::Result<Client> {
        retry(attempts, delay_ms, || Client::connect_tcp(addr))
    }

    fn retry(
        attempts: usize,
        delay_ms: u64,
        mut dial: impl FnMut() -> std::io::Result<Client>,
    ) -> std::io::Result<Client> {
        let mut last_err = None;
        for _ in 0..attempts.max(1) {
            match dial() {
                Ok(client) => return Ok(client),
                Err(e) => {
                    last_err = Some(e);
                    std::thread::sleep(Duration::from_millis(delay_ms));
                }
            }
        }
        Err(last_err.unwrap_or_else(|| {
            std::io::Error::new(std::io::ErrorKind::NotFound, "serve endpoint never appeared")
        }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backoff_grows_honors_hints_and_replays() {
        let mut a = Backoff::new(10, 1000, 42);
        let d0 = a.next_delay_ms(None);
        let d1 = a.next_delay_ms(None);
        let d2 = a.next_delay_ms(None);
        // exponential envelope with ≤25% jitter
        assert!((10..=13).contains(&d0), "{d0}");
        assert!((20..=26).contains(&d1), "{d1}");
        assert!((40..=51).contains(&d2), "{d2}");
        // a server hint overrides the exponential base
        let hinted = a.next_delay_ms(Some(500));
        assert!((500..=626).contains(&hinted), "{hinted}");
        // same seed, same schedule
        let mut b = Backoff::new(10, 1000, 42);
        assert_eq!(b.next_delay_ms(None), d0);
        assert_eq!(b.next_delay_ms(None), d1);
        // the cap bounds runaway growth
        let mut c = Backoff::new(100, 250, 7);
        for _ in 0..10 {
            assert!(c.next_delay_ms(None) <= 250 + 250 / 4 + 1);
        }
    }

    #[test]
    fn tcp_client_rotates_across_endpoints() {
        use std::io::{BufRead, BufReader, Write};
        use std::net::TcpListener;

        // A dead endpoint: bind, learn the port, drop the listener.
        let dead = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
        };
        // A live endpoint answering one NDJSON line per connection.
        let live_listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let live = live_listener.local_addr().unwrap().to_string();
        let server = std::thread::spawn(move || {
            let (stream, _) = live_listener.accept().unwrap();
            let mut reader = BufReader::new(stream.try_clone().unwrap());
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            let mut w = stream;
            w.write_all(b"{\"id\": 1, \"ok\": true, \"result\": {}}\n").unwrap();
        });

        // The dead endpoint is listed first; connect must fall through
        // to the live one and the round trip must succeed.
        let mut client = Client::connect_tcp(&format!("{dead} , {live}")).unwrap();
        let response = client.round_trip("{\"id\": 1, \"op\": \"stats\"}").unwrap();
        assert!(response.contains("\"ok\": true"), "{response}");
        server.join().unwrap();

        // An endpoint list with nothing in it is an input error.
        assert!(Client::connect_tcp(" , ").is_err());
    }

    #[test]
    fn only_shutdown_is_non_idempotent() {
        for op in ["fit_path", "fit_point", "predict", "dataset_from_file", "stats", "metrics"] {
            assert!(idempotent_op(op), "{op}");
        }
        assert!(!idempotent_op("shutdown"));
    }
}

//! Worker-pool substrate (no `rayon`/`tokio` offline).
//!
//! Provides [`WorkerPool`]: a fixed set of threads fed from a shared
//! FIFO injector queue, plus [`par_for_each`] / [`par_map`] conveniences
//! built on `std::thread::scope`. The coordinator uses it to run
//! cross-validation folds and simulation repetitions concurrently; each
//! job gets a derived RNG so results are independent of scheduling order.
//! FIFO dispatch matters for the serve layer: the oldest admitted request
//! is always the next one served, so no client starves under load.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

type Job = Box<dyn FnOnce() + Send + 'static>;

struct Queue {
    jobs: Mutex<(VecDeque<Job>, bool)>, // (pending jobs, shutdown flag)
    signal: Condvar,
}

/// A fixed-size thread pool with a FIFO injector queue.
pub struct WorkerPool {
    queue: Arc<Queue>,
    pending: Arc<(Mutex<usize>, Condvar)>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl WorkerPool {
    /// Spawn a pool with `n` worker threads (`n >= 1`).
    pub fn new(n: usize) -> Self {
        let n = n.max(1);
        let queue = Arc::new(Queue {
            jobs: Mutex::new((VecDeque::new(), false)),
            signal: Condvar::new(),
        });
        let pending = Arc::new((Mutex::new(0usize), Condvar::new()));
        let mut handles = Vec::with_capacity(n);
        for _ in 0..n {
            let q = Arc::clone(&queue);
            let p = Arc::clone(&pending);
            handles.push(std::thread::spawn(move || loop {
                let job = {
                    let mut guard = q.jobs.lock().unwrap();
                    loop {
                        if let Some(job) = guard.0.pop_front() {
                            break job;
                        }
                        if guard.1 {
                            return;
                        }
                        guard = q.signal.wait(guard).unwrap();
                    }
                };
                job();
                let mut count = p.0.lock().unwrap();
                *count -= 1;
                if *count == 0 {
                    p.1.notify_all();
                }
            }));
        }
        Self { queue, pending, handles }
    }

    /// Pool sized to the machine (`available_parallelism`, capped — the
    /// same probe the `linalg::par` backend uses, so pool and kernel
    /// budgets always agree).
    pub fn with_default_size() -> Self {
        Self::new(crate::linalg::par::detected_parallelism())
    }

    /// Number of worker threads.
    pub fn size(&self) -> usize {
        self.handles.len()
    }

    /// Submit a job. Panics in jobs abort the process (fail-fast for the
    /// experiment harness).
    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        {
            let mut count = self.pending.0.lock().unwrap();
            *count += 1;
        }
        let mut guard = self.queue.jobs.lock().unwrap();
        guard.0.push_back(Box::new(f));
        drop(guard);
        self.queue.signal.notify_one();
    }

    /// Block until every submitted job has finished.
    pub fn wait(&self) {
        let mut count = self.pending.0.lock().unwrap();
        while *count > 0 {
            count = self.pending.1.wait(count).unwrap();
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.wait();
        {
            let mut guard = self.queue.jobs.lock().unwrap();
            guard.1 = true;
        }
        self.queue.signal.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Kernel-thread budget for one job when up to `workers` jobs may run
/// concurrently on a pool: the process-wide thread budget split evenly,
/// never below 1. This is how the serve scheduler and the CV driver keep
/// the pool's parallelism and the `linalg::par` backend from
/// multiplying into oversubscription.
pub fn fit_thread_budget(workers: usize) -> usize {
    (crate::linalg::par::global_threads() / workers.max(1)).max(1)
}

/// Run `f(i)` for every `i in 0..n` across `threads` scoped workers.
/// Work-stealing via a shared atomic counter; blocks until done.
pub fn par_for_each<F: Fn(usize) + Sync>(n: usize, threads: usize, f: F) {
    let threads = threads.max(1).min(n.max(1));
    if threads <= 1 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let next = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                f(i);
            });
        }
    });
}

/// Parallel map preserving input order.
pub fn par_map<T: Send, F: Fn(usize) -> T + Sync>(n: usize, threads: usize, f: F) -> Vec<T> {
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    {
        let slots: Vec<Mutex<&mut Option<T>>> = out.iter_mut().map(Mutex::new).collect();
        par_for_each(n, threads, |i| {
            let v = f(i);
            **slots[i].lock().unwrap() = Some(v);
        });
    }
    out.into_iter().map(|v| v.expect("par_map slot unfilled")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn pool_runs_all_jobs() {
        let pool = WorkerPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn pool_wait_is_reusable() {
        let pool = WorkerPool::new(2);
        let counter = Arc::new(AtomicU64::new(0));
        for round in 0..3 {
            for _ in 0..10 {
                let c = Arc::clone(&counter);
                pool.submit(move || {
                    c.fetch_add(1, Ordering::SeqCst);
                });
            }
            pool.wait();
            assert_eq!(counter.load(Ordering::SeqCst), (round + 1) * 10);
        }
    }

    #[test]
    fn par_for_each_covers_range() {
        let hits: Vec<AtomicU64> = (0..257).map(|_| AtomicU64::new(0)).collect();
        par_for_each(hits.len(), 8, |i| {
            hits[i].fetch_add(1, Ordering::SeqCst);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
    }

    #[test]
    fn par_map_preserves_order() {
        let out = par_map(100, 7, |i| i * i);
        assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn par_for_each_single_thread_fallback() {
        let hits: Vec<AtomicU64> = (0..5).map(|_| AtomicU64::new(0)).collect();
        par_for_each(5, 1, |i| {
            hits[i].fetch_add(1, Ordering::SeqCst);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
    }
}

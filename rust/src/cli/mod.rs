//! Minimal declarative CLI flag parser (no `clap` offline).
//!
//! Supports `--flag value`, `--flag=value` and boolean `--flag`, with typed
//! accessors, defaults and a generated `--help`. Used by the `slope-screen`
//! binary, the examples and every bench harness.

use std::collections::BTreeMap;

/// One registered flag.
struct Spec {
    name: &'static str,
    help: &'static str,
    default: Option<String>,
    is_bool: bool,
}

/// Declarative argument parser.
pub struct Args {
    program: String,
    about: &'static str,
    specs: Vec<Spec>,
    values: BTreeMap<String, String>,
    positional: Vec<String>,
}

impl Args {
    /// Start building a parser for `program`.
    pub fn new(about: &'static str) -> Self {
        Self {
            program: std::env::args().next().unwrap_or_else(|| "prog".into()),
            about,
            specs: Vec::new(),
            values: BTreeMap::new(),
            positional: Vec::new(),
        }
    }

    /// Register a value flag with a default.
    pub fn opt(mut self, name: &'static str, default: &str, help: &'static str) -> Self {
        self.specs.push(Spec { name, help, default: Some(default.to_string()), is_bool: false });
        self
    }

    /// Register a boolean flag (defaults to false).
    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.specs.push(Spec { name, help, default: None, is_bool: true });
        self
    }

    /// Parse `std::env::args`; prints help and exits on `--help` or on
    /// unknown flags.
    pub fn parse(self) -> Parsed {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        match self.parse_from(&argv) {
            Ok(p) => p,
            Err(msg) => {
                eprintln!("{msg}");
                std::process::exit(if msg.starts_with("usage") { 0 } else { 2 });
            }
        }
    }

    /// Parse an explicit argv (testable core).
    pub fn parse_from(self, argv: &[String]) -> Result<Parsed, String> {
        let mut values = self.values.clone();
        let mut positional = self.positional.clone();
        let mut provided = std::collections::BTreeSet::new();
        let mut i = 0;
        while i < argv.len() {
            let arg = &argv[i];
            if arg == "--help" || arg == "-h" {
                return Err(self.usage());
            }
            if let Some(stripped) = arg.strip_prefix("--") {
                let (name, inline) = match stripped.split_once('=') {
                    Some((n, v)) => (n, Some(v.to_string())),
                    None => (stripped, None),
                };
                let spec = self
                    .specs
                    .iter()
                    .find(|s| s.name == name)
                    .ok_or_else(|| format!("unknown flag --{name}\n{}", self.usage()))?;
                let value = if spec.is_bool {
                    inline.unwrap_or_else(|| "true".into())
                } else if let Some(v) = inline {
                    v
                } else {
                    i += 1;
                    argv.get(i)
                        .cloned()
                        .ok_or_else(|| format!("flag --{name} expects a value"))?
                };
                values.insert(name.to_string(), value);
                provided.insert(name.to_string());
            } else {
                positional.push(arg.clone());
            }
            i += 1;
        }
        // fill defaults
        for spec in &self.specs {
            if !values.contains_key(spec.name) {
                if let Some(d) = &spec.default {
                    values.insert(spec.name.to_string(), d.clone());
                } else if spec.is_bool {
                    values.insert(spec.name.to_string(), "false".into());
                }
            }
        }
        Ok(Parsed { values, positional, provided })
    }

    fn usage(&self) -> String {
        let mut s = format!("usage: {} [flags]\n{}\n\nflags:\n", self.program, self.about);
        for spec in &self.specs {
            let d = match &spec.default {
                Some(d) => format!(" (default: {d})"),
                None => String::new(),
            };
            s.push_str(&format!("  --{:<18} {}{}\n", spec.name, spec.help, d));
        }
        s.push_str("  --help               show this message\n");
        s
    }
}

/// Parsed flag values with typed accessors.
#[derive(Debug)]
pub struct Parsed {
    values: BTreeMap<String, String>,
    positional: Vec<String>,
    provided: std::collections::BTreeSet<String>,
}

impl Parsed {
    /// Raw string value.
    pub fn get(&self, name: &str) -> &str {
        self.values
            .get(name)
            .unwrap_or_else(|| panic!("flag --{name} was not registered"))
    }

    /// Typed value; panics with a clear message on parse failure.
    pub fn get_as<T: std::str::FromStr>(&self, name: &str) -> T
    where
        T::Err: std::fmt::Display,
    {
        let raw = self.get(name);
        raw.parse()
            .unwrap_or_else(|e| panic!("flag --{name}={raw}: {e}"))
    }

    /// `usize` accessor.
    pub fn usize(&self, name: &str) -> usize {
        self.get_as(name)
    }

    /// `f64` accessor.
    pub fn f64(&self, name: &str) -> f64 {
        self.get_as(name)
    }

    /// `u64` accessor.
    pub fn u64(&self, name: &str) -> u64 {
        self.get_as(name)
    }

    /// Boolean accessor.
    pub fn bool(&self, name: &str) -> bool {
        matches!(self.get(name), "true" | "1" | "yes")
    }

    /// Comma-separated list of `f64`.
    pub fn f64_list(&self, name: &str) -> Vec<f64> {
        self.get(name)
            .split(',')
            .filter(|s| !s.is_empty())
            .map(|s| s.trim().parse().unwrap_or_else(|e| panic!("flag --{name}: {e}")))
            .collect()
    }

    /// Comma-separated list of `usize`.
    pub fn usize_list(&self, name: &str) -> Vec<usize> {
        self.get(name)
            .split(',')
            .filter(|s| !s.is_empty())
            .map(|s| s.trim().parse().unwrap_or_else(|e| panic!("flag --{name}: {e}")))
            .collect()
    }

    /// Positional arguments (subcommands).
    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    /// True when the flag was explicitly given on the command line
    /// (distinguishes a user's `--threads 0` from the registered
    /// default — process-global settings must only be touched on
    /// explicit request).
    pub fn provided(&self, name: &str) -> bool {
        self.provided.contains(name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    fn parser() -> Args {
        Args::new("test")
            .opt("n", "100", "rows")
            .opt("rho", "0.5", "correlation")
            .opt("ps", "10,20", "p grid")
            .flag("verbose", "chatty")
    }

    #[test]
    fn defaults_apply() {
        let p = parser().parse_from(&argv(&[])).unwrap();
        assert_eq!(p.usize("n"), 100);
        assert_eq!(p.f64("rho"), 0.5);
        assert!(!p.bool("verbose"));
    }

    #[test]
    fn space_and_equals_forms() {
        let p = parser().parse_from(&argv(&["--n", "7", "--rho=0.9", "--verbose"])).unwrap();
        assert_eq!(p.usize("n"), 7);
        assert_eq!(p.f64("rho"), 0.9);
        assert!(p.bool("verbose"));
    }

    #[test]
    fn lists_parse() {
        let p = parser().parse_from(&argv(&["--ps", "1,2,3"])).unwrap();
        assert_eq!(p.usize_list("ps"), vec![1, 2, 3]);
    }

    #[test]
    fn unknown_flag_errors() {
        assert!(parser().parse_from(&argv(&["--nope", "1"])).is_err());
    }

    #[test]
    fn positional_collected() {
        let p = parser().parse_from(&argv(&["fit", "--n", "3"])).unwrap();
        assert_eq!(p.positional(), &["fit".to_string()]);
    }

    #[test]
    fn provided_distinguishes_explicit_flags_from_defaults() {
        let p = parser().parse_from(&argv(&["--n", "100"])).unwrap();
        assert!(p.provided("n"));
        assert!(!p.provided("rho")); // default applied, not user-given
        assert_eq!(p.f64("rho"), 0.5);
        let q = parser().parse_from(&argv(&["--rho=0.5"])).unwrap();
        assert!(q.provided("rho")); // explicit, even if equal to the default
    }

    #[test]
    fn help_is_error_with_usage() {
        let err = parser().parse_from(&argv(&["--help"])).unwrap_err();
        assert!(err.starts_with("usage"));
        assert!(err.contains("--rho"));
    }
}

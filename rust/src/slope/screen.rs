//! The screening rules of §2.2: Algorithm 1 (exact support superset from a
//! gradient), Algorithm 2 (its linear-time form), the strong rule for
//! SLOPE, the lasso strong rule (Proposition 3) and a gap-safe-style
//! baseline used in Figure 1.

/// Algorithm 1 of the paper, operating on a *pre-sorted* criterion vector
/// `c` (descending) and a non-increasing `λ`. Returns the predicted
/// support positions **in sorted order** (i.e. indices into `c`).
///
/// `S, B ← ∅; for i: B ← B ∪ {i}; if Σ_{j∈B}(c_j − λ_j) ≥ 0 then
/// S ← S ∪ B; B ← ∅`.
pub fn algorithm1(c_sorted: &[f64], lambda: &[f64]) -> Vec<usize> {
    // NaN-tolerant monotonicity check (`!(a < b)` instead of `a >= b`):
    // total_cmp-sorted criteria put NaNs first, which must not trip the
    // debug assert before the caller can surface the bad fit.
    debug_assert!(c_sorted.windows(2).all(|w| !(w[0] < w[1])), "c must be sorted descending");
    let mut s = Vec::new();
    let mut b_start = 0usize;
    let mut b_sum = 0.0f64;
    for i in 0..c_sorted.len() {
        b_sum += c_sorted[i] - lambda[i];
        if b_sum >= 0.0 {
            s.extend(b_start..=i);
            b_start = i + 1;
            b_sum = 0.0;
        }
    }
    s
}

/// Algorithm 2: the fast form returning only `k`, the predicted number of
/// active predictors (the active set is the first `k` positions of the
/// ordering permutation). Single pass, `O(p)`.
pub fn algorithm2_k(c_sorted: &[f64], lambda: &[f64]) -> usize {
    debug_assert!(c_sorted.windows(2).all(|w| !(w[0] < w[1])), "c must be sorted descending");
    let p = c_sorted.len();
    let mut i = 1usize;
    let mut k = 0usize;
    let mut s = 0.0f64;
    while i + k <= p {
        s += c_sorted[i + k - 1] - lambda[i + k - 1]; // 1-based paper indexing
        if s >= 0.0 {
            k += i;
            i = 1;
            s = 0.0;
        } else {
            i += 1;
        }
    }
    k
}

/// The **strong rule for SLOPE** (§2.2.2): given the gradient at the
/// previous path point `grad = ∇f(β̂(λ⁽ᵐ⁾))` and the two penalty vectors,
/// build `c := |∇f(β̂(λ⁽ᵐ⁾))| + λ⁽ᵐ⁾ − λ⁽ᵐ⁺¹⁾` (aligned by the gradient's
/// magnitude ordering), run Algorithm 1/2, and return the screened set as
/// **predictor indices**.
///
/// `lambda_prev` and `lambda_next` are the full non-increasing penalty
/// vectors at steps m and m+1 (with the σ scaling already applied).
pub fn strong_set(grad: &[f64], lambda_prev: &[f64], lambda_next: &[f64]) -> Vec<usize> {
    strong_set_with(grad, lambda_prev, lambda_next, &mut StrongWorkspace::default())
}

/// Reusable scratch for the fused gradient sweep: the `(criterion,
/// predictor)` pairs and the sorted criterion column. The path driver
/// allocates one per fit and reuses it at every step.
///
/// The workspace also carries the sweep's *fusion state*: after
/// [`StrongWorkspace::rank`] the pairs hold the gradient's magnitude
/// ordering, which both the KKT violation check
/// ([`StrongWorkspace::kkt_flagged_ranked`]) and the next step's strong
/// set ([`StrongWorkspace::strong_set_ranked`]) consume — one `O(p log p)`
/// ordering per gradient evaluation instead of one per consumer. Together
/// with the path driver reusing the solver's final `η` for the gradient
/// itself, a σ-step reads the design once and ranks its gradient once.
#[derive(Debug, Default)]
pub struct StrongWorkspace {
    pairs: Vec<(f64, u32)>,
    crit: Vec<f64>,
    /// True while `pairs` hold `(|g|, j)` for the most recent
    /// [`StrongWorkspace::rank`] call (cleared when the strong-set pass
    /// overwrites the magnitudes with the slack-adjusted criterion).
    ranked: bool,
}

impl StrongWorkspace {
    /// Rank a gradient once: pack `(|g|, j)` pairs and sort descending
    /// with the shared comparator
    /// ([`crate::linalg::ops::sort_pairs_desc_abs`] — NaN-tolerant, so
    /// one NaN in a gradient surfaces as a bad fit, not a server panic).
    pub fn rank(&mut self, grad: &[f64]) {
        self.pairs.clear();
        self.pairs
            .extend(grad.iter().enumerate().map(|(j, &g)| (g.abs(), j as u32)));
        crate::linalg::ops::sort_pairs_desc_abs(&mut self.pairs);
        self.ranked = true;
    }

    /// True while the workspace holds a gradient ranking that no consumer
    /// has overwritten yet.
    pub fn is_ranked(&self) -> bool {
        self.ranked
    }

    /// Copy the ranked magnitudes (descending, the comparator's order)
    /// into `out` — what lets the duality-gap evaluation share the one
    /// ordering [`StrongWorkspace::rank`] produced instead of re-sorting
    /// the same vector. Must follow a [`StrongWorkspace::rank`].
    pub fn ranked_magnitudes_into(&self, out: &mut Vec<f64>) {
        debug_assert!(self.ranked, "ranked_magnitudes_into needs a fresh rank()");
        out.clear();
        out.extend(self.pairs.iter().map(|&(m, _)| m));
    }

    /// Algorithm 1 on the ranked magnitudes with a tolerance on the
    /// running sum — the KKT violation flagger, sharing the ranking the
    /// next step's strong set will consume. Returns ascending predictor
    /// indices. Must follow a [`StrongWorkspace::rank`] of the gradient
    /// being checked.
    pub fn kkt_flagged_ranked(&self, lam: &[f64], tol: f64) -> Vec<usize> {
        let mut flagged = self.kkt_flagged_in_rank_order(lam, tol);
        flagged.sort_unstable();
        flagged
    }

    /// [`StrongWorkspace::kkt_flagged_ranked`] in **rank order** (largest
    /// gradient magnitude first) instead of ascending index — the order
    /// the gap-hybrid working set consumes when admitting only the top-K
    /// violators per expansion round. Same flags, different order.
    pub fn kkt_flagged_in_rank_order(&self, lam: &[f64], tol: f64) -> Vec<usize> {
        debug_assert!(self.ranked, "kkt_flagged_in_rank_order needs a fresh rank()");
        let mut flagged = Vec::new();
        let mut block_start = 0usize;
        let mut sum = 0.0f64;
        for (pos, &(mag, _)) in self.pairs.iter().enumerate() {
            sum += mag - lam[pos];
            if sum >= tol {
                flagged.extend(self.pairs[block_start..=pos].iter().map(|&(_, j)| j as usize));
                block_start = pos + 1;
                sum = 0.0;
            }
        }
        flagged
    }

    /// The strong rule consuming the current ranking: add the slack
    /// `λ⁽ᵐ⁾ − λ⁽ᵐ⁺¹⁾` in rank order *in place*, re-sort only when the
    /// slack actually perturbed monotonicity (never on the σ-scaled grids
    /// the path driver uses), and run the short-circuiting Algorithm 2.
    /// Overwrites the magnitudes, so the ranking is spent afterwards.
    pub fn strong_set_ranked(&mut self, lambda_prev: &[f64], lambda_next: &[f64]) -> Vec<usize> {
        debug_assert!(self.ranked, "strong_set_ranked needs a fresh rank()");
        self.ranked = false;
        // c_j = |g|_(j) + (λ_prev_j − λ_next_j), written over the magnitudes.
        let mut sorted = true;
        let mut prev = f64::INFINITY;
        for (rank, pair) in self.pairs.iter_mut().enumerate() {
            pair.0 += lambda_prev[rank] - lambda_next[rank];
            sorted &= !(prev < pair.0);
            prev = pair.0;
        }
        if !sorted {
            self.pairs
                .sort_unstable_by(|a, b| b.0.total_cmp(&a.0).then(a.1.cmp(&b.1)));
        }
        self.crit.clear();
        self.crit.extend(self.pairs.iter().map(|&(c, _)| c));
        let k = algorithm2_k_short(&self.crit, lambda_next);
        let mut set: Vec<usize> =
            self.pairs[..k].iter().map(|&(_, idx)| idx as usize).collect();
        set.sort_unstable();
        set
    }
}

/// [`strong_set`] with a caller-owned workspace: one fused ordering pass
/// (see [`StrongWorkspace`]). The path driver goes through the ranked
/// form directly so the KKT check's ordering is reused; this wrapper
/// ranks and consumes in one call.
pub fn strong_set_with(
    grad: &[f64],
    lambda_prev: &[f64],
    lambda_next: &[f64],
    ws: &mut StrongWorkspace,
) -> Vec<usize> {
    debug_assert_eq!(lambda_prev.len(), grad.len());
    debug_assert_eq!(lambda_next.len(), grad.len());
    ws.rank(grad);
    ws.strong_set_ranked(lambda_prev, lambda_next)
}

/// [`algorithm2_k`] with the sorted-threshold short-circuit: when the
/// running block sum is negative and the criterion has fallen to or below
/// the smallest penalty weight, no later prefix can recover — `c` is
/// non-increasing and every remaining `λ_j ≥ λ_p`, so every remaining
/// increment `c_j − λ_j ≤ 0` and the sum stays negative. The scan then
/// stops after `O(k + t)` entries (`t` = entries above `λ_p`) instead of
/// `O(p)` — on a well-screened path step almost the whole tail is
/// skipped. Exact: returns precisely [`algorithm2_k`]'s answer (the
/// frozen reference path keeps the full scan so the regression tests pin
/// this).
fn algorithm2_k_short(c_sorted: &[f64], lambda: &[f64]) -> usize {
    debug_assert!(c_sorted.windows(2).all(|w| !(w[0] < w[1])), "c must be sorted descending");
    let p = c_sorted.len();
    if p == 0 {
        return 0;
    }
    let lam_min = lambda[p - 1];
    let mut i = 1usize;
    let mut k = 0usize;
    let mut s = 0.0f64;
    while i + k <= p {
        let pos = i + k - 1;
        s += c_sorted[pos] - lambda[pos];
        if s >= 0.0 {
            k += i;
            i = 1;
            s = 0.0;
        } else {
            if c_sorted[pos] <= lam_min {
                break;
            }
            i += 1;
        }
    }
    k
}

/// The re-sorting `strong_set` implementation [`strong_set_with`]
/// replaced: fresh pair vectors plus an unconditional second sort on
/// every call. Kept (hidden) as the frozen oracle the screen proptests
/// and the `microbench` fused-vs-reference rows both compare against —
/// one copy, so the two checks can never drift apart.
#[doc(hidden)]
pub fn strong_set_resort_reference(
    grad: &[f64],
    lambda_prev: &[f64],
    lambda_next: &[f64],
) -> Vec<usize> {
    let ord = crate::linalg::ops::order_desc_abs(grad);
    let c: Vec<f64> = ord
        .iter()
        .enumerate()
        .map(|(j, &idx)| grad[idx].abs() + lambda_prev[j] - lambda_next[j])
        .collect();
    let mut pairs: Vec<(f64, usize)> = c.into_iter().zip(ord).collect();
    pairs.sort_unstable_by(|a, b| b.0.total_cmp(&a.0).then(a.1.cmp(&b.1)));
    let c_sorted: Vec<f64> = pairs.iter().map(|&(crit, _)| crit).collect();
    let k = algorithm2_k(&c_sorted, lambda_next);
    let mut set: Vec<usize> = pairs[..k].iter().map(|&(_, idx)| idx).collect();
    set.sort_unstable();
    set
}

/// The classical **strong rule for the lasso** (Tibshirani et al. 2012):
/// keep predictor j iff `|g_j| ≥ 2λ⁽ᵐ⁺¹⁾ − λ⁽ᵐ⁾` (scalar penalties).
pub fn lasso_strong_set(grad: &[f64], lam_prev: f64, lam_next: f64) -> Vec<usize> {
    let thresh = 2.0 * lam_next - lam_prev;
    grad.iter()
        .enumerate()
        .filter(|(_, g)| g.abs() >= thresh)
        .map(|(j, _)| j)
        .collect()
}

/// Gap-safe-style sphere test for SLOPE (the "SAFE" comparator in Fig. 1).
///
/// A dual-feasible point for `min ½‖y − Xβ‖² + σJ(β;λ)` is `θ = r/s` where
/// `r = y − Xβ` and `s ≥ 1` rescales the residual until `Xᵀθ` satisfies the
/// sorted-ℓ1 dual constraint `cumsum(|Xᵀθ|↓ − σλ) ≤ 0`. With duality gap
/// `G`, every dual-optimal `θ*` lies in the ball `B(θ, √(2G))`, so
/// predictor j is *certifiably* inactive when
/// `|x_jᵀθ| + √(2G)·‖x_j‖ < σλ_p` (the smallest weight — the only
/// per-coordinate bound valid for the sorted-ℓ1 dual ball, which is what
/// makes the safe rule so much more conservative than the strong rule).
///
/// `xt_theta` = `Xᵀr` at the current primal point, `r_norm_sq = ‖r‖²`,
/// `primal` = current primal objective, `col_norms` = ‖x_j‖₂.
pub fn gap_safe_set(
    xt_r: &[f64],
    r_norm_sq: f64,
    primal: f64,
    col_norms: &[f64],
    lambda: &[f64],
    y_dot_r: f64,
) -> Vec<usize> {
    let p = xt_r.len();
    // Dual feasibility scaling: find the smallest s >= 1 with
    // cumsum(|Xᵀr|↓/s − λ) ≤ 0, i.e. s = max_k cumsum(|Xᵀr|↓)_k / cumsum(λ)_k.
    let mut mags: Vec<f64> = xt_r.iter().map(|v| v.abs()).collect();
    mags.sort_unstable_by(|a, b| b.total_cmp(a)); // NaN-tolerant (server hot path)
    let mut acc_m = 0.0;
    let mut acc_l = 0.0;
    let mut s = 1.0f64;
    for (m, l) in mags.iter().zip(lambda) {
        acc_m += m;
        acc_l += l;
        if acc_l > 0.0 {
            s = s.max(acc_m / acc_l);
        }
    }
    // Dual objective of the scaled point θ = r/s:
    // D(θ) = ⟨y, θ⟩ − ½‖θ‖².
    let dual = y_dot_r / s - 0.5 * r_norm_sq / (s * s);
    let gap = (primal - dual).max(0.0);
    let radius = (2.0 * gap).sqrt();
    let lam_min = *lambda.last().unwrap_or(&0.0);
    (0..p)
        .filter(|&j| xt_r[j].abs() / s + radius * col_norms[j] >= lam_min)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::check::{ensure, forall, gen, Config};
    use crate::linalg::ops::abs_sorted_desc;

    #[test]
    fn algorithm1_all_below_lambda_discards_all() {
        assert!(algorithm1(&[0.5, 0.4, 0.1], &[1.0, 0.9, 0.8]).is_empty());
    }

    #[test]
    fn algorithm1_all_above_keeps_all() {
        assert_eq!(algorithm1(&[2.0, 1.5, 1.2], &[1.0, 0.9, 0.8]), vec![0, 1, 2]);
    }

    #[test]
    fn algorithm1_redistribution_keeps_cluster() {
        // c = (1.5, 1.5), λ = (2, 0.5): prefix −0.5 then +0.5 ⇒ both kept
        // as one block once the running sum turns non-negative.
        assert_eq!(algorithm1(&[1.5, 1.5], &[2.0, 0.5]), vec![0, 1]);
    }

    #[test]
    fn algorithm1_tail_left_out() {
        // First passes alone, tail never recovers.
        assert_eq!(algorithm1(&[3.0, 0.1, 0.1], &[1.0, 0.9, 0.8]), vec![0]);
    }

    #[test]
    fn algorithm2_matches_algorithm1_prefix_size() {
        forall(
            Config { cases: 500, seed: 0xa1a2 },
            |rng| {
                let c = {
                    let mut v = gen::normal_vec(rng, 1, 40);
                    v.iter_mut().for_each(|x| *x = x.abs());
                    v.sort_unstable_by(|a, b| b.partial_cmp(a).unwrap());
                    v
                };
                let lam = gen::lambda_seq(rng, c.len());
                (c, lam)
            },
            |(c, lam)| {
                let s1 = algorithm1(c, lam);
                let k = algorithm2_k(c, lam);
                ensure(s1.len() == k, format!("alg1 |S|={} vs alg2 k={k}", s1.len()))?;
                // Algorithm 1's result is always a prefix 0..k.
                ensure(
                    s1.iter().copied().eq(0..k),
                    format!("alg1 not a prefix: {s1:?}"),
                )
            },
        );
    }

    #[test]
    fn strong_set_equals_lasso_strong_rule_for_constant_lambda() {
        // Proposition 3.
        forall(
            Config { cases: 300, seed: 0xbb },
            |rng| {
                let g = gen::normal_vec(rng, 1, 30);
                let lam_prev = 1.0 + rng.next_f64();
                let lam_next = lam_prev * (0.5 + 0.5 * rng.next_f64());
                (g, lam_prev, lam_next)
            },
            |(g, lam_prev, lam_next)| {
                let p = g.len();
                let lp = vec![*lam_prev; p];
                let ln = vec![*lam_next; p];
                let slope = strong_set(g, &lp, &ln);
                let lasso = lasso_strong_set(g, *lam_prev, *lam_next);
                ensure(slope == lasso, format!("slope {slope:?} vs lasso {lasso:?}"))
            },
        );
    }

    #[test]
    fn strong_set_is_monotone_in_lambda_gap() {
        // Widening the λ-gap (bigger slack) can only grow the screened set.
        let g = [0.9, -0.7, 0.5, 0.2, -0.1];
        let lam: Vec<f64> = vec![1.0, 0.8, 0.6, 0.4, 0.2];
        let next_small: Vec<f64> = lam.iter().map(|l| l * 0.95).collect();
        let next_big: Vec<f64> = lam.iter().map(|l| l * 0.6).collect();
        let s_small = strong_set(&g, &lam, &next_small);
        let s_big = strong_set(&g, &lam, &next_big);
        for j in &s_small {
            assert!(s_big.contains(j), "{j} lost when gap widened");
        }
    }

    #[test]
    fn strong_set_with_exact_gradient_is_superset_of_alg1_support() {
        // With λ_prev = λ_next the rule reduces to Algorithm 1 on |g|↓.
        forall(
            Config { cases: 200, seed: 0xcc },
            |rng| {
                let g = gen::normal_vec(rng, 1, 25);
                let lam = gen::lambda_seq(rng, g.len());
                (g, lam)
            },
            |(g, lam)| {
                let s = strong_set(g, lam, lam);
                let sorted = abs_sorted_desc(g);
                let k = algorithm2_k(&sorted, lam);
                ensure(s.len() == k, format!("|S|={} vs k={k}", s.len()))
            },
        );
    }

    #[test]
    fn fused_strong_set_matches_resorting_reference() {
        forall(
            Config { cases: 400, seed: 0xf5 },
            |rng| {
                let g = gen::normal_vec(rng, 1, 40);
                let lam_prev = gen::lambda_seq(rng, g.len());
                // Mix σ-scaled shrinks (monotone slack, the fast path) with
                // independent sequences (perturbed slack, the re-sort path).
                let lam_next: Vec<f64> = if rng.bernoulli(0.5) {
                    let s = 0.4 + 0.5 * rng.next_f64();
                    lam_prev.iter().map(|l| l * s).collect()
                } else {
                    let mut l = gen::lambda_seq(rng, g.len());
                    for (a, b) in l.iter_mut().zip(&lam_prev) {
                        *a = a.min(*b); // keep λ_next ≤ λ_prev (a shrinking path)
                    }
                    l
                };
                (g, lam_prev, lam_next)
            },
            |(g, lam_prev, lam_next)| {
                let mut ws = StrongWorkspace::default();
                let fused = strong_set_with(g, lam_prev, lam_next, &mut ws);
                let reference = strong_set_resort_reference(g, lam_prev, lam_next);
                ensure(fused == reference, format!("fused {fused:?} vs ref {reference:?}"))
            },
        );
    }

    #[test]
    fn algorithm2_short_circuit_matches_full_scan() {
        forall(
            Config { cases: 500, seed: 0xf6 },
            |rng| {
                let mut c: Vec<f64> = gen::normal_vec(rng, 1, 60).iter().map(|v| v.abs()).collect();
                // long sub-threshold tails: the short-circuit's target case
                if rng.bernoulli(0.5) {
                    for v in c.iter_mut().skip(5) {
                        *v *= 0.01;
                    }
                }
                c.sort_unstable_by(|a, b| b.total_cmp(a));
                let lam = gen::lambda_seq(rng, c.len());
                (c, lam)
            },
            |(c, lam)| {
                let short = algorithm2_k_short(c, lam);
                let full = algorithm2_k(c, lam);
                ensure(short == full, format!("short={short} vs full={full}"))
            },
        );
    }

    #[test]
    fn algorithm2_short_circuit_edge_cases() {
        assert_eq!(algorithm2_k_short(&[], &[]), 0);
        // everything exactly at the smallest weight: no early break may
        // drop the redistribution (c_j − λ_j = 0 increments keep s at 0)
        let c = [0.5, 0.5, 0.5];
        let lam = [0.5, 0.5, 0.5];
        assert_eq!(algorithm2_k_short(&c, &lam), algorithm2_k(&c, &lam));
        // tail exactly at λ_p with a negative running sum must break
        // without changing the answer
        let c = [2.0, 0.1, 0.1, 0.1];
        let lam = [1.0, 0.9, 0.8, 0.1];
        assert_eq!(algorithm2_k_short(&c, &lam), algorithm2_k(&c, &lam));
        // zero penalty tail: λ_p = 0, nothing non-negative may be skipped
        let c = [1.0, 0.0, 0.0];
        let lam = [0.5, 0.25, 0.0];
        assert_eq!(algorithm2_k_short(&c, &lam), algorithm2_k(&c, &lam));
    }

    #[test]
    fn short_circuited_strong_set_pins_to_resort_reference() {
        // The satellite regression: the fused + short-circuited strong set
        // must agree with the frozen re-sorting reference on inputs with
        // dominant sub-threshold tails (where the short-circuit actually
        // fires) and on redistribution-heavy ties.
        forall(
            Config { cases: 400, seed: 0xf7 },
            |rng| {
                let p = 10 + rng.below(80) as usize;
                let mut g = gen::normal_vec(rng, p, p);
                // crush the tail so only a handful of entries clear λ_p
                for v in g.iter_mut().skip(4) {
                    *v *= 0.02;
                }
                let lam_prev = gen::lambda_seq(rng, p);
                let s = 0.5 + 0.45 * rng.next_f64();
                let lam_next: Vec<f64> = lam_prev.iter().map(|l| l * s).collect();
                (g, lam_prev, lam_next)
            },
            |(g, lam_prev, lam_next)| {
                let mut ws = StrongWorkspace::default();
                let fused = strong_set_with(g, lam_prev, lam_next, &mut ws);
                let reference = strong_set_resort_reference(g, lam_prev, lam_next);
                ensure(fused == reference, format!("fused {fused:?} vs ref {reference:?}"))
            },
        );
    }

    #[test]
    fn ranked_sweep_shares_one_ordering() {
        let g = [0.9, -0.7, 0.5, 0.2, -0.1, 1.4];
        let lam: Vec<f64> = vec![1.2, 1.0, 0.8, 0.6, 0.4, 0.2];
        let next: Vec<f64> = lam.iter().map(|l| l * 0.9).collect();
        let mut ws = StrongWorkspace::default();
        assert!(!ws.is_ranked());
        ws.rank(&g);
        assert!(ws.is_ranked());
        // the KKT flagger reads the ranking without consuming it...
        let flagged = ws.kkt_flagged_ranked(&lam, 1e-12);
        assert!(ws.is_ranked());
        // ...and matches Algorithm 1 on |g|↓ mapped back to indices
        let ord = crate::linalg::ops::order_desc_abs(&g);
        let sorted = abs_sorted_desc(&g);
        let mut want: Vec<usize> = algorithm1(&sorted, &lam).iter().map(|&r| ord[r]).collect();
        want.sort_unstable();
        assert_eq!(flagged, want);
        // the strong set consumes the ranking and equals the fresh form
        let ranked = ws.strong_set_ranked(&lam, &next);
        assert!(!ws.is_ranked());
        assert_eq!(ranked, strong_set(&g, &lam, &next));
    }

    #[test]
    fn rank_order_flagger_matches_sorted_flagger() {
        forall(
            Config { cases: 200, seed: 0xf8 },
            |rng| {
                let g = gen::normal_vec(rng, 1, 50);
                let lam = gen::lambda_seq(rng, g.len());
                (g, lam)
            },
            |(g, lam)| {
                let mut ws = StrongWorkspace::default();
                ws.rank(g);
                let ranked_order = ws.kkt_flagged_in_rank_order(lam, 1e-9);
                let ascending = ws.kkt_flagged_ranked(lam, 1e-9);
                let mut sorted = ranked_order.clone();
                sorted.sort_unstable();
                ensure(sorted == ascending, "same flags in both orders")?;
                // rank order = non-increasing |g|
                for w in ranked_order.windows(2) {
                    ensure(
                        !(g[w[0]].abs() < g[w[1]].abs()),
                        format!("rank order violated at {w:?}"),
                    )?;
                }
                Ok(())
            },
        );
    }

    #[test]
    fn strong_workspace_is_reusable_across_steps() {
        let g1 = [0.9, -0.7, 0.5, 0.2, -0.1];
        let g2 = [0.1, 0.8, -0.6, 0.0, 0.3];
        let lam: Vec<f64> = vec![1.0, 0.8, 0.6, 0.4, 0.2];
        let next: Vec<f64> = lam.iter().map(|l| l * 0.9).collect();
        let mut ws = StrongWorkspace::default();
        let a1 = strong_set_with(&g1, &lam, &next, &mut ws);
        let a2 = strong_set_with(&g2, &lam, &next, &mut ws);
        assert_eq!(a1, strong_set(&g1, &lam, &next));
        assert_eq!(a2, strong_set(&g2, &lam, &next));
    }

    #[test]
    fn nan_gradient_does_not_panic_screening() {
        // A diverged solve must surface as a bad fit, not a server panic.
        let g = [0.5, f64::NAN, 0.3, -0.9];
        let lam = [1.0, 0.8, 0.6, 0.4];
        let next: Vec<f64> = lam.iter().map(|l| l * 0.9).collect();
        let _ = strong_set(&g, &lam, &next);
        let _ = gap_safe_set(&g, 1.0, 1.0, &[1.0; 4], &lam, 0.5);
        let _ = crate::linalg::ops::abs_sorted_desc(&g);
        let _ = crate::linalg::ops::order_desc_abs(&g);
        let _ = crate::slope::sorted::sl1_norm(&g, &lam);
        let _ = crate::slope::subdiff::kkt_infeasibility(&g, &lam);
        let _ = crate::slope::lambda::sigma_max(&g, &lam);
    }

    #[test]
    fn gap_safe_keeps_everything_at_huge_gap() {
        // With a large duality gap nothing can be certified inactive.
        let kept = gap_safe_set(&[0.1, 0.1], 100.0, 100.0, &[1.0, 1.0], &[1.0, 0.5], 0.0);
        assert_eq!(kept, vec![0, 1]);
    }

    #[test]
    fn gap_safe_discards_at_zero_gap() {
        // Zero gap + small correlations: coordinates below λ_p go.
        // primal == dual at optimum: craft y·r and ‖r‖² so gap = 0.
        let r_norm_sq: f64 = 1.0;
        let y_dot_r = 1.0;
        let primal = y_dot_r - 0.5 * r_norm_sq; // equals dual at s=1
        let kept = gap_safe_set(&[0.9, 0.05], r_norm_sq, primal, &[1.0, 1.0], &[1.0, 0.5], y_dot_r);
        assert!(kept.contains(&0));
        assert!(!kept.contains(&1));
    }
}

//! Fenchel duality for the SLOPE problem `min_β f(Xβ) + J(β; λ)`.
//!
//! The dual is `max_θ D(θ) = −f*(−θ)` subject to `Xᵀθ` lying in the
//! sorted-ℓ1 dual unit ball — the cumulative-sum feasibility condition
//! `cumsum(|Xᵀθ|↓ − λ) ≤ 0` of Theorem 1 (the same test
//! [`crate::slope::subdiff::kkt_infeasibility`] applies to a gradient).
//! Any primal candidate β and any feasible θ satisfy the weak-duality
//! inequality `P(β) ≥ D(θ)`, so `gap = P(β) − D(θ)` is a *certificate*:
//! `gap ≤ ε` proves β is within ε of optimal in objective value. At the
//! optimum the unique dual solution is `θ* = −h*`, the negated working
//! residual, which is why a near-optimal β yields a near-optimal dual
//! point by simply rescaling `−h` into feasibility
//! ([`dual_feasible_scale`]).
//!
//! The solver uses the gap two ways (DESIGN.md §10):
//!
//! * **certified stopping** — [`crate::slope::fista`]'s `gap_tol_abs`
//!   mode replaces the displacement heuristic with `gap ≤ tol`;
//! * **safe screening** — the gap bounds the distance from θ to θ*
//!   (`‖θ − θ*‖ ≤ √(2·L·gap)` for an `L`-smooth loss), which powers the
//!   Elvira–Herzet-style sphere tests in [`crate::slope::safe`].

use crate::slope::family::Family;

/// Outcome of a duality-gap evaluation.
#[derive(Clone, Copy, Debug)]
pub struct GapResult {
    /// `primal − dual_obj`. Nonnegative up to rounding (weak duality);
    /// consumers clamp at zero before taking square roots.
    pub gap: f64,
    /// Primal objective `f(β) + J(β; λ)` at the candidate.
    pub primal: f64,
    /// Dual objective `D(θ) = −f*(−θ)` of the scaled dual point.
    pub dual_obj: f64,
    /// The feasibility scaling `s ≥ 1` with `θ = −h/s`.
    pub scale: f64,
}

/// Smallest `s ≥ 1` making `θ = −h/s` dual-feasible:
/// `s = max(1, max_k cumsum(|Xᵀh|↓)_k / cumsum(λ)_k)` — the σ_max
/// computation of §3.1.2 specialized to the current residual.
///
/// `mags_desc` must hold `|Xᵀh|` sorted descending; `lambda` is the
/// matching non-increasing (σ-scaled) penalty vector with
/// `lambda.len() >= mags_desc.len()`. When a prefix of `λ` sums to zero
/// while the magnitudes do not, no finite scaling is feasible and the
/// scale is `+∞` (θ = 0, which is always feasible). A NaN magnitude
/// (diverged solve) also returns `+∞` — an explicit check, because
/// `f64::max` would silently discard the NaN and certify a scale of 1 —
/// so a bad gradient degrades to the trivial dual point, never to a
/// bogus certificate.
pub fn dual_feasible_scale(mags_desc: &[f64], lambda: &[f64]) -> f64 {
    debug_assert!(
        mags_desc.windows(2).all(|w| !(w[0] < w[1])),
        "mags must be sorted descending"
    );
    debug_assert!(lambda.len() >= mags_desc.len());
    let mut acc_m = 0.0f64;
    let mut acc_l = 0.0f64;
    let mut s = 1.0f64;
    for (m, l) in mags_desc.iter().zip(lambda) {
        acc_m += m;
        acc_l += l;
        if acc_m.is_nan() {
            return f64::INFINITY;
        }
        if acc_l > 0.0 {
            s = s.max(acc_m / acc_l);
        } else if acc_m > 0.0 {
            return f64::INFINITY;
        }
    }
    s
}

/// `x ln x`, continuously extended by 0 at `x = 0`.
#[inline]
fn xlogx(x: f64) -> f64 {
    if x > 0.0 {
        x * x.ln()
    } else {
        0.0
    }
}

/// Dual objective `D(θ) = −f*(−θ)` of the scaled dual point `θ = −h/s`,
/// where `h` is the working residual at the primal candidate
/// (`∇f(β) = Xᵀh`) and `s ≥ 1` the feasibility scaling.
///
/// Per family (conjugates of the per-observation losses in `η`):
///
/// * Gaussian — `f*(u) = ⟨u, y⟩ + ½‖u‖²`, so `D = ⟨y, θ⟩ − ½‖θ‖²`
///   (the classic gap-safe dual of the residual).
/// * Binomial — with `v = y − θ ∈ [0, 1]`,
///   `D = −Σ [v ln v + (1−v) ln(1−v)]` (binary entropy). `θ = −h/s`
///   puts `v` on the segment between `y` and `sigmoid(η)`, so the
///   domain constraint holds for every `s ≥ 1`.
/// * Poisson — with `v = y − θ ≥ 0`, `D = Σ [v − v ln v]`.
/// * Multinomial — with `q = onehot(y) − θ` per observation (a convex
///   combination of the one-hot label and the softmax probabilities,
///   hence in the simplex), `D = −Σ q ln q`.
///
/// An infinite `s` yields `θ = 0` — always feasible, giving the trivial
/// dual value.
pub fn dual_objective(family: Family, h: &[f64], y: &[f64], scale: f64) -> f64 {
    let inv = if scale.is_finite() { 1.0 / scale } else { 0.0 };
    match family {
        Family::Gaussian => {
            let mut dot = 0.0;
            let mut sq = 0.0;
            for (hi, yi) in h.iter().zip(y) {
                let t = -hi * inv;
                dot += yi * t;
                sq += t * t;
            }
            dot - 0.5 * sq
        }
        Family::Binomial => {
            let mut d = 0.0;
            for (hi, yi) in h.iter().zip(y) {
                // v = y − θ = y + h/s; clamp is a pure rounding guard —
                // mathematically v ∈ [min(y, σ(η)), max(y, σ(η))] ⊆ [0,1].
                let v = (yi + hi * inv).clamp(0.0, 1.0);
                d -= xlogx(v) + xlogx(1.0 - v);
            }
            d
        }
        Family::Poisson => {
            let mut d = 0.0;
            for (hi, yi) in h.iter().zip(y) {
                // v = y + h/s = y(1 − 1/s) + μ/s ≥ 0.
                let v = (yi + hi * inv).max(0.0);
                d += v - xlogx(v);
            }
            d
        }
        Family::Multinomial { classes } => {
            let n = y.len();
            debug_assert_eq!(h.len(), n * classes);
            let mut d = 0.0;
            for i in 0..n {
                let yi = y[i] as usize;
                for l in 0..classes {
                    let ind = if l == yi { 1.0 } else { 0.0 };
                    let q = (ind + h[l * n + i] * inv).clamp(0.0, 1.0);
                    d -= xlogx(q);
                }
            }
            d
        }
    }
}

/// Duality gap of a primal candidate from its cached solver state: `h`
/// is the working residual at β, `loss = f(β)`, `penalty = J(β; λ)`
/// (σ already folded into `lambda`), and `grad_mags_desc` holds
/// `|Xᵀh|` sorted descending over the coordinates the problem is posed
/// on (all `p·m` for the full problem, the reduced set for a reduced
/// solve — with the matching `lambda` prefix). No design product is
/// paid here: the caller already owns the gradient.
pub fn duality_gap(
    family: Family,
    y: &[f64],
    h: &[f64],
    loss: f64,
    penalty: f64,
    grad_mags_desc: &[f64],
    lambda: &[f64],
) -> GapResult {
    let scale = dual_feasible_scale(grad_mags_desc, lambda);
    let dual_obj = dual_objective(family, h, y, scale);
    let primal = loss + penalty;
    GapResult { gap: primal - dual_obj, primal, dual_obj, scale }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::check::{ensure, forall, gen, Config};
    use crate::linalg::ops::abs_sorted_desc;
    use crate::linalg::{Design, Mat, ParConfig};
    use crate::rng::Pcg64;
    use crate::slope::family::Problem;
    use crate::slope::fista::{solve, FistaConfig, Reduced};
    use crate::slope::lambda::bh_sequence;
    use crate::slope::prox::prox_sorted_l1;
    use crate::slope::sorted::sl1_norm;
    use crate::slope::subdiff::kkt_optimal;

    fn random_problem(seed: u64, n: usize, p: usize, family: Family) -> Problem {
        let mut rng = Pcg64::new(seed);
        let mut x = Mat::zeros(n, p);
        for j in 0..p {
            for i in 0..n {
                x.set(i, j, rng.normal());
            }
        }
        x.standardize(true, true);
        let beta_true: Vec<f64> = (0..p).map(|j| if j < 3 { 1.5 } else { 0.0 }).collect();
        let mut eta = vec![0.0; n];
        x.gemv(&beta_true, &mut eta);
        let y: Vec<f64> = match family {
            Family::Gaussian => eta.iter().map(|e| e + 0.2 * rng.normal()).collect(),
            Family::Binomial => eta
                .iter()
                .map(|&e| {
                    if rng.bernoulli(crate::slope::family::sigmoid(e)) {
                        1.0
                    } else {
                        0.0
                    }
                })
                .collect(),
            Family::Poisson => eta
                .iter()
                .map(|&e| rng.poisson(e.clamp(-2.0, 2.0).exp()) as f64)
                .collect(),
            Family::Multinomial { classes } => (0..n).map(|i| (i % classes) as f64).collect(),
        };
        Problem::new(Design::Dense(x), y, family)
    }

    /// Gap of a full-problem candidate, gradients through the threaded
    /// backend.
    fn full_gap(prob: &Problem, beta: &[f64], lam: &[f64], threads: usize) -> GapResult {
        let par = ParConfig::with_threads(threads);
        let n = prob.n();
        let m = prob.family.n_classes();
        let mut eta = vec![0.0; n * m];
        prob.eta_with(beta, &mut eta, par);
        let mut h = vec![0.0; n * m];
        let loss = prob.family.h_loss(&eta, &prob.y, &mut h);
        let mut grad = vec![0.0; prob.p_total()];
        prob.gradient_from_h_with(&h, &mut grad, par);
        let mags = abs_sorted_desc(&grad);
        duality_gap(prob.family, &prob.y, &h, loss, sl1_norm(beta, lam), &mags, lam)
    }

    #[test]
    fn scale_is_at_least_one_and_enforces_feasibility() {
        let mags = [3.0, 1.0, 0.5];
        let lam = [1.0, 0.8, 0.6];
        let s = dual_feasible_scale(&mags, &lam);
        assert!(s >= 1.0);
        // after scaling, every prefix is feasible
        let mut acc = 0.0;
        let mut lacc = 0.0;
        for (m, l) in mags.iter().zip(&lam) {
            acc += m / s;
            lacc += l;
            assert!(acc <= lacc + 1e-12, "prefix infeasible after scaling");
        }
        // already-feasible magnitudes scale by exactly 1
        assert_eq!(dual_feasible_scale(&[0.5, 0.1], &[1.0, 0.9]), 1.0);
        // zero penalty with mass has no finite feasible scaling
        assert!(dual_feasible_scale(&[1.0], &[0.0]).is_infinite());
        assert_eq!(dual_feasible_scale(&[], &[]), 1.0);
        // NaN magnitudes must not certify a finite scale (f64::max would
        // silently discard them)
        assert!(dual_feasible_scale(&[f64::NAN, 1.0], &[1.0, 0.5]).is_infinite());
    }

    #[test]
    fn gaussian_dual_matches_residual_formula() {
        // D(θ) = ⟨y, θ⟩ − ½‖θ‖² with θ = r/s, r = y − η = −h.
        let y = [1.0, -2.0, 0.5];
        let h = [-0.4, 0.3, 1.0]; // h = η − y, so r = −h
        let s = 2.0;
        let d = dual_objective(Family::Gaussian, &h, &y, s);
        let r = [0.4, -0.3, -1.0];
        let want: f64 = y.iter().zip(&r).map(|(yi, ri)| yi * ri / s).sum::<f64>()
            - 0.5 * r.iter().map(|ri| (ri / s) * (ri / s)).sum::<f64>();
        assert!((d - want).abs() < 1e-12);
    }

    #[test]
    fn gap_vanishes_at_the_prox_fixed_point() {
        // X = I: the SLOPE solution is prox(y; λ) exactly, so the gap at
        // it must be numerically zero for the Gaussian family.
        let n = 6;
        let mut x = Mat::zeros(n, n);
        for i in 0..n {
            x.set(i, i, 1.0);
        }
        let y = vec![3.0, -2.0, 1.5, 0.3, -0.2, 0.05];
        let prob = Problem::new(Design::Dense(x), y.clone(), Family::Gaussian);
        let lam: Vec<f64> = bh_sequence(n, 0.2).iter().map(|l| l * 0.4).collect();
        let beta = prox_sorted_l1(&y, &lam);
        let g = full_gap(&prob, &beta, &lam, 1);
        assert!(g.gap.abs() < 1e-10, "gap at the exact solution: {}", g.gap);
        assert!(g.scale >= 1.0);
    }

    #[test]
    fn null_model_gap_is_zero_for_binomial_and_multinomial() {
        // At β = 0 with σ = σ_max scaling folded in so that 0 is optimal,
        // primal = dual for the entropy families (checked at the natural
        // feasible scaling of the zero-point residual).
        for family in [Family::Binomial, Family::Multinomial { classes: 3 }] {
            let prob = random_problem(5, 40, 6, family);
            let pt = prob.p_total();
            let (loss, grad) = prob.loss_grad(&vec![0.0; pt]);
            let lam_base = bh_sequence(pt, 0.1);
            let smax = crate::slope::lambda::sigma_max(&grad, &lam_base);
            let lam: Vec<f64> = lam_base.iter().map(|l| l * smax).collect();
            let n = prob.n();
            let m = prob.family.n_classes();
            let mut h = vec![0.0; n * m];
            prob.family.h_loss(&vec![0.0; n * m], &prob.y, &mut h);
            let mags = abs_sorted_desc(&grad);
            let g = duality_gap(prob.family, &prob.y, &h, loss, 0.0, &mags, &lam);
            // σ_max makes −∇f(0) exactly feasible: s = 1 and the dual of
            // θ = −h(0) equals the null loss.
            assert!(
                (g.scale - 1.0).abs() < 1e-9,
                "{}: scale {}",
                prob.family.name(),
                g.scale
            );
            assert!(
                g.gap.abs() < 1e-8 * loss.abs().max(1.0),
                "{}: null gap {}",
                prob.family.name(),
                g.gap
            );
        }
    }

    #[test]
    fn dual_gap_is_nonnegative_and_certifies_kkt() {
        // The satellite proptest: across families and thread budgets,
        // (a) weak duality holds at arbitrary candidates, and (b) a
        // gap-certified solve satisfies the Theorem-1 KKT conditions at a
        // tolerance matching the certificate.
        let families = [
            Family::Gaussian,
            Family::Binomial,
            Family::Poisson,
            Family::Multinomial { classes: 3 },
        ];
        let threads = [1usize, 2, 7];
        let mut case = 0u64;
        for &family in &families {
            for &t in &threads {
                case += 1;
                forall(
                    Config { cases: 12, seed: 0xd0a1 + case },
                    |rng| {
                        let n = 15 + rng.below(25) as usize;
                        let p = 4 + rng.below(10) as usize;
                        let seed = rng.below(1 << 30);
                        let beta: Vec<f64> = (0..p * family.n_classes())
                            .map(|_| if rng.bernoulli(0.4) { 0.4 * rng.normal() } else { 0.0 })
                            .collect();
                        (n, p, seed, beta)
                    },
                    |(n, p, seed, beta)| {
                        let prob = random_problem(*seed, *n, *p, family);
                        let pt = prob.p_total();
                        let lam: Vec<f64> =
                            bh_sequence(pt, 0.15).iter().map(|l| l * 0.1).collect();
                        // (a) nonnegativity at an arbitrary candidate
                        let g = full_gap(&prob, beta, &lam, t);
                        ensure(
                            g.gap >= -1e-8 * g.primal.abs().max(1.0),
                            format!("negative gap {} (primal {})", g.gap, g.primal),
                        )?;
                        ensure(g.scale >= 1.0, format!("scale {} < 1", g.scale))?;
                        // (b) gap-certified solve ⇒ KKT at matching tolerance
                        let red = Reduced::new(&prob, (0..pt).collect())
                            .with_par(crate::linalg::ParConfig::with_threads(t));
                        let gap_tol = 1e-9;
                        let cfg = FistaConfig {
                            max_iter: 30_000,
                            tol: 1e-8,
                            kkt_tol_abs: None,
                            gap_tol_abs: Some(gap_tol),
                            cancel: None,
                        };
                        let res = solve(&red, &lam, None, &cfg);
                        if !res.converged {
                            return Ok(()); // surfaced, not certified — nothing to check
                        }
                        let gap = res.gap.expect("gap mode records the certificate");
                        ensure(gap <= gap_tol, format!("certified gap {gap} > {gap_tol}"))?;
                        ensure(gap >= -1e-12, format!("certified gap negative: {gap}"))?;
                        let (_, grad) = prob.loss_grad(&res.beta);
                        ensure(
                            kkt_optimal(&res.beta, &grad, &lam, 1e-4 * (1.0 + lam[0])),
                            "gap-certified point fails the KKT check",
                        )
                    },
                );
            }
        }
    }

    #[test]
    fn gap_decreases_toward_the_solution() {
        // Along a crude homotopy from 0 to the solution, the gap at the
        // endpoint is (weakly) the smallest — a smoke check that the gap
        // actually tracks optimality for every family.
        for family in [Family::Gaussian, Family::Binomial, Family::Poisson] {
            let prob = random_problem(11, 50, 8, family);
            let lam: Vec<f64> = bh_sequence(8, 0.1).iter().map(|l| l * 0.05).collect();
            let red = Reduced::new(&prob, (0..8).collect());
            let cfg = FistaConfig {
                max_iter: 30_000,
                tol: 1e-10,
                kkt_tol_abs: None,
                gap_tol_abs: Some(1e-10),
                cancel: None,
            };
            let res = solve(&red, &lam, None, &cfg);
            let g_end = full_gap(&prob, &res.beta, &lam, 1);
            let g_zero = full_gap(&prob, &vec![0.0; 8], &lam, 1);
            assert!(g_end.gap <= g_zero.gap + 1e-9, "{}", prob.family.name());
        }
    }

    #[test]
    fn nan_gradient_never_certifies() {
        let y = [1.0, 0.0];
        let h = [f64::NAN, 0.5];
        let mags = abs_sorted_desc(&h);
        let g = duality_gap(Family::Gaussian, &y, &h, 1.0, 0.0, &mags, &[1.0, 0.5]);
        assert!(!(g.gap <= 1e100), "NaN gap must fail every tolerance check");
    }

    #[test]
    fn lambda_gen_gap_nonneg_for_generated_sequences() {
        // Generated λ sequences + tied candidates (the prox's edge diet).
        forall(
            Config { cases: 60, seed: 0x9a77 },
            |rng| {
                let v = gen::tied_vec(rng, 2, 12);
                let lam = gen::lambda_seq(rng, v.len());
                (v, lam)
            },
            |(v, lam)| {
                let p = v.len();
                let prob = random_problem(17, 20, p, Family::Gaussian);
                let g = full_gap(&prob, v, lam, 1);
                ensure(
                    g.gap >= -1e-8 * g.primal.abs().max(1.0),
                    format!("negative gap {}", g.gap),
                )
            },
        );
    }
}

//! The sorted-ℓ1 norm and the ordering machinery of §1.2.

use crate::linalg::ops::order_desc_abs;

/// The sorted-ℓ1 norm `J(β; λ) = Σ_j λ_j |β|_(j)` with `λ` non-increasing.
pub fn sl1_norm(beta: &[f64], lambda: &[f64]) -> f64 {
    debug_assert!(beta.len() <= lambda.len());
    let mut mags: Vec<f64> = beta.iter().map(|b| b.abs()).collect();
    mags.sort_unstable_by(|a, b| b.total_cmp(a)); // NaN-tolerant: never panics the solver
    mags.iter().zip(lambda).map(|(m, l)| m * l).sum()
}

/// Scaled norm `σ · J(β; λ)` (the path parameterization of §3.1.2).
pub fn sl1_norm_scaled(beta: &[f64], lambda: &[f64], sigma: f64) -> f64 {
    sigma * sl1_norm(beta, lambda)
}

/// The permutation `O(x)` (descending by absolute value) — identical to
/// [`order_desc_abs`], re-exported here under the paper's name.
pub fn ordering(x: &[f64]) -> Vec<usize> {
    order_desc_abs(x)
}

/// The rank operator `R(x)`: `rank[i]` is the 0-based position of `x[i]`
/// in the descending-absolute ordering (paper Example 1, 0-indexed).
pub fn ranks(x: &[f64]) -> Vec<usize> {
    let ord = ordering(x);
    let mut rank = vec![0usize; x.len()];
    for (pos, &idx) in ord.iter().enumerate() {
        rank[idx] = pos;
    }
    rank
}

/// Clusters `A_i` of eq. (2): groups of indices with equal `|β|`, reported
/// in descending magnitude order. Each cluster carries its magnitude.
#[derive(Clone, Debug, PartialEq)]
pub struct Cluster {
    /// Common absolute value of the cluster.
    pub magnitude: f64,
    /// Member indices into `β` (ascending index order).
    pub members: Vec<usize>,
}

/// Extract the clusters of equal `|β_j|`, descending by magnitude.
/// Exact float equality defines a cluster, as in eq. (2) — SLOPE solutions
/// carry *exact* ties because the prox maps ties to ties.
pub fn clusters(beta: &[f64]) -> Vec<Cluster> {
    let ord = ordering(beta);
    let mut out: Vec<Cluster> = Vec::new();
    for &idx in &ord {
        let mag = beta[idx].abs();
        match out.last_mut() {
            Some(c) if c.magnitude == mag => c.members.push(idx),
            _ => out.push(Cluster { magnitude: mag, members: vec![idx] }),
        }
    }
    for c in &mut out {
        c.members.sort_unstable();
    }
    out
}

/// Number of *unique nonzero* coefficient magnitudes — early-stopping
/// rule 1 of §3.1.2 compares this against `n`.
pub fn unique_nonzero_magnitudes(beta: &[f64]) -> usize {
    clusters(beta).iter().filter(|c| c.magnitude > 0.0).count()
}

/// Support (indices of nonzero coefficients).
pub fn support(beta: &[f64]) -> Vec<usize> {
    beta.iter()
        .enumerate()
        .filter(|(_, &b)| b != 0.0)
        .map(|(i, _)| i)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn norm_is_weighted_sorted_sum() {
        // |β|↓ = [6,5,3,3], λ = [4,3,2,1] => 24+15+6+3 = 48
        let beta = [-3.0, 5.0, 3.0, 6.0];
        let lambda = [4.0, 3.0, 2.0, 1.0];
        assert_eq!(sl1_norm(&beta, &lambda), 48.0);
    }

    #[test]
    fn norm_reduces_to_l1_for_constant_lambda() {
        let beta = [1.0, -2.0, 0.5];
        let lambda = [2.0, 2.0, 2.0];
        assert!((sl1_norm(&beta, &lambda) - 2.0 * 3.5).abs() < 1e-12);
    }

    #[test]
    fn ordering_and_ranks_match_paper_example() {
        // Example 1: β = (−3, 5, 3, 6); O = (4,2,1,3); R = (3,2,4,1), 1-based.
        let beta = [-3.0, 5.0, 3.0, 6.0];
        assert_eq!(ordering(&beta), vec![3, 1, 0, 2]);
        assert_eq!(ranks(&beta), vec![2, 1, 3, 0]);
    }

    #[test]
    fn clusters_match_paper_example() {
        // Example 1: A_1 = {1, 3} (1-based) = {0, 2} for |β| = 3.
        let beta = [-3.0, 5.0, 3.0, 6.0];
        let cs = clusters(&beta);
        assert_eq!(cs.len(), 3);
        assert_eq!(cs[0], Cluster { magnitude: 6.0, members: vec![3] });
        assert_eq!(cs[1], Cluster { magnitude: 5.0, members: vec![1] });
        assert_eq!(cs[2], Cluster { magnitude: 3.0, members: vec![0, 2] });
    }

    #[test]
    fn zero_cluster_counted_separately() {
        let beta = [0.0, 2.0, 0.0, 2.0];
        let cs = clusters(&beta);
        assert_eq!(cs.len(), 2);
        assert_eq!(cs[0].members, vec![1, 3]);
        assert_eq!(cs[1].magnitude, 0.0);
        assert_eq!(unique_nonzero_magnitudes(&beta), 1);
    }

    #[test]
    fn support_basic() {
        assert_eq!(support(&[0.0, 1.0, 0.0, -2.0]), vec![1, 3]);
        assert!(support(&[0.0]).is_empty());
    }

    #[test]
    fn norm_is_permutation_and_sign_invariant() {
        let lambda = [3.0, 2.0, 1.0];
        let a = sl1_norm(&[1.0, -2.0, 3.0], &lambda);
        let b = sl1_norm(&[3.0, 1.0, 2.0], &lambda);
        let c = sl1_norm(&[-3.0, 2.0, -1.0], &lambda);
        assert_eq!(a, b);
        assert_eq!(a, c);
    }
}

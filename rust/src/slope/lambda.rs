//! Penalty sequences and the σ-parameterized regularization path
//! (paper §3.1.1–§3.1.2).

use crate::linalg::ops::{cumsum, probit};

/// The shape of the λ sequence (§3.1.1). All sequences are used through
/// the `σ · J(β; λ)` parameterization, so only their *shape* matters.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum LambdaKind {
    /// Benjamini–Hochberg: `λ_i = Φ⁻¹(1 − qi/(2p))`.
    Bh {
        /// FDR-like parameter `q ∈ (0, 1)`.
        q: f64,
    },
    /// Gaussian modification of BH (requires `n`; reduces to a constant
    /// sequence for small `q/p`, see §3.1.1).
    Gaussian {
        /// FDR-like parameter.
        q: f64,
        /// Number of observations.
        n: usize,
    },
    /// OSCAR: linear decay `λ_i = q(p − i) + 1`.
    Oscar {
        /// Slope of the linear decay.
        q: f64,
    },
    /// Lasso: constant sequence (all ones) — SLOPE degenerates to the
    /// lasso and the rule to the classical strong rule (Prop. 3).
    Lasso,
}

impl LambdaKind {
    /// Materialize the sequence of length `p` (non-increasing, ≥ 0).
    pub fn sequence(&self, p: usize) -> Vec<f64> {
        let seq = match *self {
            LambdaKind::Bh { q } => bh_sequence(p, q),
            LambdaKind::Gaussian { q, n } => gaussian_sequence(p, q, n),
            LambdaKind::Oscar { q } => {
                (1..=p).map(|i| q * (p - i) as f64 + 1.0).collect()
            }
            LambdaKind::Lasso => vec![1.0; p],
        };
        debug_assert!(seq.windows(2).all(|w| w[0] >= w[1] - 1e-12));
        debug_assert!(seq.last().map_or(true, |&l| l >= 0.0));
        seq
    }

    /// Short name for tables.
    pub fn name(&self) -> &'static str {
        match self {
            LambdaKind::Bh { .. } => "BH",
            LambdaKind::Gaussian { .. } => "Gaussian",
            LambdaKind::Oscar { .. } => "OSCAR",
            LambdaKind::Lasso => "lasso",
        }
    }
}

/// BH sequence: `λ_i^BH = Φ⁻¹(1 − qi/(2p))`, clipped below at 0 (for very
/// large `q` the probit can turn negative, which a penalty cannot).
pub fn bh_sequence(p: usize, q: f64) -> Vec<f64> {
    assert!(q > 0.0 && q < 1.0, "BH parameter q must be in (0,1)");
    (1..=p)
        .map(|i| probit(1.0 - q * i as f64 / (2.0 * p as f64)).max(0.0))
        .collect()
}

/// Gaussian sequence (§3.1.1): BH corrected upward by the estimated noise
/// inflation, monotonized, and undefined terms (i = n) handled by carrying
/// the previous value forward.
pub fn gaussian_sequence(p: usize, q: f64, n: usize) -> Vec<f64> {
    let bh = bh_sequence(p, q);
    let mut out = Vec::with_capacity(p);
    let mut sum_sq = 0.0f64; // Σ_{j<i} λ_j²
    for i in 0..p {
        if i == 0 {
            out.push(bh[0]);
        } else {
            let denom = n as f64 - i as f64; // n − i with 1-based i = i+1 ... paper: n - i
            let val = if denom <= 1.0 {
                out[i - 1]
            } else {
                bh[i] * (1.0 + sum_sq / denom).sqrt()
            };
            // restrict to non-increasing: carry previous value once the
            // sequence would start increasing
            out.push(val.min(out[i - 1]));
        }
        sum_sq += out[i] * out[i];
    }
    out
}

/// Configuration of the regularization path (§3.1.2).
#[derive(Clone, Debug)]
pub struct PathConfig {
    /// Penalty shape.
    pub kind: LambdaKind,
    /// Number of path points `l` (paper default: 100).
    pub length: usize,
    /// Terminal ratio `t = σ(l)/σ(1)`; paper: 1e-2 if n < p else 1e-4.
    /// `None` selects the paper default from the problem dimensions.
    pub sigma_min_ratio: Option<f64>,
    /// Early-stop rule 1: unique nonzero magnitudes > n.
    pub stop_on_saturation: bool,
    /// Early-stop rule 2: fractional deviance change < 1e-5.
    pub stop_on_dev_change: bool,
    /// Early-stop rule 3: deviance ratio > 0.995.
    pub stop_on_dev_ratio: bool,
}

impl PathConfig {
    /// Paper defaults (§3.1.2) for the given penalty shape.
    pub fn new(kind: LambdaKind) -> Self {
        Self {
            kind,
            length: 100,
            sigma_min_ratio: None,
            stop_on_saturation: true,
            stop_on_dev_change: true,
            stop_on_dev_ratio: true,
        }
    }

    /// Disable all premature-termination rules (Fig. 3 protocol).
    pub fn without_early_stopping(mut self) -> Self {
        self.stop_on_saturation = false;
        self.stop_on_dev_change = false;
        self.stop_on_dev_ratio = false;
        self
    }

    /// Resolve the terminal ratio given problem dimensions.
    pub fn resolved_min_ratio(&self, n: usize, p: usize) -> f64 {
        self.sigma_min_ratio.unwrap_or(if n < p { 1e-2 } else { 1e-4 })
    }
}

/// `σ(1)`: the smallest σ at which the all-zero solution is optimal,
/// `σ(1) = max( cumsum(|∇f(0)|↓) ⊘ cumsum(λ) )` (§3.1.2).
pub fn sigma_max(grad_at_zero: &[f64], lambda: &[f64]) -> f64 {
    let mut mags: Vec<f64> = grad_at_zero.iter().map(|g| g.abs()).collect();
    mags.sort_unstable_by(|a, b| b.total_cmp(a)); // NaN-tolerant: a bad y must error, not panic
    let cm = cumsum(&mags);
    let cl = cumsum(lambda);
    cm.iter()
        .zip(&cl)
        .filter(|(_, &l)| l > 0.0)
        .map(|(&m, &l)| m / l)
        .fold(0.0f64, f64::max)
}

/// Geometric grid of `length` σ values from `sigma_max` down to
/// `ratio * sigma_max`.
pub fn sigma_grid(sigma_max: f64, ratio: f64, length: usize) -> Vec<f64> {
    assert!(length >= 1);
    assert!(sigma_max > 0.0, "sigma_max must be positive (is the gradient at 0 all zero?)");
    if length == 1 {
        return vec![sigma_max];
    }
    let log_max = sigma_max.ln();
    let log_min = (sigma_max * ratio).ln();
    (0..length)
        .map(|m| (log_max + (log_min - log_max) * m as f64 / (length - 1) as f64).exp())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::slope::subdiff::kkt_infeasibility;

    #[test]
    fn bh_is_positive_nonincreasing() {
        let lam = bh_sequence(100, 0.1);
        assert_eq!(lam.len(), 100);
        assert!(lam.windows(2).all(|w| w[0] >= w[1]));
        assert!(lam.iter().all(|&l| l >= 0.0));
        // λ_1 = Φ⁻¹(1 − 0.1/200) = Φ⁻¹(0.9995) ≈ 3.2905
        assert!((lam[0] - 3.2905).abs() < 1e-3);
    }

    #[test]
    fn oscar_is_linear() {
        let lam = LambdaKind::Oscar { q: 0.5 }.sequence(4);
        assert_eq!(lam, vec![2.5, 2.0, 1.5, 1.0]);
    }

    #[test]
    fn lasso_is_constant() {
        assert_eq!(LambdaKind::Lasso.sequence(3), vec![1.0; 3]);
    }

    #[test]
    fn gaussian_reduces_toward_constant_for_small_n() {
        // §3.1.1: for p=100, q=0.1 the Gaussian sequence reduces to a
        // constant whenever n ≤ 82.
        let lam = gaussian_sequence(100, 0.1, 50);
        let first = lam[0];
        assert!(
            lam.iter().all(|&l| (l - first).abs() < 1e-9),
            "expected constant sequence, got range {:?}..{:?}",
            lam.first(),
            lam.last()
        );
    }

    #[test]
    fn gaussian_decays_for_large_n() {
        let lam = gaussian_sequence(100, 0.1, 10_000);
        assert!(lam[0] > lam[99]);
        assert!(lam.windows(2).all(|w| w[0] >= w[1] - 1e-12));
    }

    #[test]
    fn sigma_max_makes_zero_optimal() {
        // At σ = σ_max the zero vector satisfies the stationarity condition;
        // at σ slightly smaller it does not.
        let g = [3.0, -1.5, 0.7, 0.1];
        let lam = bh_sequence(4, 0.2);
        let smax = sigma_max(&g, &lam);
        let scaled: Vec<f64> = lam.iter().map(|l| l * smax).collect();
        assert!(kkt_infeasibility(&g, &scaled) <= 1e-9);
        let shrunk: Vec<f64> = lam.iter().map(|l| l * smax * 0.999).collect();
        assert!(kkt_infeasibility(&g, &shrunk) > 0.0);
    }

    #[test]
    fn sigma_grid_endpoints_and_monotonicity() {
        let grid = sigma_grid(10.0, 1e-2, 5);
        assert_eq!(grid.len(), 5);
        assert!((grid[0] - 10.0).abs() < 1e-12);
        assert!((grid[4] - 0.1).abs() < 1e-12);
        assert!(grid.windows(2).all(|w| w[0] > w[1]));
    }

    #[test]
    fn path_config_default_ratio_matches_paper() {
        let cfg = PathConfig::new(LambdaKind::Lasso);
        assert_eq!(cfg.resolved_min_ratio(100, 1000), 1e-2); // n < p
        assert_eq!(cfg.resolved_min_ratio(1000, 10), 1e-4); // n >= p
    }

    #[test]
    fn bh_matches_probit_direct() {
        let p = 10;
        let q = 0.05;
        let lam = bh_sequence(p, q);
        for (i, &l) in lam.iter().enumerate() {
            let expect = probit(1.0 - q * (i + 1) as f64 / (2.0 * p as f64));
            assert!((l - expect).abs() < 1e-12);
        }
    }
}

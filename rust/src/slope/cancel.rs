//! Cooperative cancellation for long-running solves.
//!
//! A [`CancelToken`] is a cheap shared flag (one `Arc`, one `AtomicBool`,
//! an optional deadline) that the FISTA loop checks once per iteration and
//! the path driver checks once per σ-step. Both checks are a relaxed
//! atomic load plus — only when a deadline is armed — one monotonic clock
//! read; against multi-microsecond iterations the overhead is
//! unmeasurable (gated <1% in `benches/path_speed.rs`,
//! `resilience.cancel_check_overhead`).
//!
//! Cancellation is *cooperative*: a fired token never tears state down
//! mid-iteration. The solver finishes the arithmetic it is in, marks the
//! result non-converged, and unwinds normally, so partial progress
//! (`steps_done`, the last certified gap) survives into the typed
//! `Deadline` error the serve layer reports.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

struct Inner {
    flag: AtomicBool,
    /// Absolute expiry; checked lazily by `is_cancelled`.
    deadline: Option<Instant>,
    /// The original budget, kept so error responses can echo it.
    deadline_ms: u64,
}

/// Shared cancellation handle. Clones observe the same flag.
#[derive(Clone)]
pub struct CancelToken {
    inner: Arc<Inner>,
}

impl std::fmt::Debug for CancelToken {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("CancelToken")
            .field("cancelled", &self.is_cancelled())
            .field("deadline_ms", &self.inner.deadline_ms)
            .finish()
    }
}

impl CancelToken {
    /// A token with no deadline; fires only via [`CancelToken::cancel`].
    pub fn new() -> Self {
        Self {
            inner: Arc::new(Inner { flag: AtomicBool::new(false), deadline: None, deadline_ms: 0 }),
        }
    }

    /// A token that auto-fires `ms` milliseconds from now (and can still
    /// be fired earlier via [`CancelToken::cancel`]).
    pub fn with_deadline_ms(ms: u64) -> Self {
        Self {
            inner: Arc::new(Inner {
                flag: AtomicBool::new(false),
                deadline: Some(Instant::now() + Duration::from_millis(ms)),
                deadline_ms: ms,
            }),
        }
    }

    /// Fire the token. Idempotent; visible to all clones.
    pub fn cancel(&self) {
        self.inner.flag.store(true, Ordering::Relaxed);
    }

    /// Has the token fired (explicitly, or by deadline expiry)?
    ///
    /// This is the hot-loop check: a relaxed load, plus one `Instant::now`
    /// only when a deadline is armed. Expiry latches the flag so later
    /// checks skip the clock.
    #[inline]
    pub fn is_cancelled(&self) -> bool {
        if self.inner.flag.load(Ordering::Relaxed) {
            return true;
        }
        if let Some(deadline) = self.inner.deadline {
            if Instant::now() >= deadline {
                self.inner.flag.store(true, Ordering::Relaxed);
                return true;
            }
        }
        false
    }

    /// The deadline budget this token was armed with (`None` when the
    /// token has no deadline).
    pub fn deadline_ms(&self) -> Option<u64> {
        self.inner.deadline.map(|_| self.inner.deadline_ms)
    }
}

impl Default for CancelToken {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fresh_token_is_live_and_fires_on_cancel() {
        let t = CancelToken::new();
        assert!(!t.is_cancelled());
        assert_eq!(t.deadline_ms(), None);
        let clone = t.clone();
        t.cancel();
        assert!(clone.is_cancelled(), "clones share the flag");
    }

    #[test]
    fn deadline_token_expires() {
        let t = CancelToken::with_deadline_ms(1);
        assert_eq!(t.deadline_ms(), Some(1));
        std::thread::sleep(Duration::from_millis(5));
        assert!(t.is_cancelled());
        // Latched: still cancelled on re-check.
        assert!(t.is_cancelled());
    }

    #[test]
    fn generous_deadline_does_not_fire_immediately() {
        let t = CancelToken::with_deadline_ms(60_000);
        assert!(!t.is_cancelled());
    }
}

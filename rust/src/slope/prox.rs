//! Proximal operator of the sorted-ℓ1 norm.
//!
//! `prox_J(v; λ) = argmin_b ½‖b − v‖² + Σ_j λ_j |b|_(j)`
//!
//! Computed by the stack-based algorithm of Bogdan et al. (2015, Alg. 3 /
//! "FastProxSL1"): sort `|v|` descending, subtract λ, then run a
//! nonincreasing isotonic regression (pool-adjacent-violators with a block
//! stack), clip at zero, undo the permutation and restore signs. `O(p)`
//! after the `O(p log p)` sort — the very cost the screening rule is
//! designed to amortize (footnote 3 of the paper).

use crate::linalg::ops::order_desc_abs;

/// Block of pooled coordinates during PAVA.
#[derive(Clone, Copy)]
struct Block {
    start: usize,
    end: usize, // inclusive
    sum: f64,
}

impl Block {
    #[inline]
    fn mean(&self) -> f64 {
        self.sum / (self.end - self.start + 1) as f64
    }
}

/// Evaluate the prox into a fresh vector. `lambda` must be non-increasing,
/// non-negative, with `lambda.len() >= v.len()`.
pub fn prox_sorted_l1(v: &[f64], lambda: &[f64]) -> Vec<f64> {
    let mut out = vec![0.0; v.len()];
    let mut ws = ProxWorkspace::new(v.len());
    prox_sorted_l1_into(v, lambda, &mut ws, &mut out);
    out
}

/// Reusable scratch buffers for the prox (the FISTA inner loop calls the
/// prox once per iteration; reusing the workspace removes all allocation
/// from the hot path — see EXPERIMENTS.md §Perf). The sort itself runs
/// over the workspace-owned `pairs` buffer, so a warmed workspace makes
/// [`prox_sorted_l1_into`] allocation-free end to end (previously the
/// ordering went through [`order_desc_abs`], which builds two fresh
/// vectors per call — one per FISTA iteration on the hot path).
pub struct ProxWorkspace {
    order: Vec<usize>,
    pairs: Vec<(f64, u32)>,
    z: Vec<f64>,
    blocks: Vec<Block>,
}

impl ProxWorkspace {
    /// Workspace for problems up to `p` coordinates.
    pub fn new(p: usize) -> Self {
        Self {
            order: Vec::with_capacity(p),
            pairs: Vec::with_capacity(p),
            z: Vec::with_capacity(p),
            blocks: Vec::with_capacity(p),
        }
    }
}

/// In-place prox: writes the result into `out` (same length as `v`).
pub fn prox_sorted_l1_into(
    v: &[f64],
    lambda: &[f64],
    ws: &mut ProxWorkspace,
    out: &mut [f64],
) {
    let p = v.len();
    assert!(lambda.len() >= p, "lambda shorter than v ({} < {p})", lambda.len());
    assert_eq!(out.len(), p);
    debug_assert!(lambda.windows(2).all(|w| w[0] >= w[1]), "lambda must be non-increasing");
    if p == 0 {
        return;
    }

    // 1. Sort |v| descending, remembering the permutation. In-workspace
    //    (|value|, index) pairs through the shared comparator
    //    ([`crate::linalg::ops::sort_pairs_desc_abs`], the one
    //    `order_desc_abs` uses) — bitwise-identical permutation, zero
    //    allocation once the buffers are warm.
    ws.pairs.clear();
    ws.pairs.extend(v.iter().enumerate().map(|(i, &x)| (x.abs(), i as u32)));
    crate::linalg::ops::sort_pairs_desc_abs(&mut ws.pairs);
    ws.order.clear();
    ws.order.extend(ws.pairs.iter().map(|&(_, i)| i as usize));

    // 2. z = |v|↓ − λ.
    ws.z.clear();
    ws.z.extend(ws.order.iter().zip(lambda).map(|(&i, &l)| v[i].abs() - l));

    // 3. Nonincreasing isotonic regression via a block stack: maintain
    //    strictly decreasing block means; merge when violated.
    ws.blocks.clear();
    for (i, &zi) in ws.z.iter().enumerate() {
        let mut blk = Block { start: i, end: i, sum: zi };
        while let Some(&prev) = ws.blocks.last() {
            if prev.mean() <= blk.mean() {
                ws.blocks.pop();
                blk = Block { start: prev.start, end: blk.end, sum: prev.sum + blk.sum };
            } else {
                break;
            }
        }
        ws.blocks.push(blk);
    }

    // 4. Clip at zero, undo permutation, restore signs. (`f64::signum`
    //    maps ±0.0 to ±1.0, so exact-zero inputs are special-cased to keep
    //    the output support clean.)
    for blk in &ws.blocks {
        let m = blk.mean().max(0.0);
        for k in blk.start..=blk.end {
            let idx = ws.order[k];
            out[idx] = if v[idx] == 0.0 { 0.0 } else { m * v[idx].signum() };
        }
    }
}

/// Independent reference prox for cross-checking the stack version: an
/// O(p²)-worst-case PAVA that maintains explicit block boundaries and
/// restarts the violation scan from the beginning after every merge —
/// structurally different from (and much slower than) the production
/// stack algorithm, but obviously correct.
pub fn prox_sorted_l1_reference(v: &[f64], lambda: &[f64]) -> Vec<f64> {
    let p = v.len();
    if p == 0 {
        return Vec::new();
    }
    let order = order_desc_abs(v);
    let z: Vec<f64> = order.iter().zip(lambda).map(|(&i, &l)| v[i].abs() - l).collect();
    // Blocks as (start, end inclusive, sum); merge any adjacent pair whose
    // means violate the non-increasing constraint, rescanning from scratch.
    let mut blocks: Vec<(usize, usize, f64)> = (0..p).map(|i| (i, i, z[i])).collect();
    let mean = |b: &(usize, usize, f64)| b.2 / (b.1 - b.0 + 1) as f64;
    loop {
        let mut violation = None;
        for i in 0..blocks.len() - 1 {
            if mean(&blocks[i]) <= mean(&blocks[i + 1]) {
                violation = Some(i);
                break;
            }
        }
        match violation {
            None => break,
            Some(i) => {
                let merged = (blocks[i].0, blocks[i + 1].1, blocks[i].2 + blocks[i + 1].2);
                blocks.splice(i..=i + 1, [merged]);
            }
        }
    }
    let mut out = vec![0.0; p];
    for blk in &blocks {
        let m = mean(blk).max(0.0);
        for k in blk.0..=blk.1 {
            let idx = order[k];
            out[idx] = if v[idx] == 0.0 { 0.0 } else { m * v[idx].signum() };
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::check::{all_close, ensure, forall, gen, Config};
    use crate::slope::sorted::sl1_norm;

    /// Objective the prox minimizes.
    fn prox_objective(b: &[f64], v: &[f64], lambda: &[f64]) -> f64 {
        let quad: f64 = b.iter().zip(v).map(|(bi, vi)| 0.5 * (bi - vi) * (bi - vi)).sum();
        quad + sl1_norm(b, lambda)
    }

    #[test]
    fn soft_threshold_when_lambda_constant() {
        // Constant λ => elementwise soft thresholding.
        let v = [3.0, -1.0, 0.5, -4.0];
        let lam = [1.0; 4];
        let got = prox_sorted_l1(&v, &lam);
        assert_eq!(got, vec![2.0, 0.0, 0.0, -3.0]);
    }

    #[test]
    fn zero_lambda_is_identity() {
        let v = [3.0, -1.0, 0.5];
        assert_eq!(prox_sorted_l1(&v, &[0.0; 3]), v.to_vec());
    }

    #[test]
    fn large_lambda_kills_everything() {
        let v = [3.0, -1.0, 0.5];
        assert_eq!(prox_sorted_l1(&v, &[100.0; 3]), vec![0.0; 3]);
    }

    #[test]
    fn clustering_of_close_values() {
        // λ = (2, 1): gap forces v = (3, 2.5) into a tie (averaging).
        // z = (3-2, 2.5-1) = (1, 1.5) violates monotonicity => pooled to 1.25.
        let got = prox_sorted_l1(&[3.0, 2.5], &[2.0, 1.0]);
        assert!((got[0] - 1.25).abs() < 1e-12);
        assert!((got[1] - 1.25).abs() < 1e-12);
    }

    #[test]
    fn preserves_signs_and_order() {
        let v = [-5.0, 4.0, -3.0];
        let lam = [1.0, 0.5, 0.25];
        let got = prox_sorted_l1(&v, &lam);
        assert!(got[0] < 0.0 && got[1] > 0.0 && got[2] < 0.0);
        // magnitudes stay ordered like the input magnitudes
        assert!(got[0].abs() >= got[1].abs());
        assert!(got[1].abs() >= got[2].abs());
    }

    #[test]
    fn output_magnitude_ordering_matches_input() {
        // The prox never swaps magnitude ranks (rearrangement property).
        forall(
            Config { cases: 200, seed: 0xabcd },
            |rng| {
                let v = gen::normal_vec(rng, 1, 30);
                let lam = gen::lambda_seq(rng, v.len());
                (v, lam)
            },
            |(v, lam)| {
                let b = prox_sorted_l1(v, lam);
                let vo = order_desc_abs(v);
                for w in vo.windows(2) {
                    ensure(
                        b[w[0]].abs() >= b[w[1]].abs() - 1e-12,
                        format!("rank swap at {w:?}"),
                    )?;
                }
                Ok(())
            },
        );
    }

    #[test]
    fn objective_beats_perturbations() {
        // The prox output minimizes the objective: random perturbations
        // never do better.
        forall(
            Config { cases: 100, seed: 0x1234 },
            |rng| {
                let v = gen::normal_vec(rng, 1, 12);
                let lam = gen::lambda_seq(rng, v.len());
                let dirs: Vec<Vec<f64>> =
                    (0..8).map(|_| (0..v.len()).map(|_| rng.normal()).collect()).collect();
                (v, lam, dirs)
            },
            |(v, lam, dirs)| {
                let b = prox_sorted_l1(v, lam);
                let fb = prox_objective(&b, v, lam);
                for d in dirs {
                    for eps in [1e-3, 1e-2, 0.1, 1.0] {
                        let cand: Vec<f64> =
                            b.iter().zip(d).map(|(bi, di)| bi + eps * di).collect();
                        let fc = prox_objective(&cand, v, lam);
                        ensure(
                            fc >= fb - 1e-9,
                            format!("perturbation improved objective: {fc} < {fb}"),
                        )?;
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prox_is_nonexpansive() {
        forall(
            Config { cases: 100, seed: 0x77 },
            |rng| {
                let v = gen::normal_vec(rng, 1, 20);
                let w: Vec<f64> = v.iter().map(|x| x + 0.5 * rng.normal()).collect();
                let lam = gen::lambda_seq(rng, v.len());
                (v, w, lam)
            },
            |(v, w, lam)| {
                let pv = prox_sorted_l1(v, lam);
                let pw = prox_sorted_l1(w, lam);
                let d_in: f64 = v.iter().zip(w).map(|(a, b)| (a - b) * (a - b)).sum();
                let d_out: f64 = pv.iter().zip(&pw).map(|(a, b)| (a - b) * (a - b)).sum();
                ensure(d_out <= d_in + 1e-9, format!("expansive: {d_out} > {d_in}"))
            },
        );
    }

    #[test]
    fn workspace_reuse_is_consistent() {
        let mut ws = ProxWorkspace::new(8);
        let lam = [2.0, 1.5, 1.0, 0.5];
        let mut out1 = vec![0.0; 4];
        let mut out2 = vec![0.0; 4];
        prox_sorted_l1_into(&[4.0, -3.0, 2.0, -1.0], &lam, &mut ws, &mut out1);
        prox_sorted_l1_into(&[4.0, -3.0, 2.0, -1.0], &lam, &mut ws, &mut out2);
        assert_eq!(out1, out2);
        assert_eq!(out1, prox_sorted_l1(&[4.0, -3.0, 2.0, -1.0], &lam));
    }

    #[test]
    fn workspace_sort_matches_order_desc_abs_bitwise() {
        // The in-workspace pair sort must reproduce `order_desc_abs`'s
        // permutation exactly (same comparator, same tiebreak), so the
        // alloc-free path is bitwise-identical to the old one — ties and
        // signed zeros included.
        forall(
            Config { cases: 200, seed: 0xa110c },
            |rng| {
                let v = if rng.bernoulli(0.5) {
                    gen::tied_vec(rng, 1, 30)
                } else {
                    gen::normal_vec(rng, 1, 30)
                };
                let lam = gen::lambda_seq(rng, v.len());
                (v, lam)
            },
            |(v, lam)| {
                let mut ws = ProxWorkspace::new(v.len());
                let mut out = vec![0.0; v.len()];
                prox_sorted_l1_into(v, lam, &mut ws, &mut out);
                ensure(
                    ws.order == order_desc_abs(v),
                    format!("permutation drifted: {:?} vs {:?}", ws.order, order_desc_abs(v)),
                )?;
                let alloc = prox_sorted_l1(v, lam);
                ensure(
                    out.iter().zip(&alloc).all(|(a, b)| a.to_bits() == b.to_bits()),
                    "into-path must be bitwise identical to the allocating path",
                )
            },
        );
    }

    #[test]
    fn handles_zeros_in_input() {
        let got = prox_sorted_l1(&[0.0, 2.0, 0.0], &[0.5, 0.5, 0.5]);
        assert_eq!(got, vec![0.0, 1.5, 0.0]);
    }

    #[test]
    fn empty_input() {
        assert!(prox_sorted_l1(&[], &[]).is_empty());
    }

    #[test]
    fn fast_matches_reference() {
        forall(
            Config { cases: 300, seed: 0x5e5e },
            |rng| {
                let v = if rng.bernoulli(0.5) {
                    gen::normal_vec(rng, 1, 25)
                } else {
                    gen::tied_vec(rng, 1, 25)
                };
                let lam = gen::lambda_seq(rng, v.len());
                (v, lam)
            },
            |(v, lam)| {
                let fast = prox_sorted_l1(v, lam);
                let slow = prox_sorted_l1_reference(v, lam);
                all_close(&fast, &slow, 1e-10)
            },
        );
    }

    #[test]
    fn agrees_with_subdifferential_optimality() {
        use crate::slope::subdiff;
        forall(
            Config { cases: 150, seed: 0x99 },
            |rng| {
                let v = gen::tied_vec(rng, 1, 15);
                let lam = gen::lambda_seq(rng, v.len());
                (v, lam)
            },
            |(v, lam)| {
                let b = prox_sorted_l1(v, lam);
                // Optimality of the prox: v − b ∈ ∂J(b; λ).
                let g: Vec<f64> = v.iter().zip(&b).map(|(vi, bi)| vi - bi).collect();
                ensure(
                    subdiff::in_subdifferential(&b, &g, lam, 1e-8),
                    "v - prox(v) not in subdifferential",
                )
            },
        );
    }
}

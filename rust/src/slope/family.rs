//! The four GLM objectives of the paper's experiments (§3.2.3): sorted-ℓ1
//! penalized OLS, logistic, Poisson and multinomial regression.
//!
//! Each family defines the smooth part `f(β)` of problem (1) through its
//! linear predictor `η = Xβ` (per class for multinomial): a pointwise
//! "working residual" `h(η, y)` with `∇f(β) = Xᵀ h(η, y)`, the loss, a
//! curvature bound for FISTA step sizes, and the deviance used by the
//! path's early-stopping rules.
//!
//! Multinomial coefficients are stored **flattened class-major**:
//! `coef[l * p + j]` is class `l`, predictor `j` — the sorted-ℓ1 norm is
//! permutation invariant, so the flattening order is immaterial to the
//! penalty (this matches the R `SLOPE` package, which penalizes the
//! whole coefficient matrix).

use crate::linalg::{Design, ParConfig};

/// GLM family: the smooth objective `f` of problem (1).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Family {
    /// Ordinary least squares: `f(β) = ½‖Xβ − y‖²`.
    Gaussian,
    /// Logistic regression with `y ∈ {0, 1}`.
    Binomial,
    /// Poisson regression with counts `y ∈ {0, 1, 2, …}`.
    Poisson,
    /// Multinomial (softmax) regression with `y ∈ {0, …, classes−1}`.
    Multinomial {
        /// Number of classes `m ≥ 2`.
        classes: usize,
    },
}

impl Family {
    /// Number of linear predictors per observation (1 except multinomial).
    pub fn n_classes(&self) -> usize {
        match *self {
            Family::Multinomial { classes } => classes,
            _ => 1,
        }
    }

    /// Parse a family name as used across the CLI, serve and ingest
    /// layers. `classes` applies to multinomial only.
    pub fn parse(name: &str, classes: usize) -> Result<Family, String> {
        match name {
            "gaussian" | "ols" => Ok(Family::Gaussian),
            "binomial" | "logistic" => Ok(Family::Binomial),
            "poisson" => Ok(Family::Poisson),
            "multinomial" => {
                if classes < 2 {
                    Err(format!("multinomial needs classes >= 2, got {classes}"))
                } else {
                    Ok(Family::Multinomial { classes })
                }
            }
            other => Err(format!(
                "unknown family `{other}` (expected gaussian|binomial|poisson|multinomial)"
            )),
        }
    }

    /// Short name for tables.
    pub fn name(&self) -> &'static str {
        match self {
            Family::Gaussian => "OLS",
            Family::Binomial => "logistic",
            Family::Poisson => "poisson",
            Family::Multinomial { .. } => "multinomial",
        }
    }

    /// Compute the working residual `h(η, y)` into `h` and return the loss
    /// `f`. `eta` and `h` have length `n * m` (class-major blocks);
    /// `y` has length `n`.
    pub fn h_loss(&self, eta: &[f64], y: &[f64], h: &mut [f64]) -> f64 {
        let n = y.len();
        debug_assert_eq!(eta.len(), n * self.n_classes());
        debug_assert_eq!(h.len(), eta.len());
        match *self {
            Family::Gaussian => {
                let mut loss = 0.0;
                for i in 0..n {
                    let r = eta[i] - y[i];
                    h[i] = r;
                    loss += 0.5 * r * r;
                }
                loss
            }
            Family::Binomial => {
                let mut loss = 0.0;
                for i in 0..n {
                    let e = eta[i];
                    // log(1 + exp(e)) computed stably
                    loss += if e > 0.0 { e + (-e).exp().ln_1p() } else { e.exp().ln_1p() };
                    loss -= y[i] * e;
                    h[i] = sigmoid(e) - y[i];
                }
                loss
            }
            Family::Poisson => {
                let mut loss = 0.0;
                for i in 0..n {
                    // exp(η) overflows to inf past η ≈ 709.78, and an inf
                    // loss/gradient feeds the degradation ladder a NaN
                    // after the first subtraction. Clamp the rate at
                    // exp(EXP_CLAMP): the clamped gradient still points
                    // steeply downhill, so the solver backs off exactly as
                    // it would with the true (astronomically large) value.
                    let mu = eta[i].min(EXP_CLAMP).exp();
                    loss += mu - y[i] * eta[i];
                    h[i] = mu - y[i];
                }
                loss
            }
            Family::Multinomial { classes } => {
                let mut loss = 0.0;
                for i in 0..n {
                    // log-sum-exp over classes for observation i
                    let mut maxe = f64::NEG_INFINITY;
                    for l in 0..classes {
                        maxe = maxe.max(eta[l * n + i]);
                    }
                    let mut z = 0.0;
                    for l in 0..classes {
                        z += (eta[l * n + i] - maxe).exp();
                    }
                    let lse = maxe + z.ln();
                    let yi = y[i] as usize;
                    debug_assert!(yi < classes);
                    loss += lse - eta[yi * n + i];
                    for l in 0..classes {
                        let p = (eta[l * n + i] - lse).exp();
                        h[l * n + i] = p - if l == yi { 1.0 } else { 0.0 };
                    }
                }
                loss
            }
        }
    }

    /// Upper bound on the per-observation curvature `sup h'(η)`:
    /// the FISTA step starts at `L = bound · ‖X‖₂²`. `None` means
    /// unbounded curvature (Poisson) — the solver then relies purely on
    /// backtracking from a heuristic initial step.
    pub fn hessian_bound(&self) -> Option<f64> {
        match self {
            Family::Gaussian => Some(1.0),
            Family::Binomial => Some(0.25),
            Family::Poisson => None,
            Family::Multinomial { .. } => Some(0.5),
        }
    }

    /// Saturated log-likelihood loss (the loss of a perfect fit), used to
    /// convert loss to deviance: `dev = 2(loss − loss_saturated)`.
    pub fn saturated_loss(&self, y: &[f64]) -> f64 {
        match *self {
            Family::Gaussian | Family::Binomial | Family::Multinomial { .. } => 0.0,
            Family::Poisson => y
                .iter()
                .map(|&yi| if yi > 0.0 { yi - yi * yi.ln() } else { 0.0 })
                .sum(),
        }
    }

    /// Deviance of a fit with the given loss.
    pub fn deviance(&self, loss: f64, y: &[f64]) -> f64 {
        2.0 * (loss - self.saturated_loss(y))
    }

    /// Null deviance: the deviance of the intercept-free null model
    /// `η = 0` — matching the path's starting point `β = 0` (the paper
    /// centers `y` for OLS so the zero model *is* the mean model there).
    pub fn null_deviance(&self, y: &[f64]) -> f64 {
        let n = y.len();
        let m = self.n_classes();
        let eta = vec![0.0; n * m];
        let mut h = vec![0.0; n * m];
        let loss = self.h_loss(&eta, y, &mut h);
        self.deviance(loss, y)
    }
}

/// Linear-predictor clamp for exponential links: `exp(709.79)` is the
/// last finite double, so Poisson rates are evaluated at
/// `exp(min(η, EXP_CLAMP))`. Anything past this bound is numerically
/// "infinite rate" anyway; clamping keeps losses and gradients finite so
/// extreme predictors degrade gracefully instead of poisoning the fit
/// with inf/NaN.
pub const EXP_CLAMP: f64 = 700.0;

/// Numerically stable logistic function.
#[inline]
pub fn sigmoid(x: f64) -> f64 {
    if x >= 0.0 {
        1.0 / (1.0 + (-x).exp())
    } else {
        let e = x.exp();
        e / (1.0 + e)
    }
}

/// A SLOPE problem instance: design, response, family.
#[derive(Clone, Debug)]
pub struct Problem {
    /// Design matrix (dense or sparse).
    pub x: Design,
    /// Response: values for OLS/Poisson, {0,1} for logistic, class indices
    /// (as `f64`) for multinomial.
    pub y: Vec<f64>,
    /// Objective family.
    pub family: Family,
}

impl Problem {
    /// Build, validating dimensions and response range.
    pub fn new(x: Design, y: Vec<f64>, family: Family) -> Self {
        assert_eq!(x.nrows(), y.len(), "X rows must match y length");
        match family {
            Family::Binomial => {
                assert!(
                    y.iter().all(|&v| v == 0.0 || v == 1.0),
                    "binomial response must be 0/1"
                );
            }
            Family::Poisson => {
                assert!(y.iter().all(|&v| v >= 0.0), "poisson response must be non-negative");
            }
            Family::Multinomial { classes } => {
                assert!(classes >= 2);
                assert!(
                    y.iter().all(|&v| v >= 0.0 && v < classes as f64 && v.fract() == 0.0),
                    "multinomial response must be class indices"
                );
            }
            Family::Gaussian => {}
        }
        Self { x, y, family }
    }

    /// Observations.
    pub fn n(&self) -> usize {
        self.x.nrows()
    }

    /// Predictors (columns of X).
    pub fn p(&self) -> usize {
        self.x.ncols()
    }

    /// Total coefficients `p · m` (the dimension the sorted-ℓ1 norm acts on).
    pub fn p_total(&self) -> usize {
        self.p() * self.family.n_classes()
    }

    /// `η = Xβ` per class into `eta` (length `n·m`); `beta` is flattened
    /// class-major of length `p·m`.
    pub fn eta(&self, beta: &[f64], eta: &mut [f64]) {
        self.eta_with(beta, eta, ParConfig::serial());
    }

    /// [`Problem::eta`] with a kernel thread budget.
    pub fn eta_with(&self, beta: &[f64], eta: &mut [f64], par: ParConfig) {
        let (n, p, m) = (self.n(), self.p(), self.family.n_classes());
        debug_assert_eq!(beta.len(), p * m);
        debug_assert_eq!(eta.len(), n * m);
        for l in 0..m {
            self.x.gemv_with(&beta[l * p..(l + 1) * p], &mut eta[l * n..(l + 1) * n], par);
        }
    }

    /// Full gradient `∇f(β) = Xᵀ h` per class into `grad` (length `p·m`).
    pub fn gradient_from_h(&self, h: &[f64], grad: &mut [f64]) {
        self.gradient_from_h_with(h, grad, ParConfig::serial());
    }

    /// [`Problem::gradient_from_h`] with a kernel thread budget — the
    /// full-design `Xᵀh` sweep is the path driver's dominant non-reduced
    /// cost, embarrassingly parallel across columns.
    pub fn gradient_from_h_with(&self, h: &[f64], grad: &mut [f64], par: ParConfig) {
        let (n, p, m) = (self.n(), self.p(), self.family.n_classes());
        debug_assert_eq!(h.len(), n * m);
        debug_assert_eq!(grad.len(), p * m);
        for l in 0..m {
            self.x.gemv_t_with(&h[l * n..(l + 1) * n], &mut grad[l * p..(l + 1) * p], par);
        }
    }

    /// Loss and full gradient at `beta` (allocating convenience for tests
    /// and σ_max computation).
    pub fn loss_grad(&self, beta: &[f64]) -> (f64, Vec<f64>) {
        let (n, m) = (self.n(), self.family.n_classes());
        let mut eta = vec![0.0; n * m];
        self.eta(beta, &mut eta);
        let mut h = vec![0.0; n * m];
        let loss = self.family.h_loss(&eta, &self.y, &mut h);
        let mut grad = vec![0.0; self.p_total()];
        self.gradient_from_h(&h, &mut grad);
        (loss, grad)
    }

    /// Map flattened coefficient indices to predictor columns: coefficient
    /// `c` lives on column `c % p` (class `c / p`).
    pub fn coef_to_col(&self, coef_idx: usize) -> usize {
        coef_idx % self.p()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;

    fn toy_design() -> Design {
        Design::Dense(Mat::from_rows(&[&[1.0, 0.5], &[-0.5, 1.0], &[0.25, -1.0]]))
    }

    #[test]
    fn gaussian_loss_and_residual() {
        let fam = Family::Gaussian;
        let eta = [1.0, 2.0];
        let y = [0.0, 4.0];
        let mut h = [0.0; 2];
        let loss = fam.h_loss(&eta, &y, &mut h);
        assert_eq!(h, [1.0, -2.0]);
        assert_eq!(loss, 0.5 * (1.0 + 4.0));
    }

    #[test]
    fn binomial_loss_stable_at_extremes() {
        let fam = Family::Binomial;
        let mut h = [0.0; 2];
        let loss = fam.h_loss(&[50.0, -50.0], &[1.0, 0.0], &mut h);
        assert!(loss < 1e-10, "perfect separation should have ~0 loss, got {loss}");
        assert!(h[0].abs() < 1e-10 && h[1].abs() < 1e-10);
        let loss_bad = fam.h_loss(&[-50.0, 50.0], &[1.0, 0.0], &mut h);
        assert!(loss_bad > 99.0);
    }

    #[test]
    fn binomial_gradient_is_sigmoid_residual() {
        let fam = Family::Binomial;
        let mut h = [0.0; 1];
        fam.h_loss(&[0.0], &[1.0], &mut h);
        assert!((h[0] - (0.5 - 1.0)).abs() < 1e-12);
    }

    #[test]
    fn poisson_loss_grad() {
        let fam = Family::Poisson;
        let mut h = [0.0; 2];
        let loss = fam.h_loss(&[0.0, 1.0_f64.ln()], &[1.0, 2.0], &mut h);
        // f = (1 − 0) + (1 − 2·0) = 2 ; h = (1−1, 1−2) = (0, −1)
        assert!((loss - 2.0).abs() < 1e-12);
        assert!((h[0] - 0.0).abs() < 1e-12);
        assert!((h[1] + 1.0).abs() < 1e-12);
    }

    #[test]
    fn multinomial_residual_sums_to_zero_per_obs() {
        let fam = Family::Multinomial { classes: 3 };
        let n = 4;
        let eta: Vec<f64> = (0..3 * n).map(|i| (i as f64) * 0.3 - 1.0).collect();
        let y = [0.0, 1.0, 2.0, 1.0];
        let mut h = vec![0.0; 3 * n];
        let loss = fam.h_loss(&eta, &y, &mut h);
        assert!(loss > 0.0);
        for i in 0..n {
            let s: f64 = (0..3).map(|l| h[l * n + i]).sum();
            assert!(s.abs() < 1e-12, "h rows must sum to 0, got {s}");
        }
    }

    #[test]
    fn numeric_gradient_check_all_families() {
        // Finite-difference check of ∇f = Xᵀh on a tiny problem.
        let families = [
            Family::Gaussian,
            Family::Binomial,
            Family::Poisson,
            Family::Multinomial { classes: 3 },
        ];
        for fam in families {
            let x = toy_design();
            let y = match fam {
                Family::Gaussian => vec![0.3, -0.8, 0.5],
                Family::Binomial => vec![1.0, 0.0, 1.0],
                Family::Poisson => vec![2.0, 0.0, 1.0],
                Family::Multinomial { .. } => vec![0.0, 2.0, 1.0],
            };
            let prob = Problem::new(x, y, fam);
            let pt = prob.p_total();
            let beta: Vec<f64> = (0..pt).map(|i| 0.1 * (i as f64) - 0.2).collect();
            let (_, grad) = prob.loss_grad(&beta);
            let eps = 1e-6;
            for c in 0..pt {
                let mut bp = beta.clone();
                bp[c] += eps;
                let (lp, _) = prob.loss_grad(&bp);
                let mut bm = beta.clone();
                bm[c] -= eps;
                let (lm, _) = prob.loss_grad(&bm);
                let fd = (lp - lm) / (2.0 * eps);
                assert!(
                    (fd - grad[c]).abs() < 1e-5 * (1.0 + fd.abs()),
                    "{}: coef {c}: fd={fd} analytic={}",
                    fam.name(),
                    grad[c]
                );
            }
        }
    }

    #[test]
    fn binomial_finite_at_eta_1e3() {
        // |η| far past exp() overflow: losses and working residuals must
        // stay finite (log1p-exp form + stable sigmoid).
        let fam = Family::Binomial;
        let mut h = [0.0; 4];
        let loss = fam.h_loss(&[1e3, -1e3, 750.0, -750.0], &[0.0, 1.0, 1.0, 0.0], &mut h);
        assert!(loss.is_finite(), "binomial loss at |eta|=1e3 must be finite, got {loss}");
        assert!(h.iter().all(|v| v.is_finite()), "binomial h must be finite: {h:?}");
        // the misclassified extremes carry ~|η| loss each
        assert!(loss > 1.9e3 && loss < 4e3, "loss {loss}");
        assert!((h[0] - 1.0).abs() < 1e-12 && (h[1] + 1.0).abs() < 1e-12);
    }

    #[test]
    fn poisson_finite_at_eta_1e3() {
        // Unclamped exp(1e3) = inf; the clamped link keeps loss, h and
        // the deviance pipeline finite.
        let fam = Family::Poisson;
        let mut h = [0.0; 3];
        let loss = fam.h_loss(&[1e3, 700.0, -1e3], &[2.0, 0.0, 1.0], &mut h);
        assert!(loss.is_finite(), "poisson loss at eta=1e3 must be finite, got {loss}");
        assert!(h.iter().all(|v| v.is_finite()), "poisson h must be finite: {h:?}");
        // clamped rate is huge but finite and still monotone in η below
        // the clamp: gradient keeps its sign and magnitude ordering
        assert!(h[0] > 0.0 && h[1] > 0.0 && h[0] >= h[1]);
        // η far negative: rate ~ 0, h → −y
        assert!((h[2] + 1.0).abs() < 1e-12);
        assert!(fam.deviance(loss, &[2.0, 0.0, 1.0]).is_finite());
    }

    #[test]
    fn poisson_clamp_is_inactive_in_normal_range() {
        // Bitwise identity below the clamp: hardening must not perturb
        // well-conditioned fits.
        let fam = Family::Poisson;
        let etas = [-30.0, -1.0, 0.0, 2.5, 100.0, EXP_CLAMP];
        for e in etas {
            let mut h = [0.0; 1];
            fam.h_loss(&[e], &[1.0], &mut h);
            assert_eq!(h[0].to_bits(), (e.exp() - 1.0).to_bits());
        }
    }

    #[test]
    fn family_parse_names_and_aliases() {
        assert_eq!(Family::parse("gaussian", 0), Ok(Family::Gaussian));
        assert_eq!(Family::parse("ols", 0), Ok(Family::Gaussian));
        assert_eq!(Family::parse("logistic", 0), Ok(Family::Binomial));
        assert_eq!(Family::parse("multinomial", 4), Ok(Family::Multinomial { classes: 4 }));
        assert!(Family::parse("multinomial", 1).is_err());
        assert!(Family::parse("tobit", 2).is_err());
    }

    #[test]
    fn null_deviance_binomial_balanced() {
        // Balanced 0/1 with η = 0: loss = n·log 2, deviance = 2n·log 2.
        let fam = Family::Binomial;
        let y = [0.0, 1.0, 0.0, 1.0];
        let expect = 2.0 * 4.0 * (2.0f64).ln();
        assert!((fam.null_deviance(&y) - expect).abs() < 1e-12);
    }

    #[test]
    fn deviance_gaussian_is_rss() {
        let fam = Family::Gaussian;
        // loss = ½‖r‖² → deviance = ‖r‖²
        assert_eq!(fam.deviance(3.0, &[1.0]), 6.0);
    }

    #[test]
    #[should_panic(expected = "binomial response")]
    fn binomial_rejects_bad_labels() {
        Problem::new(toy_design(), vec![0.0, 2.0, 1.0], Family::Binomial);
    }

    #[test]
    fn sigmoid_symmetry() {
        for x in [-30.0, -1.0, 0.0, 2.5, 40.0] {
            assert!((sigmoid(x) + sigmoid(-x) - 1.0).abs() < 1e-12);
        }
    }
}
